"""Pallas kernels vs the pure-jnp oracle — the CORE L1 correctness signal.
Hypothesis sweeps shapes, block sizes, datatypes, and program tilings."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dq, nf4, qlora_matmul, ref

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


@given(st.integers(1, 24), st.integers(0, 2**31 - 1),
       st.sampled_from(["nf4", "fp4_e2m1", "int4"]),
       st.sampled_from([1, 3, 8]))
def test_quantize_pallas_matches_ref(nb, seed, dtype, rows):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(nb * 64).astype(np.float32))
    cb = ref.codebook(dtype)
    c_ref, a_ref = ref.quantize_blockwise(x, cb, 64)
    c_pal, a_pal = nf4.quantize_blockwise_pallas(x, cb, 64,
                                                 rows_per_program=rows)
    assert np.array_equal(np.asarray(c_ref), np.asarray(c_pal))
    assert np.allclose(np.asarray(a_ref), np.asarray(a_pal))


@given(st.integers(1, 24), st.integers(0, 2**31 - 1))
def test_dequantize_pallas_matches_ref(nb, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(nb * 64).astype(np.float32))
    cb = ref.codebook("nf4")
    codes, absmax = ref.quantize_blockwise(x, cb, 64)
    d_ref = ref.dequantize_blockwise(codes, absmax, cb, 64)
    d_pal = nf4.dequantize_blockwise_pallas(codes, absmax, cb, 64)
    assert np.allclose(np.asarray(d_ref), np.asarray(d_pal))


@given(st.integers(1, 6), st.integers(0, 2**31 - 1))
def test_double_dequant_pallas_matches_ref(nb2, seed):
    rng = np.random.default_rng(seed)
    absmax = jnp.asarray(
        (np.abs(rng.standard_normal(nb2 * 256)) + 0.5).astype(np.float32))
    c2, a2, mean = ref.double_quantize(absmax, 256)
    r = ref.double_dequantize(c2, a2, mean, 256)
    p = dq.double_dequantize_pallas(c2, a2, mean, ref.fp8_e4m3_codebook(),
                                    256)
    assert np.allclose(np.asarray(r), np.asarray(p), atol=1e-6)


@given(st.sampled_from([(8, 64, 32, 4), (16, 128, 64, 8), (32, 192, 96, 16),
                        (5, 64, 48, 2)]),
       st.integers(0, 2**31 - 1))
def test_qlora_matmul_pallas_matches_eq5(shape, seed):
    m, k, o, r = shape
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray((rng.standard_normal((k, o)) * 0.05).astype(np.float32))
    a = jnp.asarray((rng.standard_normal((k, r)) * 0.05).astype(np.float32))
    b = jnp.asarray((rng.standard_normal((r, o)) * 0.05).astype(np.float32))
    q = ref.quantize_weight(w, "nf4", 64, double_quant=False)
    codes = ref.unpack_nibbles(q["packed"]).reshape(o, k)
    absmax = q["absmax"].reshape(o, k // 64)
    y_ref = ref.qlora_linear(x, q, a, b, 2.0, (k, o), "nf4", 64)
    y_pal = qlora_matmul.qlora_matmul_pallas(
        x, codes, absmax, ref.codebook("nf4"), a, b, 2.0, block=64)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-4, atol=1e-4)


def test_composition_equals_double_dequant_weight():
    """dq kernel ∘ dequant kernel == ref.double_dequant_weight (Eq. 6)."""
    rng = np.random.default_rng(11)
    flat = jnp.asarray(rng.standard_normal(64 * 512).astype(np.float32))
    cb = ref.codebook("nf4")
    codes, absmax = ref.quantize_blockwise(flat, cb, 64)
    c2, a2, mean = ref.double_quantize(absmax, 256)
    want = ref.double_dequant_weight(codes, c2, a2, mean, cb, 64, 256)
    nb = codes.shape[0] // 64
    am = dq.double_dequantize_pallas(c2, a2, mean, ref.fp8_e4m3_codebook(),
                                     256)[:nb]
    got = nf4.dequantize_blockwise_pallas(codes, am, cb, 64)
    assert np.allclose(np.asarray(want), np.asarray(got), atol=1e-6)


def test_kernels_lower_into_jit():
    """Kernels must be AOT-lowerable (inside jit) — the export path."""
    cb = ref.codebook("nf4")

    @jax.jit
    def f(x):
        c, a = nf4.quantize_blockwise_pallas(x, cb, 64)
        return nf4.dequantize_blockwise_pallas(c, a, cb, 64)

    x = jax.random.normal(jax.random.PRNGKey(0), (64 * 4,))
    y = f(x)
    assert y.shape == x.shape
