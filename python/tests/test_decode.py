"""KV-cached decode path tests: the prefill graph reproduces the plain
forward, incremental decode steps reproduce full-sequence greedy decoding
token for token, and the continuous-batching contract (pass-through rows,
per-row positions, idle rows parked at seq_len-1) holds. These are the
build-time guarantees the Rust engine's CachedDecode leans on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

CFG = configs.by_name("tiny_scope_all")
B, S, L, D = CFG.batch, CFG.seq_len, CFG.n_layers, CFG.d_model
PAD = 0


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    base = model.init_base_params(key, CFG)
    lora = model.init_lora_params(jax.random.PRNGKey(1), CFG)
    qbase = model.quantize_base(base, CFG)
    prefill = jax.jit(model.make_prefill(CFG, False))
    step = jax.jit(model.make_decode_step(CFG, False))
    fwd = jax.jit(model.make_forward(CFG, False))
    return qbase, lora, prefill, step, fwd


def zero_caches():
    z = jnp.zeros((B, L, S, D), jnp.float32)
    return z, z


def test_prefill_logits_match_forward(setup):
    qbase, lora, prefill, step, fwd = setup
    tok = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, CFG.vocab)
    k0, v0 = zero_caches()
    mask = jnp.ones((B,), jnp.float32)
    logits, _, _ = prefill(lora, qbase, k0, v0, tok, mask)
    expected = fwd(lora, qbase, tok)
    assert np.array_equal(np.asarray(logits), np.asarray(expected)), \
        "prefill logits must be bit-identical to the fwd graph"


def test_prefill_pass_through_rows(setup):
    qbase, lora, prefill, step, fwd = setup
    tok = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, CFG.vocab)
    k0 = jax.random.normal(jax.random.PRNGKey(4), (B, L, S, D))
    v0 = jax.random.normal(jax.random.PRNGKey(5), (B, L, S, D))
    mask = jnp.asarray([1.0, 0.0] * (B // 2) + [1.0] * (B % 2))
    _, k1, v1 = prefill(lora, qbase, k0, v0, tok, mask)
    for b in range(B):
        if mask[b] > 0.5:
            assert not np.allclose(np.asarray(k1[b]), np.asarray(k0[b]))
        else:
            assert np.array_equal(np.asarray(k1[b]), np.asarray(k0[b]))
            assert np.array_equal(np.asarray(v1[b]), np.asarray(v0[b]))


def greedy_full(fwd, qbase, lora, prompt, n_new):
    """Reference: full-sequence recompute per token (the fallback path)."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        buf = np.full((B, S), PAD, np.int32)
        buf[0, :len(toks)] = toks
        logits = fwd(lora, qbase, jnp.asarray(buf))
        nxt = int(jnp.argmax(logits[0, len(toks) - 1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def greedy_cached(prefill, step, qbase, lora, prompt, n_new):
    """The Rust CachedDecode protocol: one prefill, then O(1) steps."""
    k, v = zero_caches()
    buf = np.full((B, S), PAD, np.int32)
    buf[0, :len(prompt)] = prompt
    mask = np.zeros((B,), np.float32)
    mask[0] = 1.0
    logits, k, v = prefill(lora, qbase, k, v, jnp.asarray(buf),
                           jnp.asarray(mask))
    nxt = int(jnp.argmax(logits[0, len(prompt) - 1]))
    out = [nxt]
    pos = len(prompt)
    for _ in range(n_new - 1):
        token = np.zeros((B,), np.int32)
        posv = np.full((B,), S - 1, np.int32)   # idle rows park at S-1
        token[0], posv[0] = out[-1], pos
        logits, k, v = step(lora, qbase, k, v, jnp.asarray(token),
                            jnp.asarray(posv))
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        pos += 1
    return out


def test_cached_greedy_decode_matches_full(setup):
    qbase, lora, prefill, step, fwd = setup
    for seed, plen in [(7, 5), (8, 1), (9, 12)]:
        prompt = list(np.random.default_rng(seed).integers(
            1, CFG.vocab, plen))
        full = greedy_full(fwd, qbase, lora, prompt, 10)
        cached = greedy_cached(prefill, step, qbase, lora, prompt, 10)
        assert full == cached, f"prompt {prompt}: {full} != {cached}"


def test_mixed_positions_decode_rows_independently(setup):
    """Continuous batching: rows at different positions in one step call
    must each match their single-row decode."""
    qbase, lora, prefill, step, fwd = setup
    rng = np.random.default_rng(11)
    prompts = [list(rng.integers(1, CFG.vocab, n)) for n in (4, 9, 6)]

    # independent single-row references
    refs = [greedy_cached(prefill, step, qbase, lora, p, 6) for p in prompts]

    # joint: all three prompts prefilled at once, stepped in lockstep
    k, v = zero_caches()
    buf = np.full((B, S), PAD, np.int32)
    mask = np.zeros((B,), np.float32)
    for b, p in enumerate(prompts):
        buf[b, :len(p)] = p
        mask[b] = 1.0
    logits, k, v = prefill(lora, qbase, k, v, jnp.asarray(buf),
                           jnp.asarray(mask))
    outs = [[int(jnp.argmax(logits[b, len(p) - 1]))]
            for b, p in enumerate(prompts)]
    pos = [len(p) for p in prompts]
    for _ in range(5):
        token = np.zeros((B,), np.int32)
        posv = np.full((B,), S - 1, np.int32)
        for b in range(len(prompts)):
            token[b], posv[b] = outs[b][-1], pos[b]
            pos[b] += 1
        logits, k, v = step(lora, qbase, k, v, jnp.asarray(token),
                            jnp.asarray(posv))
        for b in range(len(prompts)):
            outs[b].append(int(jnp.argmax(logits[b])))
    assert outs == refs


def test_mid_flight_admission_is_isolated(setup):
    """The Rust scheduler's continuous-batching pattern: row 0 is three
    decode steps into its request when row 1's prompt is admitted (one
    prefill with row 0 passed through, row 0 idle-parked), after which
    both rows step together. Each row must match its solo decode."""
    qbase, lora, prefill, step, fwd = setup
    rng = np.random.default_rng(21)
    p0 = list(rng.integers(1, CFG.vocab, 6))
    p1 = list(rng.integers(1, CFG.vocab, 8))
    ref0 = greedy_cached(prefill, step, qbase, lora, p0, 7)
    ref1 = greedy_cached(prefill, step, qbase, lora, p1, 4)

    def prefill_row(b, prompt, k, v):
        buf = np.full((B, S), PAD, np.int32)
        buf[b, :len(prompt)] = prompt
        mask = np.zeros((B,), np.float32)
        mask[b] = 1.0
        return prefill(lora, qbase, k, v, jnp.asarray(buf),
                       jnp.asarray(mask))

    def step_rows(active, k, v):
        """active: {row: (token, pos)}; idle rows parked at S-1."""
        token = np.zeros((B,), np.int32)
        posv = np.full((B,), S - 1, np.int32)
        for b, (t, p) in active.items():
            token[b], posv[b] = t, p
        return step(lora, qbase, k, v, jnp.asarray(token),
                    jnp.asarray(posv))

    k, v = zero_caches()
    logits, k, v = prefill_row(0, p0, k, v)
    out0 = [int(jnp.argmax(logits[0, len(p0) - 1]))]
    pos0 = len(p0)
    for _ in range(3):                    # row 0 decodes alone
        logits, k, v = step_rows({0: (out0[-1], pos0)}, k, v)
        out0.append(int(jnp.argmax(logits[0])))
        pos0 += 1
    # admit row 1 mid-flight: prefill must pass row 0's cache through
    logits, k, v = prefill_row(1, p1, k, v)
    out1 = [int(jnp.argmax(logits[1, len(p1) - 1]))]
    pos1 = len(p1)
    for _ in range(3):                    # both rows step together
        logits, k, v = step_rows(
            {0: (out0[-1], pos0), 1: (out1[-1], pos1)}, k, v)
        out0.append(int(jnp.argmax(logits[0])))
        out1.append(int(jnp.argmax(logits[1])))
        pos0 += 1
        pos1 += 1
    assert out0 == ref0, "mid-flight admission perturbed the live row"
    assert out1 == ref1, "admitted row diverged from its solo decode"


def test_stale_cache_rows_never_observed(setup):
    """A freed row's leftover cache must not influence a new request in
    that row: decoding over a garbage-initialized cache equals decoding
    over a zero cache (prefill overwrites, masking hides the rest)."""
    qbase, lora, prefill, step, fwd = setup
    prompt = list(np.random.default_rng(13).integers(1, CFG.vocab, 7))

    def run(k, v):
        buf = np.full((B, S), PAD, np.int32)
        buf[0, :len(prompt)] = prompt
        mask = np.zeros((B,), np.float32)
        mask[0] = 1.0
        logits, k, v = prefill(lora, qbase, k, v, jnp.asarray(buf),
                               jnp.asarray(mask))
        out = [int(jnp.argmax(logits[0, len(prompt) - 1]))]
        pos = len(prompt)
        for _ in range(5):
            token = np.zeros((B,), np.int32)
            posv = np.full((B,), S - 1, np.int32)
            token[0], posv[0] = out[-1], pos
            logits, k, v = step(lora, qbase, k, v, jnp.asarray(token),
                                jnp.asarray(posv))
            out.append(int(jnp.argmax(logits[0])))
            pos += 1
        return out

    k0, v0 = zero_caches()
    kg = jax.random.normal(jax.random.PRNGKey(14), (B, L, S, D)) * 50.0
    vg = jax.random.normal(jax.random.PRNGKey(15), (B, L, S, D)) * 50.0
    assert run(k0, v0) == run(kg, vg)


def test_rope_at_matches_full_rope(setup):
    from compile.kernels import decode as dk
    x = jax.random.normal(jax.random.PRNGKey(16),
                          (2, 5, CFG.n_heads, CFG.head_dim))
    full = model.rope(x)
    for p in range(5):
        single = dk.rope_at(x[:, p], jnp.asarray([p, p], jnp.int32))
        assert np.allclose(np.asarray(single), np.asarray(full[:, p]),
                           atol=1e-6)
