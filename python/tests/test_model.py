"""L2 model tests: shapes, QLoRA wiring, gradient flow (adapters only),
train-step convergence, full-finetune path, eval metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

CFG = configs.by_name("tiny_scope_all")


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    base = model.init_base_params(key, CFG)
    lora = model.init_lora_params(key, CFG)
    qbase = model.quantize_base(base, CFG)
    return base, lora, qbase


def test_forward_shapes(setup):
    base, lora, qbase = setup
    tok = jnp.zeros((2, CFG.seq_len), jnp.int32)
    logits = model.forward(CFG, qbase, lora, tok)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert jnp.isfinite(logits).all()


def test_lora_b_zero_init_means_base_model(setup):
    """B=0 ⇒ adapted model == quantized base model exactly."""
    base, lora, qbase = setup
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, CFG.seq_len), 0,
                             CFG.vocab)
    with_lora = model.forward(CFG, qbase, lora, tok)
    no_lora = model.forward(
        CFG, qbase, {"layers": [{} for _ in range(CFG.n_layers)]}, tok)
    assert np.allclose(np.asarray(with_lora), np.asarray(no_lora))


def test_quantization_perturbs_but_preserves(setup):
    base, lora, qbase = setup
    tok = jax.random.randint(jax.random.PRNGKey(2), (2, CFG.seq_len), 0,
                             CFG.vocab)
    l16 = model.forward(CFG, base, lora, tok)
    l4 = model.forward(CFG, qbase, lora, tok)
    diff = float(jnp.abs(l16 - l4).mean())
    scale = float(jnp.abs(l16).mean())
    assert 0 < diff < 0.5 * scale


def test_gradients_only_flow_to_adapters(setup):
    """The paper's core mechanism: dE/dW never materializes — only LoRA
    parameters receive gradients."""
    base, lora, qbase = setup
    tok = jax.random.randint(jax.random.PRNGKey(3), (CFG.batch, CFG.seq_len),
                             0, CFG.vocab)
    mask = jnp.ones((CFG.batch, CFG.seq_len))
    grads = jax.grad(
        lambda lo: model.masked_ce_loss(CFG, qbase, lo, tok, mask))(lora)
    leaves = jax.tree_util.tree_leaves(grads)
    assert len(leaves) == len(jax.tree_util.tree_leaves(lora))
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


def test_train_step_overfits_single_batch(setup):
    base, lora, qbase = setup
    ts = jax.jit(model.make_train_step(CFG, False))
    zeros = jax.tree_util.tree_map(jnp.zeros_like, lora)
    tok = jax.random.randint(jax.random.PRNGKey(4), (CFG.batch, CFG.seq_len),
                             0, CFG.vocab)
    mask = jnp.ones((CFG.batch, CFG.seq_len))
    t, m, v, s, first = ts(lora, zeros, zeros, jnp.zeros(()), qbase, tok,
                           mask)
    for _ in range(60):
        t, m, v, s, loss = ts(t, m, v, s, qbase, tok, mask)
    assert float(loss) < float(first) - 0.3, (float(first), float(loss))
    assert float(s) == 61.0


def test_grad_clipping_bounds_update():
    """max_grad_norm=0.3 must bound the global grad norm used in Adam."""
    cfg = CFG
    key = jax.random.PRNGKey(5)
    base = model.init_base_params(key, cfg)
    qbase = model.quantize_base(base, cfg)
    lora = model.init_lora_params(key, cfg)
    tok = jax.random.randint(key, (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    mask = jnp.ones((cfg.batch, cfg.seq_len))
    grads = jax.grad(
        lambda lo: model.masked_ce_loss(cfg, qbase, lo, tok, mask))(lora)
    gnorm = model._global_norm(grads)
    clip = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-12))
    clipped = jax.tree_util.tree_map(lambda g: g * clip, grads)
    assert float(model._global_norm(clipped)) <= cfg.max_grad_norm + 1e-5


def test_mask_excludes_positions(setup):
    base, lora, qbase = setup
    tok = jax.random.randint(jax.random.PRNGKey(6), (CFG.batch, CFG.seq_len),
                             0, CFG.vocab)
    full = model.masked_ce_loss(CFG, qbase, lora, tok,
                                jnp.ones((CFG.batch, CFG.seq_len)))
    half_mask = jnp.concatenate([
        jnp.zeros((CFG.batch, CFG.seq_len // 2)),
        jnp.ones((CFG.batch, CFG.seq_len - CFG.seq_len // 2)),
    ], axis=1)
    half = model.masked_ce_loss(CFG, qbase, lora, tok, half_mask)
    assert not np.isclose(float(full), float(half))


def test_full_finetune_path():
    cfg = configs.by_name("tiny_fullft")
    key = jax.random.PRNGKey(7)
    base = model.init_base_params(key, cfg)
    lora = model.init_lora_params(key, cfg)  # stub
    ts = jax.jit(model.make_train_step(cfg, True))
    zeros = jax.tree_util.tree_map(jnp.zeros_like, base)
    tok = jax.random.randint(key, (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    mask = jnp.ones((cfg.batch, cfg.seq_len))
    t, m, v, s, l0 = ts(base, zeros, zeros, jnp.zeros(()),
                        {"lora_stub": lora}, tok, mask)
    for _ in range(15):
        t, m, v, s, loss = ts(t, m, v, s, {"lora_stub": lora}, tok, mask)
    assert float(loss) < float(l0)


def test_eval_step_accuracy_range(setup):
    base, lora, qbase = setup
    es = jax.jit(model.make_eval_step(CFG, False))
    tok = jax.random.randint(jax.random.PRNGKey(8), (CFG.batch, CFG.seq_len),
                             0, CFG.vocab)
    mask = jnp.ones((CFG.batch, CFG.seq_len))
    loss, acc = es(lora, qbase, tok, mask)
    assert 0.0 <= float(acc) <= 1.0
    assert float(loss) > 0


def test_rope_position_dependence():
    x = jnp.ones((1, 8, 2, 16))
    y = model.rope(x)
    # different positions must be rotated differently
    assert not np.allclose(np.asarray(y[0, 0]), np.asarray(y[0, 7]))
    # norm is preserved per pair
    assert np.allclose(float(jnp.linalg.norm(y[0, 3])),
                       float(jnp.linalg.norm(x[0, 3])), rtol=1e-5)


def test_scope_controls_adapter_placement():
    cfg = configs.by_name("tiny_scope_qk")
    lora = model.init_lora_params(jax.random.PRNGKey(9), cfg)
    assert set(lora["layers"][0].keys()) == {"wq", "wk"}
    cfg_all = configs.by_name("tiny_scope_all")
    lora_all = model.init_lora_params(jax.random.PRNGKey(9), cfg_all)
    assert set(lora_all["layers"][0].keys()) == set(configs.PROJ_NAMES)
