"""AOT path tests: tensorio round-trip, HLO text lowering, manifest
integrity of the built artifacts (runs against artifacts/ if present)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, tensorio

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_tensorio_roundtrip(tmp_path):
    p = str(tmp_path / "t.tensors")
    tensors = [
        ("a", np.arange(6, dtype=np.float32).reshape(2, 3)),
        ("b/c", np.array([1, 2, 255], dtype=np.uint8)),
        ("d", np.array(-7, dtype=np.int32)),
        ("scalar", np.float32(3.5)),
    ]
    tensorio.write_tensors(p, [(n, np.asarray(a)) for n, a in tensors])
    back = tensorio.read_tensors(p)
    assert [n for n, _ in back] == ["a", "b/c", "d", "scalar"]
    for (n1, a1), (n2, a2) in zip(tensors, back):
        assert np.array_equal(np.asarray(a1), a2.reshape(np.asarray(a1).shape))


def test_hlo_text_lowering_smoke():
    def f(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(f).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_flatten_named_is_deterministic():
    tree = {"b": jnp.zeros(2), "a": {"x": jnp.ones(3)}}
    n1 = [n for n, _ in aot.flatten_named(tree, "t")]
    n2 = [n for n, _ in aot.flatten_named(tree, "t")]
    assert n1 == n2
    assert all(n.startswith("t") for n in n1)
    # dict order is sorted-key order (the cross-boundary contract)
    assert n1[0].find("a") < n1[1].find("b") or "a" in n1[0]


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS,
                                                    "manifest.json")),
                    reason="artifacts not built")
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_all_configs_present(self, manifest):
        names = {a["name"] for a in manifest["artifacts"]}
        for want in ["tiny_scope_all", "tiny_fullft", "tiny_lora16",
                     "tiny_fp4", "tiny_int8", "e2e"]:
            assert want in names

    def test_signatures_match_init_files(self, manifest):
        for a in manifest["artifacts"][:4]:
            init = tensorio.read_tensors(os.path.join(ARTIFACTS, a["init"]))
            assert len(init) == a["n_state"] + a["n_frozen"]
            for (name, arr), sig in zip(
                    init, a["state_sig"] + a["frozen_sig"]):
                assert name == sig["name"]
                assert list(arr.shape) == sig["shape"]

    def test_hlo_files_exist_and_parse(self, manifest):
        for a in manifest["artifacts"]:
            for key in ["train_hlo", "eval_hlo"]:
                path = os.path.join(ARTIFACTS, a[key])
                assert os.path.exists(path), path
                with open(path) as f:
                    head = f.read(200)
                assert "HloModule" in head

    def test_no_elided_constants(self, manifest):
        """The default HLO printer elides large constants as
        'constant({...})'; the 0.5.1 text parser silently zero-fills them,
        destroying in-graph codebooks / masks. Regression guard."""
        import glob
        for path in glob.glob(os.path.join(ARTIFACTS, "*.hlo.txt")):
            with open(path) as f:
                assert "{...}" not in f.read(), f"elided constants in {path}"

    def test_golden_cases_complete(self, manifest):
        g = tensorio.read_tensors(os.path.join(ARTIFACTS, "golden.tensors"))
        names = {n for n, _ in g}
        for case in manifest["golden"]["cases"]:
            base = case["name"]
            assert f"{base}/input" in names or base == "dq"

    def test_state_ordering_contract(self, manifest):
        """trainable leaves come first, then adam_m, adam_v, step."""
        a = next(x for x in manifest["artifacts"]
                 if x["name"] == "tiny_scope_all")
        names = [s["name"] for s in a["state_sig"]]
        nt = a["n_trainable"]
        assert all(n.startswith("trainable") for n in names[:nt])
        assert names[-1] == "step"
