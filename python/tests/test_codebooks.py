"""Codebook construction tests: NF4 vs paper Appendix E, FP4 variants,
zero representability (the paper's padding requirement)."""

import numpy as np
import pytest

from compile.kernels import ref


def test_nf4_derivation_matches_paper_appendix_e():
    cb = np.asarray(ref.nf4_codebook())
    paper = np.asarray(ref.NF4_PAPER, dtype=np.float32)
    assert np.abs(cb - paper).max() < 3e-6


def test_canonical_nf4_is_paper_constants():
    cb = np.asarray(ref.codebook("nf4"))
    assert np.array_equal(cb, np.asarray(ref.NF4_PAPER, dtype=np.float32))


@pytest.mark.parametrize("name,size", [
    ("nf4", 16), ("fp4_e2m1", 15), ("fp4_e3m0", 15), ("int4", 15),
    ("int8", 255), ("fp8_e4m3", 255),
])
def test_codebook_sizes_sorted_zero(name, size):
    cb = np.asarray(ref.codebook(name))
    assert len(cb) == size
    assert (np.diff(cb) > 0).all(), "strictly sorted"
    assert (cb == 0.0).any(), "exact zero required (paper section 3)"
    assert cb[0] == -1.0 and cb[-1] == 1.0


def test_fp4_e2m1_values():
    cb = np.asarray(ref.fp4_e2m1_codebook())
    pos = cb[cb >= 0]
    expect = np.array([0, 0.5, 1, 1.5, 2, 3, 4, 6], dtype=np.float32) / 6
    assert np.allclose(pos, expect, atol=1e-7)


def test_fp4_e3m0_log_spaced():
    cb = np.asarray(ref.fp4_e3m0_codebook())
    pos = cb[cb > 0]
    ratios = pos[1:] / pos[:-1]
    assert np.allclose(ratios, 2.0), "E3M0 magnitudes are powers of two"


def test_nearest_code_ties_and_extremes():
    cb = ref.codebook("nf4")
    codes = ref.nearest_code(np.asarray([-2.0, 2.0, 0.0], dtype=np.float32),
                             cb)
    assert codes[0] == 0
    assert codes[1] == 15
    assert np.asarray(cb)[codes[2]] == 0.0
