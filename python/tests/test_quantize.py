"""Block-wise quantization + double quantization vs invariants, with
hypothesis sweeps over shapes/blocks/scales."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@st.composite
def blocked_array(draw, block=64, max_blocks=8):
    nb = draw(st.integers(1, max_blocks))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = 10.0 ** draw(st.integers(-3, 2))
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(nb * block) * scale).astype(np.float32)


@given(blocked_array())
def test_roundtrip_error_bounded(x):
    cb = ref.codebook("nf4")
    codes, absmax = ref.quantize_blockwise(jnp.asarray(x), cb, 64)
    y = np.asarray(ref.dequantize_blockwise(codes, absmax, cb, 64))
    gaps = np.diff(np.asarray(cb))
    max_gap = gaps.max()
    scale = np.repeat(np.asarray(absmax), 64)
    assert (np.abs(x - y) <= 0.5 * max_gap * scale + 1e-6).all()


@given(blocked_array(block=32, max_blocks=6))
def test_quantize_idempotent(x):
    cb = ref.codebook("fp4_e2m1")
    c1, a1 = ref.quantize_blockwise(jnp.asarray(x), cb, 32)
    y = ref.dequantize_blockwise(c1, a1, cb, 32)
    c2, a2 = ref.quantize_blockwise(y, cb, 32)
    z = np.asarray(ref.dequantize_blockwise(c2, a2, cb, 32))
    assert np.allclose(np.asarray(y), z, rtol=1e-6, atol=1e-6)


def test_zero_block_exact():
    cb = ref.codebook("nf4")
    x = jnp.zeros(128)
    codes, absmax = ref.quantize_blockwise(x, cb, 64)
    y = ref.dequantize_blockwise(codes, absmax, cb, 64)
    assert (np.asarray(y) == 0).all()


def test_pack_unpack_bijection():
    rng = np.random.default_rng(3)
    codes = rng.integers(0, 16, size=256).astype(np.uint8)
    packed = ref.pack_nibbles(jnp.asarray(codes))
    assert packed.shape[0] == 128
    back = np.asarray(ref.unpack_nibbles(packed))
    assert np.array_equal(back, codes)


@given(st.integers(1, 2000), st.integers(0, 2**31 - 1))
def test_double_quant_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    absmax = (np.abs(rng.standard_normal(n)) * 0.3 + 2.0).astype(np.float32)
    c2, a2, mean = ref.double_quantize(jnp.asarray(absmax), 256)
    back = np.asarray(ref.double_dequantize(c2, a2, mean, 256, n=n))
    assert back.shape == (n,)
    centered_max = np.abs(absmax - float(mean)).max()
    assert np.abs(absmax - back).max() <= centered_max * 0.07 + 1e-5


def test_double_quant_memory_accounting():
    # 0.5 -> 0.127 bits/param (paper section 3)
    n_params = 64 * 256 * 4
    n_blocks = n_params // 64
    absmax = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (n_blocks,)))
    c2, a2, mean = ref.double_quantize(absmax, 256)
    bits = (c2.nbytes + a2.nbytes + 4) * 8 / n_params
    assert abs(bits - 0.127) < 0.01


@pytest.mark.parametrize("dtype", ["nf4", "fp4_e2m1", "int4", "int8"])
def test_weight_container_roundtrip(dtype):
    k = jax.random.PRNGKey(1)
    w = jax.random.normal(k, (128, 64)) * 0.05
    q = ref.quantize_weight(w, dtype, double_quant=True)
    back = ref.dequantize_weight(q, (128, 64), dtype)
    assert back.shape == (128, 64)
    mse = float(jnp.mean((w - back) ** 2))
    assert mse < float(jnp.mean(w * w)) * 0.1


def test_nf4_beats_int4_and_fp4_on_normal():
    x = jax.random.normal(jax.random.PRNGKey(2), (64 * 256,))
    mses = {d: float(ref.quant_error(x, d)[0])
            for d in ["nf4", "fp4_e2m1", "int4"]}
    assert mses["nf4"] < mses["fp4_e2m1"] < mses["int4"]


def test_dq_does_not_degrade():
    x = jax.random.normal(jax.random.PRNGKey(3), (64 * 1024,))
    plain = float(ref.quant_error(x, "nf4")[0])
    dq = float(ref.quant_error(x, "nf4", double_quant=True)[0])
    assert dq < plain * 1.02
