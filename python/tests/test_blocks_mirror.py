"""Python mirror of the Rust KV block manager + block-granular admission
(`rust/src/paged/blocks.rs`, `rust/src/engine/scheduler.rs`).

The build container has no Rust toolchain (see
`.claude/skills/verify/SKILL.md`), so this line-for-line port is the
*runnable* verification of the algorithm: the same invariants the Rust
property tests (`rust/tests/prop_blocks.rs`) assert are re-derived here
against an independent implementation.

Invariants mirrored:
  1. refcounts never leak: after every row detaches, allocated == freed;
  2. CoW never mutates a shared block: each row's concatenated block
     contents equal its own externally-tracked history at every step;
  3. blocks in use never exceed the pool at any step of a serve loop;
  4. a shared-prefix workload admits strictly more rows than the dense
     worst-case `prompt + max_new` reservation at the same token budget;
  5. results are bit-identical with prefix sharing on and off;
  6. both admission code paths age queued jobs identically (the
     double-bookkeeping fix in `Scheduler::admit`).
"""

import random

import pytest

# ---------------------------------------------------------------------------
# BlockPool mirror (paged/pool.rs)


class BlockPool:
    def __init__(self, n):
        self.n = n
        self.refcounts = [0] * n
        # descending stack so pop() hands out ascending ids, as in Rust
        self.free = list(range(n - 1, -1, -1))
        self.allocated = 0
        self.freed = 0

    def free_blocks(self):
        return len(self.free)

    def in_use(self):
        return self.n - len(self.free)

    def alloc(self):
        if not self.free:
            return None
        bid = self.free.pop()
        self.refcounts[bid] = 1
        self.allocated += 1
        return bid

    def retain(self, bid):
        assert self.refcounts[bid] > 0, f"retain of free block {bid}"
        self.refcounts[bid] += 1

    def release(self, bid):
        assert self.refcounts[bid] > 0, f"release of free block {bid}"
        self.refcounts[bid] -= 1
        if self.refcounts[bid] == 0:
            self.free.append(bid)
            self.freed += 1
            return True
        return False


# ---------------------------------------------------------------------------
# BlockManager mirror (paged/blocks.rs)


def blocks_for(tokens, block_tokens):
    return -(-tokens // block_tokens)  # div_ceil


class BlockManager:
    def __init__(self, block_tokens, n_blocks, sharing=True, headroom=1):
        assert block_tokens >= 1 and n_blocks >= 1
        self.bt = block_tokens
        self.sharing = sharing
        self.headroom = headroom
        self.pool = BlockPool(n_blocks)
        # per-slot content: (tokens list, parent id, registered flag)
        self.blocks = [None] * n_blocks
        self.share = {}  # (parent, tuple(tokens)) -> block id
        self.rows = {}  # row -> [block ids]
        self.row_len = {}  # row -> tokens covered
        self.shared_hits = 0
        self.cow_forks = 0
        self.swap_outs = 0

    def n_blocks(self):
        return self.pool.n

    def free_blocks(self):
        return self.pool.free_blocks()

    def blocks_in_use(self):
        return self.pool.in_use()

    def _chunks(self, history):
        return [history[i:i + self.bt] for i in range(0, len(history), self.bt)]

    def _key(self, bid):
        tokens, parent, _ = self.blocks[bid]
        return (parent, tuple(tokens))

    def _try_register(self, bid):
        if not self.sharing:
            return
        key = self._key(bid)
        if key not in self.share:
            self.share[key] = bid
            tokens, parent, _ = self.blocks[bid]
            self.blocks[bid] = (tokens, parent, True)

    def _unregister(self, bid):
        tokens, parent, registered = self.blocks[bid]
        if registered:
            assert self.share.pop((parent, tuple(tokens))) == bid
            self.blocks[bid] = (tokens, parent, False)

    def _shared_chain(self, history):
        chain = []
        if not self.sharing:
            return chain
        parent = None
        for chunk in self._chunks(history):
            bid = self.share.get((parent, tuple(chunk)))
            if bid is None:
                break
            chain.append(bid)
            parent = bid
        return chain

    def probe_attach(self, history):
        return len(self._chunks(history)) - len(self._shared_chain(history))

    def attach(self, row, history):
        assert row not in self.rows and history
        shared = self._shared_chain(history)
        chunks = self._chunks(history)
        fresh = len(chunks) - len(shared)
        if fresh > self.pool.free_blocks():
            raise MemoryError("pool exhausted")
        for bid in shared:
            self.pool.retain(bid)
            self.shared_hits += 1
        table = list(shared)
        parent = table[-1] if table else None
        for chunk in chunks[len(shared):]:
            bid = self.pool.alloc()
            self.blocks[bid] = (list(chunk), parent, False)
            self._try_register(bid)
            table.append(bid)
            parent = bid
        self.rows[row] = table
        self.row_len[row] = len(history)
        return len(shared)

    def append(self, row, token):
        assert row in self.rows, f"append to unattached row {row}"
        table = self.rows[row]
        pos = self.row_len[row] % self.bt
        if pos == 0:
            bid = self.pool.alloc()
            if bid is None:
                return "need_block"
            parent = table[-1] if table else None
            self.blocks[bid] = ([token], parent, False)
            table.append(bid)
            self.row_len[row] += 1
            return "appended"
        tail = table[-1]
        if self.pool.refcounts[tail] > 1:
            bid = self.pool.alloc()
            if bid is None:
                return "need_block"
            tokens, parent, _ = self.blocks[tail]
            self.blocks[bid] = (list(tokens) + [token], parent, False)
            self.pool.release(tail)
            self.cow_forks += 1
            table[-1] = bid
            self.row_len[row] += 1
            return "appended"
        self._unregister(tail)
        self.blocks[tail][0].append(token)
        self.row_len[row] += 1
        return "appended"

    def release_row(self, row):
        table = self.rows.pop(row)
        del self.row_len[row]
        freed = 0
        for bid in reversed(table):  # children before parents
            if self.pool.release(bid):
                self._unregister(bid)
                self.blocks[bid] = None
                freed += 1
        return freed

    def swap_out(self, row):
        freed = self.release_row(row)
        self.swap_outs += 1
        return freed

    def row_tokens(self, row):
        if row not in self.rows:
            return None
        out = []
        for bid in self.rows[row]:
            out.extend(self.blocks[bid][0])
        return out

    def check_invariants(self):
        refs = {}
        for row, table in self.rows.items():
            assert len(table) == blocks_for(self.row_len[row], self.bt)
            covered = 0
            for i, bid in enumerate(table):
                refs[bid] = refs.get(bid, 0) + 1
                got = len(self.blocks[bid][0])
                if i + 1 < len(table):
                    assert got == self.bt, "interior blocks are full"
                covered += got
            assert covered == self.row_len[row], "blocks cover the history"
        for bid, n in refs.items():
            assert self.pool.refcounts[bid] == n, f"refcount of block {bid}"
        assert len(refs) == self.pool.in_use(), "live blocks all referenced"
        for (parent, tokens), bid in self.share.items():
            btokens, bparent, registered = self.blocks[bid]
            assert registered and self.pool.refcounts[bid] > 0
            assert parent == bparent and tokens == tuple(btokens)


# ---------------------------------------------------------------------------
# Scheduler mirror (engine/scheduler.rs) — the admission/push/retire core;
# no deadlines or cancellation (those paths predate this PR and are
# covered by the Rust unit tests that do run elsewhere)

AGING_ROUNDS = 32
RANKS = {"low": 0, "normal": 1, "high": 2}


class Scheduler:
    def __init__(self, capacity, token_budget=None, block_cfg=None):
        assert (token_budget is None) != (block_cfg is None)
        self.rows = [None] * max(capacity, 1)  # each: dict or None
        self.queue = []  # dicts: id, prompt, out
        self.meta = []  # dicts: priority, max_new, waited
        self.results = []  # None until terminal (outcome, tokens)
        self.budget = token_budget
        self.mgr = BlockManager(**block_cfg) if block_cfg else None
        self.swapped = []

    def submit(self, prompt, max_new, priority="normal"):
        jid = len(self.results)
        self.results.append(None)
        self.meta.append(
            {"priority": priority, "max_new": max_new, "waited": 0})
        self.queue.append({"id": jid, "prompt": list(prompt), "out": []})
        return jid

    def rank(self, jid):
        m = self.meta[jid]
        return min(RANKS[m["priority"]] + m["waited"] // AGING_ROUNDS,
                   RANKS["high"])

    def reserved_tokens(self):
        return sum(len(a["prompt"]) + self.meta[a["id"]]["max_new"]
                   for a in self.rows if a)

    def _pick_victim(self, below=None):
        best = None
        for r, a in enumerate(self.rows):
            if a is None:
                continue
            rank = self.rank(a["id"])
            if below is not None and rank >= below:
                continue
            # min by (rank, Reverse(id)): lowest rank, then largest id
            key = (rank, -a["id"])
            if best is None or key < best[0]:
                best = (key, r)
        return None if best is None else best[1]

    def _swap_out_row(self, row):
        a = self.rows[row]
        self.rows[row] = None
        self.mgr.swap_out(row)
        self.swapped.append((row, a["id"]))
        self.queue.append(
            {"id": a["id"], "prompt": a["prompt"], "out": a["out"]})

    def admit(self):
        placed = []
        free = [r for r, a in enumerate(self.rows) if a is None]
        if self.queue and free:
            self.queue.sort(key=lambda q: (-self.rank(q["id"]), q["id"]))
            if self.budget is not None:
                reserved = self.reserved_tokens()
                while self.queue and free:
                    q = self.queue[0]
                    need = len(q["prompt"]) + self.meta[q["id"]]["max_new"]
                    if reserved != 0 and reserved + need > self.budget:
                        break
                    row = free.pop(0)
                    self.queue.pop(0)
                    reserved += need
                    self.rows[row] = q
                    placed.append((row, q["id"], q["prompt"] + q["out"]))
            else:
                mgr = self.mgr
                while self.queue and free:
                    q = self.queue[0]
                    history = q["prompt"] + q["out"]
                    if blocks_for(len(history), mgr.bt) > mgr.n_blocks():
                        self.queue.pop(0)
                        self.results[q["id"]] = ("aborted", q["out"])
                        continue
                    need = mgr.probe_attach(history)
                    idle = not placed and all(a is None for a in self.rows)
                    headroom = 0 if idle else mgr.headroom
                    if need + headroom <= mgr.free_blocks():
                        row = free.pop(0)
                        self.queue.pop(0)
                        mgr.attach(row, history)
                        self.rows[row] = q
                        placed.append((row, q["id"], history))
                        continue
                    victim = self._pick_victim(below=self.rank(q["id"]))
                    if victim is None:
                        break
                    self._swap_out_row(victim)
                    free.append(victim)
        # the single post-round aging pass (the bug fix under test #6)
        for q in self.queue:
            self.meta[q["id"]]["waited"] += 1
        return placed

    def push(self, row, token):
        a = self.rows[row]
        assert a is not None, f"push into free row {row}"
        if self.mgr is not None:
            while True:
                outcome = self.mgr.append(row, token)
                if outcome == "appended":
                    break
                victim = self._pick_victim()
                assert victim is not None, "row itself is resident"
                self._swap_out_row(victim)
                if victim == row:
                    return False
        a["out"].append(token)
        return True

    def retire(self, row):
        a = self.rows[row]
        self.rows[row] = None
        if self.mgr is not None:
            self.mgr.release_row(row)
        self.results[a["id"]] = ("done", a["out"])

    def budget_exhausted(self, row, seq_len):
        a = self.rows[row]
        return (len(a["out"]) >= self.meta[a["id"]]["max_new"]
                or len(a["prompt"]) + len(a["out"]) >= seq_len)

    def finished(self):
        return not self.queue and all(a is None for a in self.rows)


# ---------------------------------------------------------------------------
# 1 + 2: manager lifecycle — no leaks, CoW isolation


@pytest.mark.parametrize("seed", range(40))
def test_refcounts_never_leak_and_cow_never_mutates_shared(seed):
    rng = random.Random(0x5EED0000 + seed)
    bt = rng.randint(1, 4)
    n_rows = rng.randint(1, 6)
    m = BlockManager(bt, rng.randint(4, 31),
                     sharing=rng.random() < 0.75)
    expected = [None] * n_rows
    prefixes = [[rng.randrange(5) for _ in range(bt * rng.randint(1, 3))]
                for _ in range(3)]
    for _ in range(300):
        row = rng.randrange(n_rows)
        if expected[row] is None:
            hist = list(rng.choice(prefixes))
            hist += [rng.randrange(5)
                     for _ in range(rng.randrange(2 * bt))]
            if m.probe_attach(hist) > m.free_blocks():
                with pytest.raises(MemoryError):
                    m.attach(row, hist)
            else:
                shared = m.attach(row, hist)
                assert shared + m.probe_attach(hist) >= shared  # sanity
                expected[row] = hist
        else:
            op = rng.randrange(10)
            if op == 0:
                m.release_row(row)
                expected[row] = None
            elif op == 1:
                m.swap_out(row)
                expected[row] = None
            else:
                tok = rng.randrange(5)
                if m.append(row, tok) == "appended":
                    expected[row].append(tok)
                else:
                    assert m.free_blocks() == 0
        m.check_invariants()
        for r in range(n_rows):
            assert m.row_tokens(r) == expected[r], (
                f"row {r} content diverged (seed {seed})")
    for row in range(n_rows):
        if expected[row] is not None:
            m.release_row(row)
    assert m.blocks_in_use() == 0, "all blocks returned"
    assert not m.share, "share map drained with the pool"
    assert m.pool.allocated == m.pool.freed, "every allocation freed"


# ---------------------------------------------------------------------------
# 3 + 5: serve-loop mirror — pool bound at every step, sharing on/off
# bit-identity, one outcome per job


def run_serve(jobs, capacity, seq_len, block_cfg):
    s = Scheduler(capacity, block_cfg=block_cfg)
    for prompt, max_new in jobs:
        s.submit(prompt, max_new)
    steps = 0
    while not s.finished():
        steps += 1
        assert steps < 10_000, "livelock"
        s.admit()
        s.swapped.clear()
        assert s.mgr.blocks_in_use() <= s.mgr.n_blocks()
        s.mgr.check_invariants()
        for row in range(len(s.rows)):
            if s.rows[row] and s.budget_exhausted(row, seq_len):
                s.retire(row)
        for row in range(len(s.rows)):
            a = s.rows[row]
            if a is None:
                continue  # swapped out by an earlier push this step
            s.push(row, 1000 * (a["id"] + 1) + len(a["out"]))
        s.swapped.clear()
    return s.results, s.mgr


@pytest.mark.parametrize("seed", range(40))
def test_blocks_mode_serving_preserves_lifecycles(seed):
    rng = random.Random(0xB10C + seed)
    bt = rng.randint(1, 4)
    seq_len = rng.randint(8, 31)
    capacity = rng.randint(1, 4)
    per_row = blocks_for(seq_len, bt)
    cfg = dict(block_tokens=bt, n_blocks=per_row + rng.randrange(16))
    shared = list(range(rng.randint(1, seq_len // 2)))
    jobs = []
    for _ in range(rng.randint(1, 10)):
        prompt = list(shared) if rng.random() < 0.5 else [rng.randrange(100)]
        while len(prompt) < seq_len and rng.random() < 0.67:
            prompt.append(rng.randrange(100))
        jobs.append((prompt, rng.randint(0, seq_len - len(prompt))))
    results, _ = run_serve(jobs, capacity, seq_len, cfg)
    assert all(r is not None for r in results), "one outcome per job"
    for jid, (outcome, tokens) in enumerate(results):
        assert outcome == "done"
        want = [1000 * (jid + 1) + i for i in range(jobs[jid][1])]
        assert tokens == want, f"job {jid} tokens survived swaps"


def test_results_identical_with_sharing_on_and_off():
    jobs = [([3] * 8 + [50 + i], 6) for i in range(4)]
    on, mgr_on = run_serve(
        jobs, 4, 24, dict(block_tokens=4, n_blocks=12, sharing=True))
    off, mgr_off = run_serve(
        jobs, 4, 24, dict(block_tokens=4, n_blocks=12, sharing=False))
    assert on == off, "outputs must not depend on prefix sharing"
    assert mgr_on.shared_hits > 0 and mgr_off.shared_hits == 0


# ---------------------------------------------------------------------------
# 4: the admission-capacity acceptance criterion


def test_shared_prefix_admits_more_rows_than_dense_reservation():
    prompts = [[7] * 24 + [100 + i] for i in range(6)]
    dense = Scheduler(8, token_budget=64)
    for p in prompts:
        dense.submit(p, 4)
    dense_admitted = len(dense.admit())
    assert dense_admitted == 2, "worst-case reservation admits 2 of 6"

    blocks = Scheduler(8, block_cfg=dict(
        block_tokens=8, n_blocks=blocks_for(64, 8)))
    for p in prompts:
        blocks.submit(p, 4)
    blocks_admitted = len(blocks.admit())
    assert blocks_admitted > dense_admitted
    assert blocks_admitted == 4, "3 shared prefix blocks + 1 private each"
    assert blocks.mgr.shared_hits == 9, "3 followers x 3 shared blocks"


# ---------------------------------------------------------------------------
# 6: both admission paths age queued jobs identically (the bug fix: the
# two old aging loops could double-count or skip depending on the exit
# path; the single post-round pass cannot)


def test_both_admission_paths_age_queued_jobs_identically():
    # path A: a free row exists, but admission stops mid-round
    a = Scheduler(1, token_budget=10**9)
    for p in range(3):
        a.submit([p], 4)
    a.admit()  # places job 0; jobs 1, 2 remain queued
    # path B: no free row at all when the round starts
    b = Scheduler(1, token_budget=10**9)
    b.submit([0], 4)
    b.admit()
    for p in range(1, 3):
        b.submit([p], 4)
    b.admit()  # nothing placeable
    for jid in (1, 2):
        assert a.meta[jid]["waited"] == 1, f"path A aged job {jid} once"
        assert a.meta[jid]["waited"] == b.meta[jid]["waited"]
    # and the same invariant through the blocks path under pressure
    c = Scheduler(1, block_cfg=dict(block_tokens=2, n_blocks=4))
    for p in range(3):
        c.submit([p, p, p], 2)
    c.admit()
    assert [c.meta[j]["waited"] for j in range(3)] == [0, 1, 1]


def test_aging_promotes_a_starved_low_priority_job():
    s = Scheduler(1, block_cfg=dict(block_tokens=2, n_blocks=8))
    low = s.submit([9], 2, priority="low")
    admitted_low = False
    for round_ in range(2 * AGING_ROUNDS + 2):
        s.submit([round_ % 50], 2, priority="high")
        for row, jid, _ in s.admit():
            if jid == low:
                admitted_low = True
            s.retire(row)
        if admitted_low:
            break
    assert admitted_low, "aging must eventually admit the low job"
