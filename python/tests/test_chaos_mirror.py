"""Python mirror of the Rust chaos property suite
(`rust/tests/prop_chaos.rs`) — the robustness plane of the serving
stack: deterministic fault injection (`rust/src/util/faults.rs`),
request deadlines and cancellation, the decode-step watchdog, and the
shutdown drain, all layered over the KV block manager.

The build container has no Rust toolchain (see
`.claude/skills/verify/SKILL.md`), so this line-for-line port is the
*runnable* verification: the same four invariants the Rust suite
asserts are re-derived here, seed-for-seed across >= 300 random fault
schedules, against an independent implementation.

Invariants mirrored (numbering matches `prop_chaos.rs`):
  1. every submitted request reaches exactly one terminal outcome —
     no silent drops, no double completions;
  2. the serve loop never deadlocks or livelocks (hard step bound;
     fault caps guarantee injected pressure dries up);
  3. the block-pool structural invariants hold after every step — no
     leaked, double-freed, or miscounted KV block;
  4. the drain completes: once arrivals stop, the scheduler reaches
     `finished()` with a result for everything admitted.

The block manager and admission core are the ones already mirrored in
`test_blocks_mirror.py`; this file adds the chaos machinery on top
(fault lanes, expiry sweep, watchdog) exactly as the Rust scheduler
grew it.
"""

import random

import pytest
from test_blocks_mirror import BlockManager, Scheduler, blocks_for

# ---------------------------------------------------------------------------
# Fault plane mirror (util/faults.rs) — one seeded lane per site; a lane
# draws independently of every other RNG in the system, fires with
# probability p, and stops for good once its cap is spent


class FaultLane:
    def __init__(self, seed, p, cap=None):
        self.rng = random.Random(seed)
        self.p = p
        self.cap = cap
        self.fired = 0

    def fire(self):
        if self.cap is not None and self.fired >= self.cap:
            return False
        if self.rng.random() >= self.p:
            return False
        self.fired += 1
        return True


class FaultyBlockManager(BlockManager):
    """`BlockManager` with the `block-alloc` fault site: an armed lane
    can turn any allocating append into `need_block`, indistinguishable
    from genuine pool exhaustion (which is the point — the caller's
    swap-out path must absorb both identically)."""

    def __init__(self, *args, alloc_faults=None, **kwargs):
        super().__init__(*args, **kwargs)
        self.alloc_faults = alloc_faults

    def append(self, row, token):
        # the lane is consulted only where the Rust code would reach an
        # alloc site: a fresh block boundary or a CoW fork of a shared
        # tail
        pos = self.row_len[row] % self.bt
        allocating = pos == 0 or self.pool.refcounts[self.rows[row][-1]] > 1
        if allocating and self.alloc_faults and self.alloc_faults.fire():
            return "need_block"
        return super().append(row, token)


# ---------------------------------------------------------------------------
# Scheduler mirror extension (engine/scheduler.rs) — deadlines,
# cancellation, the decode-step watchdog, and typed early outcomes, on
# top of the blocks-mode admission core from test_blocks_mirror


class ChaosScheduler(Scheduler):
    def __init__(self, capacity, block_cfg, watchdog=None):
        super().__init__(capacity, block_cfg=dict(block_cfg))
        self.watchdog = watchdog  # ms of no progress before TimedOut
        self.timed_out_jobs = 0

    # replace the manager with the fault-site-aware one, same config
    def arm_faults(self, alloc_faults):
        assert self.mgr.blocks_in_use() == 0, "arm before serving"
        self.mgr = FaultyBlockManager(
            self.mgr.bt, self.mgr.n_blocks(), sharing=self.mgr.sharing,
            headroom=self.mgr.headroom, alloc_faults=alloc_faults)

    def submit(self, prompt, max_new, now, priority="normal",
               deadline_ms=None):
        jid = super().submit(prompt, max_new, priority=priority)
        self.meta[jid].update(
            deadline=None if deadline_ms is None else now + deadline_ms,
            cancelled=False, last_progress=now)
        return jid

    def cancel(self, jid):
        self.meta[jid]["cancelled"] = True

    def _expiry(self, jid, now):
        """Mirror of `Scheduler::queued_expiry`: shared by the queued
        sweep and the in-flight poll so the two can never diverge."""
        m = self.meta[jid]
        if m["cancelled"]:
            return "cancelled"
        if m["deadline"] is not None and now >= m["deadline"]:
            return "deadline_exceeded"
        return None

    def _sweep_queue(self, now):
        kept = []
        for q in self.queue:
            outcome = self._expiry(q["id"], now)
            if outcome is None:
                kept.append(q)
            else:
                # a swapped-out job keeps the tokens it generated
                self.results[q["id"]] = (outcome, q["out"])
        self.queue = kept

    def poll(self, now):
        self._sweep_queue(now)
        for row, a in enumerate(self.rows):
            if a is None:
                continue
            outcome = self._expiry(a["id"], now)
            if outcome is None and self.watchdog is not None:
                # the resident-only watchdog: no recorded token for the
                # whole window retires the row rather than stalling the
                # batch behind a hung step
                if now - self.meta[a["id"]]["last_progress"] >= self.watchdog:
                    outcome = "timed_out"
            if outcome is None:
                continue
            self.rows[row] = None
            self.mgr.release_row(row)
            if outcome == "timed_out":
                self.timed_out_jobs += 1
            self.results[a["id"]] = (outcome, a["out"])

    def admit(self, now):
        self._sweep_queue(now)
        placed = super().admit()
        # admission is forward progress: a job that queued for longer
        # than the watchdog window must not be retired on arrival
        for _, jid, _ in placed:
            self.meta[jid]["last_progress"] = now
        return placed

    def push(self, row, token, now):
        recorded = super().push(row, token)
        if recorded:
            self.meta[self.rows[row]["id"]]["last_progress"] = now
        return recorded


# ---------------------------------------------------------------------------
# The chaos schedule driver — `run_chaos_case` in prop_chaos.rs,
# seed-for-seed


def run_chaos_case(seed):
    rng = random.Random(0xC4A05_0000 + seed)
    capacity = rng.randint(1, 4)
    seq_len = rng.randint(8, 23)
    bt = rng.randint(2, 5)
    per_row = blocks_for(seq_len, bt)
    # roomy enough that nothing aborts for sheer size — pressure comes
    # from co-residents and the injected allocation failures
    n_blocks = per_row * (capacity + 1)
    n_jobs = rng.randint(1, 10)

    # every schedule arms block-alloc (capped so it dries up); the lane
    # seed is drawn from the case RNG, so schedules differ in *where*
    # faults land, not just in how the jobs look
    lane = FaultLane(rng.randrange(2 ** 32), 0.6 * rng.random(),
                     cap=rng.randrange(24))
    watchdog = rng.randrange(2) == 0
    sched = ChaosScheduler(
        capacity, dict(block_tokens=bt, n_blocks=n_blocks),
        watchdog=rng.randint(30, 79) if watchdog else None)
    sched.arm_faults(lane)

    # arrivals trickle in until the shutdown drain closes the stream;
    # requests scheduled to arrive later are never submitted (the HTTP
    # layer sheds those with a draining 503 before they reach us)
    drain_at = rng.randint(4, 23)
    specs = []
    for _ in range(n_jobs):
        prompt_len = rng.randint(1, seq_len // 2)
        specs.append(dict(
            arrive_at=rng.randrange(24),
            cancel_at=rng.randrange(40) if rng.randrange(4) == 0 else None,
            deadline=rng.randrange(4) == 0,
            # from this step on the job's row is never pushed — a hung
            # decode step; only assigned when the watchdog is armed
            stall_at=(rng.randrange(30)
                      if watchdog and rng.randrange(5) == 0 else None),
            prompt_len=prompt_len,
            max_new=rng.randrange(seq_len - prompt_len + 1),
            jid=None))

    now = 0.0
    step = 0
    spec_of_job = []
    while True:
        no_more_arrivals = step >= drain_at or all(
            s["jid"] is not None or s["arrive_at"] < step for s in specs)
        if no_more_arrivals and sched.finished():
            break  # the drain completed (invariant 4)
        # invariant 2: no deadlock/livelock under any schedule
        assert step < 10_000, f"chaos case {seed}: drain never completed"
        now += rng.randint(1, 4)

        if step < drain_at:
            for i, spec in enumerate(specs):
                if spec["arrive_at"] == step and spec["jid"] is None:
                    jid = sched.submit(
                        [0] * spec["prompt_len"], spec["max_new"], now,
                        priority=rng.choice(["low", "normal", "high"]),
                        deadline_ms=(rng.randint(10, 89)
                                     if spec["deadline"] else None))
                    assert jid == len(spec_of_job)
                    spec_of_job.append(i)
                    spec["jid"] = jid
        for spec in specs:
            if spec["jid"] is not None and spec["cancel_at"] == step:
                sched.cancel(spec["jid"])

        # --- the serve loop, verbatim ---
        sched.poll(now)
        sched.admit(now)
        sched.swapped.clear()
        for row in range(len(sched.rows)):
            if sched.rows[row] and sched.budget_exhausted(row, seq_len):
                sched.retire(row)
        for row in range(len(sched.rows)):
            a = sched.rows[row]
            if a is None:
                continue  # swapped out by an earlier push this step
            spec = specs[spec_of_job[a["id"]]]
            if spec["stall_at"] is not None and step >= spec["stall_at"]:
                # a hung decode step: record nothing for this row, ever
                # again — the armed watchdog must retire it
                pass
            elif rng.randrange(8) == 0:
                sched.retire(row)  # "EOS"
            else:
                # stamp every token with its job id (invariant 1)
                sched.push(row, 1000 + a["id"], now)
        sched.swapped.clear()
        # invariant 3: block-pool consistency after every single step
        assert sched.mgr.blocks_in_use() <= sched.mgr.n_blocks()
        sched.mgr.check_invariants()
        step += 1

    submitted = [s for s in specs if s["jid"] is not None]
    # invariant 1: exactly one terminal outcome per submitted request
    assert len(sched.results) == len(submitted), (
        f"chaos case {seed}: outcome count mismatch")
    assert all(r is not None for r in sched.results), (
        f"chaos case {seed}: a submitted job never reached an outcome")
    for jid, (outcome, tokens) in enumerate(sched.results):
        assert all(t == 1000 + jid for t in tokens), (
            f"chaos case {seed}: job {jid} holds foreign tokens {tokens}")
        spec = specs[spec_of_job[jid]]
        assert len(tokens) <= spec["max_new"], (
            f"chaos case {seed}: job {jid} overran max_new")
        assert outcome != "aborted", (
            f"chaos case {seed}: faults must degrade, never abort")
        # a job nobody interfered with ends done; a stalled job is
        # either done (it finished before its hang began) or retired
        # timed_out by the watchdog — never stuck, never anything else
        if spec["cancel_at"] is None and not spec["deadline"]:
            if spec["stall_at"] is None:
                assert outcome == "done", (
                    f"chaos case {seed}: undisturbed job {jid} "
                    f"ended {outcome}")
            else:
                assert outcome in ("done", "timed_out"), (
                    f"chaos case {seed}: stalled job {jid} ended {outcome}")
    return sched


# >= 300 distinct seeded schedules, matching the Rust suite's count
@pytest.mark.parametrize("seed", range(300))
def test_chaos_schedules_preserve_serving_invariants(seed):
    run_chaos_case(seed)


def test_chaos_sampling_exercises_every_early_outcome():
    """The 300 schedules must actually hit the interesting paths —
    cancellation, deadline expiry, watchdog retirement, and at least
    one injected allocation fault — or the suite is vacuous."""
    outcomes = set()
    any_fault_fired = False
    for seed in range(300):
        sched = run_chaos_case(seed)
        outcomes.update(o for o, _ in sched.results)
        any_fault_fired |= sched.mgr.alloc_faults.fired > 0
    assert {"done", "cancelled", "deadline_exceeded",
            "timed_out"} <= outcomes, f"sampling too narrow: {outcomes}"
    assert any_fault_fired, "no schedule ever fired the block-alloc lane"


def test_watchdog_drains_a_fully_stalled_schedule():
    # the pathological schedule: every step stalls (nothing is ever
    # pushed); without the watchdog this would spin at the step bound,
    # with it every job is retired timed_out and the drain completes
    sched = ChaosScheduler(2, dict(block_tokens=4, n_blocks=16), watchdog=40)
    now = 0.0
    for _ in range(4):
        sched.submit([0, 0, 0], 8, now)
    steps = 0
    while not sched.finished():
        assert steps < 1_000, "watchdog never drained the stall"
        now += 10
        sched.poll(now)
        sched.admit(now)
        sched.swapped.clear()
        sched.mgr.check_invariants()
        steps += 1
    assert len(sched.results) == 4
    for outcome, tokens in sched.results:
        assert outcome == "timed_out"
        assert tokens == []
    assert sched.timed_out_jobs == 4


def test_fault_lane_is_deterministic_and_capped():
    # two lanes with the same seed fire on exactly the same draws...
    a = FaultLane(1234, 0.5, cap=None)
    b = FaultLane(1234, 0.5, cap=None)
    assert [a.fire() for _ in range(200)] == [b.fire() for _ in range(200)]
    assert a.fired > 0
    # ...and a cap stops a lane for good, even at p = 1
    capped = FaultLane(7, 1.0, cap=3)
    fires = [capped.fire() for _ in range(10)]
    assert fires == [True] * 3 + [False] * 7
    assert capped.fired == 3
