"""Python mirror of the Rust serving wire format (`rust/src/serve/`).

The build container has no Rust toolchain (see
`.claude/skills/verify/SKILL.md`), so this line-for-line port is the
*runnable* verification of the network boundary — the same pattern as
`test_blocks_mirror.py` for the KV block manager:

  * `serve/json.rs` — the untrusted-input JSON parser and the
    deterministic sorted-key writer, cross-checked here against
    Python's `json` module on a shared random corpus;
  * `serve/http.rs` — the request-reader state machine (buffer until
    the blank line, split the head, drain `Content-Length` bytes),
    `parse_head`, and the response/chunked-transfer wire formats;
  * `serve/server.rs` — `decode_generate` plus the response encoders
    (`generate_body`, `token_line`, `done_line`, `stats_body`).

Two documented divergences from Python's `json` are pinned below:
lone UTF-16 surrogates are rejected (Python accepts them), and numbers
overflowing f64 such as `1e999` are rejected (Python yields `inf`).
Python also accepts the non-JSON literals `NaN`/`Infinity`; the mirror,
like the Rust parser, does not.
"""

import json
import math
import random
import re
from decimal import Decimal

import pytest

# ---------------------------------------------------------------------------
# serve/json.rs mirror: errors

MAX_DEPTH = 64
MAX_INPUT_BYTES = 1 << 20


class JsonError(Exception):
    kind = None


class JsonParseError(JsonError):
    kind = "parse_error"

    def __init__(self, offset, msg):
        super().__init__(f"invalid JSON at byte {offset}: {msg}")
        self.offset = offset
        self.msg = msg


class JsonTypeError(JsonError):
    kind = "type_error"

    def __init__(self, field, expected, found):
        super().__init__(f"field `{field}` must be {expected}, got {found}")
        self.field = field


class JsonMissingField(JsonError):
    kind = "missing_field"

    def __init__(self, field):
        super().__init__(f"missing required field `{field}`")
        self.field = field


# ---------------------------------------------------------------------------
# serve/json.rs mirror: parser (JSON numbers always parse to float, as
# the Rust side always parses to f64)


class Parser:
    def __init__(self, b, max_depth):
        self.b = b
        self.pos = 0
        self.max_depth = max_depth

    def err(self, msg):
        return JsonParseError(self.pos, msg)

    def peek(self):
        return self.b[self.pos] if self.pos < len(self.b) else None

    def bump(self):
        c = self.peek()
        if c is not None:
            self.pos += 1
        return c

    def skip_ws(self):
        while self.peek() in (0x20, 0x09, 0x0A, 0x0D):
            self.pos += 1

    def value(self, depth):
        self.skip_ws()
        c = self.peek()
        if c is None:
            raise self.err("unexpected end of input")
        if c == ord("n"):
            return self.lit("null", None)
        if c == ord("t"):
            return self.lit("true", True)
        if c == ord("f"):
            return self.lit("false", False)
        if c == ord('"'):
            return self.string()
        if c == ord("["):
            return self.array(depth)
        if c == ord("{"):
            return self.object(depth)
        if c == ord("-") or 0x30 <= c <= 0x39:
            return self.number()
        raise self.err(f"unexpected byte 0x{c:02x}")

    def lit(self, word, v):
        wb = word.encode()
        if self.b[self.pos:self.pos + len(wb)] == wb:
            self.pos += len(wb)
            return v
        raise self.err(f"expected `{word}`")

    def digits(self):
        c = self.peek()
        if c is None or not 0x30 <= c <= 0x39:
            raise self.err("expected a digit")
        while self.peek() is not None and 0x30 <= self.peek() <= 0x39:
            self.pos += 1

    def number(self):
        start = self.pos
        if self.peek() == ord("-"):
            self.pos += 1
        # integer part: a leading zero takes no more digits (JSON bans
        # 0123), any other digit takes a run
        if self.peek() == ord("0"):
            self.pos += 1
        else:
            self.digits()
        if self.peek() == ord("."):
            self.pos += 1
            self.digits()
        if self.peek() in (ord("e"), ord("E")):
            self.pos += 1
            if self.peek() in (ord("+"), ord("-")):
                self.pos += 1
            self.digits()
        text = self.b[start:self.pos].decode("utf-8", "replace")
        try:
            n = float(text)
        except ValueError:
            raise self.err(f"bad number `{text}`")
        if not math.isfinite(n):
            raise self.err(f"number `{text}` does not fit an f64")
        return n

    def hex4(self):
        v = 0
        for _ in range(4):
            c = self.bump()
            if c is None:
                raise self.err("truncated \\u escape")
            ch = chr(c)
            if ch not in "0123456789abcdefABCDEF":
                raise self.err("bad hex digit in \\u escape")
            v = (v << 4) | int(ch, 16)
        return v

    def string(self):
        if self.bump() != ord('"'):
            raise self.err("expected a string")
        buf = bytearray()
        while True:
            c = self.bump()
            if c is None:
                raise self.err("unterminated string")
            if c == ord('"'):
                break
            if c == ord("\\"):
                e = self.bump()
                if e is None:
                    raise self.err("unterminated escape")
                simple = {
                    ord('"'): b'"', ord("\\"): b"\\", ord("/"): b"/",
                    ord("b"): b"\x08", ord("f"): b"\x0c",
                    ord("n"): b"\n", ord("r"): b"\r", ord("t"): b"\t",
                }
                if e in simple:
                    buf.extend(simple[e])
                elif e == ord("u"):
                    buf.extend(self.unicode_escape().encode("utf-8"))
                else:
                    raise self.err(f"invalid escape `\\{chr(e)}`")
            elif 0x00 <= c <= 0x1F:
                raise self.err("raw control character in string")
            else:
                buf.append(c)
        try:
            return buf.decode("utf-8")
        except UnicodeDecodeError:
            raise self.err("invalid UTF-8 in string")

    def unicode_escape(self):
        # decodes one \uXXXX escape (the \u already consumed), pairing
        # surrogates; a lone surrogate is an error, not a replacement
        hi = self.hex4()
        if 0xD800 <= hi <= 0xDBFF:
            if self.bump() != ord("\\") or self.bump() != ord("u"):
                raise self.err("lone high surrogate in \\u escape")
            lo = self.hex4()
            if not 0xDC00 <= lo <= 0xDFFF:
                raise self.err("invalid low surrogate in \\u escape")
            cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
        elif 0xDC00 <= hi <= 0xDFFF:
            raise self.err("lone low surrogate in \\u escape")
        else:
            cp = hi
        return chr(cp)

    def check_depth(self, depth):
        # containers at nesting depth max_depth are rejected, so at
        # most max_depth arrays/objects ever sit on the recursion stack
        if depth >= self.max_depth:
            raise self.err(
                f"nesting exceeds the depth limit of {self.max_depth}")

    def array(self, depth):
        self.check_depth(depth)
        self.pos += 1  # consume '['
        items = []
        self.skip_ws()
        if self.peek() == ord("]"):
            self.pos += 1
            return items
        while True:
            items.append(self.value(depth + 1))
            self.skip_ws()
            c = self.bump()
            if c == ord(","):
                continue
            if c == ord("]"):
                return items
            raise self.err("expected `,` or `]` in array")

    def object(self, depth):
        self.check_depth(depth)
        self.pos += 1  # consume '{'
        obj = {}
        self.skip_ws()
        if self.peek() == ord("}"):
            self.pos += 1
            return obj
        while True:
            self.skip_ws()
            key = self.string()
            self.skip_ws()
            if self.bump() != ord(":"):
                raise self.err("expected `:` after object key")
            # duplicate keys: last one wins, as in Python's json
            obj[key] = self.value(depth + 1)
            self.skip_ws()
            c = self.bump()
            if c == ord(","):
                continue
            if c == ord("}"):
                return obj
            raise self.err("expected `,` or `}` in object")


def parse(data, max_depth=MAX_DEPTH, max_bytes=MAX_INPUT_BYTES):
    if isinstance(data, str):
        data = data.encode("utf-8")
    if len(data) > max_bytes:
        raise JsonParseError(
            0, f"input of {len(data)} bytes exceeds the {max_bytes} "
               "byte limit")
    p = Parser(data, max_depth)
    v = p.value(0)
    p.skip_ws()
    if p.pos < len(p.b):
        raise p.err("trailing data after the document")
    return v


# ---------------------------------------------------------------------------
# serve/json.rs mirror: writer (compact, sorted keys, UTF-8 raw)


def write_num(n):
    if not math.isfinite(n):
        return "null"
    # integral values print without a fraction (and -0 keeps its sign);
    # everything else uses shortest-roundtrip digits, expanded without
    # exponent notation exactly as Rust's `{}` float Display does
    if n == math.trunc(n) and abs(n) <= 9.007199254740992e15:
        if n == 0.0 and math.copysign(1.0, n) < 0.0:
            return "-0"
        return str(int(n))
    return format(Decimal(repr(n)), "f")


def write_escaped(s):
    out = ['"']
    for ch in s:
        if ch == '"':
            out.append('\\"')
        elif ch == "\\":
            out.append("\\\\")
        elif ch == "\b":
            out.append("\\b")
        elif ch == "\f":
            out.append("\\f")
        elif ch == "\n":
            out.append("\\n")
        elif ch == "\r":
            out.append("\\r")
        elif ch == "\t":
            out.append("\\t")
        elif ord(ch) < 0x20:
            out.append(f"\\u{ord(ch):04x}")
        else:
            out.append(ch)
    out.append('"')
    return "".join(out)


def write(v):
    if v is None:
        return "null"
    if v is True:
        return "true"
    if v is False:
        return "false"
    if isinstance(v, (int, float)):
        return write_num(float(v))
    if isinstance(v, str):
        return write_escaped(v)
    if isinstance(v, list):
        return "[" + ",".join(write(x) for x in v) + "]"
    if isinstance(v, dict):
        return "{" + ",".join(
            write_escaped(k) + ":" + write(v[k]) for k in sorted(v)) + "}"
    raise ValueError(f"not a JSON value: {v!r}")


# ---------------------------------------------------------------------------
# serve/json.rs mirror: typed field extraction


def type_name(v):
    if v is None:
        return "null"
    if isinstance(v, bool):
        return "bool"
    if isinstance(v, (int, float)):
        return "number"
    if isinstance(v, str):
        return "string"
    if isinstance(v, list):
        return "array"
    return "object"


def _get(doc, field):
    return doc.get(field) if isinstance(doc, dict) else None


def req_str(doc, field):
    v = _get(doc, field)
    if v is None:
        raise JsonMissingField(field)
    if not isinstance(v, str):
        raise JsonTypeError(field, "a string", type_name(v))
    return v


def opt_str(doc, field):
    v = _get(doc, field)
    if v is None:
        return None
    if not isinstance(v, str):
        raise JsonTypeError(field, "a string", type_name(v))
    return v


def opt_u64(doc, field):
    # rejects negatives, fractions, and magnitudes past 2^53
    v = _get(doc, field)
    if v is None:
        return None
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        raise JsonTypeError(field, "a non-negative integer", type_name(v))
    n = float(v)
    if n < 0.0 or n != math.trunc(n) or n > 9.007199254740992e15:
        raise JsonTypeError(field, "a non-negative integer", type_name(v))
    return int(n)


def opt_bool(doc, field):
    v = _get(doc, field)
    if v is None:
        return None
    if not isinstance(v, bool):
        raise JsonTypeError(field, "a bool", type_name(v))
    return v


# ---------------------------------------------------------------------------
# serve/http.rs mirror


MAX_HEAD_BYTES = 16 * 1024
MAX_BODY_BYTES = 1 << 20


class HttpErr(Exception):
    status = None


class Closed(HttpErr):
    pass


class BadRequest(HttpErr):
    status = 400


class PayloadTooLarge(HttpErr):
    status = 413


def header(req, name):
    for k, v in req["headers"]:
        if k.lower() == name.lower():
            return v
    return None


def parse_head(head):
    lines = head.split("\r\n")
    request_line = lines[0]
    parts = request_line.split(" ")
    if len(parts) != 3 or not parts[0] or not parts[1]:
        raise BadRequest(f"malformed request line `{request_line}`")
    method, path, version = parts
    if version == "HTTP/1.1":
        keep_alive = True
    elif version == "HTTP/1.0":
        keep_alive = False
    else:
        raise BadRequest(f"unsupported protocol version `{version}`")
    headers = []
    for line in lines[1:]:
        if ":" not in line:
            raise BadRequest(f"malformed header line `{line}`")
        name, value = line.split(":", 1)
        if not name or " " in name or "\t" in name:
            raise BadRequest(f"malformed header name `{name}`")
        headers.append((name, value.strip()))
    req = {"method": method, "path": path, "headers": headers,
           "body": b"", "keep_alive": keep_alive}
    c = header(req, "connection")
    if c is not None:
        if c.lower() == "close":
            req["keep_alive"] = False
        elif c.lower() == "keep-alive":
            req["keep_alive"] = True
    return req


class RequestReader:
    """The next_request state machine over an in-memory byte stream
    (buffer until the blank line, split the head, drain Content-Length
    bytes; carry pipelined remainder over to the next call)."""

    def __init__(self, data, max_body=MAX_BODY_BYTES):
        self.src = data
        self.src_pos = 0
        self.buf = bytearray()
        self.max_body = max_body

    def fill(self):
        chunk = self.src[self.src_pos:self.src_pos + 4096]
        self.src_pos += len(chunk)
        self.buf.extend(chunk)
        return len(chunk)

    def next_request(self):
        while True:
            head_end = self.buf.find(b"\r\n\r\n")
            if head_end >= 0:
                break
            if len(self.buf) > MAX_HEAD_BYTES:
                raise BadRequest(
                    f"request head exceeds {MAX_HEAD_BYTES} bytes")
            if self.fill() == 0:
                if not self.buf:
                    raise Closed("connection closed")
                raise BadRequest("connection closed mid-request")
        head_bytes = bytes(self.buf[:head_end])
        del self.buf[:head_end + 4]
        try:
            head = head_bytes.decode("utf-8")
        except UnicodeDecodeError:
            raise BadRequest("request head is not UTF-8")
        req = parse_head(head)
        # chunked uploads are out of scope for this API
        if header(req, "transfer-encoding") is not None:
            raise BadRequest("chunked request bodies are not supported")
        cl = header(req, "content-length")
        if cl is None:
            body_len = 0
        else:
            t = cl.strip()
            if re.fullmatch(r"\+?[0-9]+", t) is None:
                raise BadRequest(f"invalid Content-Length `{cl}`")
            body_len = int(t)
        if body_len > self.max_body:
            raise PayloadTooLarge(
                f"body of {body_len} bytes exceeds the "
                f"{self.max_body} byte limit")
        while len(self.buf) < body_len:
            if self.fill() == 0:
                raise BadRequest("connection closed mid-body")
        req["body"] = bytes(self.buf[:body_len])
        del self.buf[:body_len]
        return req


def status_text(status):
    return {
        200: "OK", 400: "Bad Request", 404: "Not Found",
        405: "Method Not Allowed", 408: "Request Timeout",
        413: "Payload Too Large", 429: "Too Many Requests",
        500: "Internal Server Error", 503: "Service Unavailable",
    }.get(status, "Unknown")


def write_response(status, content_type, body, keep_alive):
    head = (f"HTTP/1.1 {status} {status_text(status)}\r\n"
            f"Content-Type: {content_type}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: {'keep-alive' if keep_alive else 'close'}\r\n"
            "\r\n")
    return head.encode() + body


def error_body(kind, message):
    return write({"error": {"kind": kind, "message": message}}).encode()


def write_error_after(status, kind, message, retry_after_secs, keep_alive):
    body = error_body(kind, message)
    head = (f"HTTP/1.1 {status} {status_text(status)}\r\n"
            "Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Retry-After: {retry_after_secs}\r\n"
            f"Connection: {'keep-alive' if keep_alive else 'close'}\r\n"
            "\r\n")
    return head.encode() + body


def chunked_response(status, content_type, keep_alive, chunks):
    head = (f"HTTP/1.1 {status} {status_text(status)}\r\n"
            f"Content-Type: {content_type}\r\n"
            "Transfer-Encoding: chunked\r\n"
            f"Connection: {'keep-alive' if keep_alive else 'close'}\r\n"
            "\r\n").encode()
    out = bytearray(head)
    for c in chunks:
        if not c:
            continue  # a zero-length chunk would terminate the stream
        out.extend(b"%x\r\n" % len(c))
        out.extend(c)
        out.extend(b"\r\n")
    out.extend(b"0\r\n\r\n")
    return bytes(out)


# ---------------------------------------------------------------------------
# serve/server.rs mirror: request decode + response encode


OUTCOMES = ("done", "cancelled", "deadline_exceeded", "timed_out",
            "aborted")


def decode_generate(body):
    doc = parse(body)
    prompt = req_str(doc, "prompt")
    adapter = opt_str(doc, "adapter")
    p = opt_str(doc, "priority")
    if p is None:
        priority = "normal"
    elif p in ("low", "normal", "high"):
        priority = p
    else:
        raise JsonTypeError(
            "priority", 'one of "low"/"normal"/"high"', "string")
    return {
        "prompt": prompt,
        "adapter": adapter,
        "priority": priority,
        "deadline_ms": opt_u64(doc, "deadline_ms"),
        "max_new_tokens": opt_u64(doc, "max_new_tokens"),
        "stream": opt_bool(doc, "stream") or False,
    }


def generate_body(outcome, text):
    return write({"outcome": outcome, "text": text})


def token_line(text):
    return write({"token": text}) + "\n"


def done_line(outcome, text):
    return write({"done": True, "outcome": outcome, "text": text}) + "\n"


def stats_body(st):
    budget = st["token_budget"]
    return {
        "submitted": float(st["submitted"]),
        "completed": float(st["completed"]),
        "cancelled": float(st["cancelled"]),
        "deadline_exceeded": float(st["deadline_exceeded"]),
        "timed_out_jobs": float(st["timed_out_jobs"]),
        "shed_requests": float(st["shed_requests"]),
        "worker_restarts": float(st["worker_restarts"]),
        "preemptions": float(st["preemptions"]),
        "queue_depth": float(st["queue_depth"]),
        "active_rows": float(st["active_rows"]),
        "resident_tokens": float(st["resident_tokens"]),
        "reserved_tokens": float(st["reserved_tokens"]),
        "token_budget": None if budget is None else float(budget),
        "tokens_generated": float(st["tokens_generated"]),
        "mean_ttft_ms": float(st["mean_ttft_ms"]),
        "tokens_per_sec": float(st["tokens_per_sec"]),
        "blocks": {
            "kv_blocks": float(st["kv_blocks"]),
            "kv_block_tokens": float(st["kv_block_tokens"]),
            "kv_blocks_in_use": float(st["kv_blocks_in_use"]),
            "shared_block_hits": float(st["shared_block_hits"]),
            "cow_forks": float(st["cow_forks"]),
            "swap_outs": float(st["swap_outs"]),
        },
    }


def should_shed(pending, st, max_queue):
    """Mirror of `serve::server::should_shed` (the load-shedding
    decision): queue watermark first, then resident-token saturation —
    the latter only when a backlog actually exists, so a lone request
    against a full batch is still accepted and simply queues."""
    backlog = pending + st["queue_depth"]
    if backlog >= max(max_queue, 1):
        return True
    budget = st["token_budget"]  # None mirrors usize::MAX (unbounded)
    bounded = budget is not None and budget > 0
    return bounded and st["resident_tokens"] >= budget and backlog > 0


# ---------------------------------------------------------------------------
# corpus generators (the same pools as rust/tests/prop_json.rs)


STRING_POOL = ["a", "z", "0", " ", '"', "\\", "/", "\n", "\r", "\t",
               "\b", "\f", "\x00", "\x1f", "é", "ß", "中", " ", "😀"]


def gen_string(rng):
    return "".join(rng.choice(STRING_POOL)
                   for _ in range(rng.randrange(12)))


def gen_num(rng):
    k = rng.randrange(5)
    if k == 0:
        return float(rng.randrange(-1_000_000, 1_000_001))
    if k == 1:
        return rng.randrange(-2000, 2001) / 64.0
    if k == 2:
        return 10.0 ** rng.randrange(-300, 300)
    if k == 3:
        return -0.0
    return 9.007199254740992e15 * rng.choice([1.0, -1.0])


def gen_value(rng, depth=0):
    scalar = depth >= 5 or rng.random() < 0.4
    k = rng.randrange(4) if scalar else 4 + rng.randrange(2)
    if k == 0:
        return None
    if k == 1:
        return rng.random() < 0.5
    if k == 2:
        return gen_num(rng)
    if k == 3:
        return gen_string(rng)
    if k == 4:
        return [gen_value(rng, depth + 1)
                for _ in range(rng.randrange(5))]
    return {gen_string(rng): gen_value(rng, depth + 1)
            for _ in range(rng.randrange(5))}


def canon(x):
    """Type-strict comparison key: floats and ints compare as the same
    number (the mirror always parses to float, json.loads keeps ints),
    bools stay distinct from 1/0. The sign of zero is NOT compared:
    Python's json parses `-0` down its integer path to int 0, losing
    the sign (pinned in test_negative_zero_keeps_sign_and_writes_bare).
    """
    if x is None or isinstance(x, bool):
        return ("lit", x)
    if isinstance(x, (int, float)):
        f = float(x)
        sign = 1.0 if f == 0.0 else math.copysign(1.0, f)
        return ("num", repr(abs(f)), sign)
    if isinstance(x, str):
        return ("str", x)
    if isinstance(x, list):
        return ("arr", tuple(canon(i) for i in x))
    return ("obj", tuple(sorted((k, canon(v)) for k, v in x.items())))


DUMPS = dict(sort_keys=True, separators=(",", ":"), ensure_ascii=False)


# ---------------------------------------------------------------------------
# tests: parser vs Python's json on a shared corpus


def test_parser_agrees_with_json_loads_on_random_docs():
    rng = random.Random(0x5EED)
    for _ in range(300):
        doc = write(gen_value(rng))
        assert canon(parse(doc)) == canon(json.loads(doc)), doc


def test_writer_is_a_parse_fixed_point():
    rng = random.Random(0x5EED + 1)
    for _ in range(300):
        first = write(gen_value(rng))
        assert write(parse(first)) == first


def test_writer_matches_json_dumps_on_exponent_free_values():
    # json.dumps uses repr() for floats, which switches to exponent
    # notation outside [1e-4, 1e16) — inside it, and for ints, the two
    # writers must agree byte for byte
    rng = random.Random(0x5EED + 2)
    for _ in range(300):
        v = gen_value(rng)

        def clamp(x):
            if isinstance(x, bool) or not isinstance(x, float):
                if isinstance(x, list):
                    return [clamp(i) for i in x]
                if isinstance(x, dict):
                    return {k: clamp(val) for k, val in x.items()}
                return x
            if x != math.trunc(x) and 1e-4 <= abs(x) < 1e15:
                return x
            return int(abs(x) % 10**6) * (1 if x >= 0 else -1)

        v = clamp(v)
        assert write(v) == json.dumps(v, **DUMPS)


def test_parse_raises_only_json_errors_on_mutated_docs():
    rng = random.Random(0x5EED + 3)
    for _ in range(300):
        b = bytearray(write(gen_value(rng)).encode("utf-8"))
        for _ in range(1 + rng.randrange(6)):
            k = rng.randrange(3)
            if k == 0 and b:
                b[rng.randrange(len(b))] = rng.randrange(256)
            elif k == 1:
                del b[rng.randrange(len(b) + 1):]
            else:
                b.insert(rng.randrange(len(b) + 1), rng.randrange(256))
        try:
            parse(bytes(b))
        except JsonError:
            pass  # typed rejection is the contract; anything else raises


def test_whitespace_and_sorted_keys():
    v = parse(b'{"b": [1, 2, {"x": null}], "a": "y"}')
    assert write(v) == '{"a":"y","b":[1,2,{"x":null}]}'
    assert canon(v) == canon(json.loads('{"b":[1,2,{"x":null}],"a":"y"}'))


def test_duplicate_keys_last_wins_like_python():
    doc = '{"k":1,"k":2}'
    assert parse(doc)["k"] == 2.0
    assert json.loads(doc)["k"] == 2
    assert write(parse(doc)) == '{"k":2}'


def test_escapes_decode_and_reencode():
    # byte-for-byte the rust unit test `escapes_decode_and_reencode`
    v = parse(r'"a\n\t\"\\\/\b\fAé"')
    assert v == 'a\n\t"\\/\b\fAé'
    assert write(v) == '"a\\n\\t\\"\\\\/\\b\\fAé"'
    assert write(v) == json.dumps(v, **DUMPS)


def test_surrogate_pairs_combine_lone_surrogates_pinned_divergence():
    assert parse(r'"😀"') == "😀" == json.loads(r'"😀"')
    for doc in [r'"\ud800"', r'"\udc00"', r'"\ud800x"', r'"\ud800\ud800"']:
        with pytest.raises(JsonParseError):
            parse(doc)
        json.loads(doc)  # Python accepts the lone surrogate — pinned


def test_overflow_and_nonfinite_pinned_divergences():
    for doc in ["1e999", "-1e999", "1e99999999"]:
        with pytest.raises(JsonParseError):
            parse(doc)
        assert math.isinf(json.loads(doc))  # Python yields inf — pinned
    # Python's json accepts the non-JSON literals NaN/Infinity; the
    # serving parser does not
    for doc in ["NaN", "Infinity", "-Infinity"]:
        with pytest.raises(JsonParseError):
            parse(doc)
        json.loads(doc)
    assert parse("1.7976931348623157e308") == 1.7976931348623157e308


def test_negative_zero_keeps_sign_and_writes_bare():
    for doc in ["-0", "-0.0", "-0e5"]:
        v = parse(doc)
        assert v == 0.0 and math.copysign(1.0, v) < 0.0
        assert write(v) == "-0"
    # pinned divergences: json.dumps(-0.0) spells it "-0.0", and
    # json.loads("-0") takes the integer path and loses the sign
    assert json.dumps(-0.0) == "-0.0"
    assert json.loads("-0") == 0 and isinstance(json.loads("-0"), int)
    assert write(0.0) == "0"


def test_number_grammar_edges_match_python():
    for bad in ["01", ".5", "1.", "1e", "+1", "--1", "1e+"]:
        with pytest.raises(JsonParseError):
            parse(bad)
        with pytest.raises(json.JSONDecodeError):
            json.loads(bad)
    assert parse("1e3") == 1000.0 == json.loads("1e3")


def test_strictness_matches_python():
    for bad in ['[1,]', '{"a":1,}', "[1 2]", "'x'", '{"a" 1}', "1 2",
                '"\x01"']:
        with pytest.raises(JsonParseError):
            parse(bad)
        with pytest.raises(json.JSONDecodeError):
            json.loads(bad)


def test_depth_limit():
    ok = "[" * MAX_DEPTH + "1" + "]" * MAX_DEPTH
    assert parse(ok) is not None
    with pytest.raises(JsonParseError):
        parse("[" * (MAX_DEPTH + 1) + "1" + "]" * (MAX_DEPTH + 1))
    assert parse("[[[[1]]]]", max_depth=4) == [[[[1.0]]]]
    with pytest.raises(JsonParseError):
        parse("[[[[[1]]]]]", max_depth=4)
    # scalars inside the deepest admitted container are fine
    assert parse('[[1,true,"x"]]', max_depth=2) == [[1.0, True, "x"]]


def test_size_limit():
    with pytest.raises(JsonParseError):
        parse(b" " * 32, max_bytes=16)
    assert parse(b"1", max_bytes=16) == 1.0


def test_typed_extraction():
    doc = parse(b'{"s":"x","n":5,"b":true,"z":null,"f":1.5,"neg":-1,'
                b'"big":100000000000000000}')
    assert req_str(doc, "s") == "x"
    with pytest.raises(JsonMissingField):
        req_str(doc, "missing")
    with pytest.raises(JsonMissingField):
        req_str(doc, "z")  # null counts as missing
    with pytest.raises(JsonTypeError):
        req_str(doc, "n")
    assert opt_str(doc, "missing") is None
    assert opt_u64(doc, "n") == 5
    assert opt_u64(doc, "missing") is None
    for bad in ["f", "neg", "big", "s", "b"]:
        with pytest.raises(JsonTypeError):
            opt_u64(doc, bad)
    assert opt_u64(parse(b'{"n":9007199254740992}'), "n") == 2**53
    # 2^53 + 1 rounds to exactly 2^53 in an f64, so it sits on the
    # accepted side of the limit (mirrors the Rust behaviour)
    assert opt_u64(parse(b'{"n":9007199254740993}'), "n") == 2**53
    assert opt_bool(doc, "b") is True
    with pytest.raises(JsonTypeError):
        opt_bool(doc, "n")


# ---------------------------------------------------------------------------
# tests: HTTP state machine


def test_http_parses_post_with_body():
    raw = (b"POST /v1/generate HTTP/1.1\r\nHost: x\r\n"
           b"Content-Length: 4\r\n\r\nabcd")
    req = RequestReader(raw).next_request()
    assert req["method"] == "POST"
    assert req["path"] == "/v1/generate"
    assert req["body"] == b"abcd"
    assert req["keep_alive"]
    assert header(req, "HOST") == "x"


def test_http_keep_alive_rules():
    rd = RequestReader(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n")
    assert not rd.next_request()["keep_alive"]
    assert not RequestReader(
        b"GET / HTTP/1.0\r\n\r\n").next_request()["keep_alive"]
    assert RequestReader(
        b"GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n"
    ).next_request()["keep_alive"]


def test_http_pipelined_requests_both_parse():
    rd = RequestReader(b"GET /healthz HTTP/1.1\r\n\r\n"
                       b"GET /v1/stats HTTP/1.1\r\n\r\n")
    assert rd.next_request()["path"] == "/healthz"
    assert rd.next_request()["path"] == "/v1/stats"
    with pytest.raises(Closed):
        rd.next_request()


def test_http_malformed_heads_are_400():
    for raw in [b"GARBAGE\r\n\r\n",
                b"GET /\r\n\r\n",
                b"GET / HTTP/2.0\r\n\r\n",
                b"GET / HTTP/1.1\r\nno-colon\r\n\r\n",
                b"GET / HTTP/1.1\r\nbad name: x\r\n\r\n",
                b"GET / HTTP/1.1 extra\r\n\r\n",
                b"POST / HTTP/1.1\r\nContent-Length: zz\r\n\r\n",
                b"POST / HTTP/1.1\r\nContent-Length: -1\r\n\r\n",
                b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"]:
        with pytest.raises(BadRequest):
            RequestReader(raw).next_request()


def test_http_oversized_body_is_413():
    raw = b"POST / HTTP/1.1\r\nContent-Length: 100\r\n\r\n"
    with pytest.raises(PayloadTooLarge) as e:
        RequestReader(raw, max_body=10).next_request()
    assert e.value.status == 413


def test_http_truncated_requests_fail_cleanly():
    with pytest.raises(BadRequest):
        RequestReader(b"GET / HT").next_request()
    with pytest.raises(BadRequest):
        RequestReader(
            b"POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\nabc"
        ).next_request()
    with pytest.raises(Closed):
        RequestReader(b"").next_request()


def test_http_fixed_response_wire_format():
    text = write_response(200, "application/json", b"{}", True).decode()
    assert text.startswith("HTTP/1.1 200 OK\r\n")
    assert "Content-Length: 2\r\n" in text
    assert "Connection: keep-alive\r\n" in text
    assert text.endswith("\r\n\r\n{}")


def test_http_error_body_contract():
    assert (error_body("parse_error", "broken").decode()
            == '{"error":{"kind":"parse_error","message":"broken"}}')
    assert status_text(503) == "Service Unavailable"
    assert status_text(418) == "Unknown"


def test_http_retry_after_wire_format():
    # byte-for-byte the Rust unit test `retry_after_wire_format`
    text = write_error_after(429, "overloaded", "try later", 2, True).decode()
    assert text.startswith("HTTP/1.1 429 Too Many Requests\r\n")
    assert "Retry-After: 2\r\n" in text
    assert "Connection: keep-alive\r\n" in text
    assert '"kind":"overloaded"' in text
    text = write_error_after(
        503, "draining", "shutting down", 1, False).decode()
    assert text.startswith("HTTP/1.1 503 Service Unavailable\r\n")
    assert "Retry-After: 1\r\n" in text
    assert '"kind":"draining"' in text


def test_http_chunked_stream_wire_format():
    # byte-for-byte the Rust unit test `chunked_stream_wire_format`
    raw = chunked_response(200, "application/jsonl", False,
                           [b"hello ", b"", b"world"])
    text = raw.decode()
    assert "Transfer-Encoding: chunked\r\n" in text
    body = text.split("\r\n\r\n", 1)[1]
    assert body == "6\r\nhello \r\n5\r\nworld\r\n0\r\n\r\n"


# ---------------------------------------------------------------------------
# tests: /v1/generate decode + response encoders


def test_decode_generate_full_and_minimal():
    full = decode_generate(
        b'{"prompt":"hi","adapter":"base","priority":"high",'
        b'"deadline_ms":250,"max_new_tokens":8,"stream":true}')
    assert full == {"prompt": "hi", "adapter": "base", "priority": "high",
                    "deadline_ms": 250, "max_new_tokens": 8,
                    "stream": True}
    minimal = decode_generate(b'{"prompt":"p"}')
    assert minimal["priority"] == "normal"
    assert minimal["adapter"] is None
    assert minimal["stream"] is False


def test_decode_generate_rejects_bad_bodies():
    cases = [(b"{", "parse_error"),
             (b"{}", "missing_field"),
             (b'{"prompt":7}', "type_error"),
             (b'{"prompt":"p","priority":"urgent"}', "type_error"),
             (b'{"prompt":"p","max_new_tokens":-1}', "type_error"),
             (b'{"prompt":"p","stream":1}', "type_error"),
             (b'{"prompt":null}', "missing_field")]
    for body, kind in cases:
        with pytest.raises(JsonError) as e:
            decode_generate(body)
        assert e.value.kind == kind, body


def test_response_encoders_are_deterministic():
    # byte-for-byte the Rust unit test `response_encoders_are_deterministic`
    assert generate_body("done", "ab") == '{"outcome":"done","text":"ab"}'
    assert token_line("x") == '{"token":"x"}\n'
    assert (done_line("cancelled", "part")
            == '{"done":true,"outcome":"cancelled","text":"part"}\n')
    assert set(OUTCOMES) == {"done", "cancelled", "deadline_exceeded",
                             "timed_out", "aborted"}


def test_streamed_tokens_concatenate_to_done_text():
    tokens = ["he", "l", "lo", " 😀"]
    lines = [token_line(t) for t in tokens]
    lines.append(done_line("done", "".join(tokens)))
    parsed = [parse(line) for line in lines]
    concat = "".join(p["token"] for p in parsed[:-1])
    assert concat == parsed[-1]["text"]
    # each line is also plain JSON to any standard client
    for line in lines:
        assert canon(json.loads(line)) == canon(parse(line))


def test_stats_body_shape_and_roundtrip():
    st = dict(submitted=3, completed=2, cancelled=1, deadline_exceeded=0,
              timed_out_jobs=4, shed_requests=2, worker_restarts=1,
              preemptions=4, queue_depth=1, active_rows=2,
              resident_tokens=37, reserved_tokens=64, token_budget=None,
              tokens_generated=21, mean_ttft_ms=1.5, tokens_per_sec=88.0,
              kv_blocks=8, kv_block_tokens=16, kv_blocks_in_use=5,
              shared_block_hits=2, cow_forks=1, swap_outs=0)
    body = write(stats_body(st))
    v = parse(body)
    assert v["submitted"] == 3.0
    assert v["token_budget"] is None  # unbounded budget encodes as null
    assert v["blocks"]["kv_blocks"] == 8.0
    assert v["shed_requests"] == 2.0
    assert v["worker_restarts"] == 1.0
    assert v["timed_out_jobs"] == 4.0
    assert canon(json.loads(body)) == canon(v)
    # a bounded budget is a number
    st["token_budget"] = 512
    assert parse(write(stats_body(st)))["token_budget"] == 512.0


def test_should_shed_watermarks():
    # mirror of the Rust unit test `should_shed_watermarks`
    st = dict(queue_depth=0, resident_tokens=0, token_budget=None)
    # below the queue watermark: admit
    assert not should_shed(0, st, 4)
    assert not should_shed(3, st, 4)
    # at the watermark (pending + queued): shed
    assert should_shed(4, st, 4)
    st["queue_depth"] = 2
    assert should_shed(2, st, 4)
    # resident-token pressure only sheds when a backlog exists
    st = dict(queue_depth=0, resident_tokens=100, token_budget=100)
    assert not should_shed(0, st, 4), "saturated but idle: admit"
    assert should_shed(1, st, 4), "saturated with backlog: shed"
    st["resident_tokens"] = 99
    assert not should_shed(1, st, 4)
