"""Make `pytest python/tests/` work from the repo root: the tests import
the `compile` package which lives in `python/`."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))
