"""The linter lints itself honest: fixture self-test, a clean repo, and
unit coverage of the lexer/waiver machinery via direct import.

pallas-lint is the one static-analysis pass executable in this
container (no Rust toolchain), so tier-1 leans on it staying green.
"""

import importlib.util
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[2]
LINT = REPO / "scripts" / "pallas_lint.py"


def run_lint(*args):
    return subprocess.run(
        [sys.executable, str(LINT), *args],
        capture_output=True,
        text=True,
        cwd=REPO,
    )


@pytest.fixture(scope="module")
def mod():
    spec = importlib.util.spec_from_file_location("pallas_lint", LINT)
    m = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(m)
    return m


def test_self_test_passes():
    p = run_lint("--self-test")
    assert p.returncode == 0, p.stdout + p.stderr
    assert "FAIL" not in p.stdout


def test_repo_lints_clean():
    p = run_lint("--json")
    assert p.returncode == 0, p.stdout + p.stderr
    data = json.loads(p.stdout)
    assert data["findings"] == []
    assert data["checked_files"] > 50
    # the call graph resolved a meaningful share of the crate's calls
    cg = data["callgraph"]
    assert set(cg) == {"functions", "calls", "edges", "external", "ambiguous"}
    assert cg["functions"] > 100
    assert cg["edges"] > 100
    assert cg["edges"] + cg["external"] + cg["ambiguous"] == cg["calls"]


def test_bad_file_fails_with_finding(tmp_path):
    bad = tmp_path / "bad.rs"
    bad.write_text(
        "fn f(xs: &mut [f64]) {\n"
        "    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n"
        "}\n"
    )
    p = run_lint("--json", str(bad))
    assert p.returncode == 1
    data = json.loads(p.stdout)
    assert [f["rule"] for f in data["findings"]] == ["no-float-partial-cmp"]
    assert data["findings"][0]["line"] == 2


def test_list_rules_names_all_rules():
    p = run_lint("--list-rules")
    assert p.returncode == 0
    for rule in [
        "no-hot-path-panic",
        "no-float-partial-cmp",
        "oracle-purity",
        "no-relaxed-cancel",
        "no-lossy-as",
        "scoped-threads-only",
        "result-not-panic-api",
        "no-unbounded-send",
        "no-transitive-panic",
        "lock-order",
        "untrusted-taint",
        "unused-waiver",
        "waiver-syntax",
    ]:
        assert rule in p.stdout, f"{rule} missing from --list-rules"


def test_unbounded_send_flagged_in_serving_stack_only(mod):
    src = "pub fn f() { let (_t, _r) = mpsc::channel::<i32>(); }\n"
    in_serve = mod.lint_text("rust/src/serve/server.rs", src)
    assert [f.rule for f in in_serve] == ["no-unbounded-send"]
    bounded = src.replace("mpsc::channel::<i32>()",
                          "mpsc::sync_channel::<i32>(8)")
    assert mod.lint_text("rust/src/serve/server.rs", bounded) == []
    # the serving stack is the scope: quant/ code is untouched
    assert mod.lint_text("rust/src/quant/kernels.rs", src) == []


# ---- direct-import unit coverage ----------------------------------------


def test_lexer_scrubs_strings_and_comments(mod):
    lexed = mod.lex(
        's = ".unwrap()"; // comment .expect(\n'
        '/* block /* nested */ partial_cmp */ let x = 1;\n'
        "let r = r#\"thread::spawn\"#;\n"
    )
    joined = "\n".join(lexed.lines)
    assert ".unwrap()" not in joined
    assert ".expect(" not in joined
    assert "partial_cmp" not in joined
    assert "thread::spawn" not in joined
    assert "let x = 1;" in joined
    # the line comment was captured for waiver parsing
    assert any("comment" in text for _, text in lexed.comments)


def test_lexer_char_literals_vs_lifetimes(mod):
    lexed = mod.lex("let a: &'static str = x; let q = '\\''; let z = 'y';")
    line = lexed.lines[0]
    assert "'static" in line  # lifetime kept as code
    assert "'y'" not in line  # char literal scrubbed


def test_cfg_test_spans_exempt_test_code(mod):
    text = (
        "fn hot(xs: &[u32]) -> u32 { xs[0] }\n"
        "#[cfg(test)]\n"
        "mod tests {\n"
        "    fn helper(xs: &[u32]) -> u32 { xs[1] }\n"
        "}\n"
    )
    findings = mod.lint_text("rust/src/engine/scheduler.rs", text)
    assert [(f.rule, f.line) for f in findings] == [("no-hot-path-panic", 1)]


def test_waiver_requires_reason_and_is_tracked(mod):
    waived = (
        "fn f(xs: &[u32]) -> u32 {\n"
        "    // pallas-lint: allow(no-hot-path-panic) — caller checks bounds\n"
        "    xs[0]\n"
        "}\n"
    )
    assert mod.lint_text("rust/src/engine/scheduler.rs", waived) == []
    unused = "fn f() -> u32 { 1 } // pallas-lint: allow(no-hot-path-panic) — nope\n"
    rules = [f.rule for f in mod.lint_text("rust/src/engine/scheduler.rs", unused)]
    assert rules == ["unused-waiver"]


def test_lexer_hardened_literals(mod):
    lexed = mod.lex(
        'let a = r##"panic!(" inside "# stays text"##;\n'
        'let b = b".unwrap() in a byte string";\n'
        "let c = br#\"thread::spawn in raw bytes\"#;\n"
        "let d = 1.0.max(2.0);\n"
        "/* outer /* inner .expect( */ still comment */ let e = 2;\n"
    )
    joined = "\n".join(lexed.lines)
    assert "panic!" not in joined
    assert ".unwrap()" not in joined
    assert "thread::spawn" not in joined
    assert ".expect(" not in joined
    assert ".max(" in joined  # method call after a float literal is code
    assert "let e = 2;" in joined


# ---- call graph ----------------------------------------------------------


def _graph(mod, files):
    return mod.CallGraph([mod.Unit(p, t) for p, t in files.items()])


def test_callgraph_same_file_beats_crate_wide(mod):
    g = _graph(mod, {
        "rust/src/a.rs": "fn helper() {}\nfn caller() { helper(); }\n",
        "rust/src/b.rs": "fn helper() {}\n",
    })
    caller = g.index_of("rust/src/a.rs", "caller")
    edges = g.edges[caller]
    assert len(edges) == 1
    assert g.fns[edges[0].callee].path == "rust/src/a.rs"


def test_callgraph_unique_crate_wide_resolves(mod):
    g = _graph(mod, {
        "rust/src/a.rs": "fn caller() { helper(); }\n",
        "rust/src/b.rs": "fn helper() {}\n",
    })
    caller = g.index_of("rust/src/a.rs", "caller")
    [e] = g.edges[caller]
    assert g.fns[e.callee].path == "rust/src/b.rs"
    assert g.unresolved == []


def test_callgraph_ambiguous_is_unresolved_not_guessed(mod):
    g = _graph(mod, {
        "rust/src/a.rs": "fn caller() { helper(); }\n",
        "rust/src/b.rs": "fn helper() {}\n",
        "rust/src/c.rs": "fn helper() {}\n",
    })
    caller = g.index_of("rust/src/a.rs", "caller")
    assert g.edges[caller] == []
    [u] = g.unresolved
    assert (u["name"], u["reason"]) == ("helper", "ambiguous")
    stats = g.stats()
    assert stats["ambiguous"] == 1
    assert stats["edges"] + stats["external"] + stats["ambiguous"] == \
        stats["calls"]


def test_callgraph_cycle_terminates_and_propagates(mod):
    # ping <-> pong recursion with a panic inside: the fixpoint must
    # terminate and still surface the panic at the pub API frontier
    src = (
        "fn ping(n: u32) -> u32 {\n"
        "    if n == 0 { panic!(\"boom\") } else { pong(n - 1) }\n"
        "}\n"
        "fn pong(n: u32) -> u32 {\n"
        "    ping(n)\n"
        "}\n"
        "pub fn api(n: u32) -> u32 {\n"
        "    pong(n)\n"
        "}\n"
    )
    findings = mod.lint_text("rust/src/engine/adapters.rs", src)
    assert [(f.rule, f.line) for f in findings] == [("no-transitive-panic", 8)]


# ---- interprocedural passes ----------------------------------------------


def test_transitive_panic_seen_through_helper(mod):
    src = (
        "fn helper(x: &str) -> u32 {\n"
        "    x.parse().unwrap()\n"
        "}\n"
        "pub fn api(x: &str) -> u32 {\n"
        "    helper(x)\n"
        "}\n"
    )
    findings = mod.lint_text("rust/src/engine/adapters.rs", src)
    assert [(f.rule, f.line) for f in findings] == [("no-transitive-panic", 5)]
    # the same chain outside the engine/serve API surface is not flagged
    assert mod.lint_text("rust/src/quant/kernels.rs", src) == []


def test_transitive_panic_waiver_at_root_shields_all_callers(mod):
    src = (
        "fn helper(x: &str) -> u32 {\n"
        "    // pallas-lint: allow(no-transitive-panic) — input validated upstream\n"
        "    x.parse().unwrap()\n"
        "}\n"
        "pub fn api(x: &str) -> u32 { helper(x) }\n"
        "pub fn api2(x: &str) -> u32 { helper(x) }\n"
    )
    assert mod.lint_text("rust/src/engine/adapters.rs", src) == []


def test_lock_order_double_acquire_flagged(mod):
    src = (
        "use std::sync::Mutex;\n"
        "fn f(m: &Mutex<u32>) {\n"
        "    let a = m.lock().unwrap_or_else(|p| p.into_inner());\n"
        "    let b = m.lock().unwrap_or_else(|p| p.into_inner());\n"
        "    drop(b);\n"
        "    drop(a);\n"
        "}\n"
    )
    findings = mod.lint_text("rust/src/serve/server.rs", src)
    assert [(f.rule, f.line) for f in findings] == [("lock-order", 4)]
    # scheduler.rs is in scope too; quant/ is not
    assert [f.rule for f in
            mod.lint_text("rust/src/engine/scheduler.rs", src)] == \
        ["lock-order"]
    assert mod.lint_text("rust/src/quant/kernels.rs", src) == []


def test_lock_order_condvar_wait_is_sanctioned(mod):
    src = (
        "use std::sync::{Condvar, Mutex};\n"
        "fn f(m: &Mutex<u32>, cv: &Condvar) {\n"
        "    let mut g = m.lock().unwrap_or_else(|p| p.into_inner());\n"
        "    while *g == 0 {\n"
        "        g = cv.wait_timeout(g, DUR).unwrap_or_else(|p| p.into_inner()).0;\n"
        "    }\n"
        "}\n"
    )
    assert mod.lint_text("rust/src/serve/server.rs", src) == []


def test_taint_source_to_sink_and_sanitizer(mod):
    bad = (
        "fn f(doc: &Doc) -> Vec<u8> {\n"
        "    let n = doc.req_u64(\"len\") as usize;\n"
        "    Vec::with_capacity(n)\n"
        "}\n"
    )
    findings = mod.lint_text("rust/src/serve/server.rs", bad)
    assert [(f.rule, f.line) for f in findings] == [("untrusted-taint", 3)]
    # a bounds check on the way sanitizes the value
    good = (
        "fn f(doc: &Doc) -> Vec<u8> {\n"
        "    let n = doc.req_u64(\"len\") as usize;\n"
        "    if n > MAX { return Vec::new(); }\n"
        "    Vec::with_capacity(n)\n"
        "}\n"
    )
    assert mod.lint_text("rust/src/serve/server.rs", good) == []
    # and the same code outside serve/ is out of scope
    assert mod.lint_text("rust/src/engine/session.rs", bad) == []


def test_taint_clamped_at_source_is_clean(mod):
    src = (
        "fn f(doc: &Doc, xs: &[u8]) -> u8 {\n"
        "    let i = (doc.req_u64(\"i\") as usize).min(xs.len() - 1);\n"
        "    xs[i]\n"
        "}\n"
    )
    assert mod.lint_text("rust/src/serve/server.rs", src) == []


# ---- CLI surfaces --------------------------------------------------------


def test_sarif_output_is_valid(tmp_path):
    bad = tmp_path / "bad.rs"
    bad.write_text(
        "fn f(xs: &mut [f64]) {\n"
        "    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n"
        "}\n"
    )
    out = tmp_path / "out.sarif"
    p = run_lint("--sarif", str(out), str(bad))
    assert p.returncode == 1
    doc = json.loads(out.read_text())
    assert doc["version"] == "2.1.0"
    assert "sarif-2.1.0" in doc["$schema"]
    run = doc["runs"][0]
    assert run["tool"]["driver"]["name"] == "pallas-lint"
    rule_ids = [r["id"] for r in run["tool"]["driver"]["rules"]]
    assert "no-float-partial-cmp" in rule_ids
    [res] = [r for r in run["results"]
             if r["ruleId"] == "no-float-partial-cmp"]
    assert res["level"] == "error"
    loc = res["locations"][0]["physicalLocation"]
    assert loc["region"]["startLine"] == 2
    assert res["ruleIndex"] == rule_ids.index("no-float-partial-cmp")


def test_changed_mode_reports_only_changed_files():
    # vs HEAD the repo is clean either way: with no pending .rs edits it
    # short-circuits, with pending edits those files lint clean
    p = run_lint("--changed", "HEAD")
    assert p.returncode == 0, p.stdout + p.stderr
    p2 = run_lint("--changed", "HEAD", "rust/src")
    assert p2.returncode == 2  # exclusive with explicit paths
