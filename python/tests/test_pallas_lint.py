"""The linter lints itself honest: fixture self-test, a clean repo, and
unit coverage of the lexer/waiver machinery via direct import.

pallas-lint is the one static-analysis pass executable in this
container (no Rust toolchain), so tier-1 leans on it staying green.
"""

import importlib.util
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[2]
LINT = REPO / "scripts" / "pallas_lint.py"


def run_lint(*args):
    return subprocess.run(
        [sys.executable, str(LINT), *args],
        capture_output=True,
        text=True,
        cwd=REPO,
    )


@pytest.fixture(scope="module")
def mod():
    spec = importlib.util.spec_from_file_location("pallas_lint", LINT)
    m = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(m)
    return m


def test_self_test_passes():
    p = run_lint("--self-test")
    assert p.returncode == 0, p.stdout + p.stderr
    assert "FAIL" not in p.stdout


def test_repo_lints_clean():
    p = run_lint("--json")
    assert p.returncode == 0, p.stdout + p.stderr
    data = json.loads(p.stdout)
    assert data["findings"] == []
    assert data["checked_files"] > 50


def test_bad_file_fails_with_finding(tmp_path):
    bad = tmp_path / "bad.rs"
    bad.write_text(
        "fn f(xs: &mut [f64]) {\n"
        "    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n"
        "}\n"
    )
    p = run_lint("--json", str(bad))
    assert p.returncode == 1
    data = json.loads(p.stdout)
    assert [f["rule"] for f in data["findings"]] == ["no-float-partial-cmp"]
    assert data["findings"][0]["line"] == 2


def test_list_rules_names_all_eight():
    p = run_lint("--list-rules")
    assert p.returncode == 0
    for rule in [
        "no-hot-path-panic",
        "no-float-partial-cmp",
        "oracle-purity",
        "no-relaxed-cancel",
        "no-lossy-as",
        "scoped-threads-only",
        "result-not-panic-api",
        "no-unbounded-send",
        "unused-waiver",
        "waiver-syntax",
    ]:
        assert rule in p.stdout, f"{rule} missing from --list-rules"


def test_unbounded_send_flagged_in_serving_stack_only(mod):
    src = "pub fn f() { let (_t, _r) = mpsc::channel::<i32>(); }\n"
    in_serve = mod.lint_text("rust/src/serve/server.rs", src)
    assert [f.rule for f in in_serve] == ["no-unbounded-send"]
    bounded = src.replace("mpsc::channel::<i32>()",
                          "mpsc::sync_channel::<i32>(8)")
    assert mod.lint_text("rust/src/serve/server.rs", bounded) == []
    # the serving stack is the scope: quant/ code is untouched
    assert mod.lint_text("rust/src/quant/kernels.rs", src) == []


# ---- direct-import unit coverage ----------------------------------------


def test_lexer_scrubs_strings_and_comments(mod):
    lexed = mod.lex(
        's = ".unwrap()"; // comment .expect(\n'
        '/* block /* nested */ partial_cmp */ let x = 1;\n'
        "let r = r#\"thread::spawn\"#;\n"
    )
    joined = "\n".join(lexed.lines)
    assert ".unwrap()" not in joined
    assert ".expect(" not in joined
    assert "partial_cmp" not in joined
    assert "thread::spawn" not in joined
    assert "let x = 1;" in joined
    # the line comment was captured for waiver parsing
    assert any("comment" in text for _, text in lexed.comments)


def test_lexer_char_literals_vs_lifetimes(mod):
    lexed = mod.lex("let a: &'static str = x; let q = '\\''; let z = 'y';")
    line = lexed.lines[0]
    assert "'static" in line  # lifetime kept as code
    assert "'y'" not in line  # char literal scrubbed


def test_cfg_test_spans_exempt_test_code(mod):
    text = (
        "fn hot(xs: &[u32]) -> u32 { xs[0] }\n"
        "#[cfg(test)]\n"
        "mod tests {\n"
        "    fn helper(xs: &[u32]) -> u32 { xs[1] }\n"
        "}\n"
    )
    findings = mod.lint_text("rust/src/engine/scheduler.rs", text)
    assert [(f.rule, f.line) for f in findings] == [("no-hot-path-panic", 1)]


def test_waiver_requires_reason_and_is_tracked(mod):
    waived = (
        "fn f(xs: &[u32]) -> u32 {\n"
        "    // pallas-lint: allow(no-hot-path-panic) — caller checks bounds\n"
        "    xs[0]\n"
        "}\n"
    )
    assert mod.lint_text("rust/src/engine/scheduler.rs", waived) == []
    unused = "fn f() -> u32 { 1 } // pallas-lint: allow(no-hot-path-panic) — nope\n"
    rules = [f.rule for f in mod.lint_text("rust/src/engine/scheduler.rs", unused)]
    assert rules == ["unused-waiver"]
