"""Pallas kernel for Double Quantization (paper section 3).

Dequantizes the *quantization constants*: c2 was mean-centered and
FP8-E4M3 block-quantized (block 256) with second-level constants c1.
This kernel recovers c2; composing it with ``nf4.dequantize_blockwise_pallas``
implements doubleDequant of paper Eq. 6 (composition is tested against
``ref.double_dequant_weight``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dq_kernel(codes2_ref, absmax2_ref, mean_ref, cb_ref, out_ref):
    codes = codes2_ref[...].astype(jnp.int32)          # (R, block2)
    cb = cb_ref[...]                                   # (255,) fp8-e4m3
    vals = cb[codes] * absmax2_ref[...][:, None]
    out_ref[...] = vals + mean_ref[0]


def double_dequantize_pallas(codes2: jnp.ndarray, absmax2: jnp.ndarray,
                             mean: jnp.ndarray, cb8: jnp.ndarray,
                             block2: int = 256,
                             rows_per_program: int = 4) -> jnp.ndarray:
    """Pallas twin of ref.double_dequantize. mean is a shape-(1,) array."""
    n = codes2.shape[0]
    assert n % block2 == 0
    nb = n // block2
    r = min(rows_per_program, nb)
    while nb % r != 0:
        r -= 1
    grid = (nb // r,)
    out = pl.pallas_call(
        _dq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, block2), lambda i: (i, 0)),
            pl.BlockSpec((r,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((cb8.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((r, block2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block2), jnp.float32),
        interpret=True,
    )(codes2.reshape(nb, block2), absmax2, mean.reshape(1), cb8)
    return out.reshape(-1)
