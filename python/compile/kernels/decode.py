"""L1: KV-cache decode primitives for the incremental generation path.

The serving engine (rust/src/engine/decode.rs) decodes one token per step
against per-row key/value caches instead of re-running the full-sequence
forward. These helpers define the **cache contract** shared by the prefill
and decode-step graphs (`model.make_prefill` / `model.make_decode_step`):

* cache layout: ``(batch, n_layers, seq_len, d_model)`` float32, keys and
  values stacked per layer with heads flattened into the last axis. A row
  is contiguous in ``(layer, position)`` so one batch row is one slab.
* position ``p`` of a row is written exactly once per decoded token (by
  ``update_cache`` at ``pos == p``) and read by every later step's
  attention; positions ``> pos`` are masked out, so stale slots from a
  previous request in the same row are never observed.

All math mirrors the full-sequence graph in `model.py` operation for
operation (same RoPE frequencies, same ``-1e30`` causal mask, same
softmax), so greedy decoding through the cached path reproduces the
full-recompute tokens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_at(x: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """RoPE for a single position per batch row.

    ``x`` is ``(B, H, Dh)`` — one token's heads — and ``pos`` is ``(B,)``
    int32. Identical to row ``pos[b]`` of `model.rope` applied to a full
    ``(B, T, H, Dh)`` tensor: same frequency table, same rotate-half
    pairing.
    """
    _, _, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half) / half))       # (half,)
    theta = pos.astype(jnp.float32)[:, None] * freqs[None, :]     # (B, half)
    cos = jnp.cos(theta)[:, None, :]                              # (B, 1, half)
    sin = jnp.sin(theta)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def update_cache(cache: jnp.ndarray, new: jnp.ndarray,
                 pos: jnp.ndarray) -> jnp.ndarray:
    """Write ``new`` ``(B, D)`` into ``cache`` ``(B, S, D)`` at per-row
    position ``pos`` ``(B,)``.

    A one-hot select rather than a scatter: every row writes exactly its
    own position, rows at different positions coexist in one call (the
    continuous-batching case).
    """
    s = cache.shape[1]
    onehot = jnp.arange(s)[None, :] == pos[:, None]               # (B, S)
    return jnp.where(onehot[:, :, None], new[:, None, :], cache)


def cached_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """One-token causal attention against a row's cache.

    ``q`` is ``(B, H, Dh)`` (already rotated), caches are ``(B, S, H*Dh)``
    and ``pos`` ``(B,)`` is the query's position: key positions
    ``j <= pos[b]`` participate, the rest are masked to ``-1e30`` exactly
    as the full-sequence graph masks its causal triangle. Returns the
    context ``(B, H*Dh)``.
    """
    b, h, dh = q.shape
    s = k_cache.shape[1]
    k = k_cache.reshape(b, s, h, dh)
    v = v_cache.reshape(b, s, h, dh)
    att = jnp.einsum("bhd,bkhd->bhk", q, k) / jnp.sqrt(dh)
    valid = jnp.arange(s)[None, :] <= pos[:, None]                # (B, S)
    att = jnp.where(valid[:, None, :], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    ctx = jnp.einsum("bhk,bkhd->bhd", att, v)
    return ctx.reshape(b, h * dh)
