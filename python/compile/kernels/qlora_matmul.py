"""Fused QLoRA linear as a Pallas kernel (paper Eq. 5).

    Y = X dequant(codes, absmax)  +  s (X L1) L2

This is the per-step hot path of QLoRA finetuning: the frozen base weight
is *stored* 4-bit and dequantized tile-at-a-time on the fly, never
materialized in full precision in HBM. The CUDA original (bitsandbytes)
fuses dequant into the GEMM epilogue per threadblock; the TPU rethink
(DESIGN.md section Hardware-Adaptation) makes the dequantized weight tile a
VMEM scratch value feeding the MXU:

  grid (M/TM, O/TO); per program:
    VMEM: x tile (TM, K) f32, codes tile (TO, K) u8, absmax (TO, K/64) f32,
          codebook (16,), L1 (K, r), L2 tile (r, TO)
    w_t = cb[codes] * absmax.repeat(64)          # VPU gather + mul
    acc = x @ w_t.T + s * ((x @ L1) @ L2)        # MXU, f32 accumulate

VMEM for TM=TO=128, K=4096, r=64: 128*4096*4 (x) + 128*4096 (codes)
+ 128*64*4 (absmax) + 4096*64*4 (L1) ~= 3.7 MiB -- fits the ~16 MiB VMEM
budget with double-buffering; MXU utilization is bounded by the dequant
VPU pass at ~K/64 fused multiply-selects per MAC column (estimates in
EXPERIMENTS.md section Perf).

The weight layout matches ref.quantize_weight: codes are W^T (O, K),
absmax blocks run along K. Validated against ref.qlora_linear.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qlora_kernel(s, block, x_ref, codes_ref, absmax_ref, cb_ref,
                  a_ref, b_ref, out_ref):
    x = x_ref[...]                                  # (TM, K)
    codes = codes_ref[...].astype(jnp.int32)        # (TO, K)
    cb = cb_ref[...]
    absmax = absmax_ref[...]                        # (TO, K/block)
    scales = jnp.repeat(absmax, block, axis=1)      # (TO, K)
    w_t = cb[codes] * scales                        # dequantized W^T tile
    base = jnp.dot(x, w_t.T)                        # MXU
    lora = jnp.dot(jnp.dot(x, a_ref[...]), b_ref[...])
    out_ref[...] = base + s * lora


def qlora_matmul_pallas(x: jnp.ndarray, codes: jnp.ndarray,
                        absmax: jnp.ndarray, cb: jnp.ndarray,
                        a: jnp.ndarray, b: jnp.ndarray, s: float,
                        block: int = 64, tm: int = 32,
                        to: int = 32) -> jnp.ndarray:
    """Fused dequant-matmul-plus-LoRA.

    x: (M, K) f32; codes: (O, K) uint8 (unpacked W^T codes); absmax:
    (O, K/block) f32; a: (K, r); b: (r, O). Returns (M, O) f32.
    """
    m, k = x.shape
    o = codes.shape[0]
    assert codes.shape[1] == k and absmax.shape == (o, k // block)
    tm = min(tm, m)
    while m % tm != 0:
        tm -= 1
    to = min(to, o)
    while o % to != 0:
        to -= 1
    r = a.shape[1]
    grid = (m // tm, o // to)
    kern = functools.partial(_qlora_kernel, s, block)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((to, k), lambda i, j: (j, 0)),
            pl.BlockSpec((to, k // block), lambda i, j: (j, 0)),
            pl.BlockSpec((cb.shape[0],), lambda i, j: (0,)),
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, to), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, to), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, o), jnp.float32),
        interpret=True,
    )(x, codes, absmax, cb, a, b)
