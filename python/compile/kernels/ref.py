"""Pure-jnp reference oracle for every L1 kernel.

This module is the single source of truth for the numerics of the QLoRA
quantization stack:

  * codebook construction: NF4 (paper Appendix E / Eq. 4), generic FP-k
    (E2M1, E3M0, E4M3), symmetric Int4/Int8,
  * block-wise absmax quantize / dequantize (paper Eq. 1-2, Background),
  * Double Quantization of the quantization constants (paper section 3),
  * the fused QLoRA linear:  Y = X dd(W) + s (X L1) L2   (paper Eq. 5-6).

The Pallas kernels in this package are tested `allclose` against these
functions, and the Rust `quant` crate is tested *bit-for-bit* against the
golden vectors `aot.py` emits from these functions.

Layout convention (shared with the Rust side): a weight ``W`` of shape
``(h, o)`` is stored transposed, flattened row-major as ``W^T.reshape(-1)``
so that each quantization block of 64 values is contiguous along the
reduction dimension ``h`` for a fixed output unit. See DESIGN.md section 4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri

# --------------------------------------------------------------------------
# Codebooks
# --------------------------------------------------------------------------

# Exact NF4 values from the paper, Appendix E. Used only as a golden test
# target; the code below *derives* them.
NF4_PAPER = [
    -1.0, -0.6961928009986877, -0.5250730514526367,
    -0.39491748809814453, -0.28444138169288635, -0.18477343022823334,
    -0.09105003625154495, 0.0, 0.07958029955625534, 0.16093020141124725,
    0.24611230194568634, 0.33791524171829224, 0.44070982933044434,
    0.5626170039176941, 0.7229568362236023, 1.0,
]

_NF4_OFFSET = 0.9677083  # bitsandbytes create_normal_map offset


def nf4_codebook(offset: float = _NF4_OFFSET) -> jnp.ndarray:
    """Derive the 16-value NF4 codebook (paper section 3, Eq. 4).

    Asymmetric construction: 2^{k-1} quantiles for the negative half,
    2^{k-1}+1 for the positive half, unify and drop the duplicate zero,
    normalize into [-1, 1]. Information-theoretically optimal for
    zero-centered normal data under block absmax scaling.
    """
    # positive side: 8 quantiles of N(0,1) on [0.5, offset]
    pos_p = jnp.linspace(offset, 0.5, 9)[:-1]
    pos = ndtri(pos_p)
    # negative side: 7 quantiles on [1-offset, 0.5] (via symmetry)
    neg_p = jnp.linspace(offset, 0.5, 8)[:-1]
    neg = -ndtri(neg_p)
    vals = jnp.concatenate([neg, jnp.zeros((1,)), pos])
    vals = jnp.sort(vals)
    return (vals / jnp.max(jnp.abs(vals))).astype(jnp.float32)


def fp_codebook(ebits: int, mbits: int, signed: bool = True) -> jnp.ndarray:
    """Generic k-bit float codebook, normalized to max |value| == 1.

    Values for exponent field e, mantissa field m with bias 2^{E-1}-1:
      e > 0 : 2^{e-bias} (1 + m / 2^M)        (normal)
      e == 0: 2^{1-bias} (m / 2^M)            (subnormal, includes 0)

    FP4-E2M1 -> magnitudes {0, .5, 1, 1.5, 2, 3, 4, 6}/6 (Table 2),
    FP4-E3M0 -> magnitudes {0, 2^-2..2^4}/16 (Table 2),
    FP8-E4M3 -> the Double Quantization codebook (section 3).
    """
    bias = 2 ** (ebits - 1) - 1
    mags = []
    for e in range(2 ** ebits):
        for m in range(2 ** mbits):
            if e == 0:
                v = 2.0 ** (1 - bias) * (m / 2.0 ** mbits)
            else:
                v = 2.0 ** (e - bias) * (1.0 + m / 2.0 ** mbits)
            mags.append(v)
    mags = sorted(set(mags))
    mx = mags[-1]
    mags = [m / mx for m in mags]
    if signed:
        vals = sorted(set([-m for m in mags] + mags))
    else:
        vals = mags
    return jnp.array(vals, dtype=jnp.float32)


def fp4_e2m1_codebook() -> jnp.ndarray:
    return fp_codebook(2, 1)


def fp4_e3m0_codebook() -> jnp.ndarray:
    return fp_codebook(3, 0)


def fp8_e4m3_codebook() -> jnp.ndarray:
    return fp_codebook(4, 3)


def int_codebook(bits: int) -> jnp.ndarray:
    """Symmetric integer codebook {-(2^{b-1}-1) .. 2^{b-1}-1} / (2^{b-1}-1).

    Zero is exactly representable (paper: required for padding)."""
    half = 2 ** (bits - 1) - 1
    return (jnp.arange(-half, half + 1, dtype=jnp.float32) / half)


def nf4_paper_codebook() -> jnp.ndarray:
    """The canonical NF4 codebook: the paper's exact Appendix E constants.

    `nf4_codebook()` (the derivation) reproduces these to ~1 f32 ulp; using
    the published constants as the canonical table makes the Python and
    Rust implementations bit-identical (see rust/src/quant/nf4.rs)."""
    return jnp.array(NF4_PAPER, dtype=jnp.float32)


CODEBOOKS = {
    "nf4": nf4_paper_codebook,
    "fp4_e2m1": fp4_e2m1_codebook,
    "fp4_e3m0": fp4_e3m0_codebook,
    "fp8_e4m3": fp8_e4m3_codebook,
    "int4": lambda: int_codebook(4),
    "int8": lambda: int_codebook(8),
}


def codebook(name: str) -> jnp.ndarray:
    return CODEBOOKS[name]()


# --------------------------------------------------------------------------
# Nearest-code assignment + block-wise absmax quantization (Eq. 1-2)
# --------------------------------------------------------------------------

def nearest_code(xn: jnp.ndarray, cb: jnp.ndarray) -> jnp.ndarray:
    """Index of the nearest codebook entry for each normalized value.

    cb must be sorted ascending. Round-to-nearest via bin midpoints, which
    for ties prefers the *upper* code (matches the Rust implementation).
    Returns uint8 indices.
    """
    mids = (cb[1:] + cb[:-1]) * 0.5
    idx = jnp.sum(xn[..., None] >= mids, axis=-1)
    return idx.astype(jnp.uint8)


def quantize_blockwise(x: jnp.ndarray, cb: jnp.ndarray, block: int = 64):
    """Block-wise absmax quantize a flat tensor (paper Background, Eq. 1).

    x: flat f32 array, length divisible by `block`.
    Returns (codes uint8 [n], absmax f32 [n/block]).
    """
    n = x.shape[0]
    assert n % block == 0, f"length {n} not divisible by block {block}"
    blocks = x.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    xn = blocks / scale[:, None]
    codes = nearest_code(xn, cb)
    return codes.reshape(-1), absmax.astype(jnp.float32)


def dequantize_blockwise(codes: jnp.ndarray, absmax: jnp.ndarray,
                         cb: jnp.ndarray, block: int = 64) -> jnp.ndarray:
    """Inverse of quantize_blockwise (paper Eq. 2)."""
    vals = cb[codes.astype(jnp.int32)].reshape(-1, block)
    return (vals * absmax[:, None]).reshape(-1)


# --------------------------------------------------------------------------
# Double Quantization (paper section 3)
# --------------------------------------------------------------------------

def double_quantize(absmax: jnp.ndarray, block2: int = 256):
    """Quantize the quantization constants c2 (section 3, 'Double Quantization').

    The c2 are positive, so we subtract their mean and use symmetric FP8-E4M3
    quantization with blocksize `block2`. Returns
    (codes2 uint8 [nb], absmax2 f32 [nb/block2], mean f32 scalar).

    Memory: 32/64 bits/param -> 8/64 + 32/(64*256) = 0.127 bits/param,
    saving 0.373 bits/param (verified in tests and in the Rust memory model).

    If len(absmax) is not a multiple of block2, the input is padded with
    its mean (centered value 0 has an exact FP8 code, so padding is
    lossless); `double_dequantize` slices the pad back off given `n`.
    The Rust implementation mirrors this convention exactly.
    """
    mean = jnp.mean(absmax)
    n = absmax.shape[0]
    pad = (-n) % block2
    if pad:
        absmax = jnp.concatenate([absmax, jnp.full((pad,), mean)])
    centered = absmax - mean
    cb = fp8_e4m3_codebook()
    codes2, absmax2 = quantize_blockwise(centered, cb, block2)
    return codes2, absmax2, mean.astype(jnp.float32)


def double_dequantize(codes2: jnp.ndarray, absmax2: jnp.ndarray,
                      mean: jnp.ndarray, block2: int = 256,
                      n: int | None = None) -> jnp.ndarray:
    """Recover (approximate) absmax constants c2 from their quantized form.

    n: original (pre-padding) number of constants; defaults to full length."""
    cb = fp8_e4m3_codebook()
    centered = dequantize_blockwise(codes2, absmax2, cb, block2)
    out = centered + mean
    return out if n is None else out[:n]


def double_dequant_weight(codes: jnp.ndarray, codes2: jnp.ndarray,
                          absmax2: jnp.ndarray, mean: jnp.ndarray,
                          cb: jnp.ndarray, block: int = 64,
                          block2: int = 256) -> jnp.ndarray:
    """doubleDequant(c1, c2, W) of paper Eq. 6: flat dequantized weight."""
    nb = codes.shape[0] // block
    absmax = double_dequantize(codes2, absmax2, mean, block2, n=nb)
    return dequantize_blockwise(codes, absmax, cb, block)


# --------------------------------------------------------------------------
# Nibble packing (storage format; 2 NF4 codes per byte)
# --------------------------------------------------------------------------

def pack_nibbles(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack uint8 codes in [0,16) pairwise: byte = lo | hi << 4."""
    assert codes.shape[0] % 2 == 0
    pairs = codes.reshape(-1, 2).astype(jnp.uint8)
    return (pairs[:, 0] | (pairs[:, 1] << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jnp.ndarray) -> jnp.ndarray:
    lo = packed & 0xF
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=1).reshape(-1).astype(jnp.uint8)


# --------------------------------------------------------------------------
# Quantized-weight container + the QLoRA linear (Eq. 5)
# --------------------------------------------------------------------------

def quantize_weight(w: jnp.ndarray, dtype: str = "nf4", block: int = 64,
                    block2: int = 256, double_quant: bool = True):
    """Quantize a (h, o) weight into the shared storage layout.

    Returns a dict of arrays (the cross-boundary representation):
      packed   uint8 [h*o/2]   packed codes of W^T row-major flat
               (for 8-bit codebooks, unpacked codes uint8 [h*o])
      codes2   uint8 [nb]      DQ'd absmax codes      (if double_quant)
      absmax2  f32  [nb/256]   second-level constants (if double_quant)
      mean     f32  []         absmax mean            (if double_quant)
      absmax   f32  [nb]       raw absmax             (if not double_quant)
    """
    h, o = w.shape
    flat = w.T.reshape(-1)
    cb = codebook(dtype)
    codes, absmax = quantize_blockwise(flat, cb, block)
    out = {"packed": pack_nibbles(codes) if cb.shape[0] <= 16 else codes}
    if double_quant:
        codes2, absmax2, mean = double_quantize(absmax, block2)
        out.update(codes2=codes2, absmax2=absmax2, mean=mean)
    else:
        out["absmax"] = absmax
    return out


def dequantize_weight(q: dict, shape, dtype: str = "nf4", block: int = 64,
                      block2: int = 256) -> jnp.ndarray:
    """Inverse of quantize_weight: returns W of shape (h, o), f32."""
    h, o = shape
    cb = codebook(dtype)
    codes = unpack_nibbles(q["packed"]) if cb.shape[0] <= 16 else q["packed"]
    if "codes2" in q:
        flat = double_dequant_weight(codes, q["codes2"], q["absmax2"],
                                     q["mean"], cb, block, block2)
    else:
        flat = dequantize_blockwise(codes, q["absmax"], cb, block)
    return flat.reshape(o, h).T


def qlora_linear(x: jnp.ndarray, q: dict, a: jnp.ndarray, b: jnp.ndarray,
                 s: float, shape, dtype: str = "nf4", block: int = 64,
                 block2: int = 256) -> jnp.ndarray:
    """Paper Eq. 5:  Y = X doubleDequant(c1, c2, W) + s (X L1) L2.

    x: (..., h); a: (h, r); b: (r, o). Compute dtype f32 here (the paper's
    BF16 compute dtype is a GPU tensor-core choice; see DESIGN.md
    section Hardware-Adaptation).
    """
    w = dequantize_weight(q, shape, dtype, block, block2)
    return x @ w + s * ((x @ a) @ b)


# --------------------------------------------------------------------------
# Quantization-error metrics (drives Table 2 / Figure 3 calibration)
# --------------------------------------------------------------------------

def quant_error(x: jnp.ndarray, dtype: str, block: int = 64,
                double_quant: bool = False, block2: int = 256):
    """Round-trip a flat tensor, return (mse, mae, sqnr_db)."""
    cb = codebook(dtype)
    codes, absmax = quantize_blockwise(x, cb, block)
    if double_quant:
        codes2, absmax2, mean = double_quantize(absmax, block2)
        xq = double_dequant_weight(codes, codes2, absmax2, mean, cb,
                                   block, block2)
    else:
        xq = dequantize_blockwise(codes, absmax, cb, block)
    err = x - xq
    mse = jnp.mean(err * err)
    mae = jnp.mean(jnp.abs(err))
    power = jnp.mean(x * x)
    sqnr = 10.0 * jnp.log10(power / jnp.maximum(mse, 1e-30))
    return mse, mae, sqnr
