"""Pallas kernels for block-wise k-bit quantization (NF4/FP4/Int4).

These are the L1 compute hot-spots of the paper: quantize-on-load and
dequantize-on-use of block-wise absmax-scaled codebook datatypes
(paper section 3). Kernels run under ``interpret=True`` — the CPU PJRT
client cannot execute Mosaic custom-calls — and are validated against
``ref.py`` in ``python/tests/``.

TPU mapping (DESIGN.md section Hardware-Adaptation): the quantization
block (64) is *not* the kernel tile. Each program instance owns
``rows_per_program`` quantization blocks laid out as a (R, 64) VMEM tile
(R*64*4 B activations + R*64 B codes), the 16-entry codebook lives in
VMEM and the lookup is a VPU-vectorized gather; absmax is a lane-wise
max-reduce. No MXU involvement for pure (de)quantization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, cb_ref, codes_ref, absmax_ref):
    """One program: R quantization blocks -> codes + absmax."""
    x = x_ref[...]                       # (R, block) f32
    cb = cb_ref[...]                     # (n_codes,) f32
    absmax = jnp.max(jnp.abs(x), axis=1)             # (R,)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    xn = x / scale[:, None]
    mids = (cb[1:] + cb[:-1]) * 0.5
    # round-to-nearest via midpoint comparison; ties -> upper code
    idx = jnp.sum(xn[..., None] >= mids[None, None, :], axis=-1)
    codes_ref[...] = idx.astype(jnp.uint8)
    absmax_ref[...] = absmax.astype(jnp.float32)


def quantize_blockwise_pallas(x: jnp.ndarray, cb: jnp.ndarray,
                              block: int = 64, rows_per_program: int = 8):
    """Block-wise absmax quantize; pallas twin of ref.quantize_blockwise.

    x: flat f32, length divisible by block*rows_per_program after padding
    (caller guarantees divisibility by block; we pad rows internally).
    Returns (codes uint8 [n], absmax f32 [n/block]).
    """
    n = x.shape[0]
    assert n % block == 0
    nb = n // block
    r = min(rows_per_program, nb)
    while nb % r != 0:
        r -= 1
    grid = (nb // r,)
    xb = x.reshape(nb, block)
    codes, absmax = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, block), lambda i: (i, 0)),
            pl.BlockSpec((cb.shape[0],), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((r, block), lambda i: (i, 0)),
            pl.BlockSpec((r,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.uint8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=True,
    )(xb, cb)
    return codes.reshape(-1), absmax


def _dequantize_kernel(codes_ref, absmax_ref, cb_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)          # (R, block)
    cb = cb_ref[...]
    vals = cb[codes]                                  # VPU gather
    out_ref[...] = vals * absmax_ref[...][:, None]


def dequantize_blockwise_pallas(codes: jnp.ndarray, absmax: jnp.ndarray,
                                cb: jnp.ndarray, block: int = 64,
                                rows_per_program: int = 8) -> jnp.ndarray:
    """Pallas twin of ref.dequantize_blockwise."""
    n = codes.shape[0]
    assert n % block == 0
    nb = n // block
    r = min(rows_per_program, nb)
    while nb % r != 0:
        r -= 1
    grid = (nb // r,)
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, block), lambda i: (i, 0)),
            pl.BlockSpec((r,), lambda i: (i,)),
            pl.BlockSpec((cb.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((r, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=True,
    )(codes.reshape(nb, block), absmax, cb)
    return out.reshape(-1)
