"""AOT compile path: lower every model config to HLO text + init tensors.

Python runs ONCE (`make artifacts`); the Rust coordinator then loads
`artifacts/*.hlo.txt` via PJRT and owns the training loop. Interchange is
HLO **text** — the image's xla_extension 0.5.1 rejects jax>=0.5 serialized
HloModuleProto (64-bit instruction ids); the text parser reassigns ids.
See /opt/xla-example/README.md.

Per config `<name>` this emits:
    <name>.train.hlo.txt   train_step (fwd+bwd+Adam) as one fused graph
    <name>.eval.hlo.txt    (loss, token-accuracy) on a batch
    <name>.fwd.hlo.txt     logits, for generation       (e2e config only)
    <name>.prefill.hlo.txt full forward that also fills the KV cache
                           (pass-through rows for continuous batching)
    <name>.decode.hlo.txt  O(1)-per-token KV-cached decode step
    <name>.init.tensors    state leaves ++ frozen leaves (ordered)
plus once:
    manifest.json          artifact index w/ I/O signatures (Rust reads this)
    golden.tensors         quantization golden vectors (Rust bit-exactness)
    kernel_nf4_dequant.hlo.txt, kernel_qlora_matmul.hlo.txt
                           standalone Pallas kernels lowered to HLO
                           (quickstart proves pallas->HLO->PJRT end-to-end)
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, tensorio
from .configs import ModelConfig
from .kernels import ref, nf4, qlora_matmul


# --------------------------------------------------------------------------
# Lowering helpers
# --------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # CRITICAL: the default printer ELIDES large constants ("constant({...})"),
    # which the 0.5.1 text parser silently reads back as zeros — in-graph
    # codebooks / causal masks / RoPE tables would be destroyed. Print full.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # the new printer's metadata attrs (source_end_line, ...) are rejected
    # by the 0.5.1 text parser
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "{...}" not in text, "elided constants survived printing"
    return text


def flatten_named(tree, prefix: str):
    """Flatten a pytree into (name, leaf) pairs; names from tree paths.

    Order is jax's deterministic flatten order (dict keys sorted), which is
    also the HLO parameter order when the tree is passed positionally.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = prefix + jax.tree_util.keystr(path)
        out.append((name, np.asarray(leaf)))
    return out


def tensor_sig(pairs):
    return [{"name": n, "dtype": tensorio.dtype_name(a), "shape": list(a.shape)}
            for n, a in pairs]


# --------------------------------------------------------------------------
# Per-config artifact build
# --------------------------------------------------------------------------

def build_config(cfg: ModelConfig, outdir: str, emit_fwd: bool,
                 seed: int = 0) -> dict:
    """Lower train/eval(/fwd) graphs for one config, write init tensors,
    return its manifest entry."""
    full_ft = (not cfg.lora)
    key = jax.random.PRNGKey(seed)
    kb, kl = jax.random.split(key)

    base_fp = model.init_base_params(kb, cfg)
    lora = model.init_lora_params(kl, cfg)

    if full_ft:
        trainable = base_fp                      # quant must be "none"
        frozen = {"lora_stub": lora}
        n_lora = len(jax.tree_util.tree_leaves(base_fp))
    else:
        trainable = lora
        frozen = model.quantize_base(base_fp, cfg)
        n_lora = len(jax.tree_util.tree_leaves(lora))

    zeros = jax.tree_util.tree_map(jnp.zeros_like, trainable)
    step0 = jnp.zeros((), jnp.float32)

    # ---- state ordering: trainable ++ m ++ v ++ [step] ------------------
    state_pairs = (flatten_named(trainable, "trainable") +
                   flatten_named(zeros, "adam_m") +
                   flatten_named(zeros, "adam_v") +
                   [("step", np.zeros((), np.float32))])
    frozen_pairs = flatten_named(frozen, "frozen")

    tokens_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    mask_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.float32)

    train_step = model.make_train_step(cfg, full_ft)
    eval_step = model.make_eval_step(cfg, full_ft)

    def train_wrapped(trainable, m, v, step, frozen, tokens, mask):
        new_t, new_m, new_v, new_step, loss = train_step(
            trainable, m, v, step, frozen, tokens, mask)
        return new_t, new_m, new_v, new_step, loss

    lowered = jax.jit(train_wrapped).lower(
        trainable, zeros, zeros, step0, frozen, tokens_spec, mask_spec)
    hlo_train = to_hlo_text(lowered)
    with open(os.path.join(outdir, f"{cfg.name}.train.hlo.txt"), "w") as f:
        f.write(hlo_train)

    lowered_e = jax.jit(eval_step).lower(trainable, frozen, tokens_spec,
                                         mask_spec)
    with open(os.path.join(outdir, f"{cfg.name}.eval.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_e))

    entry = {
        "name": cfg.name,
        "config": cfg.to_dict(),
        "train_hlo": f"{cfg.name}.train.hlo.txt",
        "eval_hlo": f"{cfg.name}.eval.hlo.txt",
        "init": f"{cfg.name}.init.tensors",
        "n_state": len(state_pairs),
        "n_trainable": n_lora,
        "n_frozen": len(frozen_pairs),
        "state_sig": tensor_sig(state_pairs),
        "frozen_sig": tensor_sig(frozen_pairs),
        "data_sig": [
            {"name": "tokens", "dtype": "i32",
             "shape": [cfg.batch, cfg.seq_len]},
            {"name": "loss_mask", "dtype": "f32",
             "shape": [cfg.batch, cfg.seq_len]},
        ],
        # train outputs: new state (same sig as state) ++ [loss]
        # eval inputs: first n_trainable state tensors ++ frozen ++ data
        # eval outputs: [loss, acc]
    }

    if emit_fwd:
        fwd = model.make_forward(cfg, full_ft)
        lowered_f = jax.jit(fwd).lower(trainable, frozen, tokens_spec)
        with open(os.path.join(outdir, f"{cfg.name}.fwd.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered_f))
        entry["fwd_hlo"] = f"{cfg.name}.fwd.hlo.txt"

        # KV-cached decode path: a prefill graph (full forward that also
        # fills the cache, pass-through for unmasked rows) and an
        # O(1)-per-token decode-step graph. Cache layout (B, L, S, D) —
        # see python/compile/kernels/decode.py.
        cache_shape = (cfg.batch, cfg.n_layers, cfg.seq_len, cfg.d_model)
        cache_spec = jax.ShapeDtypeStruct(cache_shape, jnp.float32)
        row_spec = jax.ShapeDtypeStruct((cfg.batch,), jnp.float32)
        tok1_spec = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
        pos_spec = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)

        lowered_p = jax.jit(model.make_prefill(cfg, full_ft)).lower(
            trainable, frozen, cache_spec, cache_spec, tokens_spec, row_spec)
        with open(os.path.join(outdir,
                               f"{cfg.name}.prefill.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered_p))
        entry["prefill_hlo"] = f"{cfg.name}.prefill.hlo.txt"

        lowered_d = jax.jit(model.make_decode_step(cfg, full_ft)).lower(
            trainable, frozen, cache_spec, cache_spec, tok1_spec, pos_spec)
        with open(os.path.join(outdir,
                               f"{cfg.name}.decode.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered_d))
        entry["decode_hlo"] = f"{cfg.name}.decode.hlo.txt"

        # prefill inputs: state[..n_trainable] ++ frozen ++ k ++ v ++
        #   tokens ++ row_mask; outputs: (logits, k, v)
        # decode inputs:  state[..n_trainable] ++ frozen ++ k ++ v ++
        #   token ++ pos; outputs: (logits, k, v)
        entry["cache_sig"] = [
            {"name": "k_cache", "dtype": "f32", "shape": list(cache_shape)},
            {"name": "v_cache", "dtype": "f32", "shape": list(cache_shape)},
        ]

    tensorio.write_tensors(os.path.join(outdir, f"{cfg.name}.init.tensors"),
                           state_pairs + frozen_pairs)
    return entry


# --------------------------------------------------------------------------
# Golden quantization vectors (Rust `quant` crate bit-exactness)
# --------------------------------------------------------------------------

def build_golden(outdir: str) -> list:
    """Emit input/expected pairs for every datatype the Rust side implements.

    Codes must match bit-for-bit; dequantized floats must match exactly
    (same f32 ops on both sides) — tests allow 0 ULP on codes, tiny atol on
    floats.
    """
    rng = np.random.default_rng(1234)
    cases = []
    pairs = []
    for dtype in ["nf4", "fp4_e2m1", "fp4_e3m0", "int4", "int8", "fp8_e4m3"]:
        cb = np.asarray(ref.codebook(dtype))
        pairs.append((f"codebook/{dtype}", cb.astype(np.float32)))
    for i, (dtype, n, block) in enumerate([
            ("nf4", 64 * 48, 64), ("nf4", 128 * 16, 128),
            ("fp4_e2m1", 64 * 32, 64), ("fp4_e3m0", 64 * 32, 64),
            ("int4", 64 * 32, 64), ("int8", 64 * 32, 64)]):
        x = rng.standard_normal(n).astype(np.float32)
        cb = ref.codebook(dtype)
        codes, absmax = ref.quantize_blockwise(jnp.asarray(x), cb, block)
        deq = ref.dequantize_blockwise(codes, absmax, cb, block)
        name = f"case{i}"
        pairs += [(f"{name}/input", x),
                  (f"{name}/codes", np.asarray(codes)),
                  (f"{name}/absmax", np.asarray(absmax)),
                  (f"{name}/dequant", np.asarray(deq))]
        cases.append({"name": name, "dtype": dtype, "block": block, "n": n})
    # double-quantization case
    x = rng.standard_normal(64 * 512).astype(np.float32)
    cb = ref.codebook("nf4")
    codes, absmax = ref.quantize_blockwise(jnp.asarray(x), cb, 64)
    c2, a2, mean = ref.double_quantize(absmax, 256)
    deq = ref.double_dequant_weight(codes, c2, a2, mean, cb, 64, 256)
    pairs += [("dq/input", x), ("dq/codes", np.asarray(codes)),
              ("dq/absmax", np.asarray(absmax)),
              ("dq/codes2", np.asarray(c2)), ("dq/absmax2", np.asarray(a2)),
              ("dq/mean", np.asarray(mean)), ("dq/dequant", np.asarray(deq))]
    cases.append({"name": "dq", "dtype": "nf4", "block": 64, "block2": 256,
                  "n": 64 * 512})
    tensorio.write_tensors(os.path.join(outdir, "golden.tensors"), pairs)
    return cases


# --------------------------------------------------------------------------
# Standalone Pallas kernel artifacts (quickstart)
# --------------------------------------------------------------------------

def build_kernel_artifacts(outdir: str) -> dict:
    """Lower the Pallas kernels themselves to HLO — the quickstart example
    loads these, proving the pallas(interpret) -> HLO -> PJRT path."""
    cb = ref.nf4_codebook()
    n, block = 64 * 16, 64

    def dequant_fn(codes, absmax):
        return (nf4.dequantize_blockwise_pallas(codes, absmax, cb, block),)

    codes_spec = jax.ShapeDtypeStruct((n,), jnp.uint8)
    absmax_spec = jax.ShapeDtypeStruct((n // block,), jnp.float32)
    low = jax.jit(dequant_fn).lower(codes_spec, absmax_spec)
    with open(os.path.join(outdir, "kernel_nf4_dequant.hlo.txt"), "w") as f:
        f.write(to_hlo_text(low))

    m, k, o, r = 16, 128, 64, 8

    def qmm_fn(x, codes, absmax, a, b):
        return (qlora_matmul.qlora_matmul_pallas(
            x, codes, absmax, cb, a, b, s=2.0, block=block),)

    low2 = jax.jit(qmm_fn).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((o, k), jnp.uint8),
        jax.ShapeDtypeStruct((o, k // block), jnp.float32),
        jax.ShapeDtypeStruct((k, r), jnp.float32),
        jax.ShapeDtypeStruct((r, o), jnp.float32))
    with open(os.path.join(outdir, "kernel_qlora_matmul.hlo.txt"), "w") as f:
        f.write(to_hlo_text(low2))

    # test vectors for the quickstart
    rng = np.random.default_rng(7)
    xflat = rng.standard_normal(n).astype(np.float32)
    codes, absmax = ref.quantize_blockwise(jnp.asarray(xflat), cb, block)
    expected = ref.dequantize_blockwise(codes, absmax, cb, block)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = (rng.standard_normal((k, o)) * 0.05).astype(np.float32)
    a = (rng.standard_normal((k, r)) * 0.05).astype(np.float32)
    b = (rng.standard_normal((r, o)) * 0.05).astype(np.float32)
    q = ref.quantize_weight(jnp.asarray(w), "nf4", block, double_quant=False)
    wcodes = np.asarray(ref.unpack_nibbles(q["packed"])).reshape(o, k)
    wabsmax = np.asarray(q["absmax"]).reshape(o, k // block)
    y = ref.qlora_linear(jnp.asarray(x), q, jnp.asarray(a), jnp.asarray(b),
                         2.0, (k, o), "nf4", block)
    tensorio.write_tensors(os.path.join(outdir, "kernel_vectors.tensors"), [
        ("dequant/codes", np.asarray(codes)),
        ("dequant/absmax", np.asarray(absmax)),
        ("dequant/expected", np.asarray(expected)),
        ("qmm/x", x), ("qmm/codes", wcodes), ("qmm/absmax", wabsmax),
        ("qmm/a", a), ("qmm/b", b), ("qmm/expected", np.asarray(y)),
    ])
    return {
        "nf4_dequant": {"hlo": "kernel_nf4_dequant.hlo.txt",
                        "n": n, "block": block},
        "qlora_matmul": {"hlo": "kernel_qlora_matmul.hlo.txt",
                         "m": m, "k": k, "o": o, "r": r, "s": 2.0,
                         "block": block},
        "vectors": "kernel_vectors.tensors",
    }


# --------------------------------------------------------------------------
# main
# --------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated config names (default: all)")
    ap.add_argument("--large", action="store_true",
                    help="also build large_configs() (slow)")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    cfgs = configs.named_configs()
    if args.large:
        cfgs += configs.large_configs()
    if args.only:
        keep = set(args.only.split(","))
        cfgs = [c for c in cfgs if c.name in keep]

    # --only merges into an existing manifest instead of clobbering it
    manifest = {"artifacts": [], "golden": None, "kernels": None}
    man_path = os.path.join(outdir, "manifest.json")
    if args.only and os.path.exists(man_path):
        with open(man_path) as f:
            manifest = json.load(f)

    for cfg in cfgs:
        print(f"[aot] lowering {cfg.name} "
              f"({cfg.n_params():,} params, quant={cfg.quant}, "
              f"lora={cfg.lora_scope if cfg.lora else 'OFF'})", flush=True)
        emit_fwd = cfg.name.startswith("e2e")
        entry = build_config(cfg, outdir, emit_fwd)
        manifest["artifacts"] = [
            a for a in manifest["artifacts"] if a["name"] != cfg.name
        ] + [entry]

    if not args.only or manifest.get("golden") is None:
        print("[aot] golden quantization vectors", flush=True)
        manifest["golden"] = {"tensors": "golden.tensors",
                              "cases": build_golden(outdir)}
        print("[aot] standalone pallas kernel artifacts", flush=True)
        manifest["kernels"] = build_kernel_artifacts(outdir)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['artifacts'])} configs -> {outdir}")


if __name__ == "__main__":
    main()
