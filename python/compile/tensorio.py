"""`.tensors` binary interchange format (python writer; Rust reader/writer).

Layout:
    magic  b"QLT1"
    u32 LE header_len
    header_len bytes of JSON: {"tensors": [{"name", "dtype", "shape",
                                            "offset", "nbytes"}, ...]}
    raw little-endian data section (offsets relative to its start)

dtypes: "f32" | "u8" | "i32". Scalars have shape [].
"""

from __future__ import annotations

import json
import struct
from typing import Dict, List, Sequence, Tuple

import numpy as np

MAGIC = b"QLT1"

_DTYPES = {"f32": np.float32, "u8": np.uint8, "i32": np.int32}
_NAMES = {np.dtype(np.float32): "f32", np.dtype(np.uint8): "u8",
          np.dtype(np.int32): "i32"}


def dtype_name(arr: np.ndarray) -> str:
    try:
        return _NAMES[arr.dtype]
    except KeyError:
        raise TypeError(f"unsupported dtype {arr.dtype}")


def write_tensors(path: str, tensors: Sequence[Tuple[str, np.ndarray]]):
    """Write named tensors; order is preserved (it matters: it is the HLO
    parameter order for artifact init files)."""
    entries = []
    offset = 0
    blobs = []
    for name, arr in tensors:
        arr = np.asarray(arr)
        if arr.ndim > 0:  # ascontiguousarray would promote 0-d to 1-d
            arr = np.ascontiguousarray(arr)
        nbytes = arr.nbytes
        entries.append({
            "name": name,
            "dtype": dtype_name(arr),
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": nbytes,
        })
        blobs.append(arr.tobytes())
        offset += nbytes
    header = json.dumps({"tensors": entries}).encode("utf-8")
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)


def read_tensors(path: str) -> List[Tuple[str, np.ndarray]]:
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == MAGIC, f"bad magic {magic!r} in {path}"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen).decode("utf-8"))
        data = f.read()
    out = []
    for e in header["tensors"]:
        dt = _DTYPES[e["dtype"]]
        arr = np.frombuffer(data, dtype=dt, count=int(np.prod(e["shape"], dtype=np.int64)) if e["shape"] else 1,
                            offset=e["offset"]).reshape(e["shape"])
        out.append((e["name"], arr))
    return out
