"""Model / finetuning configurations for the AOT compile path.

Every artifact the Rust coordinator can load is generated from one of the
named configs below. The `tiny_*` family drives the real-training
experiments (Figure 2 placement sweep, Figure 4 r sweep, Table 3 method
comparison, Table 10 loss-mask ablation); `e2e` is the end-to-end
finetuning driver model; `e2e_large`/`m100` exist for bigger machines
(this reproduction box is a single CPU core — see EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

# LoRA placement scopes (paper Figure 2 / Appendix A.1 search space).
SCOPE_QK = ("wq", "wk")
SCOPE_ATTN = ("wq", "wk", "wv", "wo")
SCOPE_FFN = ("wg", "wu", "wd")
SCOPE_ALL = ("wq", "wk", "wv", "wo", "wg", "wu", "wd")
SCOPE_ATTN_FFN_OUT = ("wq", "wk", "wv", "wo", "wd")

SCOPES = {
    "qk": SCOPE_QK,
    "attn": SCOPE_ATTN,
    "ffn": SCOPE_FFN,
    "all": SCOPE_ALL,
    "attn_ffn_out": SCOPE_ATTN_FFN_OUT,
}

PROJ_NAMES = ("wq", "wk", "wv", "wo", "wg", "wu", "wd")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 512
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 128
    seq_len: int = 64
    batch: int = 8
    # quantization of the frozen base (paper section 3)
    quant: str = "nf4"          # nf4 | fp4_e2m1 | fp4_e3m0 | int4 | int8 | none
    double_quant: bool = True
    block: int = 64
    block2: int = 256
    # LoRA (paper Eq. 3/5); lora=False + quant="none" => full finetuning
    lora: bool = True
    lora_r: int = 8
    lora_alpha: int = 16
    lora_scope: str = "all"
    # training
    lr: float = 2e-4
    adam_b1: float = 0.9
    adam_b2: float = 0.999      # paper Appendix B.2
    adam_eps: float = 1e-8
    max_grad_norm: float = 0.3  # paper Appendix B.2
    remat: bool = True          # per-layer gradient checkpointing [9]

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def scope(self) -> Tuple[str, ...]:
        return SCOPES[self.lora_scope]

    @property
    def lora_s(self) -> float:
        return self.lora_alpha / self.lora_r

    def proj_shape(self, proj: str) -> Tuple[int, int]:
        d, f = self.d_model, self.d_ff
        return {
            "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
            "wg": (d, f), "wu": (d, f), "wd": (f, d),
        }[proj]

    def n_params(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return v * d + self.n_layers * per_layer + d

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _tiny(name: str, **kw) -> ModelConfig:
    base = dict(vocab=256, d_model=64, n_layers=2, n_heads=4, d_ff=128,
                seq_len=48, batch=8, lora_r=8)
    base.update(kw)
    return ModelConfig(name=name, **base)


def named_configs() -> List[ModelConfig]:
    cfgs: List[ModelConfig] = []

    # --- Figure 2: LoRA placement sweep (which layers get adapters) ------
    for scope in SCOPES:
        cfgs.append(_tiny(f"tiny_scope_{scope}", lora_scope=scope))
    # 16-bit full-finetuning baseline for Figure 2 / Table 3
    cfgs.append(_tiny("tiny_fullft", quant="none", lora=False))

    # --- Figure 4: LoRA r sweep (r independent of performance) ----------
    for r in (1, 2, 4, 8, 16, 32):
        if r != 8:  # r=8 reuses tiny_scope_all
            cfgs.append(_tiny(f"tiny_r{r}", lora_r=r))

    # --- Table 3: datatype / method comparison ---------------------------
    cfgs.append(_tiny("tiny_lora16", quant="none"))                # LoRA BF16
    cfgs.append(_tiny("tiny_int8", quant="int8", double_quant=False))
    cfgs.append(_tiny("tiny_fp4", quant="fp4_e2m1", double_quant=False))
    cfgs.append(_tiny("tiny_nf4", quant="nf4", double_quant=False))
    # tiny_scope_all doubles as "QLoRA NF4 + DQ"

    # --- end-to-end driver (examples/finetune_guanaco.rs) ----------------
    cfgs.append(ModelConfig(
        name="e2e", vocab=512, d_model=192, n_layers=4, n_heads=6,
        d_ff=512, seq_len=96, batch=8, lora_r=16, lr=2e-4))
    # perf ablation: gradient checkpointing off (recompute vs memory —
    # EXPERIMENTS.md §Perf L2)
    cfgs.append(ModelConfig(
        name="e2e_noremat", vocab=512, d_model=192, n_layers=4, n_heads=6,
        d_ff=512, seq_len=96, batch=8, lora_r=16, lr=2e-4, remat=False))

    # chat/generation artifact shares e2e weights; fwd graph emitted too.
    return cfgs


def large_configs() -> List[ModelConfig]:
    """Bigger configs for capable machines (not built by default)."""
    return [
        ModelConfig(name="e2e_large", vocab=1024, d_model=384, n_layers=6,
                    n_heads=8, d_ff=1024, seq_len=128, batch=8, lora_r=16),
        ModelConfig(name="m100", vocab=32000, d_model=640, n_layers=10,
                    n_heads=10, d_ff=1792, seq_len=512, batch=4, lora_r=64),
    ]


def by_name(name: str) -> ModelConfig:
    for c in named_configs() + large_configs():
        if c.name == name:
            return c
    raise KeyError(name)
