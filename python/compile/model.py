"""L2: the QLoRA transformer — JAX fwd/bwd over a frozen quantized base.

A LLaMA-style decoder (RMSNorm, RoPE, causal MHA, SwiGLU) whose linear
layers are QLoRA linears (paper Eq. 5): the frozen base weight arrives as
packed NF4 codes + double-quantized absmax constants and is dequantized
in-graph; trainable LoRA adapters (Eq. 3) sit on a configurable set of
projections — the paper's key finding is that *all* linear layers need
adapters to match 16-bit full finetuning (Figure 2).

Gradients flow through the dequantization into the adapters only
(paper section 3, "QLoRA"): ``train_step`` differentiates w.r.t. the LoRA
pytree exclusively, so dW never exists; with ``quant="none", lora=False``
the same machinery performs full 16-bit finetuning (the paper's baseline).

Everything here is build-time: ``aot.py`` lowers `train_step`/`eval_step`/
`forward` to HLO text once per config; the Rust coordinator then owns the
training loop.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, PROJ_NAMES
from .kernels import decode, ref

Tree = Dict


# --------------------------------------------------------------------------
# Parameter initialization + base quantization
# --------------------------------------------------------------------------

def init_base_params(key, cfg: ModelConfig) -> Tree:
    """Initialize full-precision base parameters (frozen pretrained stand-in).

    Scaled-normal init (trained transformer weights are ~zero-centered
    normal, paper Appendix F — which is exactly the regime NF4 targets).
    """
    d = cfg.d_model
    keys = jax.random.split(key, 2 + cfg.n_layers)
    params: Tree = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, d)) * 0.02,
        "norm_f": jnp.ones((d,)),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        lk = jax.random.split(keys[2 + li], len(PROJ_NAMES))
        layer: Tree = {"ln1": jnp.ones((d,)), "ln2": jnp.ones((d,))}
        for pk, proj in zip(lk, PROJ_NAMES):
            h, o = cfg.proj_shape(proj)
            layer[proj] = {"w": jax.random.normal(pk, (h, o)) / jnp.sqrt(h)}
        params["layers"].append(layer)
    return params


def quantize_base(params: Tree, cfg: ModelConfig) -> Tree:
    """Quantize every linear projection of the base (paper section 3).

    Embeddings and norms stay full precision (the paper quantizes linear
    layers; embeddings/norms remain 16-bit).
    """
    if cfg.quant == "none":
        return params
    out = {"embed": params["embed"], "norm_f": params["norm_f"], "layers": []}
    for layer in params["layers"]:
        ql: Tree = {"ln1": layer["ln1"], "ln2": layer["ln2"]}
        for proj in PROJ_NAMES:
            ql[proj] = ref.quantize_weight(
                layer[proj]["w"], cfg.quant, cfg.block, cfg.block2,
                double_quant=cfg.double_quant)
        out["layers"].append(ql)
    return out


def init_lora_params(key, cfg: ModelConfig) -> Tree:
    """LoRA adapters: A ~ N(0, 1/r), B = 0 (standard LoRA init => the
    adapted model starts exactly at the base model)."""
    if not cfg.lora:
        return {"layers": [{} for _ in range(cfg.n_layers)]}
    layers = []
    keys = jax.random.split(key, cfg.n_layers)
    for li in range(cfg.n_layers):
        lk = jax.random.split(keys[li], len(cfg.scope))
        layer = {}
        for pk, proj in zip(lk, cfg.scope):
            h, o = cfg.proj_shape(proj)
            layer[proj] = {
                "a": jax.random.normal(pk, (h, cfg.lora_r)) / jnp.sqrt(cfg.lora_r),
                "b": jnp.zeros((cfg.lora_r, o)),
            }
        layers.append(layer)
    return {"layers": layers}


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x: jnp.ndarray) -> jnp.ndarray:
    """Rotary position embedding over (B, T, H, Dh)."""
    b, t, h, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half) / half))
    pos = jnp.arange(t)[:, None] * freqs[None, :]          # (T, half)
    cos = jnp.cos(pos)[None, :, None, :]
    sin = jnp.sin(pos)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def _linear(cfg: ModelConfig, base_entry: Tree, lora_entry: Optional[Tree],
            x: jnp.ndarray, shape: Tuple[int, int]) -> jnp.ndarray:
    """One QLoRA linear (paper Eq. 5). base_entry is either {'w': f32}
    (16-bit path) or the quantized container from ref.quantize_weight."""
    if "w" in base_entry:
        y = x @ base_entry["w"]
    else:
        w = ref.dequantize_weight(base_entry, shape, cfg.quant, cfg.block,
                                  cfg.block2)
        y = x @ w
    if lora_entry is not None:
        y = y + cfg.lora_s * ((x @ lora_entry["a"]) @ lora_entry["b"])
    return y


def _layer_fwd(cfg: ModelConfig, base_layer: Tree, lora_layer: Tree,
               x: jnp.ndarray):
    """Full-sequence layer forward. Also returns the post-RoPE keys and
    the values as (B, T, D) — the prefill graph stacks them into the KV
    cache; the plain forward discards them (XLA dead-code-eliminates)."""
    b, t, d = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim

    def lin(proj, h):
        return _linear(cfg, base_layer[proj], lora_layer.get(proj), h,
                       cfg.proj_shape(proj))

    # attention
    hpre = rms_norm(x, base_layer["ln1"])
    q = lin("wq", hpre).reshape(b, t, nh, hd)
    k = lin("wk", hpre).reshape(b, t, nh, hd)
    v = lin("wv", hpre).reshape(b, t, nh, hd)
    q, k = rope(q), rope(k)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, t, d)
    x = x + lin("wo", ctx)

    # SwiGLU MLP
    hpre = rms_norm(x, base_layer["ln2"])
    gate = jax.nn.silu(lin("wg", hpre))
    up = lin("wu", hpre)
    x = x + lin("wd", gate * up)
    return x, k.reshape(b, t, d), v.reshape(b, t, d)


def forward(cfg: ModelConfig, base: Tree, lora: Tree,
            tokens: jnp.ndarray, return_kv: bool = False):
    """tokens (B, T) int32 -> logits (B, T, V). lm_head tied to embedding.

    With ``return_kv`` also returns the per-layer post-RoPE keys and
    values stacked as (B, L, T, D) — the KV-cache layout of
    `kernels.decode` (prefill fills a cache, decode steps extend it).
    """
    x = base["embed"][tokens]
    ks, vs = [], []
    for li in range(cfg.n_layers):
        f = functools.partial(_layer_fwd, cfg, base["layers"][li],
                              lora["layers"][li])
        if cfg.remat:
            f = jax.checkpoint(f)
        x, k, v = f(x)
        ks.append(k)
        vs.append(v)
    x = rms_norm(x, base["norm_f"])
    logits = x @ base["embed"].T
    if return_kv:
        return logits, jnp.stack(ks, 1), jnp.stack(vs, 1)
    return logits


def _layer_step(cfg: ModelConfig, base_layer: Tree, lora_layer: Tree,
                x: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                pos: jnp.ndarray):
    """One-token layer forward against a (B, S, D) cache slice: write this
    token's K/V at ``pos``, attend over positions <= ``pos``. The math per
    op mirrors `_layer_fwd` restricted to one query position."""
    b, d = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim

    def lin(proj, h):
        return _linear(cfg, base_layer[proj], lora_layer.get(proj), h,
                       cfg.proj_shape(proj))

    hpre = rms_norm(x, base_layer["ln1"])
    q = lin("wq", hpre).reshape(b, nh, hd)
    k = lin("wk", hpre).reshape(b, nh, hd)
    v = lin("wv", hpre).reshape(b, nh, hd)
    q, k = decode.rope_at(q, pos), decode.rope_at(k, pos)
    k_cache = decode.update_cache(k_cache, k.reshape(b, d), pos)
    v_cache = decode.update_cache(v_cache, v.reshape(b, d), pos)
    ctx = decode.cached_attention(q, k_cache, v_cache, pos)
    x = x + lin("wo", ctx)

    hpre = rms_norm(x, base_layer["ln2"])
    gate = jax.nn.silu(lin("wg", hpre))
    up = lin("wu", hpre)
    x = x + lin("wd", gate * up)
    return x, k_cache, v_cache


# --------------------------------------------------------------------------
# Loss / train / eval steps
# --------------------------------------------------------------------------

def masked_ce_loss(cfg: ModelConfig, base: Tree, lora: Tree,
                   tokens: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross entropy over masked positions.

    mask[b, t] weights the loss of *predicting* tokens[b, t] from position
    t-1. Train-on-target-only (paper Appendix B.3 / Table 10) is expressed
    by zeroing instruction positions in the mask.
    """
    logits = forward(cfg, base, lora, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l * l) for l in leaves))


def make_train_step(cfg: ModelConfig, full_finetune: bool):
    """Build train_step(trainable, m, v, step, frozen, tokens, mask).

    Adam with bias correction, global-norm clip 0.3, constant LR (the
    paper's schedule, Appendix B.2). For QLoRA `trainable` is the LoRA
    pytree; for full finetuning it is the whole (unquantized) base.
    Returns (new_trainable, new_m, new_v, new_step, loss).
    """

    def loss_fn(trainable, frozen, tokens, mask):
        if full_finetune:
            base, lora = trainable, frozen["lora_stub"]
        else:
            base, lora = frozen, trainable
        return masked_ce_loss(cfg, base, lora, tokens, mask)

    def train_step(trainable, m, v, step, frozen, tokens, mask):
        loss, grads = jax.value_and_grad(loss_fn)(trainable, frozen,
                                                  tokens, mask)
        # global-norm clipping (max_grad_norm = 0.3, Appendix B.2)
        gnorm = _global_norm(grads)
        clip = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-12))
        grads = jax.tree_util.tree_map(lambda g: g * clip, grads)

        step = step + 1.0
        b1, b2 = cfg.adam_b1, cfg.adam_b2
        m = jax.tree_util.tree_map(lambda mm, g: b1 * mm + (1 - b1) * g,
                                   m, grads)
        v = jax.tree_util.tree_map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                                   v, grads)
        mhat = jax.tree_util.tree_map(lambda mm: mm / (1 - b1 ** step), m)
        vhat = jax.tree_util.tree_map(lambda vv: vv / (1 - b2 ** step), v)
        trainable = jax.tree_util.tree_map(
            lambda p, mh, vh: p - cfg.lr * mh / (jnp.sqrt(vh) + cfg.adam_eps),
            trainable, mhat, vhat)
        return trainable, m, v, step, loss

    return train_step


def make_eval_step(cfg: ModelConfig, full_finetune: bool):
    """eval_step(trainable, frozen, tokens, mask) -> (loss, acc)."""

    def eval_step(trainable, frozen, tokens, mask):
        if full_finetune:
            base, lora = trainable, frozen["lora_stub"]
        else:
            base, lora = frozen, trainable
        logits = forward(cfg, base, lora, tokens)
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        m = mask[:, 1:]
        denom = jnp.maximum(jnp.sum(m), 1.0)
        loss = jnp.sum(nll * m) / denom
        pred = jnp.argmax(logits[:, :-1], axis=-1)
        acc = jnp.sum((pred == tgt) * m) / denom
        return loss, acc

    return eval_step


def _split(trainable, frozen, full_finetune):
    if full_finetune:
        return trainable, frozen["lora_stub"]
    return frozen, trainable


def make_forward(cfg: ModelConfig, full_finetune: bool):
    """fwd(trainable, frozen, tokens) -> logits, for generation in Rust."""

    def fwd(trainable, frozen, tokens):
        base, lora = _split(trainable, frozen, full_finetune)
        return forward(cfg, base, lora, tokens)

    return fwd


def make_prefill(cfg: ModelConfig, full_finetune: bool):
    """prefill(trainable, frozen, k_in, v_in, tokens, row_mask)
    -> (logits (B,S,V), k (B,L,S,D), v (B,L,S,D)).

    One full-sequence forward that additionally fills the KV cache. Rows
    with ``row_mask > 0.5`` get freshly computed caches; rows with 0 pass
    ``k_in``/``v_in`` through untouched — so the serving engine can admit
    new prompts into free rows of a cache whose other rows are mid-decode
    (continuous batching) with a single canonical cache value threading
    through every graph call.
    """

    def prefill(trainable, frozen, k_in, v_in, tokens, row_mask):
        base, lora = _split(trainable, frozen, full_finetune)
        logits, k_new, v_new = forward(cfg, base, lora, tokens,
                                       return_kv=True)
        keep = row_mask[:, None, None, None] > 0.5
        return (logits, jnp.where(keep, k_new, k_in),
                jnp.where(keep, v_new, v_in))

    return prefill


def make_decode_step(cfg: ModelConfig, full_finetune: bool):
    """decode_step(trainable, frozen, k, v, token, pos)
    -> (logits (B,V), k', v').

    One O(1)-in-generated-length decode step: embed ``token`` (B,), write
    its K/V at per-row position ``pos`` (B,), attend over the cached
    prefix, and emit next-token logits for every row. Idle rows are driven
    with ``pos = seq_len - 1``: that slot is rewritten by the row's own
    final step before it can ever be attended (positions > pos are
    masked), so interleaving active and idle rows is safe.
    """

    def step(trainable, frozen, k_caches, v_caches, token, pos):
        base, lora = _split(trainable, frozen, full_finetune)
        x = base["embed"][token]                                # (B, D)
        new_k, new_v = [], []
        for li in range(cfg.n_layers):
            x, kc, vc = _layer_step(cfg, base["layers"][li],
                                    lora["layers"][li], x,
                                    k_caches[:, li], v_caches[:, li], pos)
            new_k.append(kc)
            new_v.append(vc)
        x = rms_norm(x, base["norm_f"])
        logits = x @ base["embed"].T                            # (B, V)
        return logits, jnp.stack(new_k, 1), jnp.stack(new_v, 1)

    return step
