"""L2: the QLoRA transformer — JAX fwd/bwd over a frozen quantized base.

A LLaMA-style decoder (RMSNorm, RoPE, causal MHA, SwiGLU) whose linear
layers are QLoRA linears (paper Eq. 5): the frozen base weight arrives as
packed NF4 codes + double-quantized absmax constants and is dequantized
in-graph; trainable LoRA adapters (Eq. 3) sit on a configurable set of
projections — the paper's key finding is that *all* linear layers need
adapters to match 16-bit full finetuning (Figure 2).

Gradients flow through the dequantization into the adapters only
(paper section 3, "QLoRA"): ``train_step`` differentiates w.r.t. the LoRA
pytree exclusively, so dW never exists; with ``quant="none", lora=False``
the same machinery performs full 16-bit finetuning (the paper's baseline).

Everything here is build-time: ``aot.py`` lowers `train_step`/`eval_step`/
`forward` to HLO text once per config; the Rust coordinator then owns the
training loop.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, PROJ_NAMES
from .kernels import ref

Tree = Dict


# --------------------------------------------------------------------------
# Parameter initialization + base quantization
# --------------------------------------------------------------------------

def init_base_params(key, cfg: ModelConfig) -> Tree:
    """Initialize full-precision base parameters (frozen pretrained stand-in).

    Scaled-normal init (trained transformer weights are ~zero-centered
    normal, paper Appendix F — which is exactly the regime NF4 targets).
    """
    d = cfg.d_model
    keys = jax.random.split(key, 2 + cfg.n_layers)
    params: Tree = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, d)) * 0.02,
        "norm_f": jnp.ones((d,)),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        lk = jax.random.split(keys[2 + li], len(PROJ_NAMES))
        layer: Tree = {"ln1": jnp.ones((d,)), "ln2": jnp.ones((d,))}
        for pk, proj in zip(lk, PROJ_NAMES):
            h, o = cfg.proj_shape(proj)
            layer[proj] = {"w": jax.random.normal(pk, (h, o)) / jnp.sqrt(h)}
        params["layers"].append(layer)
    return params


def quantize_base(params: Tree, cfg: ModelConfig) -> Tree:
    """Quantize every linear projection of the base (paper section 3).

    Embeddings and norms stay full precision (the paper quantizes linear
    layers; embeddings/norms remain 16-bit).
    """
    if cfg.quant == "none":
        return params
    out = {"embed": params["embed"], "norm_f": params["norm_f"], "layers": []}
    for layer in params["layers"]:
        ql: Tree = {"ln1": layer["ln1"], "ln2": layer["ln2"]}
        for proj in PROJ_NAMES:
            ql[proj] = ref.quantize_weight(
                layer[proj]["w"], cfg.quant, cfg.block, cfg.block2,
                double_quant=cfg.double_quant)
        out["layers"].append(ql)
    return out


def init_lora_params(key, cfg: ModelConfig) -> Tree:
    """LoRA adapters: A ~ N(0, 1/r), B = 0 (standard LoRA init => the
    adapted model starts exactly at the base model)."""
    if not cfg.lora:
        return {"layers": [{} for _ in range(cfg.n_layers)]}
    layers = []
    keys = jax.random.split(key, cfg.n_layers)
    for li in range(cfg.n_layers):
        lk = jax.random.split(keys[li], len(cfg.scope))
        layer = {}
        for pk, proj in zip(lk, cfg.scope):
            h, o = cfg.proj_shape(proj)
            layer[proj] = {
                "a": jax.random.normal(pk, (h, cfg.lora_r)) / jnp.sqrt(cfg.lora_r),
                "b": jnp.zeros((cfg.lora_r, o)),
            }
        layers.append(layer)
    return {"layers": layers}


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x: jnp.ndarray) -> jnp.ndarray:
    """Rotary position embedding over (B, T, H, Dh)."""
    b, t, h, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half) / half))
    pos = jnp.arange(t)[:, None] * freqs[None, :]          # (T, half)
    cos = jnp.cos(pos)[None, :, None, :]
    sin = jnp.sin(pos)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def _linear(cfg: ModelConfig, base_entry: Tree, lora_entry: Optional[Tree],
            x: jnp.ndarray, shape: Tuple[int, int]) -> jnp.ndarray:
    """One QLoRA linear (paper Eq. 5). base_entry is either {'w': f32}
    (16-bit path) or the quantized container from ref.quantize_weight."""
    if "w" in base_entry:
        y = x @ base_entry["w"]
    else:
        w = ref.dequantize_weight(base_entry, shape, cfg.quant, cfg.block,
                                  cfg.block2)
        y = x @ w
    if lora_entry is not None:
        y = y + cfg.lora_s * ((x @ lora_entry["a"]) @ lora_entry["b"])
    return y


def _layer_fwd(cfg: ModelConfig, base_layer: Tree, lora_layer: Tree,
               x: jnp.ndarray) -> jnp.ndarray:
    b, t, d = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim

    def lin(proj, h):
        return _linear(cfg, base_layer[proj], lora_layer.get(proj), h,
                       cfg.proj_shape(proj))

    # attention
    hpre = rms_norm(x, base_layer["ln1"])
    q = lin("wq", hpre).reshape(b, t, nh, hd)
    k = lin("wk", hpre).reshape(b, t, nh, hd)
    v = lin("wv", hpre).reshape(b, t, nh, hd)
    q, k = rope(q), rope(k)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, t, d)
    x = x + lin("wo", ctx)

    # SwiGLU MLP
    hpre = rms_norm(x, base_layer["ln2"])
    gate = jax.nn.silu(lin("wg", hpre))
    up = lin("wu", hpre)
    x = x + lin("wd", gate * up)
    return x


def forward(cfg: ModelConfig, base: Tree, lora: Tree,
            tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens (B, T) int32 -> logits (B, T, V). lm_head tied to embedding."""
    x = base["embed"][tokens]
    for li in range(cfg.n_layers):
        f = functools.partial(_layer_fwd, cfg, base["layers"][li],
                              lora["layers"][li])
        if cfg.remat:
            f = jax.checkpoint(f)
        x = f(x)
    x = rms_norm(x, base["norm_f"])
    return x @ base["embed"].T


# --------------------------------------------------------------------------
# Loss / train / eval steps
# --------------------------------------------------------------------------

def masked_ce_loss(cfg: ModelConfig, base: Tree, lora: Tree,
                   tokens: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross entropy over masked positions.

    mask[b, t] weights the loss of *predicting* tokens[b, t] from position
    t-1. Train-on-target-only (paper Appendix B.3 / Table 10) is expressed
    by zeroing instruction positions in the mask.
    """
    logits = forward(cfg, base, lora, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l * l) for l in leaves))


def make_train_step(cfg: ModelConfig, full_finetune: bool):
    """Build train_step(trainable, m, v, step, frozen, tokens, mask).

    Adam with bias correction, global-norm clip 0.3, constant LR (the
    paper's schedule, Appendix B.2). For QLoRA `trainable` is the LoRA
    pytree; for full finetuning it is the whole (unquantized) base.
    Returns (new_trainable, new_m, new_v, new_step, loss).
    """

    def loss_fn(trainable, frozen, tokens, mask):
        if full_finetune:
            base, lora = trainable, frozen["lora_stub"]
        else:
            base, lora = frozen, trainable
        return masked_ce_loss(cfg, base, lora, tokens, mask)

    def train_step(trainable, m, v, step, frozen, tokens, mask):
        loss, grads = jax.value_and_grad(loss_fn)(trainable, frozen,
                                                  tokens, mask)
        # global-norm clipping (max_grad_norm = 0.3, Appendix B.2)
        gnorm = _global_norm(grads)
        clip = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-12))
        grads = jax.tree_util.tree_map(lambda g: g * clip, grads)

        step = step + 1.0
        b1, b2 = cfg.adam_b1, cfg.adam_b2
        m = jax.tree_util.tree_map(lambda mm, g: b1 * mm + (1 - b1) * g,
                                   m, grads)
        v = jax.tree_util.tree_map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                                   v, grads)
        mhat = jax.tree_util.tree_map(lambda mm: mm / (1 - b1 ** step), m)
        vhat = jax.tree_util.tree_map(lambda vv: vv / (1 - b2 ** step), v)
        trainable = jax.tree_util.tree_map(
            lambda p, mh, vh: p - cfg.lr * mh / (jnp.sqrt(vh) + cfg.adam_eps),
            trainable, mhat, vhat)
        return trainable, m, v, step, loss

    return train_step


def make_eval_step(cfg: ModelConfig, full_finetune: bool):
    """eval_step(trainable, frozen, tokens, mask) -> (loss, acc)."""

    def eval_step(trainable, frozen, tokens, mask):
        if full_finetune:
            base, lora = trainable, frozen["lora_stub"]
        else:
            base, lora = frozen, trainable
        logits = forward(cfg, base, lora, tokens)
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        m = mask[:, 1:]
        denom = jnp.maximum(jnp.sum(m), 1.0)
        loss = jnp.sum(nll * m) / denom
        pred = jnp.argmax(logits[:, :-1], axis=-1)
        acc = jnp.sum((pred == tgt) * m) / denom
        return loss, acc

    return eval_step


def make_forward(cfg: ModelConfig, full_finetune: bool):
    """fwd(trainable, frozen, tokens) -> logits, for generation in Rust."""

    def fwd(trainable, frozen, tokens):
        if full_finetune:
            base, lora = trainable, frozen["lora_stub"]
        else:
            base, lora = frozen, trainable
        return forward(cfg, base, lora, tokens)

    return fwd
