# Build-time artifact pipeline. Python runs ONCE here; afterwards the
# Rust binary is self-contained (see ARCHITECTURE.md).

ARTIFACTS ?= artifacts

.PHONY: artifacts artifacts-large test test-python test-rust lint \
        lint-fast bench-quant bench-generate bench-compare

# Lower every model config to HLO text + init tensors + manifest.
artifacts:
	cd python && python3 -m compile.aot --out ../$(ARTIFACTS)

# Also build the large configs (slow; needs a capable machine).
artifacts-large:
	cd python && python3 -m compile.aot --out ../$(ARTIFACTS) --large

test: test-python test-rust

test-python:
	cd python && python3 -m pytest tests -q

test-rust:
	cd rust && cargo test -q

# Project-invariant static analysis over rust/ (stdlib Python only, no
# toolchain needed): eight per-file rules (hot-path panic freedom, float
# ordering, oracle purity, cancellation memory ordering, lossy casts,
# scoped threads, Result-returning public APIs, bounded channels) plus
# three interprocedural passes over the crate call graph (transitive
# panic reachability, lock-order analysis, untrusted-input taint
# tracking). Rules and waiver syntax: ARCHITECTURE.md.
lint:
	python3 scripts/pallas_lint.py --self-test
	python3 scripts/pallas_lint.py

# Edit-loop variant: fixture self-test + findings only for files that
# differ from HEAD. The full crate still feeds the call graph, so
# interprocedural results on the changed files stay whole-crate
# accurate; CI keeps running the full `lint` wall.
lint-fast:
	python3 scripts/pallas_lint.py --self-test
	python3 scripts/pallas_lint.py --changed HEAD

# Quant-kernel perf trajectory: fused-vs-scalar throughput + speedups,
# persisted machine-readably at the repo root (tracked from PR 3 onward).
bench-quant:
	cd rust && cargo bench --bench bench_quant -- --json ../BENCH_quant.json

# Serving perf trajectory: decode tokens/sec, lifecycle-serve overhead,
# and the shared-prefix capacity comparison (dense reservation vs
# block-granular KV admission). Needs `make artifacts` first.
bench-generate:
	cd rust && cargo bench --bench bench_generate -- --json ../BENCH_generate.json

# After re-running the bench targets (which overwrite the working-tree
# BENCH_*.json), diff them against the last committed baselines and fail
# on >25% mean-time regressions. Placeholder baselines (committed before
# any machine could run the benches) compare vacuously green.
bench-compare:
	@git show HEAD:BENCH_quant.json > .bench_baseline.json && \
	 python3 scripts/bench_compare.py .bench_baseline.json BENCH_quant.json; \
	 st=$$?; rm -f .bench_baseline.json; exit $$st
	@git show HEAD:BENCH_generate.json > .bench_baseline.json && \
	 python3 scripts/bench_compare.py .bench_baseline.json BENCH_generate.json; \
	 st=$$?; rm -f .bench_baseline.json; exit $$st
