# Build-time artifact pipeline. Python runs ONCE here; afterwards the
# Rust binary is self-contained (see ARCHITECTURE.md).

ARTIFACTS ?= artifacts

.PHONY: artifacts artifacts-large test test-python test-rust bench-quant

# Lower every model config to HLO text + init tensors + manifest.
artifacts:
	cd python && python3 -m compile.aot --out ../$(ARTIFACTS)

# Also build the large configs (slow; needs a capable machine).
artifacts-large:
	cd python && python3 -m compile.aot --out ../$(ARTIFACTS) --large

test: test-python test-rust

test-python:
	cd python && python3 -m pytest tests -q

test-rust:
	cd rust && cargo test -q

# Quant-kernel perf trajectory: fused-vs-scalar throughput + speedups,
# persisted machine-readably at the repo root (tracked from PR 3 onward).
bench-quant:
	cd rust && cargo bench --bench bench_quant -- --json ../BENCH_quant.json
