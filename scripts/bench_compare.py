#!/usr/bin/env python3
"""Compare two `util::bench` JSON files and flag throughput regressions.

Usage:
    python3 scripts/bench_compare.py BASELINE.json CURRENT.json \
        [--threshold 0.25] [--warn-only]

Both files are the output of a bench binary's `--json` flag (or `make
bench-quant` / `make bench-generate`): `{"results": [{name, mean_ns,
...}, ...], "mode": "full"|"smoke", ...}`. Results are matched by name;
a benchmark regresses when its mean time grows by more than THRESHOLD
(default 25%) over the baseline. Exit code 1 when anything regressed
(0 with --warn-only), 2 when either input is missing or unreadable.

Baselines committed before a machine could run the benches carry
`"placeholder": true` and compare as vacuously green — the first real
`make bench-quant` / `make bench-generate` run replaces them.
"""

import argparse
import json
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except OSError as e:
        print(f"bench_compare: cannot read {path}: {e}", file=sys.stderr)
        return None
    except json.JSONDecodeError as e:
        print(f"bench_compare: {path} is not valid JSON: {e}",
              file=sys.stderr)
        return None


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="fractional slowdown that counts as a regression")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but always exit 0")
    args = ap.parse_args()

    base = load(args.baseline)
    cur = load(args.current)
    if base is None or cur is None:
        # a missing or unreadable side is a broken comparison, not a
        # clean one — exit distinctly so CI can't report vacuous green
        print("bench_compare: refusing to compare without both inputs",
              file=sys.stderr)
        return 2
    if base.get("placeholder"):
        print(f"bench_compare: {args.baseline} is a placeholder baseline "
              "(no toolchain has run the bench yet); nothing to compare — "
              "run the bench on a capable machine to record one.")
        return 0
    if base.get("mode") != cur.get("mode"):
        print(f"bench_compare: mode mismatch ({base.get('mode')!r} baseline "
              f"vs {cur.get('mode')!r} current); timings are not "
              "comparable across modes — skipping.")
        return 0

    by_name = {r["name"]: r for r in base.get("results", []) if "name" in r}
    regressions = []
    compared = 0
    for r in cur.get("results", []):
        b = by_name.get(r.get("name"))
        if b is None or not b.get("mean_ns") or not r.get("mean_ns"):
            continue
        compared += 1
        ratio = r["mean_ns"] / b["mean_ns"]
        marker = ""
        if ratio > 1.0 + args.threshold:
            marker = "  <-- REGRESSION"
            regressions.append((r["name"], ratio))
        print(f"{r['name']:<56} {ratio:6.2f}x baseline{marker}")
    print(f"\nbench_compare: {compared} benchmarks compared, "
          f"{len(regressions)} regressed (threshold "
          f"{args.threshold:.0%} slowdown)")
    if regressions and not args.warn_only:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
