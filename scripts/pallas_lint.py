#!/usr/bin/env python3
"""pallas-lint CLI shim.

The analyzer lives in the `scripts/pallas_lint/` package (lexer, item
parser, call graph, per-file rules, interprocedural passes, SARIF).
This file keeps the historical entry point and import surface working:

- `python3 scripts/pallas_lint.py ...` runs the CLI exactly as before;
- tests that load this file as a module (via importlib) still find
  `lex`, `lint_text`, `RULES`, and the rest of the public API, because
  everything the package exports is re-exported here.

See `python3 scripts/pallas_lint.py --list-rules` for the rule table
and ARCHITECTURE.md ("Invariants & static analysis") for the contracts
behind it. Exit codes: 0 clean, 1 findings, 2 usage/internal error.
"""

from __future__ import annotations

import sys
from pathlib import Path

# the package directory sits next to this shim; when this file is run
# as a script (or loaded by importlib under an arbitrary name) the
# scripts/ dir is not necessarily on sys.path
_SCRIPTS_DIR = str(Path(__file__).resolve().parent)
if _SCRIPTS_DIR not in sys.path:
    sys.path.insert(0, _SCRIPTS_DIR)

from pallas_lint import *  # noqa: F401,F403  (re-export the public API)
from pallas_lint import run

if __name__ == "__main__":
    run()
