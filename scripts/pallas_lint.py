#!/usr/bin/env python3
"""pallas-lint: project-invariant static analysis for the Rust sources.

This container runs tier-1 without a Rust toolchain, so clippy cannot be
the lint wall here. pallas-lint is a zero-dependency (stdlib-only)
analyzer that lexes the Rust sources for real — line and nested block
comments, regular/raw/byte strings, char literals vs lifetimes — and
runs a small rule engine over the scrubbed code. Rules are distilled
from this repo's actual bug history and module contracts (see
ARCHITECTURE.md, "Invariants & static analysis").

Waivers
-------
A finding is suppressed by a waiver comment carrying a reason::

    thing.expect("x");  // pallas-lint: allow(no-hot-path-panic) — why it holds

A waiver on its own line applies to the next code line. A waiver that
suppresses nothing is itself an error (`unused-waiver`), as is a waiver
without a reason or naming an unknown rule (`waiver-syntax`).

Usage
-----
    python3 scripts/pallas_lint.py [paths...]   # default: <repo>/rust
    python3 scripts/pallas_lint.py --json
    python3 scripts/pallas_lint.py --self-test  # run the fixture suite
    python3 scripts/pallas_lint.py --list-rules

Exit codes: 0 clean, 1 findings, 2 usage/internal error.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
FIXTURE_DIR = Path(__file__).resolve().parent / "tests" / "lint_fixtures"

# ---------------------------------------------------------------------------
# Lexer: scrub comments / strings / char literals out of Rust source.
# ---------------------------------------------------------------------------


class Lexed:
    """Result of scrubbing one Rust file.

    ``lines`` holds the source with every comment, string literal, and
    char literal replaced by spaces (newlines preserved), so downstream
    regexes only ever match real code. ``comments`` holds the comment
    text that was removed, as ``(line_number, text)`` pairs (line
    comments only — waivers must be `//` comments).
    """

    def __init__(self, lines, comments):
        self.lines = lines  # list[str], 1-based via index+1
        self.comments = comments  # list[(line, text)]

    def line(self, n):
        """Scrubbed text of 1-based line ``n`` (empty if out of range)."""
        if 1 <= n <= len(self.lines):
            return self.lines[n - 1]
        return ""


def _is_ident(ch):
    return ch.isalnum() or ch == "_"


def lex(text):
    """Scrub Rust source: return a `Lexed` with code-only lines."""
    out = list(text)
    comments = []
    n = len(text)
    i = 0
    line = 1

    def blank(a, b):
        """Replace text[a:b] with spaces, preserving newlines."""
        for k in range(a, b):
            if out[k] != "\n":
                out[k] = " "

    while i < n:
        ch = text[i]
        if ch == "\n":
            line += 1
            i += 1
            continue
        prev = text[i - 1] if i > 0 else ""

        # -- line comment ---------------------------------------------------
        if ch == "/" and text[i : i + 2] == "//":
            end = text.find("\n", i)
            if end == -1:
                end = n
            comments.append((line, text[i + 2 : end]))
            blank(i, end)
            i = end
            continue

        # -- block comment (nests) -----------------------------------------
        if ch == "/" and text[i : i + 2] == "/*":
            depth = 1
            j = i + 2
            while j < n and depth > 0:
                if text[j : j + 2] == "/*":
                    depth += 1
                    j += 2
                elif text[j : j + 2] == "*/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            blank(i, j)
            line += text.count("\n", i, j)
            i = j
            continue

        # -- raw / byte-raw strings: r"…", r#"…"#, br#"…"# ------------------
        if ch in "rb" and not _is_ident(prev):
            j = i
            if text[j : j + 2] == "br":
                j += 2
            else:
                j += 1
            hashes = 0
            k = j
            while k < n and text[k] == "#":
                hashes += 1
                k += 1
            is_raw = "r" in text[i : i + 2].lower()[:2] and k < n and text[k] == '"'
            if is_raw and (ch == "r" or text[i : i + 2] == "br"):
                # raw string: ends at '"' + hashes '#'s, no escapes
                close = '"' + "#" * hashes
                end = text.find(close, k + 1)
                end = n if end == -1 else end + len(close)
                blank(i, end)
                line += text.count("\n", i, end)
                i = end
                continue
            if ch == "b" and text[i : i + 2] == 'b"':
                i += 1  # byte string: treat as a regular string from the quote
                ch = '"'
            elif ch == "b" and text[i : i + 2] == "b'":
                i += 1  # byte char literal
                ch = "'"
            else:
                if ch in "rb" and not is_raw and text[i : i + 1] in "rb":
                    # plain identifier starting with r/b — ordinary code
                    i += 1
                    continue

        # -- regular string --------------------------------------------------
        if ch == '"':
            j = i + 1
            while j < n:
                if text[j] == "\\":
                    j += 2
                elif text[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            blank(i, j)
            line += text.count("\n", i, j)
            i = j
            continue

        # -- char literal vs lifetime ---------------------------------------
        if ch == "'":
            if text[i + 1 : i + 2] == "\\":
                # escaped char literal: walk to the closing quote (the
                # escape-skip handles '\'' and '\\')
                j = i + 1
                while j < n and text[j] != "'":
                    j += 2 if text[j] == "\\" else 1
                blank(i, min(j + 1, n))
                i = j + 1
                continue
            if text[i + 2 : i + 3] == "'" and text[i + 1 : i + 2] != "'":
                blank(i, i + 3)  # 'x'
                i += 3
                continue
            i += 1  # lifetime / loop label: keep as code
            continue

        i += 1

    return Lexed("".join(out).split("\n"), comments)


# ---------------------------------------------------------------------------
# Structure: test spans and fn spans over the scrubbed source.
# ---------------------------------------------------------------------------

_CFG_TEST = re.compile(r"#\s*\[\s*(?:cfg\s*\(\s*test\s*\)|test\b)")
_FN = re.compile(r"\bfn\s+([A-Za-z_]\w*)")


class FnSpan:
    """One function item: name, visibility, and its body's line range."""

    def __init__(self, name, is_pub, start, end):
        self.name = name
        self.is_pub = is_pub
        self.start = start  # line of the `fn` keyword (1-based)
        self.end = end  # line of the closing brace (inclusive)


def _item_span(lines, start_idx, col):
    """Lines covered by the item starting at (start_idx, col) in scrubbed
    ``lines`` (0-based index). Scans for the first `{` or `;`; a `{`
    is brace-matched (strings/comments are already blanked, so every
    brace is structural). Returns the inclusive 0-based end index."""
    depth = 0
    seen_open = False
    i, c = start_idx, col
    while i < len(lines):
        text = lines[i][c:] if i == start_idx else lines[i]
        off = c if i == start_idx else 0
        for k, ch in enumerate(text):
            if not seen_open and ch == ";":
                return i
            if ch == "{":
                seen_open = True
                depth += 1
            elif ch == "}":
                depth -= 1
                if seen_open and depth == 0:
                    return i
        i += 1
        c = 0
    return len(lines) - 1


def test_lines(lexed):
    """The set of 1-based line numbers inside `#[cfg(test)]` / `#[test]`
    items (attribute line through closing brace, inclusive)."""
    out = set()
    for idx, text in enumerate(lexed.lines):
        m = _CFG_TEST.search(text)
        if not m:
            continue
        end = _item_span(lexed.lines, idx, m.end())
        out.update(range(idx + 1, end + 2))
    return out


def fn_spans(lexed):
    """All function items as `FnSpan`s (1-based inclusive line ranges)."""
    spans = []
    for idx, text in enumerate(lexed.lines):
        for m in _FN.finditer(text):
            before = text[: m.start()]
            is_pub = bool(re.search(r"\bpub\b", before))
            end = _item_span(lexed.lines, idx, m.end())
            spans.append(FnSpan(m.group(1), is_pub, idx + 1, end + 1))
    return spans


def enclosing_fn(spans, line):
    """The innermost `FnSpan` containing 1-based ``line``, or None."""
    best = None
    for s in spans:
        if s.start <= line <= s.end:
            if best is None or s.start >= best.start:
                best = s
    return best


# ---------------------------------------------------------------------------
# Findings and waivers.
# ---------------------------------------------------------------------------


class Finding:
    """One rule violation at (path, line)."""

    def __init__(self, path, line, rule, message):
        self.path = path
        self.line = line
        self.rule = rule
        self.message = message

    def key(self):
        return (self.line, self.rule)

    def as_dict(self):
        return {
            "path": self.path,
            "line": self.line,
            "rule": self.rule,
            "message": self.message,
        }


# `pallas-lint:` only — the fixture headers (`pallas-lint-fixture:`,
# `pallas-lint-expect:`) are not waivers
_WAIVER_HINT = re.compile(r"pallas-lint\s*:")
_WAIVER = re.compile(
    r"^\s*pallas-lint\s*:\s*allow\s*\(\s*([A-Za-z0-9_,\s-]+?)\s*\)"
    r"\s*(?:—|--|-|:)\s*(\S.*)$"
)


class Waiver:
    """A parsed `// pallas-lint: allow(...)` comment."""

    def __init__(self, comment_line, target_line, rules, reason):
        self.comment_line = comment_line
        self.target_line = target_line
        self.rules = rules
        self.reason = reason
        self.used = False


def parse_waivers(path, lexed, known_rules):
    """Extract waivers from a file's line comments.

    Returns ``(waivers, syntax_findings)``: malformed waiver comments
    (no reason, bad shape, unknown rule) become `waiver-syntax` findings
    rather than silently suppressing nothing."""
    waivers, findings = [], []
    for line_no, text in lexed.comments:
        if not _WAIVER_HINT.search(text):
            continue
        m = _WAIVER.match(text)
        if not m:
            findings.append(
                Finding(
                    path,
                    line_no,
                    "waiver-syntax",
                    "malformed waiver: expected "
                    "`// pallas-lint: allow(<rule>[, <rule>]) — <reason>`",
                )
            )
            continue
        rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
        bad = [r for r in rules if r not in known_rules]
        if bad or not rules:
            findings.append(
                Finding(
                    path,
                    line_no,
                    "waiver-syntax",
                    "waiver names unknown rule(s): "
                    + (", ".join(bad) if bad else "<none>"),
                )
            )
            continue
        # a waiver on a code line targets that line; a standalone waiver
        # targets the next non-blank code line
        target = line_no
        if not lexed.line(line_no).strip():
            target = None
            for j in range(line_no + 1, len(lexed.lines) + 1):
                if lexed.line(j).strip():
                    target = j
                    break
            if target is None:
                findings.append(
                    Finding(
                        path,
                        line_no,
                        "waiver-syntax",
                        "standalone waiver has no following code line",
                    )
                )
                continue
        waivers.append(Waiver(line_no, target, rules, m.group(2).strip()))
    return waivers, findings


# ---------------------------------------------------------------------------
# Rules.
# ---------------------------------------------------------------------------

PANIC_PAT = re.compile(
    r"\.unwrap\s*\(|\.expect\s*\(|\b(?:panic|unreachable|todo|unimplemented)\s*!"
)
# `[` directly adjacent to an expression tail is indexing; array types,
# attributes (`#[...]`), and `vec![...]` never match.
INDEX_PAT = re.compile(r"[A-Za-z0-9_)\]?]\[")
PARTIAL_CMP_PAT = re.compile(r"\bpartial_cmp\b")
FUSED_SYMBOLS = re.compile(
    r"\b(?:quantize_fused|dequantize_fused_into|quantize_blockwise_fused"
    r"|dequantize_blockwise_fused)\b|\bEncoder\s*::"
)
RELAXED_PAT = re.compile(r"\bOrdering\s*::\s*Relaxed\b")
CANCELISH_PAT = re.compile(r"(?i)cancel|abort")
# narrowing targets only: widening to usize/u64/i64/f64 keeps every value
# (BlockId is this repo's u32 alias, so it counts as narrowing too)
LOSSY_AS_PAT = re.compile(r"\bas\s+(?:u8|u16|u32|i8|i16|i32|f32|BlockId)\b")
THREAD_SPAWN_PAT = re.compile(r"\bthread\s*::\s*spawn\b")
# `mpsc::channel` (unbounded) only; `sync_channel` has a word character
# before "channel" and never matches
UNBOUNDED_CHANNEL_PAT = re.compile(r"\bmpsc\s*::\s*channel\b")

HOT_PATH_FILES = {
    "rust/src/engine/scheduler.rs",
    "rust/src/engine/session.rs",
    "rust/src/engine/sampler.rs",
    "rust/src/engine/decode.rs",
    "rust/src/paged/blocks.rs",
    "rust/src/paged/pool.rs",
    # the network boundary parses untrusted bytes: a panic here is a
    # remote denial-of-service, so it gets the line-by-line treatment
    "rust/src/serve/json.rs",
    "rust/src/serve/http.rs",
}

# pub fns under these prefixes form the serving API surface checked by
# result-not-panic-api (minus the HOT_PATH_FILES, which no-hot-path-panic
# already covers line by line)
API_SURFACE_PREFIXES = ("rust/src/engine/", "rust/src/serve/")

ACCOUNTING_PREFIXES = ("rust/src/tensorio/", "rust/src/paged/")
ACCOUNTING_FILES = {"rust/src/engine/scheduler.rs"}


class Ctx:
    """Everything a rule needs about one file."""

    def __init__(self, path, lexed):
        self.path = path  # repo-relative, forward slashes
        self.lexed = lexed
        self.tests = test_lines(lexed)
        self.fns = fn_spans(lexed)

    def code_lines(self, include_tests=False):
        """Yield (1-based line number, scrubbed text) pairs."""
        for idx, text in enumerate(self.lexed.lines):
            n = idx + 1
            if not include_tests and n in self.tests:
                continue
            yield n, text


def rule_no_hot_path_panic(ctx):
    """(1) no-hot-path-panic: panicking calls and `[...]` indexing in the
    serve-loop hot-path modules need a waiver naming the protecting
    invariant."""
    if ctx.path not in HOT_PATH_FILES:
        return []
    out = []
    for n, text in ctx.code_lines():
        if PANIC_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-hot-path-panic",
                    "panicking call on the serve hot path; return an error "
                    "or waive with the protecting invariant",
                )
            )
        if INDEX_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-hot-path-panic",
                    "`[...]` indexing on the serve hot path; use .get()/"
                    "slicing with checks or waive with the bounds invariant",
                )
            )
    return out


def rule_no_float_partial_cmp(ctx):
    """(2) no-float-partial-cmp: `partial_cmp` is how the PR 6 sampler
    NaN panic happened; float ordering must go through `total_cmp`.
    Applies everywhere, including tests."""
    out = []
    for n, text in ctx.code_lines(include_tests=True):
        if PARTIAL_CMP_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-float-partial-cmp",
                    "partial_cmp orders NaN as None (panic/flip hazard); "
                    "use f32::total_cmp / f64::total_cmp",
                )
            )
    return out


def rule_oracle_purity(ctx):
    """(3) oracle-purity: `*_scalar` fns in quant/ are the bit-exactness
    oracle the fused kernels are tested against; they must never route
    through the fused symbols themselves."""
    if "quant/" not in ctx.path:
        return []
    out = []
    for span in ctx.fns:
        if not span.name.endswith("_scalar") or span.start in ctx.tests:
            continue
        for n in range(span.start, span.end + 1):
            if n in ctx.tests:
                continue
            if FUSED_SYMBOLS.search(ctx.lexed.line(n)):
                out.append(
                    Finding(
                        ctx.path,
                        n,
                        "oracle-purity",
                        f"oracle fn `{span.name}` calls a fused-kernel "
                        "symbol; the scalar path must stay independent",
                    )
                )
    return out


def rule_no_relaxed_cancel(ctx):
    """(4) no-relaxed-cancel: `Ordering::Relaxed` on cancellation /
    abort atomics can defer the flag past the next poll; engine code and
    any cancel/abort context must use SeqCst (or Acquire/Release)."""
    out = []
    for n, text in ctx.code_lines():
        if not RELAXED_PAT.search(text):
            continue
        span = enclosing_fn(ctx.fns, n)
        fn_body = (
            "\n".join(
                ctx.lexed.line(k) for k in range(span.start, span.end + 1)
            )
            if span
            else ""
        )
        if (
            ctx.path.startswith("rust/src/engine/")
            or CANCELISH_PAT.search(text)
            or CANCELISH_PAT.search(fn_body)
        ):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-relaxed-cancel",
                    "Ordering::Relaxed on a cancellation/abort atomic; "
                    "use SeqCst so cancel() is seen by the next poll",
                )
            )
    return out


def rule_no_lossy_as(ctx):
    """(5) no-lossy-as-in-accounting: narrowing `as` casts silently
    truncate; byte/token-accounting modules must use `try_from` (the
    PR 5 f16 byte-accounting bug class). Widening casts are exempt."""
    if (
        not ctx.path.startswith(ACCOUNTING_PREFIXES)
        and ctx.path not in ACCOUNTING_FILES
    ):
        return []
    out = []
    for n, text in ctx.code_lines():
        if LOSSY_AS_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-lossy-as",
                    "narrowing `as` cast in accounting code truncates "
                    "silently; use try_from or waive with the range invariant",
                )
            )
    return out


def rule_scoped_threads_only(ctx):
    """(6) scoped-threads-only: all library parallelism goes through
    `std::thread::scope` (joins on panic, borrows locals) — bare
    `thread::spawn` leaks detached threads on early return."""
    if not ctx.path.startswith("rust/src/"):
        return []
    out = []
    for n, text in ctx.code_lines():
        if THREAD_SPAWN_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "scoped-threads-only",
                    "bare thread::spawn in library code; use "
                    "std::thread::scope (see quant/kernels.rs)",
                )
            )
    return out


def rule_result_not_panic_api(ctx):
    """(7) result-not-panic-api: `pub fn`s in engine/ and serve/ are the
    serving API surface; they must surface errors as `Result`, not
    panics. The hot-path files are already covered line-by-line by
    no-hot-path-panic and are exempt here to avoid double findings."""
    if (
        not ctx.path.startswith(API_SURFACE_PREFIXES)
        or ctx.path in HOT_PATH_FILES
    ):
        return []
    out = []
    for span in ctx.fns:
        if not span.is_pub or span.start in ctx.tests:
            continue
        for n in range(span.start, span.end + 1):
            if n in ctx.tests:
                continue
            if PANIC_PAT.search(ctx.lexed.line(n)):
                out.append(
                    Finding(
                        ctx.path,
                        n,
                        "result-not-panic-api",
                        f"pub fn `{span.name}` contains a panicking call; "
                        "engine APIs return Result",
                    )
                )
    return out


def rule_no_unbounded_send(ctx):
    """(8) no-unbounded-send: an unbounded `mpsc::channel` in the
    serving stack lets one slow consumer buffer tokens without limit —
    the overload-control plane depends on bounded `sync_channel`s whose
    full-send failure feeds back into cancellation. Bound the channel
    or waive with the invariant that bounds it externally."""
    if not ctx.path.startswith(API_SURFACE_PREFIXES):
        return []
    out = []
    for n, text in ctx.code_lines():
        if UNBOUNDED_CHANNEL_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-unbounded-send",
                    "unbounded mpsc::channel in the serving stack; use "
                    "mpsc::sync_channel with an explicit depth so a slow "
                    "consumer hits backpressure instead of unbounded memory",
                )
            )
    return out


RULES = {
    "no-hot-path-panic": rule_no_hot_path_panic,
    "no-float-partial-cmp": rule_no_float_partial_cmp,
    "oracle-purity": rule_oracle_purity,
    "no-relaxed-cancel": rule_no_relaxed_cancel,
    "no-lossy-as": rule_no_lossy_as,
    "scoped-threads-only": rule_scoped_threads_only,
    "result-not-panic-api": rule_result_not_panic_api,
    "no-unbounded-send": rule_no_unbounded_send,
}

META_RULES = ("unused-waiver", "waiver-syntax")


def lint_text(path, text):
    """Lint one file's content under repo-relative ``path``.

    Runs every rule, applies waivers, and reports unused waivers.
    Returns a list of `Finding`s, deduplicated per (line, rule) and
    sorted by line."""
    lexed = lex(text)
    ctx = Ctx(path, lexed)
    raw = []
    for rule_fn in RULES.values():
        raw.extend(rule_fn(ctx))
    seen = set()
    findings = []
    for f in sorted(raw, key=lambda f: f.key()):
        if f.key() not in seen:
            seen.add(f.key())
            findings.append(f)

    waivers, meta = parse_waivers(path, lexed, RULES)
    kept = []
    for f in findings:
        waived = False
        for w in waivers:
            if w.target_line == f.line and f.rule in w.rules:
                w.used = True
                waived = True
        if not waived:
            kept.append(f)
    for w in waivers:
        if not w.used:
            meta.append(
                Finding(
                    path,
                    w.comment_line,
                    "unused-waiver",
                    "waiver suppresses nothing "
                    f"(allow({', '.join(w.rules)})); remove it",
                )
            )
    return sorted(kept + meta, key=lambda f: (f.line, f.rule))


def lint_paths(paths):
    """Lint every .rs file under ``paths``. Returns (findings, n_files)."""
    files = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.rs")))
        elif p.suffix == ".rs":
            files.append(p)
        else:
            raise SystemExit(f"pallas-lint: not a .rs file or directory: {p}")
    findings = []
    for f in files:
        try:
            rel = f.resolve().relative_to(REPO_ROOT).as_posix()
        except ValueError:
            rel = f.as_posix()
        findings.extend(lint_text(rel, f.read_text(encoding="utf-8")))
    return findings, len(files)


# ---------------------------------------------------------------------------
# Self-test over committed fixtures.
# ---------------------------------------------------------------------------

_FIX_PATH = re.compile(r"pallas-lint-fixture:\s*path\s*=\s*(\S+)")
_FIX_EXPECT = re.compile(r"pallas-lint-expect:\s*(.+)$", re.MULTILINE)


def run_self_test():
    """Lint each fixture under scripts/tests/lint_fixtures/ and compare
    against its declared expectations.

    Fixture header grammar (plain Rust comments, so fixtures stay valid
    Rust)::

        // pallas-lint-fixture: path = rust/src/engine/scheduler.rs
        // pallas-lint-expect: no-hot-path-panic @ 5; no-hot-path-panic @ 9
        // pallas-lint-expect: clean

    Expectations accumulate across multiple expect lines. Returns the
    number of failing fixtures."""
    fixtures = sorted(FIXTURE_DIR.glob("*.rs"))
    if not fixtures:
        print(f"pallas-lint: no fixtures in {FIXTURE_DIR}", file=sys.stderr)
        return 1
    failures = 0
    for fx in fixtures:
        text = fx.read_text(encoding="utf-8")
        mpath = _FIX_PATH.search(text)
        if not mpath:
            print(f"FAIL {fx.name}: missing pallas-lint-fixture header")
            failures += 1
            continue
        expected = set()
        for m in _FIX_EXPECT.finditer(text):
            spec = m.group(1).strip()
            if spec == "clean":
                continue
            for part in spec.split(";"):
                part = part.strip()
                if not part:
                    continue
                rule, _, line = part.partition("@")
                expected.add((rule.strip(), int(line.strip())))
        got = {
            (f.rule, f.line)
            for f in lint_text(mpath.group(1), text)
        }
        if got == expected:
            print(f"ok   {fx.name} ({len(expected)} expected findings)")
        else:
            failures += 1
            print(f"FAIL {fx.name}")
            for rule, line in sorted(expected - got):
                print(f"     missing: {rule} @ {line}")
            for rule, line in sorted(got - expected):
                print(f"     unexpected: {rule} @ {line}")
    total = len(fixtures)
    print(f"self-test: {total - failures}/{total} fixtures pass")
    return failures


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="pallas_lint.py",
        description="Project-invariant static analysis for the Rust sources.",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files or directories to lint (default: <repo>/rust)",
    )
    ap.add_argument("--json", action="store_true", help="machine output")
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="run the committed fixture suite instead of linting",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule table"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, fn in RULES.items():
            doc = (fn.__doc__ or "").split("\n")[0].strip()
            print(f"{name:24s} {doc}")
        for name in META_RULES:
            print(f"{name:24s} (meta) waiver hygiene, always on")
        return 0

    if args.self_test:
        return 1 if run_self_test() else 0

    paths = args.paths or [REPO_ROOT / "rust"]
    findings, n_files = lint_paths(paths)

    if args.json:
        print(
            json.dumps(
                {
                    "findings": [f.as_dict() for f in findings],
                    "checked_files": n_files,
                },
                indent=2,
            )
        )
    else:
        for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
            print(f"{f.path}:{f.line}: [{f.rule}] {f.message}")
        noun = "finding" if len(findings) == 1 else "findings"
        print(
            f"pallas-lint: {len(findings)} {noun} in {n_files} files "
            f"({len(RULES)} rules + waiver hygiene)"
        )
    return 1 if findings else 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except SystemExit:
        raise
    except Exception as e:  # internal error: distinct exit code
        print(f"pallas-lint: internal error: {e}", file=sys.stderr)
        sys.exit(2)
