"""pallas-lint: project-invariant static analysis for the Rust sources.

This container runs tier-1 without a Rust toolchain, so clippy cannot
be the lint wall here. pallas-lint is a zero-dependency (stdlib-only)
analyzer that lexes the Rust sources for real — line and nested block
comments, regular/raw/byte strings, char literals vs lifetimes — and
runs a rule engine over the scrubbed code: eight per-file lexical
rules plus three interprocedural passes (panic reachability over the
crate call graph, lock-order analysis, untrusted-input taint
tracking). Rules are distilled from this repo's actual bug history and
module contracts (see ARCHITECTURE.md, "Invariants & static
analysis").

Package map
-----------
- `lexer`      scrub comments/strings/chars; everything downstream
               regexes over code-only lines
- `items`      fn/impl/mod item parser + call-site extraction
- `callgraph`  crate-wide call graph, honest unresolved accounting
- `rules`      the per-file rules and their scope sets
- `interproc`  no-transitive-panic / lock-order / untrusted-taint
- `waivers`    `// pallas-lint: allow(rule) — reason` parsing
- `engine`     orchestration: units -> crate -> findings
- `sarif`      SARIF 2.1.0 writer for CI annotations
- `selftest`   fixture suite under scripts/tests/lint_fixtures/
- `cli`        argument parsing and output

The `scripts/pallas_lint.py` shim re-exports this public surface so
both `python3 scripts/pallas_lint.py` and direct imports keep working.
"""

from __future__ import annotations

from .callgraph import CallGraph, Edge
from .cli import main, run
from .engine import (
    KNOWN_RULES,
    REPO_ROOT,
    Crate,
    Unit,
    analyze,
    changed_paths,
    lint_paths,
    lint_paths_ex,
    lint_text,
    rule_docs,
)
from .interproc import (
    INTERPROC_RULES,
    pass_lock_order,
    pass_no_transitive_panic,
    pass_untrusted_taint,
)
from .items import (
    Call,
    FnItem,
    FnSpan,
    enclosing_fn,
    extract_calls,
    fn_spans,
    parse_items,
    test_lines,
)
from .lexer import Lexed, lex
from .rules import (
    ACCOUNTING_FILES,
    ACCOUNTING_PREFIXES,
    API_SURFACE_PREFIXES,
    HOT_PATH_FILES,
    INDEX_PAT,
    META_RULES,
    PANIC_PAT,
    RULES,
    Ctx,
    Finding,
)
from .sarif import SARIF_SCHEMA, SARIF_VERSION, sarif_report
from .selftest import FIXTURE_DIR, run_self_test
from .waivers import Waiver, parse_waivers

__all__ = [
    "ACCOUNTING_FILES",
    "ACCOUNTING_PREFIXES",
    "API_SURFACE_PREFIXES",
    "CallGraph",
    "Call",
    "Crate",
    "Ctx",
    "Edge",
    "FIXTURE_DIR",
    "Finding",
    "FnItem",
    "FnSpan",
    "HOT_PATH_FILES",
    "INDEX_PAT",
    "INTERPROC_RULES",
    "KNOWN_RULES",
    "Lexed",
    "META_RULES",
    "PANIC_PAT",
    "REPO_ROOT",
    "RULES",
    "SARIF_SCHEMA",
    "SARIF_VERSION",
    "Unit",
    "Waiver",
    "analyze",
    "changed_paths",
    "enclosing_fn",
    "extract_calls",
    "fn_spans",
    "lex",
    "lint_paths",
    "lint_paths_ex",
    "lint_text",
    "main",
    "parse_items",
    "parse_waivers",
    "pass_lock_order",
    "pass_no_transitive_panic",
    "pass_untrusted_taint",
    "rule_docs",
    "run",
    "run_self_test",
    "sarif_report",
    "test_lines",
]
