"""Fixture self-test: the linter lints its own expectations.

Fixture header grammar (plain Rust comments, so fixtures stay valid
Rust)::

    // pallas-lint-fixture: path = rust/src/engine/scheduler.rs
    // pallas-lint-expect: no-hot-path-panic @ 5; no-hot-path-panic @ 9
    // pallas-lint-expect: clean

Expectations accumulate across multiple expect lines. Each fixture is
linted as a one-file crate under its pretend path, so rule scoping and
the interprocedural passes behave exactly as on the real tree.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

from .engine import lint_text

FIXTURE_DIR = Path(__file__).resolve().parents[1] / "tests" / "lint_fixtures"

_FIX_PATH = re.compile(r"pallas-lint-fixture:\s*path\s*=\s*(\S+)")
_FIX_EXPECT = re.compile(r"pallas-lint-expect:\s*(.+)$", re.MULTILINE)


def run_self_test():
    """Lint each fixture under scripts/tests/lint_fixtures/ and compare
    against its declared expectations. Returns the number of failing
    fixtures."""
    fixtures = sorted(FIXTURE_DIR.glob("*.rs"))
    if not fixtures:
        print(f"pallas-lint: no fixtures in {FIXTURE_DIR}", file=sys.stderr)
        return 1
    failures = 0
    for fx in fixtures:
        text = fx.read_text(encoding="utf-8")
        mpath = _FIX_PATH.search(text)
        if not mpath:
            print(f"FAIL {fx.name}: missing pallas-lint-fixture header")
            failures += 1
            continue
        expected = set()
        for m in _FIX_EXPECT.finditer(text):
            spec = m.group(1).strip()
            if spec == "clean":
                continue
            for part in spec.split(";"):
                part = part.strip()
                if not part:
                    continue
                rule, _, line = part.partition("@")
                expected.add((rule.strip(), int(line.strip())))
        got = {
            (f.rule, f.line)
            for f in lint_text(mpath.group(1), text)
        }
        if got == expected:
            print(f"ok   {fx.name} ({len(expected)} expected findings)")
        else:
            failures += 1
            print(f"FAIL {fx.name}")
            for rule, line in sorted(expected - got):
                print(f"     missing: {rule} @ {line}")
            for rule, line in sorted(got - expected):
                print(f"     unexpected: {rule} @ {line}")
    total = len(fixtures)
    print(f"self-test: {total - failures}/{total} fixtures pass")
    return failures
