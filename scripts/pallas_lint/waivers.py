"""Waiver parsing: `// pallas-lint: allow(rule[, rule]) — reason`.

A waiver on a code line targets that line; a standalone waiver targets
the next non-blank code line. The reason is mandatory — an audited
waiver with no stated invariant is just a muted alarm. Malformed
waivers and waivers naming unknown rules are themselves findings
(`waiver-syntax`), and a waiver that suppresses nothing is an
`unused-waiver` finding (computed in the engine after every pass —
including the interprocedural ones — has had a chance to use it).
"""

from __future__ import annotations

import re

from .rules import Finding

# `pallas-lint:` only — the fixture headers (`pallas-lint-fixture:`,
# `pallas-lint-expect:`) are not waivers
_WAIVER_HINT = re.compile(r"pallas-lint\s*:")
_WAIVER = re.compile(
    r"^\s*pallas-lint\s*:\s*allow\s*\(\s*([A-Za-z0-9_,\s-]+?)\s*\)"
    r"\s*(?:—|--|-|:)\s*(\S.*)$"
)


class Waiver:
    """A parsed `// pallas-lint: allow(...)` comment."""

    def __init__(self, comment_line, target_line, rules, reason):
        self.comment_line = comment_line
        self.target_line = target_line
        self.rules = rules
        self.reason = reason
        self.used = False


def parse_waivers(path, lexed, known_rules):
    """Extract waivers from a file's line comments.

    Returns ``(waivers, syntax_findings)``: malformed waiver comments
    (no reason, bad shape, unknown rule) become `waiver-syntax` findings
    rather than silently suppressing nothing."""
    waivers, findings = [], []
    for line_no, text in lexed.comments:
        if not _WAIVER_HINT.search(text):
            continue
        m = _WAIVER.match(text)
        if not m:
            findings.append(
                Finding(
                    path,
                    line_no,
                    "waiver-syntax",
                    "malformed waiver: expected "
                    "`// pallas-lint: allow(<rule>[, <rule>]) — <reason>`",
                )
            )
            continue
        rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
        bad = [r for r in rules if r not in known_rules]
        if bad or not rules:
            findings.append(
                Finding(
                    path,
                    line_no,
                    "waiver-syntax",
                    "waiver names unknown rule(s): "
                    + (", ".join(bad) if bad else "<none>"),
                )
            )
            continue
        # a waiver on a code line targets that line; a standalone waiver
        # targets the next non-blank code line
        target = line_no
        if not lexed.line(line_no).strip():
            target = None
            for j in range(line_no + 1, len(lexed.lines) + 1):
                if lexed.line(j).strip():
                    target = j
                    break
            if target is None:
                findings.append(
                    Finding(
                        path,
                        line_no,
                        "waiver-syntax",
                        "standalone waiver has no following code line",
                    )
                )
                continue
        waivers.append(Waiver(line_no, target, rules, m.group(2).strip()))
    return waivers, findings


def waived_lines(waivers, rule):
    """Target lines of waivers naming ``rule`` (does not mark used)."""
    return {w.target_line for w in waivers if rule in w.rules}
