"""Command-line front end.

Usage::

    python3 scripts/pallas_lint.py [paths...]   # default: <repo>/rust
    python3 scripts/pallas_lint.py --json
    python3 scripts/pallas_lint.py --self-test  # run the fixture suite
    python3 scripts/pallas_lint.py --list-rules
    python3 scripts/pallas_lint.py --changed HEAD   # only files vs a ref
    python3 scripts/pallas_lint.py --sarif out.sarif

Exit codes: 0 clean, 1 findings, 2 usage/internal error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .engine import (
    REPO_ROOT,
    changed_paths,
    lint_paths_ex,
    rule_docs,
)
from .interproc import INTERPROC_RULES
from .rules import META_RULES, RULES
from .sarif import sarif_report
from .selftest import run_self_test


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="pallas_lint.py",
        description="Project-invariant static analysis for the Rust sources.",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files or directories to lint (default: <repo>/rust)",
    )
    ap.add_argument("--json", action="store_true", help="machine output")
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="run the committed fixture suite instead of linting",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule table"
    )
    ap.add_argument(
        "--changed",
        metavar="GIT_REF",
        help="report only on .rs files differing from GIT_REF (the full "
        "crate still feeds the call graph, so cross-file results stay "
        "accurate)",
    )
    ap.add_argument(
        "--sarif",
        metavar="FILE",
        help="additionally write findings as SARIF 2.1.0 to FILE",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, fn in {**RULES, **INTERPROC_RULES}.items():
            doc = (fn.__doc__ or "").split("\n")[0].strip()
            print(f"{name:24s} {doc}")
        for name in META_RULES:
            print(f"{name:24s} (meta) waiver hygiene, always on")
        return 0

    if args.self_test:
        return 1 if run_self_test() else 0

    report_rel = None
    if args.changed:
        if args.paths:
            ap.error("--changed and explicit paths are exclusive")
        report_rel = changed_paths(args.changed)
        if not report_rel:
            print(f"pallas-lint: no Rust files changed vs {args.changed}")
            return 0
        paths = [REPO_ROOT / "rust"]
    else:
        paths = args.paths or [REPO_ROOT / "rust"]

    findings, n_files, crate = lint_paths_ex(paths, report_rel=report_rel)

    if args.sarif:
        doc = sarif_report(findings, rule_docs())
        Path(args.sarif).write_text(
            json.dumps(doc, indent=2) + "\n", encoding="utf-8"
        )

    if args.json:
        print(
            json.dumps(
                {
                    "findings": [f.as_dict() for f in findings],
                    "checked_files": n_files,
                    "callgraph": crate.graph.stats(),
                },
                indent=2,
            )
        )
    else:
        for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
            print(f"{f.path}:{f.line}: [{f.rule}] {f.message}")
        noun = "finding" if len(findings) == 1 else "findings"
        n_rules = len(RULES) + len(INTERPROC_RULES)
        print(
            f"pallas-lint: {len(findings)} {noun} in {n_files} files "
            f"({n_rules} rules + waiver hygiene)"
        )
    return 1 if findings else 0


def run():  # pragma: no cover - exercised via the CLI shim
    try:
        sys.exit(main())
    except SystemExit:
        raise
    except Exception as e:  # internal error: distinct exit code
        print(f"pallas-lint: internal error: {e}", file=sys.stderr)
        sys.exit(2)
