"""Structure over scrubbed source: test spans, fn/impl/mod items, calls.

This is deliberately a *lightweight* item parser, not a Rust parser:
it brace-matches scrubbed lines (the lexer already blanked strings and
comments, so every brace is structural) and extracts just enough shape
for the interprocedural passes — function items with spans, the impl
type and inline module that encloses them, whether they are `pub`,
whether they return `Result`, and the call sites inside their bodies.

Known, documented approximations:

- trait *declarations* (`fn f(&self);` with no body) are parsed but
  marked body-less; they are excluded from the call-graph name index so
  a trait decl plus its single impl still resolves uniquely.
- nested named fns are attributed to the outer fn's call list as well;
  closures belong to the enclosing fn (which is what we want).
- a call spelled through a chain (`a.b().c()`) contributes each method
  name as its own call site.
"""

from __future__ import annotations

import re

from .lexer import Lexed  # noqa: F401  (re-exported for convenience)

_CFG_TEST = re.compile(r"#\s*\[\s*(?:cfg\s*\(\s*test\s*\)|test\b)")
_FN = re.compile(r"\bfn\s+([A-Za-z_]\w*)")
_MOD = re.compile(r"\bmod\s+([A-Za-z_]\w*)")
_IMPL = re.compile(r"\bimpl\b")
_GENERICS = re.compile(r"<[^<>]*>")


class FnSpan:
    """One function item: name, visibility, and its body's line range."""

    def __init__(self, name, is_pub, start, end):
        self.name = name
        self.is_pub = is_pub
        self.start = start  # line of the `fn` keyword (1-based)
        self.end = end  # line of the closing brace (inclusive)


def item_span(lines, start_idx, col):
    """Span of the item starting at (start_idx, col) in scrubbed
    ``lines`` (0-based index). Scans for the first `{` or `;`; a `{`
    is brace-matched (strings/comments are already blanked, so every
    brace is structural). Returns ``(end_idx, has_body)`` where
    ``end_idx`` is the inclusive 0-based end index and ``has_body``
    says whether a braced body was found (False for `fn f();`)."""
    depth = 0
    seen_open = False
    i, c = start_idx, col
    while i < len(lines):
        text = lines[i][c:] if i == start_idx else lines[i]
        for ch in text:
            if not seen_open and ch == ";":
                return i, False
            if ch == "{":
                seen_open = True
                depth += 1
            elif ch == "}":
                depth -= 1
                if seen_open and depth == 0:
                    return i, True
        i += 1
        c = 0
    return len(lines) - 1, seen_open


def _item_span(lines, start_idx, col):
    """Back-compat wrapper: end index only."""
    return item_span(lines, start_idx, col)[0]


def test_lines(lexed):
    """The set of 1-based line numbers inside `#[cfg(test)]` / `#[test]`
    items (attribute line through closing brace, inclusive)."""
    out = set()
    for idx, text in enumerate(lexed.lines):
        m = _CFG_TEST.search(text)
        if not m:
            continue
        end, _ = item_span(lexed.lines, idx, m.end())
        out.update(range(idx + 1, end + 2))
    return out


def fn_spans(lexed):
    """All function items as `FnSpan`s (1-based inclusive line ranges)."""
    spans = []
    for idx, text in enumerate(lexed.lines):
        for m in _FN.finditer(text):
            before = text[: m.start()]
            is_pub = bool(re.search(r"\bpub\b", before))
            end, _ = item_span(lexed.lines, idx, m.end())
            spans.append(FnSpan(m.group(1), is_pub, idx + 1, end + 1))
    return spans


def enclosing_fn(spans, line):
    """The innermost `FnSpan` containing 1-based ``line``, or None."""
    best = None
    for s in spans:
        if s.start <= line <= s.end:
            if best is None or s.start >= best.start:
                best = s
    return best


# ---------------------------------------------------------------------------
# Rich function items for the interprocedural passes.
# ---------------------------------------------------------------------------


class FnItem:
    """A function with everything the call-graph passes need."""

    __slots__ = (
        "name",
        "path",
        "start",
        "end",
        "is_pub",
        "is_test",
        "has_body",
        "impl_type",
        "mod_name",
        "sig",
        "returns_result",
        "params",
    )

    def __init__(self, **kw):
        for k in self.__slots__:
            setattr(self, k, kw.get(k))

    @property
    def display(self):
        if self.impl_type:
            return f"{self.impl_type}::{self.name}"
        return self.name

    def __repr__(self):  # pragma: no cover - debug aid
        return f"<FnItem {self.path}:{self.start} {self.display}>"


def _strip_generics(text):
    """Erase `<...>` segments (repeatedly, for nesting) from a header."""
    prev = None
    while prev != text:
        prev = text
        text = _GENERICS.sub(" ", text)
    return text


def _impl_type(lines, idx, col):
    """The self type of the `impl` starting at (idx, col): the type
    after `for` in a trait impl, the first type otherwise. Returns the
    last path segment, or None if unparseable."""
    header = []
    for k in range(idx, min(idx + 6, len(lines))):
        text = lines[k][col:] if k == idx else lines[k]
        brace = text.find("{")
        if brace != -1:
            header.append(text[:brace])
            break
        header.append(text)
    head = _strip_generics(" ".join(header))
    m = re.search(r"\bfor\s+([A-Za-z_][\w:]*)", head)
    if not m:
        m = re.match(r"\s*([A-Za-z_][\w:]*)", head)
    if not m:
        return None
    return m.group(1).split("::")[-1]


_PARAM = re.compile(r"([A-Za-z_]\w*)\s*:\s*([^,]+)")


def _parse_sig(lines, idx, col):
    """Signature text: from just after the fn name to the body `{` or
    the `;` of a body-less declaration (capped at 12 lines)."""
    parts = []
    depth = 0
    for k in range(idx, min(idx + 12, len(lines))):
        text = lines[k][col:] if k == idx else lines[k]
        for p, ch in enumerate(text):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
            elif depth == 0 and ch in "{;":
                parts.append(text[:p])
                return " ".join(parts)
        parts.append(text)
    return " ".join(parts)


def parse_items(path, lexed, tests):
    """All function items in a file as `FnItem`s."""
    lines = lexed.lines
    # inline modules (mod name { ... }); declarations (mod name;) skipped
    mods = []
    for idx, text in enumerate(lines):
        for m in _MOD.finditer(text):
            end, has_body = item_span(lines, idx, m.end())
            if has_body:
                mods.append((m.group(1), idx + 1, end + 1))
    # impl blocks with their self type
    impls = []
    for idx, text in enumerate(lines):
        for m in _IMPL.finditer(text):
            # `impl` inside a signature (`impl Trait` in argument
            # position) is preceded by `(`/`,`/`:`/`&` context — accept
            # only line-leading or visibility-leading impls
            before = text[: m.start()].strip()
            if before not in ("", "pub", "pub(crate)", "unsafe"):
                continue
            end, has_body = item_span(lines, idx, m.end())
            if not has_body:
                continue
            impls.append((_impl_type(lines, idx, m.end()), idx + 1, end + 1))

    items = []
    for idx, text in enumerate(lines):
        for m in _FN.finditer(text):
            before = text[: m.start()]
            is_pub = bool(re.search(r"\bpub\b", before))
            end, has_body = item_span(lines, idx, m.end())
            sig = _parse_sig(lines, idx, m.end())
            ret = sig.split("->")[-1] if "->" in sig else ""
            args = sig[sig.find("(") + 1 :] if "(" in sig else sig
            params = []
            for pm in _PARAM.finditer(_strip_generics(args)):
                params.append((pm.group(1), pm.group(2).strip()))
            impl_type = None
            for t, s, e in impls:
                if s <= idx + 1 <= e:
                    impl_type = t  # innermost wins via ordering below
            mod_name = None
            for name, s, e in mods:
                if s <= idx + 1 <= e:
                    mod_name = name
            items.append(
                FnItem(
                    name=m.group(1),
                    path=path,
                    start=idx + 1,
                    end=end + 1,
                    is_pub=is_pub,
                    is_test=(idx + 1) in tests,
                    has_body=has_body,
                    impl_type=impl_type,
                    mod_name=mod_name,
                    sig=sig,
                    returns_result=bool(re.search(r"\bResult\b", ret)),
                    params=params,
                )
            )
    return items


# ---------------------------------------------------------------------------
# Call extraction.
# ---------------------------------------------------------------------------

# keywords / built-in constructors that look like calls but are not
# crate functions
_NOT_CALLS = {
    "if", "while", "for", "match", "return", "loop", "fn", "let", "in",
    "as", "move", "ref", "mut", "else", "use", "pub", "impl", "struct",
    "enum", "trait", "where", "unsafe", "dyn", "break", "continue",
    "Some", "None", "Ok", "Err", "Box", "Vec", "String", "Default",
    "Arc", "Rc", "Mutex", "Condvar", "Duration", "Instant", "HashMap",
    "HashSet", "BTreeMap", "VecDeque", "PathBuf", "Option", "Result",
}

# `a::b::c(` — path call; qualifier is the segment before the fn name
_PATH_CALL = re.compile(
    r"(?<![\w.])([A-Za-z_]\w*(?:\s*::\s*[A-Za-z_]\w*)+)\s*\("
)
# `name(` not preceded by `.`/`::`/ident — free-function call
_BARE_CALL = re.compile(r"(?<![\w.:])([A-Za-z_]\w*)\s*\(")
# `.name(` — method call; the lookbehind keeps the second dot of a
# range (`0..n`) from starting a match; float literals never match
# because a method name cannot start with a digit
_METHOD_CALL = re.compile(r"(?<!\.)\.\s*([A-Za-z_]\w*)\s*\(")


class Call:
    """One call site inside a function body."""

    __slots__ = ("name", "qual", "kind", "line", "guarded")

    def __init__(self, name, qual, kind, line, guarded=False):
        self.name = name
        self.qual = qual  # path qualifier segment, or None
        self.kind = kind  # "bare" | "path" | "method"
        self.line = line
        # True when the call sits inside a `catch_unwind(...)` on the
        # same line: panics do not propagate past that boundary, so the
        # panic-reachability pass skips the edge
        self.guarded = guarded

    def __repr__(self):  # pragma: no cover - debug aid
        return f"<Call {self.kind} {self.name} @ {self.line}>"


def _guarded_spans(lexed, fn):
    """Argument spans of `catch_unwind(...)` calls in the fn body, as
    ``(start_line, start_col, end_line, end_col)`` with the paren
    balanced across lines — panics do not propagate out of them."""
    spans = []
    for n in range(fn.start, fn.end + 1):
        for m in re.finditer(r"\bcatch_unwind\b", lexed.line(n)):
            ln, col = n, m.end()
            depth = 0
            started = False
            done = False
            while ln <= fn.end and not done:
                t = lexed.line(ln)
                for k in range(col, len(t)):
                    c = t[k]
                    if c == "(":
                        depth += 1
                        started = True
                    elif c == ")":
                        depth -= 1
                        if started and depth == 0:
                            spans.append((n, m.end(), ln, k))
                            done = True
                            break
                ln += 1
                col = 0
    return spans


def _in_spans(spans, line, col):
    for sl, sc, el, ec in spans:
        if (line, col) >= (sl, sc) and (line, col) <= (el, ec):
            return True
    return False


def extract_calls(lexed, fn):
    """Call sites in ``fn``'s body (scrubbed lines start..end)."""
    calls = []
    guarded_spans = _guarded_spans(lexed, fn)
    for n in range(fn.start, fn.end + 1):
        text = lexed.line(n)
        covered = set()
        for m in _PATH_CALL.finditer(text):
            segs = [s.strip() for s in m.group(1).split("::")]
            name, qual = segs[-1], segs[-2]
            covered.update(range(m.start(), m.end()))
            if name in _NOT_CALLS:
                continue
            calls.append(
                Call(name, qual, "path", n,
                     guarded=_in_spans(guarded_spans, n, m.start()))
            )
        for m in _BARE_CALL.finditer(text):
            if any(k in covered for k in range(m.start(), m.end())):
                continue
            name = m.group(1)
            # skip the fn's own definition line name (`fn name(`)
            if re.search(r"\bfn\s*$", text[: m.start()]):
                continue
            if name in _NOT_CALLS or name == "catch_unwind":
                continue
            calls.append(
                Call(name, None, "bare", n,
                     guarded=_in_spans(guarded_spans, n, m.start()))
            )
        for m in _METHOD_CALL.finditer(text):
            name = m.group(1)
            if name in _NOT_CALLS:
                continue
            calls.append(
                Call(name, None, "method", n,
                     guarded=_in_spans(guarded_spans, n, m.start()))
            )
    return calls
