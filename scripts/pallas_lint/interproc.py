"""Interprocedural passes: panic reachability, lock order, taint.

All three consume the crate-wide call graph. They are deliberately
*lexical* analyses lifted to whole-crate scope — no types, no borrow
information — and each documents its approximations inline. The
guiding rule is the same as for the per-file rules: prefer a missed
finding (documented) over a fabricated one, because a lint wall the
team stops trusting is worse than no lint wall.

Waiver interaction
------------------
- r10 seeds skip panic sites whose line carries a waiver naming any of
  `no-hot-path-panic`, `result-not-panic-api`, or `no-transitive-panic`
  (the waiver's stated invariant covers transitive callers too).
- A waiver naming `no-transitive-panic` on a *call site* stops
  propagation through that edge — this is how a contained boundary
  (e.g. a `catch_unwind` worker loop) is audited once instead of at
  every public caller. Calls lexically inside `catch_unwind(...)` are
  skipped automatically.
"""

from __future__ import annotations

import re
from collections import defaultdict, deque

from .rules import API_SURFACE_PREFIXES, PANIC_PAT, Finding

# ---------------------------------------------------------------------------
# r10: no-transitive-panic
# ---------------------------------------------------------------------------

PANIC_WAIVER_RULES = (
    "no-hot-path-panic",
    "result-not-panic-api",
    "no-transitive-panic",
)


def _chain(graph, evidence, start, limit=8):
    """Render the call chain from ``start`` down to the local panic."""
    parts = []
    seen = set()
    cur = start
    while cur is not None and cur not in seen and len(parts) < limit:
        seen.add(cur)
        f = graph.fns[cur]
        ev = evidence.get(cur)
        if ev is None:
            break
        if ev[0] == "local":
            parts.append(f"{f.display} panics at {f.path}:{ev[1]}")
            break
        parts.append(f.display)
        cur = ev[2]
    return " -> ".join(parts)


def pass_no_transitive_panic(crate):
    """(10) no-transitive-panic: a pub engine/serve API must not reach a
    panicking operation through any chain of crate-internal calls; the
    per-file rules only see panics written in the pub fn itself."""
    g = crate.graph
    evidence = {}  # fn idx -> ("local", line) | ("call", line, callee, name)

    for i, f in enumerate(g.fns):
        if not f.has_body:
            continue
        u = crate.units[f.path]
        shielded = set()
        for w in u.waivers:
            if any(r in w.rules for r in PANIC_WAIVER_RULES):
                shielded.add(w.target_line)
        for n in range(f.start, f.end + 1):
            if n in u.ctx.tests:
                continue
            if not PANIC_PAT.search(u.lexed.line(n)):
                continue
            if n in shielded:
                # an explicit transitive waiver on the panic site is
                # "used" by shielding every caller at once
                for w in u.waivers:
                    if (
                        w.target_line == n
                        and "no-transitive-panic" in w.rules
                    ):
                        w.used = True
                continue
            evidence[i] = ("local", n)
            break

    # fixpoint: propagate panickiness to callers (BFS over reverse
    # edges; each fn is enqueued once, so cycles terminate)
    queue = deque(evidence)
    while queue:
        j = queue.popleft()
        for e in g.rev.get(j, []):
            i = e.caller
            if i in evidence or e.guarded:
                continue
            u = crate.units[g.fns[i].path]
            if e.line in u.ctx.tests:
                continue
            stopped = False
            for w in u.waivers:
                if (
                    w.target_line == e.line
                    and "no-transitive-panic" in w.rules
                ):
                    w.used = True
                    stopped = True
            if stopped:
                continue
            evidence[i] = ("call", e.line, j, e.name)
            queue.append(i)

    # report at the API frontier: each call edge from a pub engine/serve
    # fn into a panicky callee that does not get its own finding
    findings = []
    for i, f in enumerate(g.fns):
        if not f.is_pub or not f.path.startswith(API_SURFACE_PREFIXES):
            continue
        u = crate.units[f.path]
        if f.start in u.ctx.tests:
            continue
        for e in g.edges.get(i, []):
            if e.guarded or e.callee not in evidence:
                continue
            if e.line in u.ctx.tests:
                continue
            callee = g.fns[e.callee]
            if callee.is_pub and callee.path.startswith(
                API_SURFACE_PREFIXES
            ):
                # the callee is itself API surface: it carries its own
                # finding (r1/r7 locally, r10 transitively) — one
                # audited location per root cause
                continue
            chain = _chain(g, evidence, e.callee)
            findings.append(
                Finding(
                    f.path,
                    e.line,
                    "no-transitive-panic",
                    f"pub fn `{f.display}` can panic via this call: "
                    f"{chain}; return an error or waive at the root "
                    "with the protecting invariant",
                )
            )
    return findings


# ---------------------------------------------------------------------------
# r11: lock-order
# ---------------------------------------------------------------------------

LOCK_SCOPE_FILES = {
    "rust/src/serve/server.rs",
    "rust/src/engine/scheduler.rs",
}

# `recv.lock(` — the receiver's last path component names the lock
_LOCK_RECV = re.compile(r"([A-Za-z_][\w.]*)\s*\.\s*lock\s*\(")
_DROP = re.compile(r"\bdrop\s*\(\s*([A-Za-z_]\w*)\s*\)")
_LET = re.compile(r"^\s*let\s+(?:mut\s+)?([A-Za-z_]\w*)")
# Condvar wait/wait_timeout/wait_while atomically release + reacquire
# the guard they consume: neither a new acquisition nor "blocking while
# holding" in the deadlock sense
_WAITISH = re.compile(r"\.\s*wait(?:_timeout|_while)?\s*\(")
# operations that can block indefinitely (or long enough to matter)
# while a Mutex guard pins every other thread that needs the lock.
# `.send(` does not match `.try_send(` — the dot is part of the match.
BLOCKING_PAT = re.compile(
    r"\.send\s*\(|\.recv\s*\(|\.recv_timeout\s*\(|\.write_all\s*\(|"
    r"\.write_fmt\s*\(|\.flush\s*\(|\.read_exact\s*\(|\.join\s*\(|"
    r"\bthread\s*::\s*sleep\b|\.accept\s*\("
)


def _helper_arg(text, name):
    """First argument's last path component for a `name(&expr, ...)`
    call on ``text`` (the lock a guard-returning helper acquires)."""
    m = re.search(
        r"\b" + re.escape(name) + r"\s*\(\s*&?\s*(?:mut\s+)?([A-Za-z_][\w.]*)",
        text,
    )
    return m.group(1).split(".")[-1] if m else None


def _param_acquirers(crate):
    """fn index -> True for crate fns that lock a *parameter* and hand
    the guard back (e.g. the poison-recovering `lock()` helper in
    serve/server.rs). Call sites of these acquire their argument."""
    g = crate.graph
    out = set()
    for i, f in enumerate(g.fns):
        if not f.has_body or "Mutex" not in (f.sig or ""):
            continue
        pnames = {p[0] for p in f.params}
        u = crate.units[f.path]
        for n in range(f.start, f.end + 1):
            for m in _LOCK_RECV.finditer(u.lexed.line(n)):
                if m.group(1).split(".")[-1] in pnames:
                    out.add(i)
    return out


def _acquire_summaries(crate, pacq):
    """fn index -> set of lock ids the fn's body acquires (directly or
    through any chain of crate calls). Param-locking helpers contribute
    at their call sites, not here."""
    g = crate.graph
    direct = defaultdict(set)
    for i, f in enumerate(g.fns):
        if not f.has_body:
            continue
        u = crate.units[f.path]
        pnames = {p[0] for p in f.params}
        for n in range(f.start, f.end + 1):
            text = u.lexed.line(n)
            if _WAITISH.search(text):
                continue
            for m in _LOCK_RECV.finditer(text):
                recv = m.group(1).split(".")[-1]
                if recv not in pnames:
                    direct[i].add(recv)
        for e in g.edges.get(i, []):
            if e.callee in pacq:
                arg = _helper_arg(u.lexed.line(e.line), e.name)
                if arg:
                    direct[i].add(arg)
    acq = {i: set(s) for i, s in direct.items()}
    changed = True
    while changed:
        changed = False
        for i in range(len(g.fns)):
            mine = acq.get(i)
            for e in g.edges.get(i, []):
                theirs = acq.get(e.callee)
                if not theirs:
                    continue
                if mine is None:
                    mine = acq[i] = set()
                add = theirs - mine
                if add:
                    mine.update(add)
                    changed = True
    return acq


def _blocking_summaries(crate):
    """fn indexes whose bodies (transitively) contain a blocking op."""
    g = crate.graph
    blocks = set()
    for i, f in enumerate(g.fns):
        if not f.has_body:
            continue
        u = crate.units[f.path]
        for n in range(f.start, f.end + 1):
            if n in u.ctx.tests:
                continue
            text = u.lexed.line(n)
            if _WAITISH.search(text):
                continue
            if BLOCKING_PAT.search(text):
                blocks.add(i)
                break
    queue = deque(blocks)
    while queue:
        j = queue.popleft()
        for e in g.rev.get(j, []):
            if e.caller not in blocks:
                blocks.add(e.caller)
                queue.append(e.caller)
    return blocks


def pass_lock_order(crate):
    """(11) lock-order: in serve/server.rs + engine/scheduler.rs, model
    Mutex guard lifetimes and flag double-acquisition, inconsistent
    pairwise acquisition order across the crate, and guards held across
    blocking calls (channel sends, socket writes, joins, sleeps).

    Guard model (lexical, documented approximations): a guard is born
    at a same-line `let g = ...lock()...` / `let g = lock(&x)` binding
    and dies at the end of its binding block or at `drop(g)`; a
    `drop(g)` *inside a nested block* only suspends the guard until
    that block closes (the other branch still holds it). Condvar
    `wait*` calls are sanctioned release points and never flagged."""
    g = crate.graph
    pacq = _param_acquirers(crate)
    acq = _acquire_summaries(crate, pacq)
    blocks = _blocking_summaries(crate)
    findings = []
    pair_sites = defaultdict(list)  # (held, taken) -> [(path, line)]

    for i, f in enumerate(g.fns):
        if f.path not in LOCK_SCOPE_FILES or not f.has_body:
            continue
        u = crate.units[f.path]
        if f.start in u.ctx.tests:
            continue
        edges_by_line = defaultdict(list)
        for e in g.edges.get(i, []):
            edges_by_line[e.line].append(e)
        guards = []  # {var, id, depth, susp}
        depth = 0
        for n in range(f.start, f.end + 1):
            text = u.lexed.line(n)
            line_depth = depth
            waitish = bool(_WAITISH.search(text))

            acq_here = []  # (lock id, starts a new guard here)
            if not waitish:
                for m in _LOCK_RECV.finditer(text):
                    acq_here.append((m.group(1).split(".")[-1], True))
                for e in edges_by_line.get(n, []):
                    if e.callee in pacq:
                        arg = _helper_arg(text, e.name)
                        if arg:
                            acq_here.append((arg, True))
                    else:
                        for lid in sorted(acq.get(e.callee, ())):
                            acq_here.append((lid, False))

            active = [gd for gd in guards if gd["susp"] is None]
            for lid, _new in acq_here:
                for gd in active:
                    if gd["id"] == lid:
                        findings.append(
                            Finding(
                                f.path,
                                n,
                                "lock-order",
                                f"lock `{lid}` acquired in `{f.display}` "
                                f"while guard `{gd['var']}` already holds "
                                "it (self-deadlock on a non-reentrant "
                                "Mutex)",
                            )
                        )
                    else:
                        pair_sites[(gd["id"], lid)].append((f.path, n))

            blocking = not waitish and (
                bool(BLOCKING_PAT.search(text))
                or any(
                    e.callee in blocks and e.callee not in pacq
                    for e in edges_by_line.get(n, [])
                )
            )
            if blocking and active:
                held = ", ".join(sorted({gd["id"] for gd in active}))
                findings.append(
                    Finding(
                        f.path,
                        n,
                        "lock-order",
                        f"guard on `{held}` held across a blocking call "
                        f"in `{f.display}`; drop the guard before "
                        "sending/writing",
                    )
                )

            letm = _LET.match(text)
            new_ids = [lid for lid, new in acq_here if new]
            if letm and new_ids:
                guards.append(
                    {
                        "var": letm.group(1),
                        "id": new_ids[0],
                        "depth": line_depth,
                        "susp": None,
                    }
                )

            for dm in _DROP.finditer(text):
                for gd in guards:
                    if gd["var"] == dm.group(1) and gd["susp"] is None:
                        if line_depth <= gd["depth"]:
                            gd["dead"] = True
                        else:
                            gd["susp"] = line_depth
            guards = [gd for gd in guards if not gd.get("dead")]

            depth = depth + text.count("{") - text.count("}")
            for gd in guards:
                if gd["susp"] is not None and depth < gd["susp"]:
                    gd["susp"] = None  # the branch holding the drop closed
            guards = [gd for gd in guards if depth >= gd["depth"]]

    for (a, b), sites in sorted(pair_sites.items()):
        if (b, a) not in pair_sites:
            continue
        other = pair_sites[(b, a)][0]
        for path, line in sites:
            findings.append(
                Finding(
                    path,
                    line,
                    "lock-order",
                    f"inconsistent lock order: `{b}` acquired while "
                    f"holding `{a}` here, but `{a}` is acquired while "
                    f"holding `{b}` at {other[0]}:{other[1]}; pick one "
                    "global order (see LOCK_ORDER in serve/server.rs)",
                )
            )
    return findings


# ---------------------------------------------------------------------------
# r12: untrusted-taint
# ---------------------------------------------------------------------------

TAINT_SCOPE_PREFIX = "rust/src/serve/"

# expressions whose value is attacker-controlled: header lookups and
# parsed-JSON extractors (the Doc/Value API in serve/json.rs)
_SOURCE_CALL = re.compile(
    r"\.\s*(?:header|opt_u64|opt_str|opt_bool|opt_f64|req_str|req_u64|"
    r"req_f64|as_str|as_u64|as_f64|as_num|as_i64)\s*\("
)
_REQ_FIELD = re.compile(r"\breq\w*\s*\.\s*(?:body|path|method|target)\b")
# bounding combinators: the result is capped whatever the input was
_CLAMP = re.compile(r"\.\s*(?:min|clamp)\s*\(|\bmin\s*\(")
_IF_WHILE = re.compile(r"\b(?:if|while)\b")
# simple assignment only (not ==, <=, >=, !=, +=, -=, …)
_ASSIGN = re.compile(
    r"^\s*(?:let\s+(?:mut\s+)?)?([A-Za-z_]\w*)\s*"
    r"(?::\s*[^=<>!]+)?=(?![=])\s*(.+)$"
)


def _untrusted_params(fn):
    """Parameter names whose type marks them as raw request data."""
    out = set()
    for name, ty in fn.params or ():
        flat = ty.replace(" ", "")
        if "[u8]" in flat or flat in ("&str", "&mutstr") or "HttpRequest" in ty:
            out.add(name)
    return out


def _word(v):
    return re.compile(r"\b" + re.escape(v) + r"\b")


def _sink_on(text, v):
    """The sink description if tainted ``v`` feeds a sink on ``text``."""
    wb = r"\b" + re.escape(v) + r"\b"
    checks = (
        (rf"with_capacity\s*\([^)]*{wb}", "allocation size (with_capacity)"),
        (rf"vec!\s*\[[^\]]*;[^\]]*{wb}", "allocation size (vec![_; n])"),
        (rf"\.\s*reserve\s*\([^)]*{wb}", "allocation size (reserve)"),
        (rf"\.\s*split_off\s*\([^)]*{wb}", "offset (split_off panics past len)"),
        (rf"\.\s*drain\s*\([^)]*{wb}", "range (drain panics past len)"),
        (rf"[A-Za-z0-9_)\]?]\[[^\]]*{wb}[^\]]*\]", "slice index"),
        (rf"-(?!>)\s*{wb}|{wb}\s*-(?!>)", "length arithmetic (underflow)"),
    )
    for pat, desc in checks:
        if re.search(pat, text):
            return desc
    return None


def pass_untrusted_taint(crate):
    """(12) untrusted-taint: in serve/, values derived from request
    bytes or parsed JSON must be bounds-checked before they reach an
    allocation size, slice index, or length arithmetic.

    Tracking is per-function and lexical: seeds are untrusted params
    (`&[u8]`/`&str`/`HttpRequest` in serve/) and extractor results
    (`.header(...)`, `Doc::opt_u64(...)`, ...); `let`/assignment lines
    propagate taint; an `if`/`while` comparison against the value, or a
    `.min(...)`/`.clamp(...)` combinator, sanitizes it. Struct fields
    are not tracked across functions (documented gap — the session
    layer re-clamps `max_new_tokens` for exactly that reason)."""
    g = crate.graph
    findings = []
    for i, f in enumerate(g.fns):
        if not f.path.startswith(TAINT_SCOPE_PREFIX) or not f.has_body:
            continue
        u = crate.units[f.path]
        if f.start in u.ctx.tests:
            continue
        tainted = {}  # var -> origin line
        for p in _untrusted_params(f):
            tainted[p] = f.start
        sanitized = set()
        for n in range(f.start, f.end + 1):
            if n in u.ctx.tests:
                continue
            text = u.lexed.line(n)
            live = [
                v
                for v in tainted
                if v not in sanitized and _word(v).search(text)
            ]
            # sinks first: the guard on this line protects later lines
            for v in live:
                sink = _sink_on(text, v)
                if sink:
                    findings.append(
                        Finding(
                            f.path,
                            n,
                            "untrusted-taint",
                            f"untrusted value `{v}` (from line "
                            f"{tainted[v]}) reaches a {sink} in "
                            f"`{f.display}`; compare it against an "
                            "explicit cap first",
                        )
                    )
            # sanitizing guard: an if/while comparison on the value
            if _IF_WHILE.search(text):
                for v in live:
                    wb = re.escape(v)
                    if re.search(
                        rf"\b{wb}\b\s*(?:<=|>=|<|>|==)|"
                        rf"(?:<=|>=|<|>|==)\s*\b{wb}\b",
                        text,
                    ):
                        sanitized.add(v)
            # assignments: propagate or clear taint
            m = _ASSIGN.match(text)
            if m:
                lhs, rhs = m.group(1), m.group(2)
                rhs_tainted = bool(
                    _SOURCE_CALL.search(rhs) or _REQ_FIELD.search(rhs)
                ) or any(
                    v not in sanitized and _word(v).search(rhs)
                    for v in tainted
                )
                if rhs_tainted and _CLAMP.search(rhs):
                    rhs_tainted = False  # bounded at the source
                if rhs_tainted:
                    tainted.setdefault(lhs, n)
                    sanitized.discard(lhs)
                elif lhs in tainted:
                    del tainted[lhs]
                    sanitized.discard(lhs)
    return findings


INTERPROC_RULES = {
    "no-transitive-panic": pass_no_transitive_panic,
    "lock-order": pass_lock_order,
    "untrusted-taint": pass_untrusted_taint,
}
