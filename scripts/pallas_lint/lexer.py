"""Rust lexer: scrub comments / strings / char literals out of source.

The whole analyzer rests on this pass: every downstream regex (rules,
item parser, call extraction) runs over *scrubbed* lines where
comments, string literals, and char literals have been replaced by
spaces. That means a `.unwrap(` inside a log message or a `{` inside a
raw string can never confuse the brace matcher or a rule.

Handled Rust surface syntax:

- line comments (captured for waiver parsing) and block comments,
  including *nested* block comments (`/* a /* b */ c */`)
- regular strings with escapes, byte strings (`b"..."`), byte char
  literals (`b'x'`)
- raw and raw-byte strings with any number of hashes (`r"..."`,
  `r#"..."#`, `r##"..."##`, `br#"..."#`)
- raw identifiers (`r#fn`) pass through as ordinary code
- char literals vs lifetimes/loop labels (`'x'` scrubbed, `'static`
  kept)
- float literals need no special casing here: the decimal point is
  always followed by a digit, an exponent, or nothing — never by an
  identifier character — so method-call extraction downstream cannot
  mistake `1.0` for a call (`1.0.max(2.0)` still yields `.max`).
"""

from __future__ import annotations


class Lexed:
    """Result of scrubbing one Rust file.

    ``lines`` holds the source with every comment, string literal, and
    char literal replaced by spaces (newlines preserved), so downstream
    regexes only ever match real code. ``comments`` holds the comment
    text that was removed, as ``(line_number, text)`` pairs (line
    comments only — waivers must be `//` comments).
    """

    def __init__(self, lines, comments):
        self.lines = lines  # list[str], 1-based via index+1
        self.comments = comments  # list[(line, text)]

    def line(self, n):
        """Scrubbed text of 1-based line ``n`` (empty if out of range)."""
        if 1 <= n <= len(self.lines):
            return self.lines[n - 1]
        return ""


def _is_ident(ch):
    return ch.isalnum() or ch == "_"


def lex(text):
    """Scrub Rust source: return a `Lexed` with code-only lines."""
    out = list(text)
    comments = []
    n = len(text)
    i = 0
    line = 1

    def blank(a, b):
        """Replace text[a:b] with spaces, preserving newlines."""
        for k in range(a, b):
            if out[k] != "\n":
                out[k] = " "

    while i < n:
        ch = text[i]
        if ch == "\n":
            line += 1
            i += 1
            continue
        prev = text[i - 1] if i > 0 else ""

        # -- line comment ---------------------------------------------------
        if ch == "/" and text[i : i + 2] == "//":
            end = text.find("\n", i)
            if end == -1:
                end = n
            comments.append((line, text[i + 2 : end]))
            blank(i, end)
            i = end
            continue

        # -- block comment (nests) -----------------------------------------
        if ch == "/" and text[i : i + 2] == "/*":
            depth = 1
            j = i + 2
            while j < n and depth > 0:
                if text[j : j + 2] == "/*":
                    depth += 1
                    j += 2
                elif text[j : j + 2] == "*/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            blank(i, j)
            line += text.count("\n", i, j)
            i = j
            continue

        # -- raw / byte / byte-raw string prefixes --------------------------
        # `r`, `b`, or `br` not glued to a preceding identifier char may
        # start a literal: r"…", r#"…"#, r##"…"##, b"…", b'…', br#"…"#.
        if ch in "rb" and not _is_ident(prev):
            prefix = 2 if text[i : i + 2] in ("br", "rb") else 1
            has_r = "r" in text[i : i + prefix]
            j = i + prefix
            hashes = 0
            while j < n and text[j] == "#":
                hashes += 1
                j += 1
            if has_r and j < n and text[j] == '"':
                # raw string: ends at '"' + the same number of '#'s, no
                # escape processing (that is the point of raw strings)
                close = '"' + "#" * hashes
                end = text.find(close, j + 1)
                end = n if end == -1 else end + len(close)
                blank(i, end)
                line += text.count("\n", i, end)
                i = end
                continue
            if has_r and hashes > 0:
                # raw identifier (`r#fn`) — ordinary code, skip prefix
                i += prefix + hashes
                continue
            if ch == "b" and text[i : i + 2] == 'b"':
                i += 1  # byte string: treat as a regular string from the quote
                ch = '"'
            elif ch == "b" and text[i : i + 2] == "b'":
                i += 1  # byte char literal
                ch = "'"
            else:
                # plain identifier starting with r/b — ordinary code
                i += 1
                continue

        # -- regular string --------------------------------------------------
        if ch == '"':
            j = i + 1
            while j < n:
                if text[j] == "\\":
                    j += 2
                elif text[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            blank(i, j)
            line += text.count("\n", i, j)
            i = j
            continue

        # -- char literal vs lifetime ---------------------------------------
        if ch == "'":
            if text[i + 1 : i + 2] == "\\":
                # escaped char literal: walk to the closing quote (the
                # escape-skip handles '\'' and '\\')
                j = i + 1
                while j < n and text[j] != "'":
                    j += 2 if text[j] == "\\" else 1
                blank(i, min(j + 1, n))
                i = j + 1
                continue
            if text[i + 2 : i + 3] == "'" and text[i + 1 : i + 2] != "'":
                blank(i, i + 3)  # 'x'
                i += 3
                continue
            i += 1  # lifetime / loop label: keep as code
            continue

        i += 1

    return Lexed("".join(out).split("\n"), comments)
