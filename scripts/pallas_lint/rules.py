"""The per-file (lexical) rules, their scope sets, and shared patterns.

Rules are plain functions `(ctx) -> list[Finding]`; the first docstring
line is the human description shown by `--list-rules` and embedded in
SARIF rule metadata. The interprocedural passes live in
`interproc.py`; this module is deliberately unchanged in spirit from
the single-file analyzer so the rule history stays reviewable.
"""

from __future__ import annotations

import re

from .items import enclosing_fn, fn_spans, test_lines


class Finding:
    """One rule violation at (path, line)."""

    def __init__(self, path, line, rule, message):
        self.path = path
        self.line = line
        self.rule = rule
        self.message = message

    def key(self):
        return (self.line, self.rule)

    def as_dict(self):
        return {
            "path": self.path,
            "line": self.line,
            "rule": self.rule,
            "message": self.message,
        }

    def __eq__(self, other):
        return (
            isinstance(other, Finding)
            and (self.path, self.line, self.rule) ==
                (other.path, other.line, other.rule)
        )

    def __repr__(self):  # pragma: no cover - debug aid
        return f"<Finding {self.path}:{self.line} {self.rule}>"


PANIC_PAT = re.compile(
    r"\.unwrap\s*\(|\.expect\s*\(|\b(?:panic|unreachable|todo|unimplemented)\s*!"
)
# `[` directly adjacent to an expression tail is indexing; array types,
# attributes (`#[...]`), and `vec![...]` never match.
INDEX_PAT = re.compile(r"[A-Za-z0-9_)\]?]\[")
PARTIAL_CMP_PAT = re.compile(r"\bpartial_cmp\b")
FUSED_SYMBOLS = re.compile(
    r"\b(?:quantize_fused|dequantize_fused_into|quantize_blockwise_fused"
    r"|dequantize_blockwise_fused)\b|\bEncoder\s*::"
)
RELAXED_PAT = re.compile(r"\bOrdering\s*::\s*Relaxed\b")
CANCELISH_PAT = re.compile(r"(?i)cancel|abort")
# narrowing targets only: widening to usize/u64/i64/f64 keeps every value
# (BlockId is this repo's u32 alias, so it counts as narrowing too)
LOSSY_AS_PAT = re.compile(r"\bas\s+(?:u8|u16|u32|i8|i16|i32|f32|BlockId)\b")
THREAD_SPAWN_PAT = re.compile(r"\bthread\s*::\s*spawn\b")
# `mpsc::channel` (unbounded) only; `sync_channel` has a word character
# before "channel" and never matches
UNBOUNDED_CHANNEL_PAT = re.compile(r"\bmpsc\s*::\s*channel\b")

HOT_PATH_FILES = {
    "rust/src/engine/scheduler.rs",
    "rust/src/engine/session.rs",
    "rust/src/engine/sampler.rs",
    "rust/src/engine/decode.rs",
    "rust/src/paged/blocks.rs",
    "rust/src/paged/pool.rs",
    # the network boundary parses untrusted bytes: a panic here is a
    # remote denial-of-service, so it gets the line-by-line treatment
    "rust/src/serve/json.rs",
    "rust/src/serve/http.rs",
}

# pub fns under these prefixes form the serving API surface checked by
# result-not-panic-api (minus the HOT_PATH_FILES, which no-hot-path-panic
# already covers line by line)
API_SURFACE_PREFIXES = ("rust/src/engine/", "rust/src/serve/")

ACCOUNTING_PREFIXES = ("rust/src/tensorio/", "rust/src/paged/")
ACCOUNTING_FILES = {"rust/src/engine/scheduler.rs"}


class Ctx:
    """Everything a lexical rule needs about one file."""

    def __init__(self, path, lexed):
        self.path = path  # repo-relative, forward slashes
        self.lexed = lexed
        self.tests = test_lines(lexed)
        self.fns = fn_spans(lexed)

    def code_lines(self, include_tests=False):
        """Yield (1-based line number, scrubbed text) pairs."""
        for idx, text in enumerate(self.lexed.lines):
            n = idx + 1
            if not include_tests and n in self.tests:
                continue
            yield n, text


def rule_no_hot_path_panic(ctx):
    """(1) no-hot-path-panic: panicking calls and `[...]` indexing in the
    serve-loop hot-path modules need a waiver naming the protecting
    invariant."""
    if ctx.path not in HOT_PATH_FILES:
        return []
    out = []
    for n, text in ctx.code_lines():
        if PANIC_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-hot-path-panic",
                    "panicking call on the serve hot path; return an error "
                    "or waive with the protecting invariant",
                )
            )
        if INDEX_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-hot-path-panic",
                    "`[...]` indexing on the serve hot path; use .get()/"
                    "slicing with checks or waive with the bounds invariant",
                )
            )
    return out


def rule_no_float_partial_cmp(ctx):
    """(2) no-float-partial-cmp: `partial_cmp` is how the PR 6 sampler
    NaN panic happened; float ordering must go through `total_cmp`.
    Applies everywhere, including tests."""
    out = []
    for n, text in ctx.code_lines(include_tests=True):
        if PARTIAL_CMP_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-float-partial-cmp",
                    "partial_cmp orders NaN as None (panic/flip hazard); "
                    "use f32::total_cmp / f64::total_cmp",
                )
            )
    return out


def rule_oracle_purity(ctx):
    """(3) oracle-purity: `*_scalar` fns in quant/ are the bit-exactness
    oracle the fused kernels are tested against; they must never route
    through the fused symbols themselves."""
    if "quant/" not in ctx.path:
        return []
    out = []
    for span in ctx.fns:
        if not span.name.endswith("_scalar") or span.start in ctx.tests:
            continue
        for n in range(span.start, span.end + 1):
            if n in ctx.tests:
                continue
            if FUSED_SYMBOLS.search(ctx.lexed.line(n)):
                out.append(
                    Finding(
                        ctx.path,
                        n,
                        "oracle-purity",
                        f"oracle fn `{span.name}` calls a fused-kernel "
                        "symbol; the scalar path must stay independent",
                    )
                )
    return out


def rule_no_relaxed_cancel(ctx):
    """(4) no-relaxed-cancel: `Ordering::Relaxed` on cancellation /
    abort atomics can defer the flag past the next poll; engine code and
    any cancel/abort context must use SeqCst (or Acquire/Release)."""
    out = []
    for n, text in ctx.code_lines():
        if not RELAXED_PAT.search(text):
            continue
        span = enclosing_fn(ctx.fns, n)
        fn_body = (
            "\n".join(
                ctx.lexed.line(k) for k in range(span.start, span.end + 1)
            )
            if span
            else ""
        )
        if (
            ctx.path.startswith("rust/src/engine/")
            or CANCELISH_PAT.search(text)
            or CANCELISH_PAT.search(fn_body)
        ):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-relaxed-cancel",
                    "Ordering::Relaxed on a cancellation/abort atomic; "
                    "use SeqCst so cancel() is seen by the next poll",
                )
            )
    return out


def rule_no_lossy_as(ctx):
    """(5) no-lossy-as-in-accounting: narrowing `as` casts silently
    truncate; byte/token-accounting modules must use `try_from` (the
    PR 5 f16 byte-accounting bug class). Widening casts are exempt."""
    if (
        not ctx.path.startswith(ACCOUNTING_PREFIXES)
        and ctx.path not in ACCOUNTING_FILES
    ):
        return []
    out = []
    for n, text in ctx.code_lines():
        if LOSSY_AS_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-lossy-as",
                    "narrowing `as` cast in accounting code truncates "
                    "silently; use try_from or waive with the range invariant",
                )
            )
    return out


def rule_scoped_threads_only(ctx):
    """(6) scoped-threads-only: all library parallelism goes through
    `std::thread::scope` (joins on panic, borrows locals) — bare
    `thread::spawn` leaks detached threads on early return."""
    if not ctx.path.startswith("rust/src/"):
        return []
    out = []
    for n, text in ctx.code_lines():
        if THREAD_SPAWN_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "scoped-threads-only",
                    "bare thread::spawn in library code; use "
                    "std::thread::scope (see quant/kernels.rs)",
                )
            )
    return out


def rule_result_not_panic_api(ctx):
    """(7) result-not-panic-api: `pub fn`s in engine/ and serve/ are the
    serving API surface; they must surface errors as `Result`, not
    panics. The hot-path files are already covered line-by-line by
    no-hot-path-panic and are exempt here to avoid double findings."""
    if (
        not ctx.path.startswith(API_SURFACE_PREFIXES)
        or ctx.path in HOT_PATH_FILES
    ):
        return []
    out = []
    for span in ctx.fns:
        if not span.is_pub or span.start in ctx.tests:
            continue
        for n in range(span.start, span.end + 1):
            if n in ctx.tests:
                continue
            if PANIC_PAT.search(ctx.lexed.line(n)):
                out.append(
                    Finding(
                        ctx.path,
                        n,
                        "result-not-panic-api",
                        f"pub fn `{span.name}` contains a panicking call; "
                        "engine APIs return Result",
                    )
                )
    return out


def rule_no_unbounded_send(ctx):
    """(8) no-unbounded-send: an unbounded `mpsc::channel` in the
    serving stack lets one slow consumer buffer tokens without limit —
    the overload-control plane depends on bounded `sync_channel`s whose
    full-send failure feeds back into cancellation. Bound the channel
    or waive with the invariant that bounds it externally."""
    if not ctx.path.startswith(API_SURFACE_PREFIXES):
        return []
    out = []
    for n, text in ctx.code_lines():
        if UNBOUNDED_CHANNEL_PAT.search(text):
            out.append(
                Finding(
                    ctx.path,
                    n,
                    "no-unbounded-send",
                    "unbounded mpsc::channel in the serving stack; use "
                    "mpsc::sync_channel with an explicit depth so a slow "
                    "consumer hits backpressure instead of unbounded memory",
                )
            )
    return out


RULES = {
    "no-hot-path-panic": rule_no_hot_path_panic,
    "no-float-partial-cmp": rule_no_float_partial_cmp,
    "oracle-purity": rule_oracle_purity,
    "no-relaxed-cancel": rule_no_relaxed_cancel,
    "no-lossy-as": rule_no_lossy_as,
    "scoped-threads-only": rule_scoped_threads_only,
    "result-not-panic-api": rule_result_not_panic_api,
    "no-unbounded-send": rule_no_unbounded_send,
}

META_RULES = ("unused-waiver", "waiver-syntax")
