"""Analysis orchestration: units -> crate -> findings.

The per-file rules run on each unit independently; the interprocedural
passes run once over the whole crate. Waivers are applied *after* both
so a single waiver can suppress a lexical finding, stop transitive
propagation, or shield a seed — and `unused-waiver` accounting sees
every use. `lint_text` wraps a single file as a one-unit crate, which
keeps the fixture self-test and unit tests working unchanged.
"""

from __future__ import annotations

import subprocess
from pathlib import Path

from .callgraph import CallGraph
from .interproc import INTERPROC_RULES
from .lexer import lex
from .rules import META_RULES, RULES, Ctx, Finding
from .waivers import parse_waivers

REPO_ROOT = Path(__file__).resolve().parents[2]

# every name a waiver may legally cite
KNOWN_RULES = dict(RULES)
KNOWN_RULES.update(INTERPROC_RULES)


class Unit:
    """One Rust file: path, scrubbed source, per-file context, waivers."""

    def __init__(self, path, text):
        self.path = path  # repo-relative, forward slashes
        self.lexed = lex(text)
        self.ctx = Ctx(path, self.lexed)
        self.waivers, self.waiver_syntax = parse_waivers(
            path, self.lexed, KNOWN_RULES
        )


class Crate:
    """All units plus the crate-wide call graph."""

    def __init__(self, units):
        self.units = {u.path: u for u in units}
        self.graph = CallGraph(units)


def _dedupe(findings):
    seen = set()
    out = []
    for f in sorted(findings, key=lambda f: f.key()):
        if f.key() not in seen:
            seen.add(f.key())
            out.append(f)
    return out


def _apply_waivers(unit, findings):
    kept = []
    for f in findings:
        waived = False
        for w in unit.waivers:
            if w.target_line == f.line and f.rule in w.rules:
                w.used = True
                waived = True
        if not waived:
            kept.append(f)
    meta = list(unit.waiver_syntax)
    for w in unit.waivers:
        if not w.used:
            meta.append(
                Finding(
                    unit.path,
                    w.comment_line,
                    "unused-waiver",
                    "waiver suppresses nothing "
                    f"(allow({', '.join(w.rules)})); remove it",
                )
            )
    return sorted(kept + meta, key=lambda f: (f.line, f.rule))


def analyze(units):
    """Run everything over ``units``. Returns (findings, crate)."""
    crate = Crate(units)
    by_path = {u.path: [] for u in units}
    for u in units:
        for rule_fn in RULES.values():
            by_path[u.path].extend(rule_fn(u.ctx))
    for pass_fn in INTERPROC_RULES.values():
        for f in pass_fn(crate):
            by_path.setdefault(f.path, []).append(f)
    findings = []
    for u in crate.units.values():
        findings.extend(_apply_waivers(u, _dedupe(by_path[u.path])))
    return findings, crate


def lint_text(path, text):
    """Lint one file's content under repo-relative ``path``.

    Runs every rule (the interprocedural passes see a one-file crate),
    applies waivers, and reports unused waivers. Returns a list of
    `Finding`s, deduplicated per (line, rule) and sorted by line."""
    findings, _ = analyze([Unit(path, text)])
    return findings


def _collect_files(paths):
    files = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.rs")))
        elif p.suffix == ".rs":
            files.append(p)
        else:
            raise SystemExit(f"pallas-lint: not a .rs file or directory: {p}")
    return files


def _rel(path):
    try:
        return Path(path).resolve().relative_to(REPO_ROOT).as_posix()
    except ValueError:
        return Path(path).as_posix()


def lint_paths_ex(paths, report_rel=None):
    """Lint every .rs file under ``paths``.

    ``report_rel``: optional set of repo-relative paths to *report* on;
    the full file set still feeds the call graph so interprocedural
    results stay whole-crate accurate (this is how `--changed` keeps
    cross-file edges). Returns (findings, checked_files, crate)."""
    files = _collect_files(paths)
    units = [
        Unit(_rel(f), f.read_text(encoding="utf-8")) for f in files
    ]
    findings, crate = analyze(units)
    checked = len(files)
    if report_rel is not None:
        report_rel = set(report_rel)
        findings = [f for f in findings if f.path in report_rel]
        checked = len(report_rel)
    return findings, checked, crate


def lint_paths(paths):
    """Back-compat wrapper: (findings, checked_files)."""
    findings, checked, _ = lint_paths_ex(paths)
    return findings, checked


def changed_paths(ref):
    """Repo-relative .rs paths under rust/ differing from git ``ref``
    (including uncommitted edits); deleted files are skipped."""
    proc = subprocess.run(
        ["git", "-C", str(REPO_ROOT), "diff", "--name-only", ref, "--", "rust"],
        capture_output=True,
        text=True,
    )
    if proc.returncode != 0:
        raise SystemExit(
            f"pallas-lint: git diff against {ref!r} failed: "
            + proc.stderr.strip()
        )
    out = []
    for line in proc.stdout.splitlines():
        line = line.strip()
        if line.endswith(".rs") and (REPO_ROOT / line).is_file():
            out.append(line)
    return out


def rule_docs():
    """(rule id, first docstring line) for every rule, lint + meta."""
    out = []
    for name, fn in {**RULES, **INTERPROC_RULES}.items():
        doc = (fn.__doc__ or "").split("\n")[0].strip()
        out.append((name, doc))
    for name in META_RULES:
        out.append((name, "(meta) waiver hygiene, always on"))
    return out
