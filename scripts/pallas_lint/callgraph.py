"""Crate-wide call graph with honest resolution accounting.

Resolution policy (first match wins):

1. **path calls** (`a::b::f(...)`): `Self::f` resolves through the
   caller's impl type; a qualifier naming a crate impl type resolves
   through that type's methods; a qualifier naming a module (by file
   stem or inline `mod` name) resolves within that module's file(s);
   otherwise fall through to unique-name.
2. **bare calls** (`f(...)`): same-file definition first, then a
   crate-wide *unique* name.
3. **method calls** (`x.f(...)`): same-file unique method first, then
   a crate-wide unique method name.

Anything else is recorded in ``unresolved`` with a reason —
``external`` (no crate definition, e.g. `std`) or ``ambiguous``
(several candidate definitions; guessing would fabricate edges, and a
fabricated edge is how an interprocedural linter starts lying). The
counts are surfaced in ``--json`` so the resolution rate is visible.

Test functions are excluded from both the node set and the name index:
edges into test helpers would let `#[cfg(test)]` code poison
panic-reachability for production APIs.
"""

from __future__ import annotations

from collections import defaultdict
from pathlib import PurePosixPath

from .items import extract_calls, parse_items


class Edge:
    __slots__ = ("caller", "callee", "line", "name", "guarded")

    def __init__(self, caller, callee, line, name, guarded):
        self.caller = caller  # fn index
        self.callee = callee  # fn index
        self.line = line  # call-site line in the caller's file
        self.name = name
        self.guarded = guarded  # inside catch_unwind(...)


class CallGraph:
    """Nodes are non-test crate fns; edges are resolved call sites."""

    def __init__(self, units):
        """``units``: iterable of objects with .path, .lexed, .ctx
        (ctx provides the test-line set)."""
        self.fns = []  # list[FnItem]
        self.items_by_path = {}
        self.edges = defaultdict(list)  # caller idx -> [Edge]
        self.rev = defaultdict(list)  # callee idx -> [Edge]
        self.unresolved = []  # [{file, line, name, kind, reason}]
        self.calls_total = 0

        units = list(units)
        for u in units:
            items = parse_items(u.path, u.lexed, u.ctx.tests)
            self.items_by_path[u.path] = items
            for it in items:
                if not it.is_test:
                    self.fns.append(it)

        # ---- name indexes (bodied fns only: trait declarations must
        # not shadow their single implementation) --------------------
        self._by_name = defaultdict(list)
        self._by_file_name = defaultdict(list)
        self._by_type_method = defaultdict(list)
        self._files_by_stem = defaultdict(set)
        for i, f in enumerate(self.fns):
            self._files_by_stem[PurePosixPath(f.path).stem].add(f.path)
            if not f.has_body:
                continue
            self._by_name[f.name].append(i)
            self._by_file_name[(f.path, f.name)].append(i)
            if f.impl_type:
                self._by_type_method[(f.impl_type, f.name)].append(i)
        for u in units:
            self._files_by_stem[PurePosixPath(u.path).stem].add(u.path)

        # ---- resolve every call site --------------------------------
        lexed_by_path = {u.path: u.lexed for u in units}
        for i, f in enumerate(self.fns):
            if not f.has_body:
                continue
            for call in extract_calls(lexed_by_path[f.path], f):
                self.calls_total += 1
                j, reason = self._resolve(call, f)
                if j is None:
                    self.unresolved.append(
                        {
                            "file": f.path,
                            "line": call.line,
                            "name": call.name,
                            "kind": call.kind,
                            "reason": reason,
                        }
                    )
                    continue
                e = Edge(i, j, call.line, call.name, call.guarded)
                self.edges[i].append(e)
                self.rev[j].append(e)

    # ------------------------------------------------------------------
    def _unique(self, candidates):
        """(index, reason) for a candidate list under the honesty rule."""
        if len(candidates) == 1:
            return candidates[0], None
        if not candidates:
            return None, "external"
        return None, "ambiguous"

    def _resolve(self, call, caller):
        if call.kind == "path":
            qual = call.qual
            if qual == "Self" and caller.impl_type:
                qual = caller.impl_type
            cands = self._by_type_method.get((qual, call.name))
            if cands:
                return self._unique(cands)
            # module qualifier: any crate file whose stem matches
            files = self._files_by_stem.get(qual)
            if files:
                cands = []
                for fp in files:
                    cands.extend(self._by_file_name.get((fp, call.name), []))
                if cands:
                    return self._unique(cands)
                return None, "external"
            return self._unique(self._by_name.get(call.name, []))
        if call.kind == "bare":
            cands = self._by_file_name.get((caller.path, call.name), [])
            if cands:
                return self._unique(cands)
            return self._unique(self._by_name.get(call.name, []))
        # method call: same-file methods first, then crate-wide
        cands = [
            i
            for i in self._by_file_name.get((caller.path, call.name), [])
            if self.fns[i].impl_type
        ]
        if cands:
            return self._unique(cands)
        cands = [
            i
            for i in self._by_name.get(call.name, [])
            if self.fns[i].impl_type
        ]
        return self._unique(cands)

    # ------------------------------------------------------------------
    def index_of(self, path, name):
        """Index of the unique bodied fn (path, name), or None (tests'
        convenience accessor)."""
        c = self._by_file_name.get((path, name), [])
        return c[0] if len(c) == 1 else None

    def stats(self):
        edges = sum(len(v) for v in self.edges.values())
        ambiguous = sum(
            1 for u in self.unresolved if u["reason"] == "ambiguous"
        )
        return {
            "functions": len(self.fns),
            "calls": self.calls_total,
            "edges": edges,
            "external": len(self.unresolved) - ambiguous,
            "ambiguous": ambiguous,
        }
