"""SARIF 2.1.0 output for CI annotation upload.

Minimal but valid: one run, the full rule table as driver rules (so
viewers can show descriptions), one result per finding with a physical
location. The plain-text output stays the CI failure gate — SARIF is
presentation only.
"""

from __future__ import annotations

SARIF_SCHEMA = "https://json.schemastore.org/sarif-2.1.0.json"
SARIF_VERSION = "2.1.0"


def sarif_report(findings, docs):
    """Findings + (rule, description) pairs -> a SARIF 2.1.0 dict."""
    rule_ids = [rid for rid, _ in docs]
    rules = [
        {
            "id": rid,
            "shortDescription": {"text": doc or rid},
        }
        for rid, doc in docs
    ]
    results = []
    for f in findings:
        results.append(
            {
                "ruleId": f.rule,
                "ruleIndex": rule_ids.index(f.rule)
                if f.rule in rule_ids
                else -1,
                "level": "error",
                "message": {"text": f.message},
                "locations": [
                    {
                        "physicalLocation": {
                            "artifactLocation": {"uri": f.path},
                            "region": {"startLine": max(1, f.line)},
                        }
                    }
                ],
            }
        )
    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [
            {
                "tool": {
                    "driver": {
                        "name": "pallas-lint",
                        "rules": rules,
                    }
                },
                "results": results,
            }
        ],
    }
