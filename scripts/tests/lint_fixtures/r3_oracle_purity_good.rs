// pallas-lint-fixture: path = rust/src/quant/tensor.rs
// pallas-lint-expect: clean

pub fn quantize_scalar(xs: &[f32]) -> Vec<u8> {
    xs.iter().map(|&x| (x * 15.0).round() as u8).collect()
}

pub fn quantize(xs: &[f32]) -> Vec<u8> {
    quantize_fused(xs)
}
