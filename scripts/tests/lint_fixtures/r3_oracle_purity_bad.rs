// pallas-lint-fixture: path = rust/src/quant/tensor.rs
// pallas-lint-expect: oracle-purity @ 6

pub fn quantize_scalar(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend(quantize_fused(xs));
    out
}
