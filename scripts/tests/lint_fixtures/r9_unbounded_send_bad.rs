// pallas-lint-fixture: path = rust/src/serve/server.rs
// pallas-lint-expect: no-unbounded-send @ 7

use std::sync::mpsc;

pub fn drive() {
    let (tx, rx) = mpsc::channel::<i32>();
    drop((tx, rx));
}
