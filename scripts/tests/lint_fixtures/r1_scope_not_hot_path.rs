// pallas-lint-fixture: path = rust/src/util/stats.rs
// pallas-lint-expect: clean

fn mean(xs: &[f64]) -> f64 {
    let first = xs.first().unwrap();
    first + xs[0]
}
