// pallas-lint-fixture: path = rust/src/serve/server.rs
// pallas-lint-expect: result-not-panic-api @ 6

// serve/server.rs is API surface, not in the line-by-line hot-path set
pub fn decode(body: &[u8]) -> String {
    String::from_utf8(body.to_vec()).unwrap()
}

fn private_helper(body: &[u8]) -> Option<&u8> {
    body.first()
}
