// pallas-lint-fixture: path = rust/src/serve/server.rs
// pallas-lint-expect: clean

struct Doc;

impl Doc {
    fn opt_u64(&self, _key: &str) -> u64 {
        7
    }
}

const MAX_REPLY: usize = 4096;

fn shape_reply(doc: &Doc, table: &[u8]) -> Vec<u8> {
    let n = (doc.opt_u64("count") as usize).min(MAX_REPLY);
    let mut out = Vec::with_capacity(n);
    let idx = doc.opt_u64("idx") as usize;
    if idx < table.len() {
        out.push(table[idx]);
    }
    out
}
