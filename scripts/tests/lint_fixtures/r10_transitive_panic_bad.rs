// pallas-lint-fixture: path = rust/src/engine/adapters.rs
// pallas-lint-expect: no-transitive-panic @ 13

fn slot_of(name: &str) -> usize {
    name.parse().unwrap()
}

fn resolve(name: &str) -> usize {
    slot_of(name)
}

pub fn activate(name: &str) -> usize {
    resolve(name)
}
