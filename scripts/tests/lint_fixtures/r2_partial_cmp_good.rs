// pallas-lint-fixture: path = rust/src/util/stats.rs
// pallas-lint-expect: clean

fn sort(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}
