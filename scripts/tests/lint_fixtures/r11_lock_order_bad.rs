// pallas-lint-fixture: path = rust/src/serve/server.rs
// pallas-lint-expect: lock-order @ 18; lock-order @ 25; lock-order @ 32
// pallas-lint-expect: lock-order @ 40

use std::sync::{Mutex, MutexGuard};

struct Shared {
    inbox: Mutex<u32>,
    stats: Mutex<u32>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn double_acquire(s: &Shared) {
    let a = lock(&s.inbox);
    let b = lock(&s.inbox);
    drop(b);
    drop(a);
}

fn order_ab(s: &Shared) {
    let a = lock(&s.inbox);
    let b = lock(&s.stats);
    drop(b);
    drop(a);
}

fn order_ba(s: &Shared) {
    let b = lock(&s.stats);
    let a = lock(&s.inbox);
    drop(a);
    drop(b);
}

fn blocking_while_held(s: &Shared, out: &mut std::net::TcpStream) {
    use std::io::Write;
    let g = lock(&s.inbox);
    out.flush().ok();
    drop(g);
}
