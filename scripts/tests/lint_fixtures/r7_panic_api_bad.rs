// pallas-lint-fixture: path = rust/src/engine/mod.rs
// pallas-lint-expect: result-not-panic-api @ 7

pub struct Registry;

pub fn load(name: &str) -> u32 {
    name.parse().unwrap()
}
