// pallas-lint-fixture: path = rust/src/serve/json.rs
// pallas-lint-expect: no-hot-path-panic @ 6; no-hot-path-panic @ 7

// serve/json.rs parses untrusted bytes, so it is in the hot-path scope
fn first_byte(input: &[u8]) -> u8 {
    let b = input[0];
    b.checked_add(1).unwrap()
}
