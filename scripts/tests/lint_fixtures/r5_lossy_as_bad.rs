// pallas-lint-fixture: path = rust/src/tensorio/mod.rs
// pallas-lint-expect: no-lossy-as @ 5

pub fn header_len(header: &[u8]) -> u32 {
    header.len() as u32
}
