// pallas-lint-fixture: path = rust/src/engine/scheduler.rs
// pallas-lint-expect: clean

// a comment mentioning .unwrap() and partial_cmp and rows[row]
/* block comment: panic!("x") /* nested: thread::spawn */ still comment */
fn describe(b: &[u8]) -> String {
    let s = "calls .unwrap() and .expect(\"x\") and panic!";
    let r = r#"raw: partial_cmp and rows[i] and "quoted" stuff"#;
    let raw2 = r"thread::spawn inside a plain raw string";
    let bytes = b"byte string with .unwrap() and arr[0]";
    let quote = '\'';
    let newline = '\n';
    let lt: &'static str = "partial_cmp in a string after a lifetime";
    format!("{s}{r}{raw2}{quote}{newline}{lt}{}", String::from_utf8_lossy(bytes))
}

fn hardened(t: (f64, f64)) -> f64 {
    let hashes = r##"raw with "# inside: .unwrap() stays text"##;
    let braw = br#"byte raw with panic!("x") and rows[i]"#;
    let bplain = b"plain byte string: .expect(\"y\")";
    let x = 1.0.max(2.5_f64);
    let y = t.0 + t.1;
    let mut acc = 0.0;
    for _step in 0..3 {
        acc += x.min(y);
    }
    acc + hashes.len() as f64 + braw.len() as f64 + bplain.len() as f64
}
