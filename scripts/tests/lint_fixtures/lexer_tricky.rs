// pallas-lint-fixture: path = rust/src/engine/scheduler.rs
// pallas-lint-expect: clean

// a comment mentioning .unwrap() and partial_cmp and rows[row]
/* block comment: panic!("x") /* nested: thread::spawn */ still comment */
fn describe(b: &[u8]) -> String {
    let s = "calls .unwrap() and .expect(\"x\") and panic!";
    let r = r#"raw: partial_cmp and rows[i] and "quoted" stuff"#;
    let raw2 = r"thread::spawn inside a plain raw string";
    let bytes = b"byte string with .unwrap() and arr[0]";
    let quote = '\'';
    let newline = '\n';
    let lt: &'static str = "partial_cmp in a string after a lifetime";
    format!("{s}{r}{raw2}{quote}{newline}{lt}{}", String::from_utf8_lossy(bytes))
}
