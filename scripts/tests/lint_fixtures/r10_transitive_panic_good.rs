// pallas-lint-fixture: path = rust/src/engine/adapters.rs
// pallas-lint-expect: clean

fn slot_of(name: &str) -> usize {
    name.parse().unwrap_or(0)
}

fn registered_slot(name: &str) -> usize {
    // pallas-lint: allow(no-transitive-panic) — adapter names are validated at registration time
    name.parse().unwrap()
}

fn risky_slot(name: &str) -> usize {
    name.parse().expect("caller catches")
}

pub fn activate(name: &str) -> usize {
    slot_of(name) + registered_slot(name)
}

pub fn shielded(name: &str) -> usize {
    std::panic::catch_unwind(
        || risky_slot(name)
    )
    .unwrap_or(0)
}
