// pallas-lint-fixture: path = rust/src/engine/scheduler.rs
// pallas-lint-expect: unused-waiver @ 5

fn ok() -> u32 {
    1 + 1 // pallas-lint: allow(no-hot-path-panic) — nothing to waive here
}
