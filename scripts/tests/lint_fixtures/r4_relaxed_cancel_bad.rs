// pallas-lint-fixture: path = rust/src/runtime/client.rs
// pallas-lint-expect: no-relaxed-cancel @ 5

pub fn cancel(flag: &std::sync::atomic::AtomicBool) {
    flag.store(true, Ordering::Relaxed);
}
