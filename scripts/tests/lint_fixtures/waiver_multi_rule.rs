// pallas-lint-fixture: path = rust/src/engine/sampler.rs
// pallas-lint-expect: clean

fn pick(xs: &[(f32, usize)]) -> usize {
    // pallas-lint: allow(no-hot-path-panic, no-float-partial-cmp) — xs non-empty by construction; NaN filtered upstream
    xs.iter().max_by(|a, b| a.0.partial_cmp(&b.0).unwrap()).unwrap().1
}
