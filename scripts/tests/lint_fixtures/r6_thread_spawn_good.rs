// pallas-lint-fixture: path = rust/src/quant/kernels.rs
// pallas-lint-expect: clean

pub fn run() {
    std::thread::scope(|s| {
        s.spawn(|| {});
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn spawn_in_test_is_fine() {
        std::thread::spawn(|| {}).join().unwrap();
    }
}
