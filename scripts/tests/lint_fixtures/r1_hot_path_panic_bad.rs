// pallas-lint-fixture: path = rust/src/engine/scheduler.rs
// pallas-lint-expect: no-hot-path-panic @ 6; no-hot-path-panic @ 7
// pallas-lint-expect: no-hot-path-panic @ 8; no-hot-path-panic @ 9

fn poll(rows: &mut [Option<u32>], row: usize) -> u32 {
    let v = rows[row].take().unwrap();
    let w = v.checked_add(1).expect("no overflow");
    if w == 0 { unreachable!("w > 0") }
    todo!("rest of poll")
}
