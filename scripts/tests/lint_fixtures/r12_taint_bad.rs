// pallas-lint-fixture: path = rust/src/serve/server.rs
// pallas-lint-expect: untrusted-taint @ 15; untrusted-taint @ 16
// pallas-lint-expect: untrusted-taint @ 17

struct Doc;

impl Doc {
    fn opt_u64(&self, _key: &str) -> u64 {
        7
    }
}

fn shape_reply(doc: &Doc, table: &[u8]) -> Vec<u8> {
    let n = doc.opt_u64("count") as usize;
    let mut out = Vec::with_capacity(n);
    out.push(table[n]);
    let tail = n - 1;
    out.truncate(tail);
    out
}
