// pallas-lint-fixture: path = rust/src/serve/server.rs
// pallas-lint-expect: clean

use std::sync::mpsc;

pub fn drive() {
    let (tx, rx) = mpsc::sync_channel::<i32>(64);
    drop((tx, rx));
}

pub fn control_plane() {
    // pallas-lint: allow(no-unbounded-send) — shutdown signal: at most one message is ever sent
    let (tx, rx) = mpsc::channel::<()>();
    drop((tx, rx));
}

#[cfg(test)]
mod tests {
    #[test]
    fn unbounded_in_tests_is_fine() {
        let (tx, rx) = std::sync::mpsc::channel::<i32>();
        tx.send(1).unwrap();
        assert_eq!(rx.recv().unwrap(), 1);
    }
}
