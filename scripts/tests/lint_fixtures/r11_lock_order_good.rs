// pallas-lint-fixture: path = rust/src/serve/server.rs
// pallas-lint-expect: clean

use std::sync::{Condvar, Mutex, MutexGuard};

struct Shared {
    inbox: Mutex<u32>,
    cv: Condvar,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn wait_for_work(s: &Shared) -> u32 {
    let mut g = lock(&s.inbox);
    while *g == 0 {
        g = s.cv.wait(g).unwrap_or_else(|p| p.into_inner());
    }
    *g
}

fn drop_before_write(s: &Shared, out: &mut std::net::TcpStream) {
    use std::io::Write;
    let g = lock(&s.inbox);
    let n = *g;
    drop(g);
    out.write_all(&n.to_le_bytes()).ok();
}
