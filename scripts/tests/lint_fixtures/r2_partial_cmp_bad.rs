// pallas-lint-fixture: path = rust/src/util/stats.rs
// pallas-lint-expect: no-float-partial-cmp @ 5; no-float-partial-cmp @ 11

fn sort(xs: &mut Vec<f64>) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

#[cfg(test)]
mod tests {
    fn in_test_still_fires(a: f64, b: f64) -> bool {
        a.partial_cmp(&b).is_some()
    }
}
