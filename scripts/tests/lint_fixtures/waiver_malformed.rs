// pallas-lint-fixture: path = rust/src/engine/scheduler.rs
// pallas-lint-expect: waiver-syntax @ 5; waiver-syntax @ 6; no-hot-path-panic @ 7

fn bad(rows: &[u32]) -> u32 {
    // pallas-lint: allow(no-hot-path-panic)
    // pallas-lint: allow(not-a-rule) — reason text
    rows[0]
}
