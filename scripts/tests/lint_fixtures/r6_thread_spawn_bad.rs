// pallas-lint-fixture: path = rust/src/util/bench.rs
// pallas-lint-expect: scoped-threads-only @ 5

pub fn run() {
    std::thread::spawn(|| {});
}
