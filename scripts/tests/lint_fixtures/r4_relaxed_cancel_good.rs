// pallas-lint-fixture: path = rust/src/runtime/executor.rs
// pallas-lint-expect: clean

pub fn bump(counter: &std::sync::atomic::AtomicU64) {
    counter.fetch_add(1, Ordering::Relaxed);
}
