// pallas-lint-fixture: path = rust/src/paged/pool.rs
// pallas-lint-expect: clean

pub fn widen(id: u32, bytes: u32) -> (usize, u64) {
    (id as usize, bytes as u64)
}

pub fn narrow(len: usize) -> Option<u32> {
    u32::try_from(len).ok()
}
