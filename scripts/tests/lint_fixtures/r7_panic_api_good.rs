// pallas-lint-fixture: path = rust/src/engine/mod.rs
// pallas-lint-expect: clean

pub fn load(name: &str) -> Result<u32, std::num::ParseIntError> {
    name.parse()
}

fn private_helper(name: &str) -> u32 {
    name.parse().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(super::load("3").unwrap(), 3);
    }
}
