// pallas-lint-fixture: path = rust/src/engine/scheduler.rs
// pallas-lint-expect: clean

fn poll(rows: &mut [Option<u32>], row: usize) -> Option<u32> {
    // pallas-lint: allow(no-hot-path-panic) — row < rows.len(): admit bounds-checks row ids
    let v = rows[row].take();
    v
}

fn tail(xs: &[u32]) -> u32 {
    *xs.last().expect("non-empty by admission") // pallas-lint: allow(no-hot-path-panic) — admit rejects empty prompts
}

fn safe(rows: &[u32], row: usize) -> Option<u32> {
    rows.get(row).copied()
}
