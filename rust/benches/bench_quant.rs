//! Quantization benches — the kernels behind Table 2 / Figure 3, engine
//! weight prep, and checkpoint round-trips. Throughput in params/sec.
//!
//! Headline: the fused-vs-scalar comparison on a 4096x4096 NF4+DQ weight
//! (the `QuantizedTensor` hot path), measured three ways — scalar
//! reference tier, fused single-thread, fused multicore — with derived
//! speedups printed and persisted.
//!
//! Flags (after `--`):
//!   --smoke        tiny tensors + short budgets (CI bit-rot check)
//!   --json <path>  write results + speedups as JSON (the perf
//!                  trajectory file: `make bench-quant` writes
//!                  BENCH_quant.json at the repo root)

use std::path::PathBuf;

use qlora::quant::codebook::{Codebook, DType};
use qlora::quant::double::{double_dequantize, double_quantize};
use qlora::quant::kernels::{
    auto_threads, dequantize_blockwise_fused, quantize_blockwise_fused,
};
use qlora::quant::tensor::QuantizedTensor;
use qlora::quant::{
    dequantize_blockwise, pack_nibbles, quantize_blockwise, unpack_nibbles,
};
use qlora::util::bench::Bencher;
use qlora::util::json::Value;
use qlora::util::rng::Rng;

fn main() {
    let mut smoke = false;
    let mut json_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--json" => {
                json_path = Some(PathBuf::from(
                    args.next().expect("--json needs a path"),
                ))
            }
            // cargo passes --bench to every bench binary, even with
            // harness = false (criterion ignores it the same way)
            "--bench" => {}
            other => panic!("unknown bench_quant flag {other:?}"),
        }
    }
    if smoke {
        std::env::set_var("QLORA_BENCH_FAST", "1");
    }

    let mut b = Bencher::new();
    let mut rng = Rng::new(1);
    let n = if smoke { 64 * 256 } else { 64 * 4096 };
    let x: Vec<f32> = rng.normal_vec_f32(n);

    b.group("blockwise quantize (block=64): scalar vs fused");
    for dt in [DType::NF4, DType::FP4E2M1, DType::Int4, DType::Int8] {
        let cb = Codebook::new(dt);
        b.bench_items(&format!("quantize/{}/scalar", dt.name()), n, || {
            quantize_blockwise(&x, &cb, 64).unwrap()
        });
        b.bench_items(&format!("quantize/{}/fused1", dt.name()), n, || {
            quantize_blockwise_fused(&x, &cb, 64, Some(1)).unwrap()
        });
        b.bench_items(&format!("quantize/{}/fusedN", dt.name()), n, || {
            quantize_blockwise_fused(&x, &cb, 64, None).unwrap()
        });
    }

    b.group("blockwise dequantize: scalar vs fused");
    let cb = Codebook::new(DType::NF4);
    let (codes, absmax) = quantize_blockwise(&x, &cb, 64).unwrap();
    b.bench_items("dequantize/nf4/scalar", n, || {
        dequantize_blockwise(&codes, &absmax, &cb, 64).unwrap()
    });
    b.bench_items("dequantize/nf4/fused1", n, || {
        dequantize_blockwise_fused(&codes, &absmax, &cb, 64, Some(1)).unwrap()
    });
    b.bench_items("dequantize/nf4/fusedN", n, || {
        dequantize_blockwise_fused(&codes, &absmax, &cb, 64, None).unwrap()
    });

    b.group("nibble packing (scalar tier)");
    b.bench_items("pack", n, || pack_nibbles(&codes).unwrap());
    let packed = pack_nibbles(&codes).unwrap();
    b.bench_items("unpack", n, || unpack_nibbles(&packed));

    b.group("double quantization (constants)");
    b.bench_items("double_quantize", absmax.len(), || {
        double_quantize(&absmax, 256).unwrap()
    });
    let dq = double_quantize(&absmax, 256).unwrap();
    b.bench_items("double_dequantize", absmax.len(), || {
        double_dequantize(&dq).unwrap()
    });

    // ----------------------------------------------------------------
    // Headline: the full weight container (quantize+pack+DQ / LUT
    // dequant) — the engine weight-prep and checkpoint-round-trip path.
    // ----------------------------------------------------------------
    let (h, o) = if smoke { (512, 512) } else { (4096, 4096) };
    let np = h * o;
    let threads = auto_threads(np);
    // the fused1 passes pin the kernels to one thread via the env knob;
    // restore any externally set value so the fusedN passes (and the
    // `threads` recorded above) stay consistent with the caller's intent
    let prior_threads = std::env::var("QLORA_QUANT_THREADS").ok();
    let restore_threads = || match &prior_threads {
        Some(v) => std::env::set_var("QLORA_QUANT_THREADS", v),
        None => std::env::remove_var("QLORA_QUANT_THREADS"),
    };
    b.group(&format!(
        "QuantizedTensor {h}x{o} NF4+DQ: scalar vs fused ({threads} threads)"
    ));
    let w: Vec<f32> = rng.normal_vec_f32(np);
    let qt = |name: &str| format!("QuantizedTensor::quantize {h}x{o}/{name}");
    let dt_ = |name: &str| format!("QuantizedTensor::dequantize {h}x{o}/{name}");
    b.bench_items(&qt("scalar"), np, || {
        QuantizedTensor::quantize_scalar(&w, (h, o), DType::NF4, 64, Some(256))
            .unwrap()
    });
    std::env::set_var("QLORA_QUANT_THREADS", "1");
    b.bench_items(&qt("fused1"), np, || {
        QuantizedTensor::quantize(&w, (h, o), DType::NF4, 64, Some(256))
            .unwrap()
    });
    restore_threads();
    b.bench_items(&qt("fusedN"), np, || {
        QuantizedTensor::quantize(&w, (h, o), DType::NF4, 64, Some(256))
            .unwrap()
    });
    let q = QuantizedTensor::quantize(&w, (h, o), DType::NF4, 64, Some(256))
        .unwrap();
    // both sides allocate their output (the public API shape), so the
    // ratios don't flatter the fused path with a pre-allocated buffer
    b.bench_items(&dt_("scalar"), np, || q.dequantize_scalar().unwrap());
    std::env::set_var("QLORA_QUANT_THREADS", "1");
    b.bench_items(&dt_("fused1"), np, || q.dequantize().unwrap());
    restore_threads();
    b.bench_items(&dt_("fusedN"), np, || q.dequantize().unwrap());
    // the zero-alloc variant engine code can use for repeated dequants
    let mut out = vec![0f32; np];
    b.bench_items(&dt_("fusedN_into"), np, || {
        q.dequantize_into(&mut out).unwrap()
    });

    // derived speedups (mean-based; quantize+dequantize combined is the
    // acceptance metric: >= 2x fused single-thread, >= 4x multicore)
    let mean = |name: &str| b.find(name).map(|s| s.mean_ns).unwrap_or(f64::NAN);
    let qs = mean(&qt("scalar"));
    let ds = mean(&dt_("scalar"));
    let speed = |tag: &str| {
        let (qf, df) = (mean(&qt(tag)), mean(&dt_(tag)));
        (qs / qf, ds / df, (qs + ds) / (qf + df))
    };
    let (q1, d1, c1) = speed("fused1");
    let (qn, dn, cn) = speed("fusedN");
    println!("\n== speedups vs scalar ({h}x{o} NF4+DQ) ==");
    println!("fused single-thread: quantize {q1:.2}x  dequantize {d1:.2}x  \
              combined {c1:.2}x (target >= 2x)");
    println!("fused {threads}-thread:      quantize {qn:.2}x  \
              dequantize {dn:.2}x  combined {cn:.2}x (target >= 4x)");

    if let Some(path) = json_path {
        let meta = [
            ("bench", Value::s("bench_quant")),
            ("mode", Value::s(if smoke { "smoke" } else { "full" })),
            ("shape", Value::array([Value::n(h as f64), Value::n(o as f64)])),
            ("threads", Value::n(threads as f64)),
            ("speedup_quantize_fused1", Value::n(q1)),
            ("speedup_dequantize_fused1", Value::n(d1)),
            ("speedup_combined_fused1", Value::n(c1)),
            ("speedup_quantize_fusedN", Value::n(qn)),
            ("speedup_dequantize_fusedN", Value::n(dn)),
            ("speedup_combined_fusedN", Value::n(cn)),
        ];
        b.write_json(&path, &meta).unwrap();
        println!("\nwrote {}", path.display());
    }
}
