//! Quantization benches — the kernels behind Table 2 / Figure 3 and the
//! load-time weight preparation path. Throughput in params/sec.

use qlora::quant::codebook::{Codebook, DType};
use qlora::quant::{
    dequantize_blockwise, pack_nibbles, quantize_blockwise, unpack_nibbles,
};
use qlora::quant::double::{double_dequantize, double_quantize};
use qlora::quant::tensor::QuantizedTensor;
use qlora::util::bench::Bencher;
use qlora::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(1);
    let n = 64 * 4096; // 256k params
    let x: Vec<f32> = rng.normal_vec_f32(n);

    b.group("blockwise quantize (block=64)");
    for dt in [DType::NF4, DType::FP4E2M1, DType::Int4, DType::Int8] {
        let cb = Codebook::new(dt);
        b.bench_items(&format!("quantize/{}", dt.name()), n, || {
            quantize_blockwise(&x, &cb, 64).unwrap()
        });
    }

    b.group("blockwise dequantize");
    let cb = Codebook::new(DType::NF4);
    let (codes, absmax) = quantize_blockwise(&x, &cb, 64).unwrap();
    b.bench_items("dequantize/nf4", n, || {
        dequantize_blockwise(&codes, &absmax, &cb, 64).unwrap()
    });

    b.group("nibble packing");
    b.bench_items("pack", n, || pack_nibbles(&codes).unwrap());
    let packed = pack_nibbles(&codes).unwrap();
    b.bench_items("unpack", n, || unpack_nibbles(&packed));

    b.group("double quantization (constants)");
    b.bench_items("double_quantize", absmax.len(), || {
        double_quantize(&absmax, 256).unwrap()
    });
    let dq = double_quantize(&absmax, 256).unwrap();
    b.bench_items("double_dequantize", absmax.len(), || {
        double_dequantize(&dq).unwrap()
    });

    b.group("full weight container (quantize+pack+DQ)");
    let (h, o) = (512, 512);
    let w: Vec<f32> = rng.normal_vec_f32(h * o);
    b.bench_items("QuantizedTensor::quantize 512x512", h * o, || {
        QuantizedTensor::quantize(&w, (h, o), DType::NF4, 64, Some(256))
            .unwrap()
    });
    let q = QuantizedTensor::quantize(&w, (h, o), DType::NF4, 64, Some(256))
        .unwrap();
    b.bench_items("QuantizedTensor::dequantize 512x512", h * o, || {
        q.dequantize().unwrap()
    });
}
