//! End-to-end training benches over the PJRT runtime: per-step latency of
//! the AOT train graph (the paper's "QLoRA does not degrade runtime"
//! claim at reproduction scale), eval latency, and quantized vs 16-bit
//! step-time comparison. Requires `make artifacts`.

use std::rc::Rc;

use qlora::coordinator::trainer::Trainer;
use qlora::data::batching::Batcher;
use qlora::data::synthetic::{corpus, CorpusKind};
use qlora::data::tokenizer::Tokenizer;
use qlora::engine::Engine;
use qlora::runtime::artifact::Manifest;
use qlora::runtime::client::Runtime;
use qlora::util::bench::Bencher;

fn main() {
    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        println!("bench_train: artifacts not built (run `make artifacts`); \
                  skipping");
        return;
    };
    let rt = Rc::new(Runtime::cpu().expect("PJRT client"));
    let mut b = Bencher::new();

    for name in ["tiny_scope_all", "tiny_lora16", "tiny_fullft", "e2e", "e2e_noremat"] {
        let Ok(engine) = Engine::new(rt.clone(), &manifest, name) else {
            println!("({name} not in manifest; skipping)");
            continue;
        };
        let mut trainer = Trainer::new(&engine).expect("trainer");
        let cfg = trainer.spec().cfg.clone();
        let ds = corpus(CorpusKind::Alpaca, 128, 1);
        let batcher = Batcher::new(&ds, Tokenizer::new(cfg.vocab), cfg.batch,
                                   cfg.seq_len, false);
        let batch = &batcher.epoch(0)[0];
        let tokens_per_step = cfg.batch * cfg.seq_len;
        b.group(&format!("{name} ({} params, quant={}, lora={})",
                         cfg.n_params(), cfg.quant,
                         if cfg.lora { cfg.lora_scope.as_str() } else { "off" }));
        b.bench_items("train_step", tokens_per_step, || {
            trainer.step(batch).unwrap()
        });
        b.bench_items("eval_step", tokens_per_step, || {
            trainer.eval(batch).unwrap()
        });
    }
}
