//! Evaluation-harness benches: judge throughput, Elo tournament at the
//! paper's full scale (10k orderings — Table 1/7), agreement statistics.

use qlora::elo::{MatchRecord, Tournament};
use qlora::eval::judge::Judge;
use qlora::eval::systems::roster;
use qlora::util::bench::Bencher;
use qlora::util::rng::Rng;
use qlora::util::stats;

fn main() {
    let mut b = Bencher::new();
    let systems = roster();
    let judge = Judge::gpt4();

    b.group("judge model");
    let mut rng = Rng::new(2);
    b.bench_items("judge_pair", 1, || {
        judge.judge_pair(&systems[1], &systems[4], true, &mut rng)
    });

    b.group("Elo tournament (paper scale: 4480 matches)");
    let matches: Vec<MatchRecord> =
        qlora::experiments::table1::play_matches(&systems, &judge, true, 80,
                                                 3);
    let mut t = Tournament::new(systems.len());
    for m in &matches {
        t.add(*m);
    }
    b.bench("run/100-orderings", || t.run(100, 4));
    // one full paper-scale run, timed once
    let t0 = std::time::Instant::now();
    let res = t.run(10_000, 5);
    println!(
        "full 10k-ordering tournament: {:.2}s (top: {} at {:.0})",
        t0.elapsed().as_secs_f64(),
        systems[res.iter().min_by_key(|r| r.rank).unwrap().system].name,
        res.iter().min_by_key(|r| r.rank).unwrap().mean
    );

    b.group("agreement statistics");
    let a: Vec<f64> = (0..1000).map(|i| (i as f64).sin()).collect();
    let c: Vec<f64> = (0..1000).map(|i| (i as f64).cos()).collect();
    b.bench("kendall_tau/1000", || stats::kendall_tau(&a, &c));
    b.bench("spearman/1000", || stats::spearman(&a, &c));
}
