//! Serving benches through `engine::Session`: tokens/sec of the decode
//! loop for single-prompt vs batched multi-prompt generation, and the
//! adapter hot-swap overhead (must be tiny next to a forward). Uses the
//! repo's mini-criterion harness (`util::bench`); requires
//! `make artifacts`.

use qlora::engine::{Engine, Sampler, BASE_ADAPTER};
use qlora::runtime::artifact::Manifest;
use qlora::util::bench::Bencher;

fn main() {
    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        println!("bench_generate: artifacts not built (run `make \
                  artifacts`); skipping");
        return;
    };
    let Ok(engine) = Engine::cpu(&manifest, "e2e") else {
        println!("(e2e not in manifest; skipping)");
        return;
    };
    let cfg = engine.spec.cfg.clone();
    let sampler = Sampler { max_new_tokens: 16, ..Sampler::default() };
    let mut b = Bencher::new();
    b.group(&format!(
        "Session::generate over \"e2e\" ({} params, batch {}x{})",
        cfg.n_params(), cfg.batch, cfg.seq_len
    ));

    // greedy decoding is deterministic, so count tokens once and use the
    // count as the per-iteration throughput denominator
    let mut session = engine
        .session()
        .sampler(sampler.clone())
        .greedy(true)
        .build()
        .expect("session");
    let prompt = "copy qlora engine";
    let before = session.tokens_generated();
    session.generate(prompt).expect("warm generate");
    let tokens_single = (session.tokens_generated() - before).max(1) as usize;
    b.bench_items(&format!("single prompt ({tokens_single} tok)"),
                  tokens_single, || {
        session.generate(prompt).unwrap()
    });

    // batched: fill the compiled batch with distinct prompts
    let prompts: Vec<String> = (0..cfg.batch)
        .map(|i| format!("rev prompt{i}"))
        .collect();
    let refs: Vec<&str> = prompts.iter().map(String::as_str).collect();
    let before = session.tokens_generated();
    session.generate_batch(&refs).expect("warm batch");
    let tokens_batch = (session.tokens_generated() - before).max(1) as usize;
    b.bench_items(
        &format!("batched x{} ({tokens_batch} tok)", refs.len()),
        tokens_batch,
        || session.generate_batch(&refs).unwrap(),
    );

    // hot-swap: re-register the base adapters under a new name (bumping
    // the registry version so the device-literal cache is invalidated)
    // and switch to them — this measures the real swap path, registry
    // insert + literal re-upload, not a cache hit
    let tensors = engine.adapter_tensors(BASE_ADAPTER).expect("base tensors");
    b.bench("adapter hot-swap (register + upload + switch)", || {
        engine.register_adapter("swap", tensors.clone()).unwrap();
        session.set_adapter("swap").unwrap();
        session.set_adapter(BASE_ADAPTER).unwrap();
    });
}
