//! Serving benches through `engine::Session`: tokens/sec of the decode
//! loop for the KV-cached vs full-recompute paths (single prompt,
//! continuous-batched multi-prompt, and per-step latency as a function of
//! generated length — the cached path's step cost must stay flat), the
//! request-lifecycle serve path (mixed-priority workload, with the
//! scheduler's `ServerStats` block: throughput, mean TTFT, preemptions),
//! plus the adapter hot-swap overhead (must be tiny next to a forward).
//! Uses the repo's mini-criterion harness (`util::bench`); requires
//! `make artifacts`.

use qlora::engine::{
    DecodeMode, Engine, GenRequest, Priority, Sampler, BASE_ADAPTER,
};
use qlora::runtime::artifact::Manifest;
use qlora::util::bench::Bencher;

fn main() {
    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        println!("bench_generate: artifacts not built (run `make \
                  artifacts`); skipping");
        return;
    };
    let Ok(engine) = Engine::cpu(&manifest, "e2e") else {
        println!("(e2e not in manifest; skipping)");
        return;
    };
    let cfg = engine.spec.cfg.clone();
    let mut b = Bencher::new();
    b.group(&format!(
        "Session decode over \"e2e\" ({} params, batch {}x{})",
        cfg.n_params(), cfg.batch, cfg.seq_len
    ));

    let modes: Vec<(&str, DecodeMode)> = if engine.has_cached_decode() {
        vec![("cached", DecodeMode::Cached), ("full", DecodeMode::Full)]
    } else {
        println!("(artifact has no decode graphs; re-run `make artifacts` \
                  for cached-path numbers)");
        vec![("full", DecodeMode::Full)]
    };
    let prompt = "copy qlora engine";

    for &(label, mode) in &modes {
        // greedy decoding is deterministic, so count tokens once and use
        // the count as the per-iteration throughput denominator
        let sampler = Sampler { max_new_tokens: 16, ..Sampler::default() };
        let mut session = engine
            .session()
            .sampler(sampler)
            .greedy(true)
            .decode(mode)
            .build()
            .expect("session");
        let before = session.tokens_generated();
        session.generate(prompt).expect("warm generate");
        let tokens = (session.tokens_generated() - before).max(1) as usize;
        b.bench_items(&format!("[{label}] single prompt ({tokens} tok)"),
                      tokens, || session.generate(prompt).unwrap());

        // 2x the compiled batch rows: continuous batching refills rows
        // mid-flight instead of running two padded batches
        let prompts: Vec<String> = (0..cfg.batch * 2)
            .map(|i| format!("rev prompt{i}"))
            .collect();
        let refs: Vec<&str> = prompts.iter().map(String::as_str).collect();
        let before = session.tokens_generated();
        session.generate_batch(&refs).expect("warm batch");
        let tokens_batch =
            (session.tokens_generated() - before).max(1) as usize;
        b.bench_items(
            &format!("[{label}] continuous batch x{} ({tokens_batch} tok)",
                     refs.len()),
            tokens_batch,
            || session.generate_batch(&refs).unwrap(),
        );

        // per-step cost as a function of generated length: time whole
        // generations at increasing gen_len, then report the *marginal*
        // cost per extra token between lengths — this subtracts out the
        // (shared) prefill, so a flat marginal across the windows is the
        // visible signature of the O(1) cached step; the full path shows
        // a far larger marginal (a whole full-sequence forward per token)
        let mut points: Vec<(f64, f64)> = Vec::new(); // (tokens, mean_ns)
        for gen_len in [4usize, 16, 32] {
            let s = Sampler { max_new_tokens: gen_len, ..Sampler::default() };
            let mut sess = engine
                .session()
                .sampler(s)
                .greedy(true)
                .decode(mode)
                .build()
                .expect("session");
            let before = sess.tokens_generated();
            sess.generate(prompt).expect("warm generate");
            let tokens = (sess.tokens_generated() - before).max(1) as usize;
            let summary = b.bench_items(
                &format!("[{label}] generate @ gen_len {gen_len} \
                          ({tokens} tok)"),
                tokens,
                || sess.generate(prompt).unwrap(),
            );
            points.push((tokens as f64, summary.mean_ns));
        }
        for w in points.windows(2) {
            let (tok0, t0) = w[0];
            let (tok1, t1) = w[1];
            if tok1 > tok0 {
                let step_ns = (t1 - t0) / (tok1 - tok0);
                println!(
                    "{:<44} {:>10}",
                    format!("[{label}] marginal step cost {tok0}→{tok1} tok"),
                    qlora::util::bench::human_ns(step_ns.max(0.0)),
                );
            }
        }
    }

    // request-lifecycle serving: a mixed-priority workload (2x the
    // compiled rows) through Session::serve, which adds priority/aging
    // admission, token-budget accounting and per-step stats on top of
    // the raw continuous-batching loop — the interesting number is how
    // little throughput that bookkeeping costs vs generate_batch above
    let mixed_requests = |n: usize| -> Vec<GenRequest> {
        (0..n)
            .map(|i| {
                let r = GenRequest::new(format!("rev prompt{i}"));
                match i % 3 {
                    0 => r.priority(Priority::High),
                    1 => r,
                    _ => r.priority(Priority::Low),
                }
            })
            .collect()
    };
    let n_reqs = cfg.batch * 2;
    let sampler = Sampler { max_new_tokens: 16, ..Sampler::default() };
    let mut session = engine
        .session()
        .sampler(sampler)
        .greedy(true)
        .build()
        .expect("session");
    let report = session.serve(mixed_requests(n_reqs)).expect("warm serve");
    let tokens_serve = report.stats.tokens_generated.max(1) as usize;
    b.bench_items(
        &format!("lifecycle serve x{n_reqs} mixed-priority \
                  ({tokens_serve} tok)"),
        tokens_serve,
        || session.serve(mixed_requests(n_reqs)).unwrap(),
    );
    println!(
        "{:<44} {}",
        "lifecycle serve stats (warm run)",
        report.stats.summary()
    );

    // hot-swap: re-register the base adapters under a new name (bumping
    // the registry version so the device-literal cache is invalidated)
    // and switch to them — this measures the real swap path, registry
    // insert + literal re-upload, not a cache hit
    let tensors = engine.adapter_tensors(BASE_ADAPTER).expect("base tensors");
    let mut session = engine.session().build().expect("session");
    b.bench("adapter hot-swap (register + upload + switch)", || {
        engine.register_adapter("swap", tensors.clone()).unwrap();
        session.set_adapter("swap").unwrap();
        session.set_adapter(BASE_ADAPTER).unwrap();
    });
}
