//! Serving benches through `engine::Session`: tokens/sec of the decode
//! loop for the KV-cached vs full-recompute paths (single prompt,
//! continuous-batched multi-prompt, and per-step latency as a function of
//! generated length — the cached path's step cost must stay flat), the
//! request-lifecycle serve path (mixed-priority workload, with the
//! scheduler's `ServerStats` block: throughput, mean TTFT, preemptions),
//! the shared-prefix capacity comparison (N requests opening with one
//! system prompt: block-granular admission with copy-on-write prefix
//! sharing vs the dense worst-case token reservation — peak concurrent
//! rows and tokens/sec), plus the adapter hot-swap overhead (must be
//! tiny next to a forward). Uses the repo's mini-criterion harness
//! (`util::bench`); requires `make artifacts`.
//!
//! Flags (after `--`):
//!   --smoke        short budgets (CI bit-rot check)
//!   --json <path>  write results as JSON (the perf trajectory file:
//!                  `make bench-generate` writes BENCH_generate.json at
//!                  the repo root)

use std::path::PathBuf;

use qlora::engine::{
    DecodeMode, Engine, GenRequest, Priority, Sampler, BASE_ADAPTER,
};
use qlora::runtime::artifact::Manifest;
use qlora::util::bench::Bencher;
use qlora::util::json::Value;

fn main() {
    let mut smoke = false;
    let mut json_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--json" => {
                json_path = Some(PathBuf::from(
                    args.next().expect("--json needs a path"),
                ))
            }
            // cargo passes --bench to every bench binary
            "--bench" => {}
            other => panic!("unknown bench_generate flag {other:?}"),
        }
    }
    if smoke {
        std::env::set_var("QLORA_BENCH_FAST", "1");
    }

    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        println!("bench_generate: artifacts not built (run `make \
                  artifacts`); skipping");
        return;
    };
    let Ok(engine) = Engine::cpu(&manifest, "e2e") else {
        println!("(e2e not in manifest; skipping)");
        return;
    };
    let cfg = engine.spec.cfg.clone();
    let mut b = Bencher::new();
    b.group(&format!(
        "Session decode over \"e2e\" ({} params, batch {}x{})",
        cfg.n_params(), cfg.batch, cfg.seq_len
    ));

    let modes: Vec<(&str, DecodeMode)> = if engine.has_cached_decode() {
        vec![("cached", DecodeMode::Cached), ("full", DecodeMode::Full)]
    } else {
        println!("(artifact has no decode graphs; re-run `make artifacts` \
                  for cached-path numbers)");
        vec![("full", DecodeMode::Full)]
    };
    let prompt = "copy qlora engine";

    for &(label, mode) in &modes {
        // greedy decoding is deterministic, so count tokens once and use
        // the count as the per-iteration throughput denominator
        let sampler = Sampler { max_new_tokens: 16, ..Sampler::default() };
        let mut session = engine
            .session()
            .sampler(sampler)
            .greedy(true)
            .decode(mode)
            .build()
            .expect("session");
        let before = session.tokens_generated();
        session.generate(prompt).expect("warm generate");
        let tokens = (session.tokens_generated() - before).max(1) as usize;
        b.bench_items(&format!("[{label}] single prompt ({tokens} tok)"),
                      tokens, || session.generate(prompt).unwrap());

        // 2x the compiled batch rows: continuous batching refills rows
        // mid-flight instead of running two padded batches
        let prompts: Vec<String> = (0..cfg.batch * 2)
            .map(|i| format!("rev prompt{i}"))
            .collect();
        let refs: Vec<&str> = prompts.iter().map(String::as_str).collect();
        let before = session.tokens_generated();
        session.generate_batch(&refs).expect("warm batch");
        let tokens_batch =
            (session.tokens_generated() - before).max(1) as usize;
        b.bench_items(
            &format!("[{label}] continuous batch x{} ({tokens_batch} tok)",
                     refs.len()),
            tokens_batch,
            || session.generate_batch(&refs).unwrap(),
        );

        // per-step cost as a function of generated length: time whole
        // generations at increasing gen_len, then report the *marginal*
        // cost per extra token between lengths — this subtracts out the
        // (shared) prefill, so a flat marginal across the windows is the
        // visible signature of the O(1) cached step; the full path shows
        // a far larger marginal (a whole full-sequence forward per token)
        let mut points: Vec<(f64, f64)> = Vec::new(); // (tokens, mean_ns)
        for gen_len in [4usize, 16, 32] {
            let s = Sampler { max_new_tokens: gen_len, ..Sampler::default() };
            let mut sess = engine
                .session()
                .sampler(s)
                .greedy(true)
                .decode(mode)
                .build()
                .expect("session");
            let before = sess.tokens_generated();
            sess.generate(prompt).expect("warm generate");
            let tokens = (sess.tokens_generated() - before).max(1) as usize;
            let summary = b.bench_items(
                &format!("[{label}] generate @ gen_len {gen_len} \
                          ({tokens} tok)"),
                tokens,
                || sess.generate(prompt).unwrap(),
            );
            points.push((tokens as f64, summary.mean_ns));
        }
        for w in points.windows(2) {
            let (tok0, t0) = w[0];
            let (tok1, t1) = w[1];
            if tok1 > tok0 {
                let step_ns = (t1 - t0) / (tok1 - tok0);
                println!(
                    "{:<44} {:>10}",
                    format!("[{label}] marginal step cost {tok0}→{tok1} tok"),
                    qlora::util::bench::human_ns(step_ns.max(0.0)),
                );
            }
        }
    }

    // request-lifecycle serving: a mixed-priority workload (2x the
    // compiled rows) through Session::serve, which adds priority/aging
    // admission, token-budget accounting and per-step stats on top of
    // the raw continuous-batching loop — the interesting number is how
    // little throughput that bookkeeping costs vs generate_batch above
    let mixed_requests = |n: usize| -> Vec<GenRequest> {
        (0..n)
            .map(|i| {
                let r = GenRequest::new(format!("rev prompt{i}"));
                match i % 3 {
                    0 => r.priority(Priority::High),
                    1 => r,
                    _ => r.priority(Priority::Low),
                }
            })
            .collect()
    };
    let n_reqs = cfg.batch * 2;
    let sampler = Sampler { max_new_tokens: 16, ..Sampler::default() };
    let mut session = engine
        .session()
        .sampler(sampler)
        .greedy(true)
        .build()
        .expect("session");
    let report = session.serve(mixed_requests(n_reqs)).expect("warm serve");
    let tokens_serve = report.stats.tokens_generated.max(1) as usize;
    b.bench_items(
        &format!("lifecycle serve x{n_reqs} mixed-priority \
                  ({tokens_serve} tok)"),
        tokens_serve,
        || session.serve(mixed_requests(n_reqs)).unwrap(),
    );
    println!(
        "{:<44} {}",
        "lifecycle serve stats (warm run)",
        report.stats.summary()
    );

    // ----------------------------------------------------------------
    // Shared-prefix capacity: N requests opening with one system prompt.
    // The dense baseline reserves `prompt + max_new` tokens per row up
    // front; block-granular admission stores the shared prefix once and
    // charges only the blocks actually allocated, so it runs strictly
    // more rows concurrently at the same token budget (this PR's
    // acceptance criterion, measured end to end).
    // ----------------------------------------------------------------
    b.group("shared-prefix serving: dense budget vs KV blocks");
    let seq_len = cfg.seq_len;
    // a "system prompt" taking ~half the sequence, per-request suffix
    let system: String =
        std::iter::repeat('s').take(seq_len / 2).collect();
    let shared_requests = || -> Vec<GenRequest> {
        (0..cfg.batch * 2)
            .map(|i| GenRequest::new(format!("rev {system}{:02}", i)))
            .collect()
    };
    let budget_tokens = 2 * seq_len; // fits ~2 dense rows
    let block_tokens = 8usize;
    let max_new = if smoke { 4 } else { 8 };
    let sampler = Sampler { max_new_tokens: max_new, ..Sampler::default() };
    let mut peaks: Vec<(&str, usize, u64)> = Vec::new();
    let mut shared_texts: Vec<Vec<String>> = Vec::new();
    for (label, share) in
        [("dense", None), ("blocks", Some(true)), ("noshare", Some(false))]
    {
        let mut builder = engine
            .session()
            .sampler(sampler.clone())
            .greedy(true);
        builder = match share {
            None => builder.token_budget(budget_tokens),
            Some(on) => builder
                .kv_block_tokens(block_tokens)
                .kv_blocks(budget_tokens / block_tokens)
                .prefix_sharing(on),
        };
        let mut session = builder.build().expect("session");
        let mut peak_rows = 0usize;
        let report = session
            .serve_with(shared_requests(), |p| {
                peak_rows = peak_rows.max(p.stats.active_rows);
            })
            .expect("warm serve");
        let tokens = report.stats.tokens_generated.max(1) as usize;
        b.bench_items(
            &format!("[{label}] shared-prefix serve x{} ({tokens} tok)",
                     cfg.batch * 2),
            tokens,
            || session.serve(shared_requests()).unwrap(),
        );
        println!(
            "{:<44} peak {} concurrent rows; {}",
            format!("[{label}] shared-prefix capacity"),
            peak_rows,
            report.stats.summary()
        );
        peaks.push((label, peak_rows, report.stats.shared_block_hits));
        if share.is_some() {
            shared_texts.push(
                report.outputs.iter().map(|o| o.text.clone()).collect(),
            );
        }
    }
    assert_eq!(
        shared_texts[0], shared_texts[1],
        "prefix sharing changed greedy serve outputs"
    );
    let dense_peak = peaks[0].1;
    let blocks_peak = peaks[1].1;
    println!(
        "{:<44} {} vs {} rows ({}x)",
        "capacity: blocks vs dense at equal budget",
        blocks_peak,
        dense_peak,
        if dense_peak > 0 {
            blocks_peak as f64 / dense_peak as f64
        } else {
            f64::NAN
        }
    );

    // hot-swap: re-register the base adapters under a new name (bumping
    // the registry version so the device-literal cache is invalidated)
    // and switch to them — this measures the real swap path, registry
    // insert + literal re-upload, not a cache hit
    let tensors = engine.adapter_tensors(BASE_ADAPTER).expect("base tensors");
    let mut session = engine.session().build().expect("session");
    b.bench("adapter hot-swap (register + upload + switch)", || {
        engine.register_adapter("swap", tensors.clone()).unwrap();
        session.set_adapter("swap").unwrap();
        session.set_adapter(BASE_ADAPTER).unwrap();
    });

    if let Some(path) = json_path {
        let meta = [
            ("bench", Value::s("bench_generate")),
            ("mode", Value::s(if smoke { "smoke" } else { "full" })),
            ("artifact", Value::s(cfg.name.as_str())),
            ("peak_rows_dense", Value::n(peaks[0].1 as f64)),
            ("peak_rows_blocks", Value::n(peaks[1].1 as f64)),
            ("peak_rows_noshare", Value::n(peaks[2].1 as f64)),
            ("shared_block_hits", Value::n(peaks[1].2 as f64)),
        ];
        b.write_json(&path, &meta).unwrap();
        println!("\nwrote {}", path.display());
    }
}
