//! Serving benches through `engine::Session`: tokens/sec of the decode
//! loop for the KV-cached vs full-recompute paths (single prompt,
//! continuous-batched multi-prompt, and per-step latency as a function of
//! generated length — the cached path's step cost must stay flat), the
//! request-lifecycle serve path (mixed-priority workload, with the
//! scheduler's `ServerStats` block: throughput, mean TTFT, preemptions),
//! the shared-prefix capacity comparison (N requests opening with one
//! system prompt: block-granular admission with copy-on-write prefix
//! sharing vs the dense worst-case token reservation — peak concurrent
//! rows and tokens/sec), the adapter hot-swap overhead (must be
//! tiny next to a forward), and a loopback-TCP load generator against
//! the `serve-http` front end (closed-loop clients plus fixed-rate
//! open arrivals, streamed responses: P50/P99 TTFT and end-to-end
//! tokens/sec, HTTP + scheduling overhead included). Uses the repo's
//! mini-criterion harness (`util::bench`); requires `make artifacts`.
//!
//! Flags (after `--`):
//!   --smoke        short budgets (CI bit-rot check)
//!   --json <path>  write results as JSON (the perf trajectory file:
//!                  `make bench-generate` writes BENCH_generate.json at
//!                  the repo root)

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use qlora::engine::{
    DecodeMode, Engine, GenRequest, Priority, Sampler, BASE_ADAPTER,
};
use qlora::runtime::artifact::Manifest;
use qlora::serve::{HttpServer, ServerConfig};
use qlora::util::bench::Bencher;
use qlora::util::json::Value;
use qlora::util::stats::percentile;

/// One streamed `POST /v1/generate` over a fresh connection; returns
/// (TTFT in ms, token lines received). TTFT is wall time from the last
/// request byte to the first `"token"` line byte — the number a
/// streaming client actually experiences, HTTP and scheduling included.
fn timed_stream_request(addr: SocketAddr, prompt: &str) -> (f64, usize) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .expect("read timeout");
    let body = format!(r#"{{"prompt":"{prompt}","stream":true}}"#);
    let head = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: bench\r\n\
         Content-Length: {}\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes()).expect("write head");
    stream.write_all(body.as_bytes()).expect("write body");
    let start = Instant::now();
    let mut buf = Vec::new();
    let mut ttft = None;
    let mut tmp = [0u8; 4096];
    loop {
        match stream.read(&mut tmp) {
            Ok(0) => break, // server closes after the done line
            Ok(n) => {
                buf.extend_from_slice(&tmp[..n]);
                if ttft.is_none()
                    && buf.windows(7).any(|w| w == b"\"token\"")
                {
                    ttft = Some(start.elapsed());
                }
            }
            Err(e) => panic!("load-gen read failed: {e}"),
        }
    }
    // one line per token; chunk framing never splits a line, so a
    // substring count is exact
    let tokens = buf.windows(8).filter(|w| w == b"\"token\":").count();
    (ttft.unwrap_or_else(|| start.elapsed()).as_secs_f64() * 1e3, tokens)
}

fn post_shutdown(addr: SocketAddr) {
    let Ok(mut stream) = TcpStream::connect(addr) else { return };
    let _ = stream.write_all(
        b"POST /v1/shutdown HTTP/1.1\r\nHost: bench\r\n\
          Content-Length: 0\r\n\r\n",
    );
    let mut sink = Vec::new();
    let _ = stream.read_to_end(&mut sink);
}

fn main() {
    let mut smoke = false;
    let mut json_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--json" => {
                json_path = Some(PathBuf::from(
                    args.next().expect("--json needs a path"),
                ))
            }
            // cargo passes --bench to every bench binary
            "--bench" => {}
            other => panic!("unknown bench_generate flag {other:?}"),
        }
    }
    if smoke {
        std::env::set_var("QLORA_BENCH_FAST", "1");
    }

    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        println!("bench_generate: artifacts not built (run `make \
                  artifacts`); skipping");
        return;
    };
    let Ok(engine) = Engine::cpu(&manifest, "e2e") else {
        println!("(e2e not in manifest; skipping)");
        return;
    };
    let cfg = engine.spec.cfg.clone();
    let mut b = Bencher::new();
    b.group(&format!(
        "Session decode over \"e2e\" ({} params, batch {}x{})",
        cfg.n_params(), cfg.batch, cfg.seq_len
    ));

    let modes: Vec<(&str, DecodeMode)> = if engine.has_cached_decode() {
        vec![("cached", DecodeMode::Cached), ("full", DecodeMode::Full)]
    } else {
        println!("(artifact has no decode graphs; re-run `make artifacts` \
                  for cached-path numbers)");
        vec![("full", DecodeMode::Full)]
    };
    let prompt = "copy qlora engine";

    for &(label, mode) in &modes {
        // greedy decoding is deterministic, so count tokens once and use
        // the count as the per-iteration throughput denominator
        let sampler = Sampler { max_new_tokens: 16, ..Sampler::default() };
        let mut session = engine
            .session()
            .sampler(sampler)
            .greedy(true)
            .decode(mode)
            .build()
            .expect("session");
        let before = session.tokens_generated();
        session.generate(prompt).expect("warm generate");
        let tokens = (session.tokens_generated() - before).max(1) as usize;
        b.bench_items(&format!("[{label}] single prompt ({tokens} tok)"),
                      tokens, || session.generate(prompt).unwrap());

        // 2x the compiled batch rows: continuous batching refills rows
        // mid-flight instead of running two padded batches
        let prompts: Vec<String> = (0..cfg.batch * 2)
            .map(|i| format!("rev prompt{i}"))
            .collect();
        let refs: Vec<&str> = prompts.iter().map(String::as_str).collect();
        let before = session.tokens_generated();
        session.generate_batch(&refs).expect("warm batch");
        let tokens_batch =
            (session.tokens_generated() - before).max(1) as usize;
        b.bench_items(
            &format!("[{label}] continuous batch x{} ({tokens_batch} tok)",
                     refs.len()),
            tokens_batch,
            || session.generate_batch(&refs).unwrap(),
        );

        // per-step cost as a function of generated length: time whole
        // generations at increasing gen_len, then report the *marginal*
        // cost per extra token between lengths — this subtracts out the
        // (shared) prefill, so a flat marginal across the windows is the
        // visible signature of the O(1) cached step; the full path shows
        // a far larger marginal (a whole full-sequence forward per token)
        let mut points: Vec<(f64, f64)> = Vec::new(); // (tokens, mean_ns)
        for gen_len in [4usize, 16, 32] {
            let s = Sampler { max_new_tokens: gen_len, ..Sampler::default() };
            let mut sess = engine
                .session()
                .sampler(s)
                .greedy(true)
                .decode(mode)
                .build()
                .expect("session");
            let before = sess.tokens_generated();
            sess.generate(prompt).expect("warm generate");
            let tokens = (sess.tokens_generated() - before).max(1) as usize;
            let summary = b.bench_items(
                &format!("[{label}] generate @ gen_len {gen_len} \
                          ({tokens} tok)"),
                tokens,
                || sess.generate(prompt).unwrap(),
            );
            points.push((tokens as f64, summary.mean_ns));
        }
        for w in points.windows(2) {
            let (tok0, t0) = w[0];
            let (tok1, t1) = w[1];
            if tok1 > tok0 {
                let step_ns = (t1 - t0) / (tok1 - tok0);
                println!(
                    "{:<44} {:>10}",
                    format!("[{label}] marginal step cost {tok0}→{tok1} tok"),
                    qlora::util::bench::human_ns(step_ns.max(0.0)),
                );
            }
        }
    }

    // request-lifecycle serving: a mixed-priority workload (2x the
    // compiled rows) through Session::serve, which adds priority/aging
    // admission, token-budget accounting and per-step stats on top of
    // the raw continuous-batching loop — the interesting number is how
    // little throughput that bookkeeping costs vs generate_batch above
    let mixed_requests = |n: usize| -> Vec<GenRequest> {
        (0..n)
            .map(|i| {
                let r = GenRequest::new(format!("rev prompt{i}"));
                match i % 3 {
                    0 => r.priority(Priority::High),
                    1 => r,
                    _ => r.priority(Priority::Low),
                }
            })
            .collect()
    };
    let n_reqs = cfg.batch * 2;
    let sampler = Sampler { max_new_tokens: 16, ..Sampler::default() };
    let mut session = engine
        .session()
        .sampler(sampler)
        .greedy(true)
        .build()
        .expect("session");
    let report = session.serve(mixed_requests(n_reqs)).expect("warm serve");
    let tokens_serve = report.stats.tokens_generated.max(1) as usize;
    b.bench_items(
        &format!("lifecycle serve x{n_reqs} mixed-priority \
                  ({tokens_serve} tok)"),
        tokens_serve,
        || session.serve(mixed_requests(n_reqs)).unwrap(),
    );
    println!(
        "{:<44} {}",
        "lifecycle serve stats (warm run)",
        report.stats.summary()
    );

    // ----------------------------------------------------------------
    // Shared-prefix capacity: N requests opening with one system prompt.
    // The dense baseline reserves `prompt + max_new` tokens per row up
    // front; block-granular admission stores the shared prefix once and
    // charges only the blocks actually allocated, so it runs strictly
    // more rows concurrently at the same token budget (this PR's
    // acceptance criterion, measured end to end).
    // ----------------------------------------------------------------
    b.group("shared-prefix serving: dense budget vs KV blocks");
    let seq_len = cfg.seq_len;
    // a "system prompt" taking ~half the sequence, per-request suffix
    let system: String =
        std::iter::repeat('s').take(seq_len / 2).collect();
    let shared_requests = || -> Vec<GenRequest> {
        (0..cfg.batch * 2)
            .map(|i| GenRequest::new(format!("rev {system}{:02}", i)))
            .collect()
    };
    let budget_tokens = 2 * seq_len; // fits ~2 dense rows
    let block_tokens = 8usize;
    let max_new = if smoke { 4 } else { 8 };
    let sampler = Sampler { max_new_tokens: max_new, ..Sampler::default() };
    let mut peaks: Vec<(&str, usize, u64)> = Vec::new();
    let mut shared_texts: Vec<Vec<String>> = Vec::new();
    for (label, share) in
        [("dense", None), ("blocks", Some(true)), ("noshare", Some(false))]
    {
        let mut builder = engine
            .session()
            .sampler(sampler.clone())
            .greedy(true);
        builder = match share {
            None => builder.token_budget(budget_tokens),
            Some(on) => builder
                .kv_block_tokens(block_tokens)
                .kv_blocks(budget_tokens / block_tokens)
                .prefix_sharing(on),
        };
        let mut session = builder.build().expect("session");
        let mut peak_rows = 0usize;
        let report = session
            .serve_with(shared_requests(), |p| {
                peak_rows = peak_rows.max(p.stats.active_rows);
            })
            .expect("warm serve");
        let tokens = report.stats.tokens_generated.max(1) as usize;
        b.bench_items(
            &format!("[{label}] shared-prefix serve x{} ({tokens} tok)",
                     cfg.batch * 2),
            tokens,
            || session.serve(shared_requests()).unwrap(),
        );
        println!(
            "{:<44} peak {} concurrent rows; {}",
            format!("[{label}] shared-prefix capacity"),
            peak_rows,
            report.stats.summary()
        );
        peaks.push((label, peak_rows, report.stats.shared_block_hits));
        if share.is_some() {
            shared_texts.push(
                report.outputs.iter().map(|o| o.text.clone()).collect(),
            );
        }
    }
    assert_eq!(
        shared_texts[0], shared_texts[1],
        "prefix sharing changed greedy serve outputs"
    );
    let dense_peak = peaks[0].1;
    let blocks_peak = peaks[1].1;
    println!(
        "{:<44} {} vs {} rows ({}x)",
        "capacity: blocks vs dense at equal budget",
        blocks_peak,
        dense_peak,
        if dense_peak > 0 {
            blocks_peak as f64 / dense_peak as f64
        } else {
            f64::NAN
        }
    );

    // hot-swap: re-register the base adapters under a new name (bumping
    // the registry version so the device-literal cache is invalidated)
    // and switch to them — this measures the real swap path, registry
    // insert + literal re-upload, not a cache hit
    let tensors = engine.adapter_tensors(BASE_ADAPTER).expect("base tensors");
    let mut session = engine.session().build().expect("session");
    b.bench("adapter hot-swap (register + upload + switch)", || {
        engine.register_adapter("swap", tensors.clone()).unwrap();
        session.set_adapter("swap").unwrap();
        session.set_adapter(BASE_ADAPTER).unwrap();
    });

    // ----------------------------------------------------------------
    // HTTP load generator: the serve-http front end on a loopback
    // socket, driven by a closed-loop client pool (next request fires
    // when the previous finishes — the classic saturation probe) mixed
    // with fixed-rate open arrivals (fire on a clock no matter how far
    // behind the server is — the latency-under-load probe). Streamed
    // responses, so TTFT is measured where a client sees it.
    // ----------------------------------------------------------------
    b.group("HTTP serving: closed + open loopback load (streamed)");
    let closed_clients = 4usize;
    let per_client = if smoke { 3 } else { 12 };
    let open_reqs = if smoke { 3 } else { 12 };
    let open_gap = Duration::from_millis(15);
    let sampler = Sampler {
        max_new_tokens: if smoke { 4 } else { 8 },
        ..Sampler::default()
    };
    let mut session = engine
        .session()
        .sampler(sampler)
        .greedy(true)
        .build()
        .expect("session");
    let server = HttpServer::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: closed_clients + 2,
        ..ServerConfig::default()
    })
    .expect("bind loopback");
    let addr = server.local_addr().expect("local addr");
    let samples: Mutex<Vec<(f64, usize)>> = Mutex::new(Vec::new());
    let t0 = Instant::now();
    let http_report = std::thread::scope(|scope| {
        let samples = &samples;
        scope.spawn(move || {
            std::thread::scope(|load| {
                for c in 0..closed_clients {
                    load.spawn(move || {
                        for i in 0..per_client {
                            let r = timed_stream_request(
                                addr,
                                &format!("rev closed{c}x{i}"),
                            );
                            samples.lock().unwrap().push(r);
                        }
                    });
                }
                load.spawn(move || {
                    std::thread::scope(|open| {
                        for i in 0..open_reqs {
                            open.spawn(move || {
                                let r = timed_stream_request(
                                    addr,
                                    &format!("up open{i}"),
                                );
                                samples.lock().unwrap().push(r);
                            });
                            std::thread::sleep(open_gap);
                        }
                    });
                });
            });
            // every client is done: drain and stop the server
            post_shutdown(addr);
        });
        server.run(&mut session).expect("server run")
    });
    let wall = t0.elapsed().as_secs_f64();
    let data = samples.into_inner().unwrap();
    let ttfts: Vec<f64> = data.iter().map(|r| r.0).collect();
    let total_tokens: usize = data.iter().map(|r| r.1).sum();
    let (ttft_p50, ttft_p99) =
        (percentile(&ttfts, 50.0), percentile(&ttfts, 99.0));
    let http_tps = total_tokens as f64 / wall;
    println!(
        "{:<44} {} requests ({} closed-loop, {} open), {} tok",
        "loopback load mix",
        data.len(),
        closed_clients * per_client,
        open_reqs,
        total_tokens
    );
    println!(
        "{:<44} p50 {ttft_p50:.2} ms   p99 {ttft_p99:.2} ms",
        "TTFT (request sent → first token line)"
    );
    println!(
        "{:<44} {:.0} tok/s end to end over {:.2} s",
        "streamed throughput", http_tps, wall
    );
    println!(
        "{:<44} {}",
        "server-side stats",
        http_report.stats.summary()
    );

    if let Some(path) = json_path {
        let meta = [
            ("bench", Value::s("bench_generate")),
            ("mode", Value::s(if smoke { "smoke" } else { "full" })),
            ("artifact", Value::s(cfg.name.as_str())),
            ("peak_rows_dense", Value::n(peaks[0].1 as f64)),
            ("peak_rows_blocks", Value::n(peaks[1].1 as f64)),
            ("peak_rows_noshare", Value::n(peaks[2].1 as f64)),
            ("shared_block_hits", Value::n(peaks[1].2 as f64)),
            ("http_requests", Value::n(data.len() as f64)),
            ("http_ttft_p50_ms", Value::n(ttft_p50)),
            ("http_ttft_p99_ms", Value::n(ttft_p99)),
            ("http_tokens_per_sec", Value::n(http_tps)),
        ];
        b.write_json(&path, &meta).unwrap();
        println!("\nwrote {}", path.display());
    }
}
