//! Data-pipeline benches: corpus generation, tokenization, and the
//! group-by-length batcher — the producer side of the training loop.

use qlora::data::batching::Batcher;
use qlora::data::synthetic::{corpus, CorpusKind};
use qlora::data::tokenizer::Tokenizer;
use qlora::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();

    b.group("corpus generation");
    b.bench_items("alpaca/512-examples", 512, || {
        corpus(CorpusKind::Alpaca, 512, 1)
    });
    b.bench_items("oasst1-trees/256-examples", 256, || {
        corpus(CorpusKind::Oasst1, 256, 1)
    });

    b.group("tokenizer");
    let tok = Tokenizer::new(512);
    let text = "sort abcdefghijklmnop";
    b.bench("encode_example", || {
        tok.encode_example(text, "abcdefghijklmnop", 64, false)
    });

    b.group("group-by-length batcher");
    let ds = corpus(CorpusKind::Alpaca, 1024, 2);
    b.bench("Batcher::new/1024-examples", || {
        Batcher::new(&ds, Tokenizer::new(512), 8, 48, false)
    });
    let batcher = Batcher::new(&ds, Tokenizer::new(512), 8, 48, false);
    b.bench_items("epoch/128-batches", batcher.n_batches(), || {
        batcher.epoch(3)
    });
}
