//! Paged-optimizer benches: pager fault/touch throughput and the
//! end-to-end per-step overhead in the three regimes of the paged
//! experiment (roomy / spiky / thrash).

use qlora::paged::optimizer::PagedOptimizerSim;
use qlora::paged::pager::{Pager, PagerConfig};
use qlora::util::bench::Bencher;
use qlora::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();

    b.group("pager primitives");
    let cfg = PagerConfig {
        page_bytes: 64 << 10,
        device_budget: 64 << 20,
        ..PagerConfig::default()
    };
    let mut pager = Pager::new(cfg);
    let ids = pager.register(0, 128 << 20); // 2x over budget
    let mut rng = Rng::new(1);
    b.bench("touch/resident-hit", || {
        pager.touch(ids[rng.below(512)], 0) // working set fits
    });
    b.bench("touch/faulting", || {
        pager.touch(ids[rng.below(ids.len())], 0) // uniform: ~50% faults
    });

    b.group("optimizer-step simulation");
    for (label, budget_mb, seq) in [
        ("roomy/short-seq", 1024usize, 64usize),
        ("tight/long-seq", 9, 4096),
    ] {
        let mut sim =
            PagedOptimizerSim::new(budget_mb << 20, 0, 8 << 20, 1024, 8);
        b.bench(&format!("on_step/{label}"), || sim.on_step(seq));
    }
}
