//! Training metrics: loss curve, eval points, step timing; CSV export for
//! the E2E example and EXPERIMENTS.md plots.

use std::path::Path;
use std::time::Duration;

use anyhow::Result;

use crate::paged::optimizer::PagerStats;

/// One held-out evaluation snapshot.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    /// training step the eval ran at
    pub step: usize,
    /// held-out loss
    pub loss: f32,
    /// held-out token accuracy in [0, 1]
    pub accuracy: f32,
}

/// Per-run record of training losses, step times, and eval points.
#[derive(Debug, Clone)]
pub struct TrainingLog {
    /// run name (used in report headers)
    pub name: String,
    /// training loss at each optimizer step
    pub losses: Vec<f32>,
    /// wall time of each optimizer step
    pub step_times: Vec<Duration>,
    /// periodic held-out evaluations
    pub evals: Vec<EvalPoint>,
    /// final paged-optimizer counters, when the pager ran
    pub pager_stats: Option<PagerStats>,
}

impl TrainingLog {
    /// An empty log for a run called `name`.
    pub fn new(name: &str) -> TrainingLog {
        TrainingLog {
            name: name.to_string(),
            losses: Vec::new(),
            step_times: Vec::new(),
            evals: Vec::new(),
            pager_stats: None,
        }
    }

    /// Append one optimizer step's loss and wall time.
    pub fn record_step(&mut self, step: usize, loss: f32, dt: Duration) {
        debug_assert_eq!(step, self.losses.len());
        self.losses.push(loss);
        self.step_times.push(dt);
    }

    /// Append one held-out evaluation.
    pub fn record_eval(&mut self, step: usize, loss: f32, accuracy: f32) {
        self.evals.push(EvalPoint { step, loss, accuracy });
    }

    /// Loss of the last recorded step (NaN when no steps ran).
    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    /// Mean loss over the last `n` steps (robust to the oscillation that
    /// group-by-length batching produces — paper Appendix B.2).
    pub fn smoothed_final_loss(&self, n: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        tail.iter().sum::<f32>() / tail.len() as f32
    }

    /// Mean wall time per optimizer step.
    pub fn mean_step_time(&self) -> Duration {
        if self.step_times.is_empty() {
            return Duration::ZERO;
        }
        self.step_times.iter().sum::<Duration>() / self.step_times.len() as u32
    }

    /// Highest held-out accuracy seen across evals.
    pub fn best_eval_accuracy(&self) -> Option<f32> {
        self.evals
            .iter()
            .map(|e| e.accuracy)
            .fold(None, |a, b| Some(a.map_or(b, |x: f32| x.max(b))))
    }

    /// Write `step,loss` CSV plus eval points as comment rows.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut s = String::from("step,loss,step_ms\n");
        for (i, (l, t)) in
            self.losses.iter().zip(self.step_times.iter()).enumerate()
        {
            s.push_str(&format!("{i},{l},{:.3}\n", t.as_secs_f64() * 1e3));
        }
        for e in &self.evals {
            s.push_str(&format!(
                "# eval step={} loss={} acc={}\n",
                e.step, e.loss, e.accuracy
            ));
        }
        if let Some(p) = &self.pager_stats {
            s.push_str(&format!(
                "# pager faults={} evictions={} peak_resident={}B stall_us={}\n",
                p.faults, p.evictions, p.peak_resident, p.stall_us
            ));
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, s)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoothing_and_best() {
        let mut log = TrainingLog::new("t");
        for (i, l) in [5.0f32, 4.0, 3.0, 2.0].iter().enumerate() {
            log.record_step(i, *l, Duration::from_millis(1));
        }
        log.record_eval(1, 4.5, 0.2);
        log.record_eval(3, 2.5, 0.6);
        assert_eq!(log.final_loss(), 2.0);
        assert_eq!(log.smoothed_final_loss(2), 2.5);
        assert_eq!(log.best_eval_accuracy(), Some(0.6));
    }

    #[test]
    fn csv_writes() {
        let mut log = TrainingLog::new("t");
        log.record_step(0, 1.0, Duration::from_millis(2));
        let p = std::env::temp_dir().join("qlora_log_test/loss.csv");
        log.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("0,1,"));
    }
}
