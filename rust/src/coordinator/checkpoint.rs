//! Checkpointing: save/restore the training state (LoRA adapters + Adam
//! moments + step counter) via the `.tensors` interchange format. A QLoRA
//! checkpoint is tiny — only adapters are trainable (paper section 2:
//! "the LoRA parameters take up only 26 MB" for 7B) — which is what makes
//! releasing "a collection of adapters" practical. Either file shape can
//! be loaded straight into a serving engine with `Engine::load_adapter`.
//!
//! Saves are **atomic**: the tensors are written to a temp file in the
//! destination directory and renamed into place, so a crash mid-save
//! (the classic way to lose a run) leaves the previous checkpoint
//! intact rather than a truncated, unreadable file.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::coordinator::trainer::Trainer;
use crate::tensorio::{read_tensors, write_tensors_atomic};

/// Save the full training state (atomic write-then-rename).
pub fn save(trainer: &Trainer<'_>, path: &Path) -> Result<()> {
    let tensors = trainer.state_tensors()?;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    write_tensors_atomic(path, &tensors).context("writing checkpoint")
}

/// Save only the adapters (the releasable artifact); atomic like
/// [`save`].
pub fn save_adapters(trainer: &Trainer<'_>, path: &Path) -> Result<()> {
    let adapters = trainer.adapter_tensors()?;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    write_tensors_atomic(path, &adapters).context("writing adapters")
}

/// Restore a full training state checkpoint.
pub fn load(trainer: &mut Trainer<'_>, path: &Path) -> Result<()> {
    let tensors = read_tensors(path).context("reading checkpoint")?;
    ensure!(
        tensors.len() == trainer.spec().n_state,
        "checkpoint tensor count {} != state size {}",
        tensors.len(),
        trainer.spec().n_state
    );
    trainer.load_state(&tensors)
}
