//! Checkpointing: save/restore the training state (LoRA adapters + Adam
//! moments + step counter) via the `.tensors` interchange format. A QLoRA
//! checkpoint is tiny — only adapters are trainable (paper section 2:
//! "the LoRA parameters take up only 26 MB" for 7B) — which is what makes
//! releasing "a collection of adapters" practical. Either file shape can
//! be loaded straight into a serving engine with `Engine::load_adapter`.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::coordinator::trainer::Trainer;
use crate::tensorio::{read_tensors, write_tensors};

/// Save the full training state.
pub fn save(trainer: &Trainer<'_>, path: &Path) -> Result<()> {
    let tensors = trainer.state_tensors()?;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    write_tensors(path, &tensors).context("writing checkpoint")
}

/// Save only the adapters (the releasable artifact).
pub fn save_adapters(trainer: &Trainer<'_>, path: &Path) -> Result<()> {
    let adapters = trainer.adapter_tensors()?;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    write_tensors(path, &adapters).context("writing adapters")
}

/// Restore a full training state checkpoint.
pub fn load(trainer: &mut Trainer<'_>, path: &Path) -> Result<()> {
    let tensors = read_tensors(path).context("reading checkpoint")?;
    ensure!(
        tensors.len() == trainer.spec().n_state,
        "checkpoint tensor count {} != state size {}",
        tensors.len(),
        trainer.spec().n_state
    );
    trainer.load_state(&tensors)
}
