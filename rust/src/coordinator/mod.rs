//! The finetuning coordinator: the training loop over AOT-compiled
//! train/eval graphs, checkpoints, and metrics. The coordinator is a
//! *client* of `crate::engine` — it borrows the runtime and the frozen
//! quantized base from an `Engine` and owns only the mutable training
//! state. Inference (sampling, decoding, serving) lives in
//! `crate::engine`, not here.

pub mod checkpoint;
pub mod metrics;
pub mod trainer;

pub use metrics::TrainingLog;
pub use trainer::{TrainOptions, Trainer};
