//! The finetuning coordinator: owns the training loop over AOT-compiled
//! train/eval graphs, the data pipeline, checkpoints, metrics, and
//! generation. This is the L3 run-time half of the paper's recipe — the
//! Python side lowered the *math* once; everything operational lives here.

pub mod checkpoint;
pub mod generate;
pub mod metrics;
pub mod trainer;

pub use generate::Sampler;
pub use metrics::TrainingLog;
pub use trainer::{TrainOptions, Trainer};
