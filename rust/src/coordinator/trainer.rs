//! The training loop: threads state literals through successive
//! executions of the AOT train-step graph.
//!
//! Input order (the AOT contract, DESIGN.md section 4):
//!   `state[0..S] ++ frozen[0..F] ++ [tokens, loss_mask]`
//! Output order: `state'[0..S] ++ [loss]`.
//! The eval graph takes `state[0..n_trainable] ++ frozen ++ data` and
//! returns `[loss, token_accuracy]`.
//!
//! The trainer is a *client* of [`Engine`]: it borrows the runtime,
//! compiled executables, and frozen quantized base from the engine and
//! owns only the mutable training state (adapters ++ Adam moments ++
//! step). Finished adapters are published back into the engine's registry
//! ([`Trainer::publish_adapter`]) where serving sessions pick them up —
//! the paper's one-base/many-adapters economy in one loop. The trainer is
//! generic over the manifest signature and never assumes model internals,
//! so the same loop drives QLoRA adapters and 16-bit full finetuning
//! (the paper's baseline) alike.

use anyhow::{ensure, Context, Result};

use crate::data::batching::{Batch, Batcher};
use crate::engine::Engine;
use crate::paged::optimizer::PagedOptimizerSim;
use crate::runtime::artifact::ArtifactSpec;
use crate::runtime::executor::{
    literal_from_tensor, literal_scalar_f32, Executable,
};
use crate::tensorio::Tensor;

use super::metrics::TrainingLog;

/// Knobs for one training run.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// optimizer steps to run
    pub steps: usize,
    /// evaluate every N steps (0 disables periodic eval)
    pub eval_every: usize,
    /// data-order and eval seed
    pub seed: u64,
    /// attach the paged-optimizer simulator (paper section 3)
    pub paged: bool,
    /// simulated device memory budget in bytes for the pager
    pub device_budget: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 100,
            eval_every: 25,
            seed: 0,
            paged: false,
            device_budget: 64 << 20,
        }
    }
}

/// Drives the train/eval executables of one artifact: owns the mutable
/// training state and steps it on-device.
pub struct Trainer<'e> {
    engine: &'e Engine,
    train_exe: std::sync::Arc<Executable>,
    eval_exe: std::sync::Arc<Executable>,
    /// mutable training state (trainable ++ adam_m ++ adam_v ++ step)
    state: Vec<xla::Literal>,
    /// optional paged-optimizer simulation running alongside
    pub pager: Option<PagedOptimizerSim>,
}

impl<'e> Trainer<'e> {
    /// Start a training run over `engine`'s artifact from its init state.
    /// Re-reads the artifact's init file: the engine keeps only the
    /// serving-relevant tensors (frozen base + adapters) resident, not
    /// the Adam moments.
    pub fn new(engine: &'e Engine) -> Result<Trainer<'e>> {
        let train_exe = engine.train_exe()?;
        let eval_exe = engine.eval_exe()?;
        let state = engine
            .read_init_state()?
            .iter()
            .map(literal_from_tensor)
            .collect::<Result<Vec<_>>>()
            .context("uploading init training state")?;
        Ok(Trainer { engine, train_exe, eval_exe, state, pager: None })
    }

    /// The engine whose artifact this trainer is training.
    pub fn engine(&self) -> &'e Engine {
        self.engine
    }

    /// The artifact spec being trained.
    pub fn spec(&self) -> &ArtifactSpec {
        &self.engine.spec
    }

    /// Attach the paged-optimizer simulation (sizes taken from the state
    /// signature: adam_m/adam_v tensors are the paged allocations).
    /// Tensor bytes use each spec's real dtype width — sizing every
    /// non-u8 tensor as 4 bytes over-counted f16/bf16 frozen tensors 2×
    /// and skewed the simulated device budget.
    pub fn attach_pager(&mut self, device_budget: usize) {
        let spec = &self.engine.spec;
        let opt_bytes: usize = spec
            .state_sig
            .iter()
            .filter(|t| t.name.starts_with("adam_"))
            .map(|t| t.nbytes())
            .sum();
        let model_bytes: usize =
            spec.frozen_sig.iter().map(|t| t.nbytes()).sum();
        self.pager = Some(PagedOptimizerSim::new(
            device_budget,
            model_bytes,
            opt_bytes,
            spec.cfg.d_model,
            spec.cfg.n_layers,
        ));
    }

    /// One optimizer step; returns the loss.
    pub fn step(&mut self, batch: &Batch) -> Result<f32> {
        let [tok, mask] = self.engine.batch_literals(batch)?;
        let frozen = self.engine.frozen();
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(self.state.len() + frozen.len() + 2);
        inputs.extend(self.state.iter());
        inputs.extend(frozen.iter());
        inputs.push(&tok);
        inputs.push(&mask);
        let mut out = self.train_exe.run(&inputs)?;
        let n_state = self.spec().n_state;
        ensure!(
            out.len() == n_state + 1,
            "train step returned {} outputs, expected {}",
            out.len(),
            n_state + 1
        );
        let loss = literal_scalar_f32(&out[n_state])?;
        out.truncate(n_state);
        self.state = out;
        if let Some(p) = &mut self.pager {
            // max sequence length in the batch drives the activation spike
            let max_len = batch.lens.iter().copied().max().unwrap_or(0);
            p.on_step(max_len);
        }
        Ok(loss)
    }

    /// Evaluate (loss, token accuracy) on a batch without updating state.
    pub fn eval(&self, batch: &Batch) -> Result<(f32, f32)> {
        let [tok, mask] = self.engine.batch_literals(batch)?;
        let frozen = self.engine.frozen();
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        inputs.extend(self.state.iter().take(self.spec().n_trainable));
        inputs.extend(frozen.iter());
        inputs.push(&tok);
        inputs.push(&mask);
        let out = self.eval_exe.run(&inputs)?;
        ensure!(out.len() == 2, "eval returned {} outputs", out.len());
        Ok((literal_scalar_f32(&out[0])?, literal_scalar_f32(&out[1])?))
    }

    /// Mean eval over a whole batcher.
    pub fn eval_all(&self, batcher: &Batcher, seed: u64) -> Result<(f32, f32)> {
        let batches = batcher.epoch(seed);
        ensure!(!batches.is_empty(), "empty eval set");
        let mut loss = 0f64;
        let mut acc = 0f64;
        for b in &batches {
            let (l, a) = self.eval(b)?;
            loss += l as f64;
            acc += a as f64;
        }
        let n = batches.len() as f64;
        Ok(((loss / n) as f32, (acc / n) as f32))
    }

    /// Run the full loop: `opts.steps` steps cycling over epochs, periodic
    /// eval on `eval_batcher`, everything recorded in the returned log.
    pub fn train(
        &mut self,
        train_batcher: &Batcher,
        eval_batcher: Option<&Batcher>,
        opts: &TrainOptions,
    ) -> Result<TrainingLog> {
        if opts.paged && self.pager.is_none() {
            self.attach_pager(opts.device_budget);
        }
        let mut log = TrainingLog::new(&self.spec().name);
        let mut step = 0usize;
        let mut epoch = 0u64;
        'outer: loop {
            let batches = train_batcher.epoch(opts.seed ^ epoch);
            ensure!(!batches.is_empty(), "train set smaller than one batch");
            for b in &batches {
                let t0 = std::time::Instant::now();
                let loss = self.step(b)?;
                log.record_step(step, loss, t0.elapsed());
                if let Some(ev) = eval_batcher {
                    if opts.eval_every > 0
                        && (step + 1) % opts.eval_every == 0
                    {
                        let (l, a) = self.eval_all(ev, 0)?;
                        log.record_eval(step, l, a);
                    }
                }
                step += 1;
                if step >= opts.steps {
                    break 'outer;
                }
            }
            epoch += 1;
        }
        if let Some(p) = &self.pager {
            log.pager_stats = Some(p.stats.clone());
        }
        Ok(log)
    }

    /// Current state as host tensors (checkpointing).
    pub fn state_tensors(&self) -> Result<Vec<Tensor>> {
        self.state
            .iter()
            .zip(self.spec().state_sig.iter())
            .map(|(l, s)| crate::runtime::executor::literal_to_tensor(&s.name, l))
            .collect()
    }

    /// Just the adapter tensors (the releasable artifact).
    pub fn adapter_tensors(&self) -> Result<Vec<Tensor>> {
        let mut tensors = self.state_tensors()?;
        tensors.truncate(self.spec().n_trainable);
        Ok(tensors)
    }

    /// Publish the current adapters into the engine's registry under
    /// `name`, hot-swapping any previous version. Live sessions serving
    /// `name` observe the swap on their next forward; the frozen base is
    /// untouched.
    pub fn publish_adapter(&self, name: &str) -> Result<()> {
        self.engine.register_adapter(name, self.adapter_tensors()?)
    }

    /// Restore state from host tensors (must match the state signature).
    pub fn load_state(&mut self, tensors: &[Tensor]) -> Result<()> {
        ensure!(
            tensors.len() == self.spec().n_state,
            "checkpoint has {} tensors, expected {}",
            tensors.len(),
            self.spec().n_state
        );
        self.state = tensors
            .iter()
            .map(literal_from_tensor)
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }
}
