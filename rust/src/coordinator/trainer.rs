//! The training loop: threads state literals through successive
//! executions of the AOT train-step graph.
//!
//! Input order (the AOT contract, DESIGN.md section 4):
//!   `state[0..S] ++ frozen[0..F] ++ [tokens, loss_mask]`
//! Output order: `state'[0..S] ++ [loss]`.
//! The eval graph takes `state[0..n_trainable] ++ frozen ++ data` and
//! returns `[loss, token_accuracy]`.
//!
//! The trainer is generic over the manifest signature — it never assumes
//! model internals, so the same loop drives QLoRA adapters and 16-bit
//! full finetuning (the paper's baseline) alike.

use anyhow::{ensure, Context, Result};

use crate::data::batching::{Batch, Batcher};
use crate::paged::optimizer::PagedOptimizerSim;
use crate::runtime::artifact::{ArtifactSpec, Manifest};
use crate::runtime::client::Runtime;
use crate::runtime::executor::{
    literal_from_tensor, literal_scalar_f32, Executable,
};
use crate::tensorio::{read_tensors, Tensor};

use super::metrics::TrainingLog;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: usize,
    pub eval_every: usize,
    pub seed: u64,
    /// attach the paged-optimizer simulator (paper section 3)
    pub paged: bool,
    /// simulated device memory budget in bytes for the pager
    pub device_budget: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 100,
            eval_every: 25,
            seed: 0,
            paged: false,
            device_budget: 64 << 20,
        }
    }
}

pub struct Trainer {
    pub spec: ArtifactSpec,
    train_exe: std::sync::Arc<Executable>,
    eval_exe: std::sync::Arc<Executable>,
    fwd_exe: Option<std::sync::Arc<Executable>>,
    /// mutable training state (trainable ++ adam_m ++ adam_v ++ step)
    state: Vec<xla::Literal>,
    /// frozen quantized base — uploaded once, reused every step
    frozen: Vec<xla::Literal>,
    /// optional paged-optimizer simulation running alongside
    pub pager: Option<PagedOptimizerSim>,
}

impl Trainer {
    /// Load artifact `name`: compile graphs, read init tensors.
    pub fn new(rt: &Runtime, manifest: &Manifest, name: &str) -> Result<Trainer> {
        let spec = manifest.get(name)?.clone();
        let train_exe = rt.load_hlo(&spec.train_hlo)?;
        let eval_exe = rt.load_hlo(&spec.eval_hlo)?;
        let fwd_exe = match &spec.fwd_hlo {
            Some(p) => Some(rt.load_hlo(p)?),
            None => None,
        };
        let init = read_tensors(&spec.init)
            .with_context(|| format!("init tensors for {name}"))?;
        ensure!(
            init.len() == spec.n_state + spec.n_frozen,
            "init file has {} tensors, manifest expects {}",
            init.len(),
            spec.n_state + spec.n_frozen
        );
        let mut lits = init
            .iter()
            .map(literal_from_tensor)
            .collect::<Result<Vec<_>>>()?;
        let frozen = lits.split_off(spec.n_state);
        Ok(Trainer {
            spec,
            train_exe,
            eval_exe,
            fwd_exe,
            state: lits,
            frozen,
            pager: None,
        })
    }

    /// Attach the paged-optimizer simulation (sizes taken from the state
    /// signature: adam_m/adam_v tensors are the paged allocations).
    pub fn attach_pager(&mut self, device_budget: usize) {
        let opt_bytes: usize = self
            .spec
            .state_sig
            .iter()
            .filter(|t| t.name.starts_with("adam_"))
            .map(|t| t.elems() * 4)
            .sum();
        let model_bytes: usize = self
            .spec
            .frozen_sig
            .iter()
            .map(|t| t.elems() * if t.dtype == "u8" { 1 } else { 4 })
            .sum();
        self.pager = Some(PagedOptimizerSim::new(
            device_budget,
            model_bytes,
            opt_bytes,
            self.spec.cfg.batch * self.spec.cfg.seq_len,
            self.spec.cfg.d_model,
            self.spec.cfg.n_layers,
        ));
    }

    fn batch_literals(&self, batch: &Batch) -> Result<[xla::Literal; 2]> {
        ensure!(
            batch.batch == self.spec.cfg.batch
                && batch.seq_len == self.spec.cfg.seq_len,
            "batch shape {}x{} does not match artifact {}x{}",
            batch.batch,
            batch.seq_len,
            self.spec.cfg.batch,
            self.spec.cfg.seq_len
        );
        let t = Tensor::i32("tokens", vec![batch.batch, batch.seq_len],
                            &batch.tokens);
        let m = Tensor::f32("loss_mask", vec![batch.batch, batch.seq_len],
                            &batch.mask);
        Ok([literal_from_tensor(&t)?, literal_from_tensor(&m)?])
    }

    /// One optimizer step; returns the loss.
    pub fn step(&mut self, batch: &Batch) -> Result<f32> {
        let [tok, mask] = self.batch_literals(batch)?;
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(self.state.len() + self.frozen.len() + 2);
        inputs.extend(self.state.iter());
        inputs.extend(self.frozen.iter());
        inputs.push(&tok);
        inputs.push(&mask);
        let mut out = self.train_exe.run(&inputs)?;
        ensure!(
            out.len() == self.spec.n_state + 1,
            "train step returned {} outputs, expected {}",
            out.len(),
            self.spec.n_state + 1
        );
        let loss = literal_scalar_f32(&out[self.spec.n_state])?;
        out.truncate(self.spec.n_state);
        self.state = out;
        if let Some(p) = &mut self.pager {
            // max sequence length in the batch drives the activation spike
            let max_len = batch.lens.iter().copied().max().unwrap_or(0);
            p.on_step(max_len, batch.seq_len);
        }
        Ok(loss)
    }

    /// Evaluate (loss, token accuracy) on a batch without updating state.
    pub fn eval(&self, batch: &Batch) -> Result<(f32, f32)> {
        let [tok, mask] = self.batch_literals(batch)?;
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        inputs.extend(self.state.iter().take(self.spec.n_trainable));
        inputs.extend(self.frozen.iter());
        inputs.push(&tok);
        inputs.push(&mask);
        let out = self.eval_exe.run(&inputs)?;
        ensure!(out.len() == 2, "eval returned {} outputs", out.len());
        Ok((literal_scalar_f32(&out[0])?, literal_scalar_f32(&out[1])?))
    }

    /// Forward logits for generation (requires a fwd artifact).
    pub fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let exe = self
            .fwd_exe
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no fwd artifact for {}",
                                           self.spec.name))?;
        let t = Tensor::i32(
            "tokens",
            vec![self.spec.cfg.batch, self.spec.cfg.seq_len],
            tokens,
        );
        let tok = literal_from_tensor(&t)?;
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        inputs.extend(self.state.iter().take(self.spec.n_trainable));
        inputs.extend(self.frozen.iter());
        inputs.push(&tok);
        let out = exe.run(&inputs)?;
        crate::runtime::executor::literal_to_f32(&out[0])
    }

    /// Mean eval over a whole batcher.
    pub fn eval_all(&self, batcher: &Batcher, seed: u64) -> Result<(f32, f32)> {
        let batches = batcher.epoch(seed);
        ensure!(!batches.is_empty(), "empty eval set");
        let mut loss = 0f64;
        let mut acc = 0f64;
        for b in &batches {
            let (l, a) = self.eval(b)?;
            loss += l as f64;
            acc += a as f64;
        }
        let n = batches.len() as f64;
        Ok(((loss / n) as f32, (acc / n) as f32))
    }

    /// Run the full loop: `opts.steps` steps cycling over epochs, periodic
    /// eval on `eval_batcher`, everything recorded in the returned log.
    pub fn train(
        &mut self,
        train_batcher: &Batcher,
        eval_batcher: Option<&Batcher>,
        opts: &TrainOptions,
    ) -> Result<TrainingLog> {
        if opts.paged && self.pager.is_none() {
            self.attach_pager(opts.device_budget);
        }
        let mut log = TrainingLog::new(&self.spec.name);
        let mut step = 0usize;
        let mut epoch = 0u64;
        'outer: loop {
            let batches = train_batcher.epoch(opts.seed ^ epoch);
            ensure!(!batches.is_empty(), "train set smaller than one batch");
            for b in &batches {
                let t0 = std::time::Instant::now();
                let loss = self.step(b)?;
                log.record_step(step, loss, t0.elapsed());
                if let Some(ev) = eval_batcher {
                    if opts.eval_every > 0
                        && (step + 1) % opts.eval_every == 0
                    {
                        let (l, a) = self.eval_all(ev, 0)?;
                        log.record_eval(step, l, a);
                    }
                }
                step += 1;
                if step >= opts.steps {
                    break 'outer;
                }
            }
            epoch += 1;
        }
        if let Some(p) = &self.pager {
            log.pager_stats = Some(p.stats.clone());
        }
        Ok(log)
    }

    /// Current state as host tensors (checkpointing).
    pub fn state_tensors(&self) -> Result<Vec<Tensor>> {
        self.state
            .iter()
            .zip(self.spec.state_sig.iter())
            .map(|(l, s)| crate::runtime::executor::literal_to_tensor(&s.name, l))
            .collect()
    }

    /// Restore state from host tensors (must match the state signature).
    pub fn load_state(&mut self, tensors: &[Tensor]) -> Result<()> {
        ensure!(
            tensors.len() == self.spec.n_state,
            "checkpoint has {} tensors, expected {}",
            tensors.len(),
            self.spec.n_state
        );
        self.state = tensors
            .iter()
            .map(literal_from_tensor)
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }
}
