//! Generation: nucleus sampling over the AOT forward graph.
//!
//! The paper's evaluation setup uses nucleus sampling with p = 0.9 and
//! temperature 0.7 throughout (section 5.2); those are the defaults here.
//! The fwd artifact has fixed (batch, seq_len) shape, so decoding re-runs
//! the full-sequence forward with the prompt left-aligned and reads the
//! logits at the current position (fine for demo-scale models; a KV-cache
//! decode graph is the standard extension).

use anyhow::{ensure, Result};

use crate::coordinator::trainer::Trainer;
use crate::data::tokenizer::{Tokenizer, BOS, EOS, PAD, SEP};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Sampler {
    pub top_p: f64,
    pub temperature: f64,
    pub max_new_tokens: usize,
}

impl Default for Sampler {
    fn default() -> Self {
        // paper section 5.2: "nucleus sampling with p=0.9 and temperature 0.7"
        Sampler { top_p: 0.9, temperature: 0.7, max_new_tokens: 32 }
    }
}

impl Sampler {
    /// Sample one token id from a logits row.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> i32 {
        let inv_t = 1.0 / self.temperature.max(1e-6);
        // softmax with temperature
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<(usize, f64)> = logits
            .iter()
            .enumerate()
            .map(|(i, &l)| (i, (((l - mx) as f64) * inv_t).exp()))
            .collect();
        let z: f64 = probs.iter().map(|(_, p)| p).sum();
        for p in probs.iter_mut() {
            p.1 /= z;
        }
        // nucleus: smallest set with cumulative mass >= top_p
        probs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut cum = 0.0;
        let mut cut = probs.len();
        for (i, (_, p)) in probs.iter().enumerate() {
            cum += p;
            if cum >= self.top_p {
                cut = i + 1;
                break;
            }
        }
        probs.truncate(cut);
        let weights: Vec<f64> = probs.iter().map(|(_, p)| *p).collect();
        probs[rng.categorical(&weights)].0 as i32
    }

    /// Greedy argmax (deterministic decoding for accuracy-style eval).
    pub fn greedy(logits: &[f32]) -> i32 {
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }

    /// Generate a response to `instruction` (row 0 of the batch; other
    /// rows are padding).
    pub fn generate(
        &self,
        trainer: &Trainer,
        tok: &Tokenizer,
        instruction: &str,
        rng: &mut Rng,
        greedy: bool,
    ) -> Result<String> {
        let cfg = &trainer.spec.cfg;
        let vocab = cfg.vocab;
        let mut ids = vec![BOS];
        ids.extend(tok.encode(instruction));
        ids.push(SEP);
        ensure!(ids.len() < cfg.seq_len, "prompt too long");
        let prompt_len = ids.len();
        let mut out_ids: Vec<i32> = Vec::new();
        for _ in 0..self.max_new_tokens {
            let pos = prompt_len + out_ids.len();
            if pos >= cfg.seq_len {
                break;
            }
            let mut tokens = vec![PAD; cfg.batch * cfg.seq_len];
            tokens[..prompt_len].copy_from_slice(&ids[..prompt_len]);
            tokens[prompt_len..pos]
                .copy_from_slice(&out_ids);
            let logits = trainer.logits(&tokens)?;
            // logits shape (batch, seq, vocab); want row 0, position pos-1
            let off = (pos - 1) * vocab;
            let row = &logits[off..off + vocab];
            let next = if greedy {
                Self::greedy(row)
            } else {
                self.sample(row, rng)
            };
            if next == EOS {
                break;
            }
            out_ids.push(next);
        }
        Ok(tok.decode(&out_ids))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        assert_eq!(Sampler::greedy(&[0.1, 5.0, -2.0]), 1);
    }

    #[test]
    fn nucleus_restricts_tail() {
        // with a sharply peaked distribution and p=0.5 only the mode remains
        let s = Sampler { top_p: 0.5, temperature: 1.0, max_new_tokens: 1 };
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(s.sample(&logits, &mut rng), 0);
        }
    }

    #[test]
    fn temperature_flattens() {
        // with huge temperature sampling becomes ~uniform
        let s = Sampler { top_p: 1.0, temperature: 1e6, max_new_tokens: 1 };
        let logits = vec![3.0, 0.0];
        let mut rng = Rng::new(2);
        let ones = (0..2000).filter(|_| s.sample(&logits, &mut rng) == 1).count();
        assert!(ones > 700, "tail sampled {ones}/2000");
    }
}
