//! `qlora` — CLI for the QLoRA reproduction.
//!
//! Subcommands:
//!   train        finetune an artifact on a synthetic corpus
//!   eval         evaluate an adapter over the frozen base (no trainer)
//!   generate     sample from the serving engine (single, batched, or
//!                streamed; nucleus p=0.9, T=0.7)
//!   serve        request-lifecycle serving: per-request priorities and
//!                deadlines, block-granular KV admission with prefix
//!                sharing (or legacy --token-budget), typed outcomes,
//!                and a ServerStats block
//!   serve-http   the same lifecycle pipeline behind an HTTP/1.1 API:
//!                POST /v1/generate (complete or streamed), GET
//!                /v1/stats, GET /healthz, POST /v1/shutdown
//!   arena        judged Elo tournament between adapters on one base
//!   quantize     quantization round-trip report for a datatype
//!   memory       analytical memory planner (Figure 6 / Table 6)
//!   experiment   regenerate a paper table/figure (or `all`)
//!   list         list artifacts and experiments
//!
//! Inference paths (`generate`, `eval`, `arena`) run entirely through
//! `engine::Engine` + `Session`: one frozen base is uploaded once and any
//! number of adapters are multiplexed over it.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Result};

use qlora::coordinator::checkpoint;
use qlora::coordinator::trainer::{TrainOptions, Trainer};
use qlora::data::batching::Batcher;
use qlora::data::synthetic::{corpus, eval_set, CorpusKind, EvalSuite};
use qlora::data::tokenizer::Tokenizer;
use qlora::engine::{
    DecodeMode, Engine, GenRequest, Priority, Sampler, BASE_ADAPTER,
};
use qlora::eval::arena::run_arena;
use qlora::eval::Judge;
use qlora::experiments::{runner, Ctx};
use qlora::memory;
use qlora::quant::codebook::DType;
use qlora::quant::error::{quant_error, synthetic_llm_weights};
use qlora::runtime::artifact::Manifest;
use qlora::runtime::client::Runtime;
use qlora::serve::{HttpServer, ServerConfig};
use qlora::util::cli::Args;
use qlora::util::faults::Faults;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: qlora <command> [flags]\n\
     commands:\n\
       train       --artifact <name> [--corpus alpaca] [--steps N] \
     [--seed S] [--paged] [--out ckpt.tensors] [--curve loss.csv]\n\
       eval        --artifact <name> [--ckpt ckpt.tensors] [--suite \
     mmlu|vicuna]\n\
       generate    --artifact <name> [--ckpt ...] [--adapter <name>] \
     --prompt \"rev abc\" [--prompts \"a|b|...\" (any count: continuous \
     batching)] [--decode auto|cached|full] [--stream] [--greedy] \
     [--top-p P] [--top-k K] [--temperature T] [--max-new N]\n\
       serve       --artifact <name> [--ckpt ...] [--adapter <name>] \
     --requests \"spec|spec|...\" (spec: [high|normal|low[@<ms>]:]prompt) \
     [--kv-block N] [--kv-blocks N] [--no-prefix-sharing] \
     [--token-budget N (legacy admission)] [--decode ...] \
     [sampling flags as generate]\n\
       serve-http  --artifact <name> [--ckpt ...] [--adapter <name>] \
     [--addr 127.0.0.1:8080] [--workers 4] [--max-body-kb 1024] \
     [--max-connections 128] [--max-queue 256 (shed 429 past this \
     backlog)] [--request-timeout-ms MS (wall-clock cap -> scheduler \
     deadline)] [--watchdog-ms MS (retire stalled jobs as timed_out)] \
     [--header-deadline-ms 2000 (slowloris guard)] [--write-timeout-ms \
     10000] [--channel-depth 64 (per-job token buffer; slow consumers \
     are cancelled)] [--retry-after-secs 1] [--faults \
     \"seed=S,delay-ms=MS,<site>=<p>[x<max>],...\" or $QLORA_FAULTS \
     (sites: slow-write conn-reset worker-panic block-alloc \
     decode-delay)] [session flags as serve]\n\
       arena       --artifact <name> --adapters \"tuned=ck.tensors[,...]\" \
     [--n-prompts N] [--judge gpt4|human] [--orderings N]\n\
       quantize    [--dtype nf4] [--block 64] [--dq]\n\
       memory      [--size 65B] [--r 64] [--seq 512]\n\
       experiment  <id|all> [--fast] [--seed S] [--results results/]\n\
       list\n\
     global: --artifacts <dir> (default artifacts/ or $QLORA_ARTIFACTS)"
}

fn corpus_kind(name: &str) -> Result<CorpusKind> {
    CorpusKind::all()
        .into_iter()
        .find(|k| k.name() == name)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown corpus {name:?}; one of: {}",
            CorpusKind::all().map(|k| k.name()).join(", ")))
}

/// Parse one `serve` request spec: `[high|normal|low[@<deadline ms>]:]
/// prompt`. A bare prompt is `Normal` priority with no deadline; a
/// prompt that happens to start with a priority word followed by `:` can
/// be escaped as `normal:high: actual prompt`.
fn parse_request_spec(spec: &str) -> Result<GenRequest> {
    let Some((head, rest)) = spec.split_once(':') else {
        return Ok(GenRequest::new(spec));
    };
    let (prio_word, deadline_ms) = match head.split_once('@') {
        Some((p, ms)) => (p, Some(ms)),
        None => (head, None),
    };
    let priority = match prio_word.trim() {
        "high" => Priority::High,
        "normal" => Priority::Normal,
        "low" => Priority::Low,
        // not a priority prefix: the colon belongs to the prompt itself
        _ => return Ok(GenRequest::new(spec)),
    };
    let mut req = GenRequest::new(rest.trim()).priority(priority);
    if let Some(ms) = deadline_ms {
        let ms: u64 = ms.trim().parse().map_err(|_| {
            anyhow::anyhow!("bad deadline {ms:?} in request spec {spec:?} \
                             (expected milliseconds)")
        })?;
        req = req.deadline(std::time::Duration::from_millis(ms));
    }
    Ok(req)
}

/// Build the serving engine for `--artifact`, loading `--ckpt` (if given)
/// as the adapter named "ckpt".
fn engine_from_args(args: &Args, artifacts_dir: &Path) -> Result<Engine> {
    let name = args
        .get("artifact")
        .ok_or_else(|| anyhow::anyhow!("--artifact required"))?;
    let manifest = Manifest::load(artifacts_dir)?;
    let engine = Engine::cpu(&manifest, name)?;
    if let Some(ck) = args.get("ckpt") {
        engine.load_adapter("ckpt", &PathBuf::from(ck))?;
    }
    Ok(engine)
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        println!("{}", usage());
        return Ok(());
    };
    let artifacts_dir = PathBuf::from(
        args.get_or("artifacts",
                    Manifest::default_dir().to_str().unwrap_or("artifacts")));

    match cmd {
        "list" => {
            match Manifest::load(&artifacts_dir) {
                Ok(m) => {
                    println!("artifacts in {:?}:", m.dir);
                    for a in &m.artifacts {
                        println!(
                            "  {:<24} {:>10} params  quant={:<9} lora={}",
                            a.name,
                            a.cfg.n_params(),
                            a.cfg.quant,
                            if a.cfg.lora {
                                a.cfg.lora_scope.clone()
                            } else {
                                "off".into()
                            }
                        );
                    }
                }
                Err(e) => println!("(no artifacts: {e})"),
            }
            println!("\nexperiments:");
            for (id, needs, desc, _) in runner::registry() {
                println!("  {:<12} {}{}", id, desc,
                         if needs { "  [needs artifacts]" } else { "" });
            }
        }
        "train" => {
            let name = args
                .get("artifact")
                .ok_or_else(|| anyhow::anyhow!("--artifact required"))?;
            let manifest = Manifest::load(&artifacts_dir)?;
            let engine = Engine::cpu(&manifest, name)?;
            let mut trainer = Trainer::new(&engine)?;
            let cfg = trainer.spec().cfg.clone();
            let kind = corpus_kind(&args.get_or("corpus", "alpaca"))?;
            let tok = Tokenizer::new(cfg.vocab);
            let ds = corpus(kind, args.usize_or("corpus-size", 512)?,
                            args.u64_or("seed", 0)?);
            let batcher = Batcher::new(&ds, tok.clone(), cfg.batch,
                                       cfg.seq_len, args.flag("train-on-source"));
            let eval_ds = eval_set(EvalSuite::VicunaProxy, cfg.batch * 4, 99);
            let eval_b = Batcher::new(&eval_ds, tok, cfg.batch, cfg.seq_len,
                                      false);
            let opts = TrainOptions {
                steps: args.usize_or("steps", 200)?,
                eval_every: args.usize_or("eval-every", 50)?,
                seed: args.u64_or("seed", 0)?,
                paged: args.flag("paged"),
                device_budget: args.usize_or("device-mb", 64)? << 20,
            };
            println!(
                "training {name} ({} params, quant={}, lora={}) on {} \
                 for {} steps",
                cfg.n_params(), cfg.quant, cfg.lora_scope, kind.name(),
                opts.steps
            );
            let log = trainer.train(&batcher, Some(&eval_b), &opts)?;
            println!(
                "final loss {:.4} (smoothed {:.4}); mean step {:.1} ms",
                log.final_loss(),
                log.smoothed_final_loss(10),
                log.mean_step_time().as_secs_f64() * 1e3
            );
            for e in &log.evals {
                println!("  eval@{:<5} loss {:.4} acc {:.3}", e.step, e.loss,
                         e.accuracy);
            }
            if let Some(p) = &log.pager_stats {
                println!(
                    "  pager: {} faults, {} evictions, stall {:.1} ms total",
                    p.faults, p.evictions, p.stall_us / 1e3
                );
            }
            if let Some(out) = args.get("out") {
                checkpoint::save(&trainer, &PathBuf::from(out))?;
                println!("checkpoint -> {out}");
            }
            if let Some(curve) = args.get("curve") {
                log.write_csv(&PathBuf::from(curve))?;
                println!("loss curve -> {curve}");
            }
        }
        "eval" => {
            let engine = engine_from_args(&args, &artifacts_dir)?;
            let adapter = if args.get("ckpt").is_some() {
                "ckpt"
            } else {
                BASE_ADAPTER
            };
            let session = engine.session().adapter(adapter).build()?;
            let cfg = engine.spec.cfg.clone();
            let suite = match args.get_or("suite", "vicuna").as_str() {
                "mmlu" => EvalSuite::MmluProxy,
                _ => EvalSuite::VicunaProxy,
            };
            let tok = Tokenizer::new(cfg.vocab);
            let ds = eval_set(suite, cfg.batch * 8, args.u64_or("seed", 7)?);
            let b = Batcher::new(&ds, tok, cfg.batch, cfg.seq_len, false);
            let (loss, acc) = session.eval_all(&b, 0)?;
            println!("eval[{adapter}] loss {loss:.4}  token accuracy {acc:.3}");
        }
        "generate" => {
            let engine = engine_from_args(&args, &artifacts_dir)?;
            let adapter = args.get_or(
                "adapter",
                if args.get("ckpt").is_some() { "ckpt" } else { BASE_ADAPTER },
            );
            let decode = match args.get_or("decode", "auto").as_str() {
                "auto" => DecodeMode::Auto,
                "cached" => DecodeMode::Cached,
                "full" => DecodeMode::Full,
                other => bail!("--decode must be auto|cached|full, \
                                got {other:?}"),
            };
            let mut session = engine
                .session()
                .adapter(&adapter)
                .sampler(Sampler::from_args(&args, 32)?)
                .greedy(args.flag("greedy"))
                .seed(args.u64_or("seed", 0)?)
                .decode(decode)
                .build()?;
            if let Some(batch) = args.get("prompts") {
                // continuous batching: any number of prompts multiplexed
                // over the compiled batch rows, refilled as rows retire
                let prompts: Vec<&str> =
                    batch.split('|').map(str::trim).collect();
                let outs = session.generate_batch(&prompts)?;
                for (p, o) in prompts.iter().zip(outs.iter()) {
                    println!("{p} -> {o}");
                }
            } else {
                let prompt = args
                    .get("prompt")
                    .ok_or_else(|| {
                        anyhow::anyhow!("--prompt (or --prompts) required")
                    })?
                    .to_string();
                if args.flag("stream") {
                    use std::io::Write;
                    print!("{prompt} -> ");
                    std::io::stdout().flush()?;
                    session.generate_with(&prompt, |piece| {
                        print!("{piece}");
                        let _ = std::io::stdout().flush();
                    })?;
                    println!();
                } else {
                    let out = session.generate(&prompt)?;
                    println!("{prompt} -> {out}");
                }
            }
        }
        "serve" => {
            let engine = engine_from_args(&args, &artifacts_dir)?;
            let adapter = args.get_or(
                "adapter",
                if args.get("ckpt").is_some() { "ckpt" } else { BASE_ADAPTER },
            );
            let decode = match args.get_or("decode", "auto").as_str() {
                "auto" => DecodeMode::Auto,
                "cached" => DecodeMode::Cached,
                "full" => DecodeMode::Full,
                other => bail!("--decode must be auto|cached|full, \
                                got {other:?}"),
            };
            let mut builder = engine
                .session()
                .adapter(&adapter)
                .sampler(Sampler::from_args(&args, 32)?)
                .greedy(args.flag("greedy"))
                .seed(args.u64_or("seed", 0)?)
                .decode(decode);
            if let Some(budget) = args.get("token-budget") {
                builder = builder.token_budget(budget.parse()?);
            }
            if let Some(bt) = args.get("kv-block") {
                builder = builder.kv_block_tokens(bt.parse()?);
            }
            if let Some(n) = args.get("kv-blocks") {
                builder = builder.kv_blocks(n.parse()?);
            }
            builder = builder.prefix_sharing(!args.flag("no-prefix-sharing"));
            let mut session = builder.build()?;
            let spec = args.get("requests").ok_or_else(|| {
                anyhow::anyhow!("--requests \"spec|spec|...\" required \
                                 (spec: [high|normal|low[@<ms>]:]prompt)")
            })?;
            let requests = spec
                .split('|')
                .map(|part| parse_request_spec(part.trim()))
                .collect::<Result<Vec<_>>>()?;
            let prompts: Vec<String> =
                requests.iter().map(|r| r.prompt.clone()).collect();
            let report = session.serve(requests)?;
            for (p, out) in prompts.iter().zip(report.outputs.iter()) {
                println!("[{:?}] {} -> {}", out.outcome, p, out.text);
            }
            let s = &report.stats;
            println!("--- server stats ---");
            println!("{}", s.summary());
            println!(
                "{}; elapsed {:.1} ms",
                if s.kv_blocks > 0 {
                    format!(
                        "KV pool {} blocks x {} tokens ({} tokens)",
                        s.kv_blocks, s.kv_block_tokens, s.token_budget
                    )
                } else if s.token_budget == usize::MAX {
                    "token budget unbounded".to_string()
                } else {
                    format!("token budget {}", s.token_budget)
                },
                s.elapsed.as_secs_f64() * 1e3
            );
        }
        "serve-http" => {
            let engine = engine_from_args(&args, &artifacts_dir)?;
            let adapter = args.get_or(
                "adapter",
                if args.get("ckpt").is_some() { "ckpt" } else { BASE_ADAPTER },
            );
            let decode = match args.get_or("decode", "auto").as_str() {
                "auto" => DecodeMode::Auto,
                "cached" => DecodeMode::Cached,
                "full" => DecodeMode::Full,
                other => bail!("--decode must be auto|cached|full, \
                                got {other:?}"),
            };
            let mut builder = engine
                .session()
                .adapter(&adapter)
                .sampler(Sampler::from_args(&args, 32)?)
                .greedy(args.flag("greedy"))
                .seed(args.u64_or("seed", 0)?)
                .decode(decode);
            if let Some(budget) = args.get("token-budget") {
                builder = builder.token_budget(budget.parse()?);
            }
            if let Some(bt) = args.get("kv-block") {
                builder = builder.kv_block_tokens(bt.parse()?);
            }
            if let Some(n) = args.get("kv-blocks") {
                builder = builder.kv_blocks(n.parse()?);
            }
            builder = builder.prefix_sharing(!args.flag("no-prefix-sharing"));
            // deterministic fault injection: --faults wins over the
            // QLORA_FAULTS env var; one shared plan drives both the
            // engine-side sites (decode-delay, block-alloc) and the
            // serving-side ones (slow-write, conn-reset, worker-panic)
            let env_spec = std::env::var("QLORA_FAULTS").ok();
            let fault_spec =
                args.get("faults").or(env_spec.as_deref());
            let faults = match fault_spec {
                Some(spec) => Faults::from_spec(spec)
                    .map_err(|e| anyhow::anyhow!("--faults: {e}"))?,
                None => Faults::disabled(),
            };
            if faults.enabled() {
                println!("fault injection armed: {faults:?}");
            }
            builder = builder.faults(faults.clone());
            if let Some(ms) = args.get("watchdog-ms") {
                builder = builder.watchdog(std::time::Duration::from_millis(
                    ms.parse()?,
                ));
            }
            let mut session = builder.build()?;
            let defaults = ServerConfig::default();
            let request_timeout = args
                .get("request-timeout-ms")
                .map(|ms| ms.parse().map(std::time::Duration::from_millis))
                .transpose()?;
            let cfg = ServerConfig {
                addr: args.get_or("addr", "127.0.0.1:8080"),
                workers: args.usize_or("workers", 4)?,
                max_body_bytes: args.usize_or("max-body-kb", 1024)? << 10,
                max_connections: args
                    .usize_or("max-connections", defaults.max_connections)?,
                max_queue: args.usize_or("max-queue", defaults.max_queue)?,
                token_channel_depth: args.usize_or(
                    "channel-depth",
                    defaults.token_channel_depth,
                )?,
                request_timeout,
                header_deadline: std::time::Duration::from_millis(
                    args.u64_or("header-deadline-ms", 2000)?,
                ),
                write_timeout: std::time::Duration::from_millis(
                    args.u64_or("write-timeout-ms", 10_000)?,
                ),
                retry_after_secs: args
                    .u64_or("retry-after-secs", defaults.retry_after_secs)?,
                faults,
            };
            println!(
                "limits: {} connections, queue watermark {}, channel \
                 depth {}, header deadline {:?}, write timeout {:?}{}",
                cfg.max_connections,
                cfg.max_queue,
                cfg.token_channel_depth,
                cfg.header_deadline,
                cfg.write_timeout,
                match cfg.request_timeout {
                    Some(t) => format!(", request timeout {t:?}"),
                    None => String::new(),
                }
            );
            let server = HttpServer::bind(cfg)?;
            println!(
                "serving adapter {adapter:?} on http://{}",
                server.local_addr()?
            );
            println!("  POST /v1/generate   {{\"prompt\": \"...\", \
                      \"stream\": true, \"priority\": \"high\", ...}}");
            println!("  GET  /v1/stats      scheduler + KV-block stats");
            println!("  GET  /healthz       liveness");
            println!("  POST /v1/shutdown   drain and stop");
            let report = server.run(&mut session)?;
            println!("--- server stats ---");
            println!("{}", report.stats.summary());
        }
        "arena" => {
            let engine = engine_from_args(&args, &artifacts_dir)?;
            // --adapters "name=ckpt.tensors,name2=ckpt2.tensors"
            if let Some(spec) = args.get("adapters") {
                for part in spec.split(',') {
                    let Some((name, path)) = part.split_once('=') else {
                        bail!("--adapters expects name=path[,name=path...], \
                               got {part:?}");
                    };
                    engine.load_adapter(name.trim(),
                                        &PathBuf::from(path.trim()))?;
                }
            }
            let names = engine.adapter_names();
            let adapters: Vec<&str> =
                names.iter().map(String::as_str).collect();
            let judge = match args.get_or("judge", "gpt4").as_str() {
                "human" => Judge::human(),
                _ => Judge::gpt4(),
            };
            let report = run_arena(
                &engine,
                &adapters,
                EvalSuite::VicunaProxy,
                args.usize_or("n-prompts", 16)?,
                &judge,
                args.usize_or("orderings", 500)?,
                args.u64_or("seed", 0)?,
            )?;
            print!("{}", report.table());
        }
        "quantize" => {
            let dtype = DType::from_name(&args.get_or("dtype", "nf4"))
                .ok_or_else(|| anyhow::anyhow!("unknown dtype"))?;
            let block = args.usize_or("block", 64)?;
            let dq = args.flag("dq").then_some(256);
            let mut rng = qlora::util::rng::Rng::new(args.u64_or("seed", 0)?);
            let w = synthetic_llm_weights(&mut rng, 64 * 4096, 0.01, 5.0);
            let e = quant_error(&w, dtype, block, dq)?;
            println!(
                "{} block={block} dq={}: mse {:.6} mae {:.5} sqnr {:.2} dB",
                dtype.name(),
                dq.is_some(),
                e.mse,
                e.mae,
                e.sqnr_db
            );
        }
        "memory" => {
            let size = args.get_or("size", "65B");
            let spec = memory::llama_family()
                .into_iter()
                .find(|s| s.name == size)
                .ok_or_else(|| anyhow::anyhow!("size must be 7B/13B/33B/65B"))?;
            let r = args.usize_or("r", 64)?;
            let seq = args.usize_or("seq", 512)?;
            for (label, strat) in [
                ("Full-16bit", memory::Strategy::Full16),
                ("LoRA-16bit", memory::Strategy::LoRA16 { r }),
                ("QLoRA-4bit",
                 memory::Strategy::QLoRA4 { r, double_quant: false }),
                ("QLoRA-4bit+DQ",
                 memory::Strategy::QLoRA4 { r, double_quant: true }),
            ] {
                let f = memory::train_footprint(&spec, strat, seq, 1);
                println!("{size} {label:<14} {:.1} GB  (weights {:.1} GB, \
                          optim {:.1} GB, act {:.1} GB)",
                         f.total_gb(),
                         f.base_weights as f64 / 1e9,
                         f.optimizer as f64 / 1e9,
                         f.input_grads as f64 / 1e9);
            }
        }
        "experiment" => {
            let id = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            let results = PathBuf::from(args.get_or("results", "results"));
            let needs_rt = id == "all"
                || runner::registry()
                    .iter()
                    .any(|(n, needs, ..)| *n == id && *needs);
            let (rt, manifest) = if needs_rt {
                match Manifest::load(&artifacts_dir) {
                    Ok(m) => (Some(Rc::new(Runtime::cpu()?)), Some(m)),
                    Err(e) => {
                        eprintln!("warning: no artifacts ({e}); training \
                                   experiments will be skipped");
                        (None, None)
                    }
                }
            } else {
                (None, None)
            };
            let ctx = Ctx {
                rt,
                manifest,
                seed: args.u64_or("seed", 42)?,
                fast: args.flag("fast"),
            };
            let out = if id == "all" {
                runner::run_all(&ctx, &results)?
            } else {
                runner::run_one(id, &ctx, &results)?
            };
            println!("{out}");
        }
        _ => bail!("unknown command {cmd:?}\n{}", usage()),
    }
    Ok(())
}
