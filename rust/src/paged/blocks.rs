//! The KV block manager: paged decode caches with copy-on-write prefix
//! sharing.
//!
//! This generalizes the paged-optimizer machinery (paper section 3) to
//! the *serving* side's capacity bottleneck: per-row KV caches. Instead
//! of charging every admitted request a dense worst-case
//! `prompt + max_new_tokens` slab, each row's cache is a **block table**
//! — an ordered list of fixed-size physical blocks
//! ([`BlockConfig::block_tokens`] tokens each) drawn from a refcounted
//! [`BlockPool`]:
//!
//! * **Prefix sharing.** A prefix→block map keyed by
//!   `(parent block, exact chunk tokens)` lets rows whose prompts share
//!   a block-aligned prefix attach to the *same* physical blocks (the
//!   map is the flattened radix tree of attached prompts: a chunk can
//!   only be shared when its parent chunk already is, so lookup walks
//!   the chain and stops at the first divergence). Keys store the exact
//!   token content, so a hash collision can never alias two different
//!   prefixes.
//! * **Copy-on-write.** Appending to a block with more than one owner
//!   forks a private copy first; the shared block is never mutated. A
//!   sole-owner block that is still registered in the prefix map is
//!   unregistered before its content changes, so the map never points
//!   at stale content.
//! * **Swap-out.** Releasing a row under memory pressure frees only the
//!   blocks nobody else references; the migrated bytes and stall are
//!   charged through the same [`MigrateModel`] the optimizer pager uses.
//!
//! Like the rest of `paged/`, this is the *policy* made explicit: on
//! this CPU substrate the compiled decode graphs still thread dense
//! `(batch, layers, seq_len, d_model)` cache literals (that layout is
//! owned by `python/compile/kernels/decode.py`), so the block manager is
//! the accounting layer that decides **admission, sharing, and
//! eviction** — exactly the part of vLLM-style paged attention that
//! changes serving capacity. Because a row's logits depend only on its
//! own history (see the cache-discipline invariants in
//! `engine::decode`), sharing policy cannot change greedy outputs — only
//! how many rows fit.

use std::collections::HashMap;

use anyhow::{bail, ensure, Result};

use super::pager::MigrateModel;
use super::pool::{BlockId, BlockPool};
use crate::util::faults::{FaultSite, Faults};

/// Caller-side row identifier (the decode row index in the engine).
pub type RowId = usize;

/// Sizing and policy knobs for a [`BlockManager`].
#[derive(Debug, Clone)]
pub struct BlockConfig {
    /// Tokens of K/V one block covers.
    pub block_tokens: usize,
    /// Physical blocks in the pool.
    pub n_blocks: usize,
    /// Attach identical block-aligned prompt prefixes to shared blocks.
    pub prefix_sharing: bool,
    /// Free blocks admission keeps aside for in-flight growth (waived
    /// for a sole tenant so a big job can never deadlock an idle pool).
    pub headroom_blocks: usize,
    /// K+V bytes one full block occupies (swap-traffic accounting only;
    /// 0 disables byte/stall accounting).
    pub bytes_per_block: usize,
    /// Cost model for swapped-out bytes (shared with the pager).
    pub migrate: MigrateModel,
}

impl BlockConfig {
    /// A sharing-enabled config with one block of growth headroom and no
    /// byte accounting.
    pub fn new(block_tokens: usize, n_blocks: usize) -> BlockConfig {
        BlockConfig {
            block_tokens,
            n_blocks,
            prefix_sharing: true,
            headroom_blocks: 1,
            bytes_per_block: 0,
            migrate: MigrateModel::default(),
        }
    }

    /// Size the pool to cover `budget_tokens` tokens of K/V — the
    /// apples-to-apples pool for comparing block-granular admission
    /// against a dense `token_budget` reservation of the same size.
    pub fn for_token_budget(
        budget_tokens: usize,
        block_tokens: usize,
    ) -> BlockConfig {
        BlockConfig::new(
            block_tokens,
            budget_tokens.div_ceil(block_tokens.max(1)),
        )
    }

    /// Blocks needed to cover `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }
}

/// Exact prefix identity of one block: the physical parent block (the
/// whole prefix before this chunk, by induction) plus this chunk's
/// tokens. Two rows share a chunk iff they share the parent *object*
/// and the chunk content — no hash-collision aliasing possible.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ShareKey {
    parent: Option<BlockId>,
    tokens: Vec<i32>,
}

/// Content + registration state of one live physical block.
#[derive(Debug, Default, Clone)]
struct Block {
    /// tokens written so far (≤ `block_tokens`)
    tokens: Vec<i32>,
    /// parent block at creation (prefix chain; `None` for block 0)
    parent: Option<BlockId>,
    /// whether `(parent, tokens)` is currently in the share map — always
    /// unregistered *before* content can change
    registered: bool,
}

/// One row's cache view: the ordered physical blocks backing its
/// history plus the token count they cover.
#[derive(Debug, Clone, Default)]
pub struct RowTable {
    /// Physical block ids, in history order.
    pub blocks: Vec<BlockId>,
    /// Tokens covered (the last block may be partially filled).
    pub len: usize,
}

/// Counters the serving stats surface ([`ServerStats`]
/// (crate::engine::ServerStats)) snapshots.
#[derive(Debug, Default, Clone)]
pub struct BlockStats {
    /// Block attachments served by prefix sharing instead of a fresh
    /// allocation.
    pub shared_hits: u64,
    /// Copy-on-write forks (first write past a shared prefix).
    pub cow_forks: u64,
    /// Rows swapped out under memory pressure.
    pub swap_outs: u64,
    /// Bytes migrated to host by swap-outs.
    pub swapped_bytes: u64,
    /// Simulated migration stall from swap-outs, microseconds.
    pub swap_stall_us: f64,
}

/// Effect of one [`BlockManager::append`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppendOutcome {
    /// Token recorded; flags say which physical work it took.
    Appended {
        /// a fresh tail block was allocated
        new_block: bool,
        /// a shared block was forked first (copy-on-write)
        cow_fork: bool,
    },
    /// The pool is exhausted: nothing was recorded — free or swap a row
    /// and retry.
    NeedBlock,
}

/// Refcounted block tables with prefix sharing, CoW, and swap
/// accounting. See the module docs for the model.
#[derive(Debug)]
pub struct BlockManager {
    cfg: BlockConfig,
    pool: BlockPool,
    /// per-slot content, indexable by any live [`BlockId`]
    blocks: Vec<Block>,
    /// prefix→block map (the flattened radix tree of attached prompts)
    share: HashMap<ShareKey, BlockId>,
    rows: HashMap<RowId, RowTable>,
    /// fault-injection plane: `block-alloc` firings make [`BlockManager::
    /// append`] report [`AppendOutcome::NeedBlock`] as if the pool were
    /// exhausted (disabled by default; one branch per append)
    faults: Faults,
    /// sharing/CoW/swap counters (allocation totals live in the pool)
    pub stats: BlockStats,
}

impl BlockManager {
    /// A manager over a fresh pool of `cfg.n_blocks` blocks.
    pub fn new(cfg: BlockConfig) -> Result<BlockManager> {
        ensure!(cfg.block_tokens >= 1, "block_tokens must be >= 1");
        ensure!(cfg.n_blocks >= 1, "n_blocks must be >= 1");
        Ok(BlockManager {
            pool: BlockPool::new(cfg.n_blocks),
            blocks: vec![Block::default(); cfg.n_blocks],
            share: HashMap::new(),
            rows: HashMap::new(),
            faults: Faults::disabled(),
            stats: BlockStats::default(),
            cfg,
        })
    }

    /// Install a fault-injection handle: `block-alloc` firings make
    /// [`BlockManager::append`] report [`AppendOutcome::NeedBlock`] with
    /// nothing mutated — exactly the exhausted-pool contract, so every
    /// caller already handles it. Other sites are ignored here.
    pub fn set_faults(&mut self, faults: Faults) {
        self.faults = faults;
    }

    /// The sizing/policy knobs this manager was built with.
    pub fn cfg(&self) -> &BlockConfig {
        &self.cfg
    }

    /// Total physical blocks.
    pub fn n_blocks(&self) -> usize {
        self.pool.n_blocks()
    }

    /// Physical blocks currently live.
    pub fn blocks_in_use(&self) -> usize {
        self.pool.in_use()
    }

    /// Physical blocks currently free.
    pub fn free_blocks(&self) -> usize {
        self.pool.free_blocks()
    }

    /// Blocks ever allocated / ever freed (leak accounting).
    pub fn totals(&self) -> (u64, u64) {
        self.pool.totals()
    }

    /// Rows currently attached.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// The block table backing `row`, if attached.
    pub fn row_table(&self, row: RowId) -> Option<&RowTable> {
        self.rows.get(&row)
    }

    /// The tokens `row`'s blocks actually hold, concatenated — the
    /// ground truth the CoW property test compares against each row's
    /// expected history.
    pub fn row_tokens(&self, row: RowId) -> Option<Vec<i32>> {
        let table = self.rows.get(&row)?;
        let mut out = Vec::with_capacity(table.len);
        for &id in &table.blocks {
            // pallas-lint: allow(no-hot-path-panic) — blocks is n_blocks-sized; a live id (refcount > 0) is always in range
            out.extend_from_slice(&self.blocks[id as usize].tokens);
        }
        Some(out)
    }

    /// Content of one live block (diagnostics / property tests).
    pub fn block_content(&self, id: BlockId) -> Option<&[i32]> {
        (self.pool.refcount(id) > 0)
            // pallas-lint: allow(no-hot-path-panic) — blocks is n_blocks-sized; a live id (refcount > 0) is always in range
            .then(|| self.blocks[id as usize].tokens.as_slice())
    }

    /// Entries currently in the prefix-sharing map.
    pub fn shared_entries(&self) -> usize {
        self.share.len()
    }

    /// Reference count of one block (0 = free) — how many row tables
    /// currently include it.
    pub fn block_refcount(&self, id: BlockId) -> u32 {
        self.pool.refcount(id)
    }

    fn key_of(&self, id: BlockId) -> ShareKey {
        // pallas-lint: allow(no-hot-path-panic) — blocks is n_blocks-sized; a live id (refcount > 0) is always in range
        let b = &self.blocks[id as usize];
        ShareKey { parent: b.parent, tokens: b.tokens.clone() }
    }

    /// Register `id` under its current `(parent, tokens)` if that key is
    /// vacant (first writer wins; losing the race just means no reuse).
    fn try_register(&mut self, id: BlockId) {
        if !self.cfg.prefix_sharing {
            return;
        }
        let key = self.key_of(id);
        if !self.share.contains_key(&key) {
            self.share.insert(key, id);
            // pallas-lint: allow(no-hot-path-panic) — blocks is n_blocks-sized; a live id (refcount > 0) is always in range
            self.blocks[id as usize].registered = true;
        }
    }

    /// Remove `id` from the prefix map. Must run *before* its content
    /// changes (the key is reconstructed from current content).
    fn unregister(&mut self, id: BlockId) {
        // pallas-lint: allow(no-hot-path-panic) — blocks is n_blocks-sized; a live id (refcount > 0) is always in range
        if self.blocks[id as usize].registered {
            let key = self.key_of(id);
            let removed = self.share.remove(&key);
            debug_assert_eq!(removed, Some(id), "share map points at {id}");
            // pallas-lint: allow(no-hot-path-panic) — blocks is n_blocks-sized; a live id (refcount > 0) is always in range
            self.blocks[id as usize].registered = false;
        }
    }

    /// How many *new* physical blocks attaching `history` would need,
    /// after prefix sharing (read-only; admission probes this before
    /// committing).
    pub fn probe_attach(&self, history: &[i32]) -> usize {
        let chunks = history.chunks(self.cfg.block_tokens);
        let total = chunks.len();
        total - self.shared_chain(history).len()
    }

    /// The longest chain of already-registered blocks covering a prefix
    /// of `history` (empty when sharing is off).
    fn shared_chain(&self, history: &[i32]) -> Vec<BlockId> {
        let mut chain = Vec::new();
        if !self.cfg.prefix_sharing {
            return chain;
        }
        let mut parent = None;
        for chunk in history.chunks(self.cfg.block_tokens) {
            let key = ShareKey { parent, tokens: chunk.to_vec() };
            match self.share.get(&key) {
                Some(&id) => {
                    chain.push(id);
                    parent = Some(id);
                }
                None => break,
            }
        }
        chain
    }

    /// Attach `row` to block tables covering `history`, sharing every
    /// already-attached block-aligned prefix chunk and allocating the
    /// rest. Errors if the row is already attached, the history is
    /// empty, or the pool cannot cover the non-shared chunks (probe
    /// first with [`BlockManager::probe_attach`]); on error nothing was
    /// mutated. Returns the number of blocks served by sharing.
    pub fn attach(&mut self, row: RowId, history: &[i32]) -> Result<usize> {
        ensure!(!self.rows.contains_key(&row), "row {row} already attached");
        ensure!(!history.is_empty(), "empty history for row {row}");
        let shared = self.shared_chain(history);
        let total = history.chunks(self.cfg.block_tokens).len();
        let fresh = total - shared.len();
        ensure!(
            fresh <= self.pool.free_blocks(),
            "pool exhausted: row {row} needs {fresh} new blocks, {} free",
            self.pool.free_blocks()
        );
        // commit: retain the shared chain, then allocate the rest
        for &id in &shared {
            // pallas-lint: allow(no-hot-path-panic) — shared_chain only returns registered blocks, and registered blocks are live
            self.pool.retain(id).expect("shared chain is live");
            self.stats.shared_hits += 1;
        }
        let mut table = RowTable { blocks: shared, len: history.len() };
        let mut parent = table.blocks.last().copied();
        for chunk in history
            .chunks(self.cfg.block_tokens)
            .skip(table.blocks.len())
        {
            // pallas-lint: allow(no-hot-path-panic) — the ensure! above reserved `fresh` free blocks and nothing frees between it and this loop
            let id = self.pool.alloc().expect("free count checked above");
            // pallas-lint: allow(no-hot-path-panic) — alloc() mints ids < n_blocks
            self.blocks[id as usize] = Block {
                tokens: chunk.to_vec(),
                parent,
                registered: false,
            };
            self.try_register(id);
            table.blocks.push(id);
            parent = Some(id);
        }
        self.rows.insert(row, table);
        Ok(total - fresh)
    }

    /// Record one generated token for `row`. Allocates a fresh tail
    /// block at block boundaries and forks a private copy before the
    /// first write into a shared block (copy-on-write). Returns
    /// [`AppendOutcome::NeedBlock`] — with nothing recorded — when the
    /// pool is exhausted; an unattached row is an error.
    pub fn append(&mut self, row: RowId, token: i32) -> Result<AppendOutcome> {
        let Some(table) = self.rows.get(&row) else {
            bail!("append to unattached row {row}");
        };
        let pos = table.len % self.cfg.block_tokens;
        if pos == 0 {
            // boundary: open a fresh private tail block (an injected
            // block-alloc fault fails exactly like an exhausted pool)
            if self.faults.fire(FaultSite::BlockAlloc) {
                return Ok(AppendOutcome::NeedBlock);
            }
            let Some(id) = self.pool.alloc() else {
                return Ok(AppendOutcome::NeedBlock);
            };
            let parent = table.blocks.last().copied();
            // pallas-lint: allow(no-hot-path-panic) — alloc() mints ids < n_blocks
            self.blocks[id as usize] =
                Block { tokens: vec![token], parent, registered: false };
            // pallas-lint: allow(no-hot-path-panic) — row presence checked at fn entry and nothing removes it in between
            let table = self.rows.get_mut(&row).expect("checked above");
            table.blocks.push(id);
            table.len += 1;
            return Ok(AppendOutcome::Appended {
                new_block: true,
                cow_fork: false,
            });
        }
        // pallas-lint: allow(no-hot-path-panic) — pos != 0 means the table already covers ≥ 1 token, so it has a tail block
        let tail = *table.blocks.last().expect("len > 0 implies blocks");
        if self.pool.refcount(tail) > 1 {
            // copy-on-write: fork a private tail, leave the shared block
            // untouched for its other owners (same injected-failure
            // contract as the boundary allocation above)
            if self.faults.fire(FaultSite::BlockAlloc) {
                return Ok(AppendOutcome::NeedBlock);
            }
            let Some(id) = self.pool.alloc() else {
                return Ok(AppendOutcome::NeedBlock);
            };
            // pallas-lint: allow(no-hot-path-panic) — blocks is n_blocks-sized; a live id (refcount > 0) is always in range
            let mut forked = self.blocks[tail as usize].clone();
            forked.registered = false;
            forked.tokens.push(token);
            // pallas-lint: allow(no-hot-path-panic) — alloc() mints ids < n_blocks
            self.blocks[id as usize] = forked;
            // pallas-lint: allow(no-hot-path-panic) — refcount > 1 checked above, so this release cannot fail or free the slot
            self.pool.release(tail).expect("tail was shared");
            self.stats.cow_forks += 1;
            // pallas-lint: allow(no-hot-path-panic) — row presence checked at fn entry and nothing removes it in between
            let table = self.rows.get_mut(&row).expect("checked above");
            // pallas-lint: allow(no-hot-path-panic) — same table had a tail block at fn entry and only grew
            *table.blocks.last_mut().expect("tail exists") = id;
            table.len += 1;
            return Ok(AppendOutcome::Appended {
                new_block: true,
                cow_fork: true,
            });
        }
        // sole owner: the map must never point at mutated content
        self.unregister(tail);
        // pallas-lint: allow(no-hot-path-panic) — blocks is n_blocks-sized; a live id (refcount > 0) is always in range
        self.blocks[tail as usize].tokens.push(token);
        // pallas-lint: allow(no-hot-path-panic) — row presence checked at fn entry and nothing removes it in between
        self.rows.get_mut(&row).expect("checked above").len += 1;
        Ok(AppendOutcome::Appended { new_block: false, cow_fork: false })
    }

    /// Detach `row`, releasing its blocks (freed physically once the
    /// last owner lets go). Returns how many blocks were physically
    /// freed.
    pub fn release_row(&mut self, row: RowId) -> Result<usize> {
        let Some(table) = self.rows.remove(&row) else {
            bail!("release of unattached row {row}");
        };
        let mut freed = 0;
        // children before parents: a registered child never outlives the
        // prefix chain its key points into
        for &id in table.blocks.iter().rev() {
            // pallas-lint: allow(no-hot-path-panic) — every id in a row table holds one reference, so it is live until this release
            if self.pool.release(id).expect("table blocks are live") {
                self.unregister(id);
                // pallas-lint: allow(no-hot-path-panic) — blocks is n_blocks-sized; a live id (refcount > 0) is always in range
                self.blocks[id as usize] = Block::default();
                freed += 1;
            }
        }
        Ok(freed)
    }

    /// Swap `row` out under memory pressure: release its blocks and
    /// charge the privately-owned bytes (shared blocks stay resident for
    /// their other owners) through the migration model. Returns the
    /// blocks physically freed.
    pub fn swap_out(&mut self, row: RowId) -> Result<usize> {
        let freed = self.release_row(row)?;
        let bytes = freed * self.cfg.bytes_per_block;
        self.stats.swap_outs += 1;
        self.stats.swapped_bytes += bytes as u64;
        self.stats.swap_stall_us += self.cfg.migrate.transfer_us(bytes);
        Ok(freed)
    }

    /// Structural self-check for the property tests: pool accounting,
    /// refcounts == table references, chunking shape, and share-map
    /// consistency.
    pub fn check_invariants(&self) {
        self.pool.check_invariants();
        // every table reference counted exactly once
        let mut refs: HashMap<BlockId, u32> = HashMap::new();
        for table in self.rows.values() {
            assert_eq!(
                table.blocks.len(),
                self.cfg.blocks_for(table.len),
                "table covers its length in blocks"
            );
            let mut covered = 0;
            for (i, &id) in table.blocks.iter().enumerate() {
                *refs.entry(id).or_insert(0) += 1;
                // pallas-lint: allow(no-hot-path-panic) — blocks is n_blocks-sized; a live id (refcount > 0) is always in range
                let got = self.blocks[id as usize].tokens.len();
                if i + 1 < table.blocks.len() {
                    assert_eq!(got, self.cfg.block_tokens, "interior full");
                }
                covered += got;
            }
            assert_eq!(covered, table.len, "blocks cover the history");
        }
        for (id, &n) in &refs {
            assert_eq!(self.pool.refcount(*id), n, "refcount of block {id}");
        }
        assert_eq!(
            refs.len(),
            self.pool.in_use(),
            "every live block is referenced by some row"
        );
        for (key, &id) in &self.share {
            // pallas-lint: allow(no-hot-path-panic) — blocks is n_blocks-sized; a live id (refcount > 0) is always in range
            let b = &self.blocks[id as usize];
            assert!(b.registered, "share entry block {id} marked registered");
            assert!(self.pool.refcount(id) > 0, "share entry {id} is live");
            assert_eq!(key.parent, b.parent, "share key parent of {id}");
            assert_eq!(key.tokens, b.tokens, "share key content of {id}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(block_tokens: usize, n_blocks: usize) -> BlockManager {
        BlockManager::new(BlockConfig::new(block_tokens, n_blocks)).unwrap()
    }

    #[test]
    fn attach_chunks_history_into_blocks() {
        let mut m = mgr(4, 8);
        m.attach(0, &[1, 2, 3, 4, 5, 6]).unwrap();
        let t = m.row_table(0).unwrap();
        assert_eq!(t.blocks.len(), 2);
        assert_eq!(t.len, 6);
        assert_eq!(m.blocks_in_use(), 2);
        assert_eq!(m.row_tokens(0).unwrap(), vec![1, 2, 3, 4, 5, 6]);
        m.check_invariants();
    }

    #[test]
    fn identical_prompts_share_all_blocks() {
        let mut m = mgr(4, 8);
        m.attach(0, &[1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(m.probe_attach(&[1, 2, 3, 4, 5, 6]), 0, "fully shared");
        m.attach(1, &[1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(m.blocks_in_use(), 2, "no new physical blocks");
        assert_eq!(m.stats.shared_hits, 2);
        let (a, b) = (m.row_table(0).unwrap(), m.row_table(1).unwrap());
        assert_eq!(a.blocks, b.blocks);
        m.check_invariants();
    }

    #[test]
    fn shared_prefix_diverging_suffix() {
        let mut m = mgr(2, 8);
        m.attach(0, &[9, 9, 9, 9, 1]).unwrap(); // blocks [99][99][1]
        m.attach(1, &[9, 9, 9, 9, 2]).unwrap(); // shares [99][99], own [2]
        assert_eq!(m.blocks_in_use(), 4);
        assert_eq!(m.stats.shared_hits, 2);
        assert_eq!(
            m.row_table(0).unwrap().blocks[..2],
            m.row_table(1).unwrap().blocks[..2]
        );
        m.check_invariants();
    }

    #[test]
    fn same_content_different_parent_never_aliases() {
        let mut m = mgr(2, 8);
        m.attach(0, &[1, 1, 7, 7]).unwrap();
        // second block content [7,7] matches, but the parent chain
        // differs — sharing must not kick in
        m.attach(1, &[2, 2, 7, 7]).unwrap();
        assert_eq!(m.stats.shared_hits, 0);
        assert_eq!(m.blocks_in_use(), 4);
        m.check_invariants();
    }

    #[test]
    fn append_grows_and_allocates_at_boundaries() {
        let mut m = mgr(2, 4);
        m.attach(0, &[1]).unwrap();
        assert_eq!(
            m.append(0, 2).unwrap(),
            AppendOutcome::Appended { new_block: false, cow_fork: false }
        );
        assert_eq!(
            m.append(0, 3).unwrap(),
            AppendOutcome::Appended { new_block: true, cow_fork: false }
        );
        assert_eq!(m.row_tokens(0).unwrap(), vec![1, 2, 3]);
        assert_eq!(m.blocks_in_use(), 2);
        m.check_invariants();
    }

    #[test]
    fn cow_fork_never_mutates_the_shared_block() {
        let mut m = mgr(4, 8);
        m.attach(0, &[1, 2, 3]).unwrap(); // partial tail, registered
        m.attach(1, &[1, 2, 3]).unwrap(); // shares it (refcount 2)
        let shared = m.row_table(0).unwrap().blocks[0];
        assert_eq!(m.block_refcount(shared), 2);
        // row 0 writes past the shared prefix: fork, not mutate
        m.append(0, 40).unwrap();
        assert_eq!(m.stats.cow_forks, 1);
        assert_eq!(m.row_tokens(0).unwrap(), vec![1, 2, 3, 40]);
        assert_eq!(m.row_tokens(1).unwrap(), vec![1, 2, 3], "untouched");
        assert_eq!(m.block_content(shared).unwrap(), &[1, 2, 3]);
        // row 1 now appends into the (sole-owned again) original
        m.append(1, 41).unwrap();
        assert_eq!(m.stats.cow_forks, 1, "sole owner appends in place");
        assert_eq!(m.row_tokens(1).unwrap(), vec![1, 2, 3, 41]);
        m.check_invariants();
    }

    #[test]
    fn release_frees_only_unshared_blocks() {
        let mut m = mgr(2, 8);
        m.attach(0, &[5, 5, 1]).unwrap();
        m.attach(1, &[5, 5, 2]).unwrap();
        assert_eq!(m.blocks_in_use(), 3);
        let freed = m.release_row(0).unwrap();
        assert_eq!(freed, 1, "only row 0's private tail is freed");
        assert_eq!(m.blocks_in_use(), 2);
        assert_eq!(m.row_tokens(1).unwrap(), vec![5, 5, 2]);
        let freed = m.release_row(1).unwrap();
        assert_eq!(freed, 2);
        assert_eq!(m.blocks_in_use(), 0);
        let (alloc, free) = m.totals();
        assert_eq!(alloc, free, "no leaked blocks after all rows retire");
        assert_eq!(m.shared_entries(), 0, "share map fully drained");
        m.check_invariants();
    }

    #[test]
    fn pool_exhaustion_is_need_block_not_corruption() {
        let mut m = mgr(2, 2);
        m.attach(0, &[1, 2, 3, 4]).unwrap(); // both blocks
        assert!(m.attach(1, &[9]).is_err(), "attach reports exhaustion");
        assert_eq!(m.append(0, 5).unwrap(), AppendOutcome::NeedBlock);
        assert_eq!(m.row_tokens(0).unwrap(), vec![1, 2, 3, 4], "unchanged");
        m.check_invariants();
    }

    #[test]
    fn swap_out_charges_private_bytes_only() {
        let mut cfg = BlockConfig::new(2, 8);
        cfg.bytes_per_block = 100;
        let mut m = BlockManager::new(cfg).unwrap();
        m.attach(0, &[5, 5, 1]).unwrap();
        m.attach(1, &[5, 5, 2]).unwrap();
        let freed = m.swap_out(0).unwrap();
        assert_eq!(freed, 1);
        assert_eq!(m.stats.swap_outs, 1);
        assert_eq!(m.stats.swapped_bytes, 100, "shared blocks stay resident");
        assert!(m.stats.swap_stall_us > 0.0);
        m.check_invariants();
    }

    #[test]
    fn injected_alloc_faults_surface_as_need_block() {
        use crate::util::faults::FaultPlan;
        let mut m = mgr(2, 8);
        m.attach(0, &[1, 2]).unwrap();
        m.set_faults(Faults::new(
            &FaultPlan::default().with(FaultSite::BlockAlloc, 1.0, Some(2)),
        ));
        // boundary append: the fault fires although the pool has room,
        // and nothing is mutated — exactly the exhausted-pool contract
        assert_eq!(m.append(0, 3).unwrap(), AppendOutcome::NeedBlock);
        assert_eq!(m.row_tokens(0).unwrap(), vec![1, 2]);
        assert!(m.free_blocks() > 0);
        m.check_invariants();
        // the cap exhausts after two firings; appends then succeed
        assert_eq!(m.append(0, 3).unwrap(), AppendOutcome::NeedBlock);
        assert_eq!(
            m.append(0, 3).unwrap(),
            AppendOutcome::Appended { new_block: true, cow_fork: false }
        );
        assert_eq!(m.row_tokens(0).unwrap(), vec![1, 2, 3]);
        m.check_invariants();
    }

    #[test]
    fn sharing_off_disables_the_prefix_map() {
        let mut cfg = BlockConfig::new(2, 8);
        cfg.prefix_sharing = false;
        let mut m = BlockManager::new(cfg).unwrap();
        m.attach(0, &[1, 2, 3]).unwrap();
        assert_eq!(m.probe_attach(&[1, 2, 3]), 2, "no sharing probed");
        m.attach(1, &[1, 2, 3]).unwrap();
        assert_eq!(m.blocks_in_use(), 4);
        assert_eq!(m.stats.shared_hits, 0);
        assert_eq!(m.shared_entries(), 0);
        m.check_invariants();
    }
}
