//! The unified-memory pager: page table + LRU residency for pageable
//! allocations. Pages migrate device↔host on demand; touching a
//! non-resident page faults, which (a) evicts LRU pages to make room and
//! (b) charges a migration latency (PCIe-like bandwidth model).

use std::collections::HashMap;

/// Identifier of one simulated page.
pub type PageId = u64;

/// Parameters of the simulated paging hardware.
#[derive(Debug, Clone)]
pub struct PagerConfig {
    /// page size in bytes (CUDA UM uses 2 MiB large pages on modern GPUs)
    pub page_bytes: usize,
    /// device bytes available to *pageable* memory (after pinned allocs)
    pub device_budget: usize,
    /// simulated host<->device bandwidth, bytes/sec (PCIe 4.0 x16 ≈ 25 GB/s)
    pub bandwidth: f64,
    /// per-fault fixed cost in microseconds (driver + TLB shootdown)
    pub fault_fixed_us: f64,
}

impl Default for PagerConfig {
    fn default() -> Self {
        PagerConfig {
            page_bytes: 2 << 20,
            device_budget: 16 << 30,
            bandwidth: 25e9,
            fault_fixed_us: 20.0,
        }
    }
}

impl PagerConfig {
    /// The host↔device transfer model implied by this config (shared
    /// with the KV block manager's swap accounting).
    pub fn migrate(&self) -> MigrateModel {
        MigrateModel {
            bandwidth: self.bandwidth,
            fixed_us: self.fault_fixed_us,
        }
    }
}

/// PCIe-like host↔device migration cost model: a fixed per-fault driver
/// cost plus bandwidth-limited transfer time. Extracted from the pager so
/// every subsystem that migrates state (optimizer pages, swapped KV
/// blocks) charges latency the same way.
#[derive(Debug, Clone)]
pub struct MigrateModel {
    /// simulated link bandwidth, bytes/sec
    pub bandwidth: f64,
    /// fixed per-fault cost in microseconds (driver + TLB shootdown)
    pub fixed_us: f64,
}

impl Default for MigrateModel {
    fn default() -> Self {
        PagerConfig::default().migrate()
    }
}

impl MigrateModel {
    /// Bandwidth-limited transfer time for `bytes`, in microseconds.
    pub fn transfer_us(&self, bytes: usize) -> f64 {
        bytes as f64 / self.bandwidth * 1e6
    }

    /// One page fault moving `bytes`: fixed cost plus the transfer.
    pub fn fault_us(&self, bytes: usize) -> f64 {
        self.fixed_us + self.transfer_us(bytes)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Residency {
    Device,
    Host,
}

#[derive(Debug)]
struct PageEntry {
    residency: Residency,
    /// LRU clock of last touch
    last_touch: u64,
}

/// Counters accumulated by a [`Pager`].
#[derive(Debug, Default, Clone)]
pub struct FaultStats {
    /// page faults (touches of non-resident pages)
    pub faults: u64,
    /// pages evicted under memory pressure
    pub evictions: u64,
    /// bytes migrated host<->device
    pub migrated_bytes: u64,
    /// total simulated migration stall, microseconds
    pub stall_us: f64,
}

/// Page table for one pageable region.
#[derive(Debug)]
pub struct Pager {
    /// the hardware model this pager simulates
    pub cfg: PagerConfig,
    pages: HashMap<PageId, PageEntry>,
    resident_bytes: usize,
    /// high-water mark of resident bytes
    pub peak_resident: usize,
    clock: u64,
    /// counters accumulated so far
    pub stats: FaultStats,
}

impl Pager {
    /// A pager with no pages tracked yet.
    pub fn new(cfg: PagerConfig) -> Pager {
        Pager {
            cfg,
            pages: HashMap::new(),
            resident_bytes: 0,
            peak_resident: 0,
            clock: 0,
            stats: FaultStats::default(),
        }
    }

    /// Register a pageable allocation of `bytes`, initially host-resident.
    /// Returns the page ids.
    pub fn register(&mut self, base: PageId, bytes: usize) -> Vec<PageId> {
        let n = bytes.div_ceil(self.cfg.page_bytes);
        let ids: Vec<PageId> = (0..n as u64).map(|i| base + i).collect();
        for &id in &ids {
            self.pages.insert(
                id,
                PageEntry { residency: Residency::Host, last_touch: 0 },
            );
        }
        ids
    }

    /// Bytes currently resident on the simulated device.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// Shrink the device budget (a transient activation spike claims the
    /// space), evicting pages if needed. Returns evicted count.
    pub fn pressure(&mut self, reserved: usize) -> u64 {
        let budget = self.cfg.device_budget.saturating_sub(reserved);
        let mut evicted = 0;
        while self.resident_bytes > budget {
            if !self.evict_lru() {
                break;
            }
            evicted += 1;
        }
        evicted
    }

    /// Touch (access) a page: fault + migrate if non-resident. Touching
    /// an unregistered page is a caller bug; it trips a debug assertion
    /// under test and is a no-op in release builds.
    pub fn touch(&mut self, id: PageId, reserved: usize) {
        self.clock += 1;
        let clock = self.clock;
        let Some(entry) = self.pages.get_mut(&id) else {
            debug_assert!(false, "touch on unregistered page {id}");
            return;
        };
        entry.last_touch = clock;
        if entry.residency == Residency::Device {
            return;
        }
        // page fault: make room under the current pressure, then migrate in
        self.stats.faults += 1;
        let page = self.cfg.page_bytes;
        let budget = self.cfg.device_budget.saturating_sub(reserved);
        while self.resident_bytes + page > budget {
            if !self.evict_lru() {
                break; // thrashing floor: single page still migrates
            }
        }
        let Some(entry) = self.pages.get_mut(&id) else {
            // the entry existed above; evict_lru never removes entries
            debug_assert!(false, "page {id} vanished during eviction");
            return;
        };
        entry.residency = Residency::Device;
        self.resident_bytes += page;
        self.peak_resident = self.peak_resident.max(self.resident_bytes);
        self.stats.migrated_bytes += page as u64;
        self.stats.stall_us += self.cfg.migrate().fault_us(page);
    }

    fn evict_lru(&mut self) -> bool {
        let victim = self
            .pages
            .iter()
            .filter(|(_, e)| e.residency == Residency::Device)
            .min_by_key(|(_, e)| e.last_touch)
            .map(|(id, _)| *id);
        // the victim id was just drawn from the page table, so the
        // lookup can only miss if the table mutated in between (it
        // did not); treating a miss as "nothing evictable" keeps the
        // accounting consistent either way
        let Some(e) = victim.and_then(|id| self.pages.get_mut(&id)) else {
            return false;
        };
        e.residency = Residency::Host;
        self.resident_bytes -= self.cfg.page_bytes;
        self.stats.evictions += 1;
        self.stats.migrated_bytes += self.cfg.page_bytes as u64;
        self.stats.stall_us +=
            self.cfg.migrate().transfer_us(self.cfg.page_bytes);
        true
    }

    /// Invariant check: resident bytes equals page-table residency.
    pub fn check_invariants(&self) {
        let resident = self
            .pages
            .values()
            .filter(|e| e.residency == Residency::Device)
            .count()
            * self.cfg.page_bytes;
        assert_eq!(resident, self.resident_bytes, "residency accounting");
        assert!(self.resident_bytes <= self.cfg.device_budget);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn cfg(budget_pages: usize) -> PagerConfig {
        PagerConfig {
            page_bytes: 1024,
            device_budget: budget_pages * 1024,
            bandwidth: 1e9,
            fault_fixed_us: 1.0,
        }
    }

    #[test]
    fn faults_then_hits() {
        let mut p = Pager::new(cfg(4));
        let ids = p.register(0, 2048);
        assert_eq!(ids.len(), 2);
        p.touch(ids[0], 0);
        p.touch(ids[1], 0);
        assert_eq!(p.stats.faults, 2);
        p.touch(ids[0], 0); // hit
        assert_eq!(p.stats.faults, 2);
        p.check_invariants();
    }

    #[test]
    fn eviction_under_pressure() {
        let mut p = Pager::new(cfg(2));
        let ids = p.register(0, 4096); // 4 pages, budget 2
        for &id in &ids {
            p.touch(id, 0);
        }
        assert_eq!(p.stats.faults, 4);
        assert!(p.stats.evictions >= 2);
        assert!(p.resident_bytes() <= 2048);
        p.check_invariants();
    }

    #[test]
    fn spike_pressure_evicts_then_recovers() {
        let mut p = Pager::new(cfg(4));
        let ids = p.register(0, 4096);
        for &id in &ids {
            p.touch(id, 0);
        }
        assert_eq!(p.resident_bytes(), 4096);
        // spike reserves 3 pages -> only 1 page budget remains
        let evicted = p.pressure(3 * 1024);
        assert_eq!(evicted, 3);
        assert_eq!(p.resident_bytes(), 1024);
        // spike gone; touching pages brings them back
        for &id in &ids {
            p.touch(id, 0);
        }
        assert_eq!(p.resident_bytes(), 4096);
        p.check_invariants();
    }

    #[test]
    fn lru_victim_selection() {
        let mut p = Pager::new(cfg(2));
        let ids = p.register(0, 3072); // 3 pages, budget 2
        p.touch(ids[0], 0);
        p.touch(ids[1], 0);
        p.touch(ids[0], 0); // refresh page 0 -> page 1 is LRU
        p.touch(ids[2], 0); // must evict page 1
        p.touch(ids[0], 0); // page 0 still resident -> no fault
        assert_eq!(p.stats.faults, 3);
    }

    #[test]
    fn prop_resident_never_exceeds_budget() {
        prop::check("pager-budget", 32, |rng| {
            let pages = 2 + rng.below(16);
            let mut p = Pager::new(cfg(pages));
            let ids = p.register(0, (pages * 3) * 1024);
            for _ in 0..200 {
                let id = ids[rng.below(ids.len())];
                let reserved = rng.below(pages) * 1024;
                p.touch(id, reserved);
                assert!(p.resident_bytes() <= p.cfg.device_budget);
            }
            p.check_invariants();
        });
    }
}
