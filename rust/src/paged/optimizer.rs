//! The paged-optimizer simulation attached to training: optimizer state
//! (Adam m/v) lives in pageable memory; each step's activation spike
//! (driven by the longest sequence in the mini-batch, exactly the
//! gradient-checkpointing spike the paper describes) pressures the pager,
//! and the optimizer update touches every state page.

use super::pager::{Pager, PagerConfig};

/// Counters from one paged-optimizer simulation.
#[derive(Debug, Clone, Default)]
pub struct PagerStats {
    /// optimizer steps simulated
    pub steps: u64,
    /// page faults taken by optimizer-state touches
    pub faults: u64,
    /// pages evicted to fit the device budget
    pub evictions: u64,
    /// bytes migrated host<->device
    pub migrated_bytes: u64,
    /// total simulated migration stall, microseconds
    pub stall_us: f64,
    /// high-water mark of resident pageable bytes
    pub peak_resident: usize,
    /// steps whose activation spike forced evictions
    pub spike_steps: u64,
}

/// Simulates paged Adam state (paper section 3) under a device budget:
/// the model is pinned, optimizer moments are pageable.
#[derive(Debug)]
pub struct PagedOptimizerSim {
    pager: Pager,
    state_pages: Vec<super::pager::PageId>,
    /// bytes pinned by the (quantized) model itself
    pub model_bytes: usize,
    /// exact pageable optimizer-state bytes (not rounded up to pages)
    opt_state_bytes: usize,
    /// per-token activation-gradient bytes under checkpointing
    act_bytes_per_token: usize,
    /// counters accumulated so far
    pub stats: PagerStats,
}

impl PagedOptimizerSim {
    /// `device_budget`: total simulated device bytes; the model is pinned,
    /// optimizer state (2 f32 moments per trainable param) is pageable.
    pub fn new(
        device_budget: usize,
        model_bytes: usize,
        opt_state_bytes: usize,
        d_model: usize,
        n_layers: usize,
    ) -> PagedOptimizerSim {
        let cfg = PagerConfig {
            page_bytes: 64 << 10, // smaller pages at simulation scale
            device_budget: device_budget.saturating_sub(model_bytes),
            ..PagerConfig::default()
        };
        let mut pager = Pager::new(cfg);
        let state_pages = pager.register(0, opt_state_bytes.max(1));
        // with gradient checkpointing the recompute spike holds one layer's
        // activations (~4 tensors of d_model) per token plus input grads
        // (paper section 2: ~18 MB/seq for 7B after checkpointing)
        let act_bytes_per_token = 4 * d_model * 4 + 2 * d_model * 4
            + n_layers * 8; // small per-layer bookkeeping
        PagedOptimizerSim {
            pager,
            state_pages,
            model_bytes,
            opt_state_bytes,
            act_bytes_per_token,
            stats: PagerStats::default(),
        }
    }

    /// One training step: the activation spike scales with the longest
    /// sequence in the batch (long sequences trigger paging; short ones
    /// don't — the paper's "only occurs when processing mini-batches with
    /// long sequence lengths").
    pub fn on_step(&mut self, max_seq: usize) {
        self.stats.steps += 1;
        // spike: recompute buffers for the *longest* sample dominate
        let spike = self.act_bytes_per_token * max_seq;
        let evicted = self.pager.pressure(spike);
        if evicted > 0 {
            self.stats.spike_steps += 1;
        }
        // optimizer update touches every optimizer-state page (spike over)
        for &id in &self.state_pages.clone() {
            self.pager.touch(id, 0);
        }
        let s = &self.pager.stats;
        self.stats.faults = s.faults;
        self.stats.evictions = s.evictions;
        self.stats.migrated_bytes = s.migrated_bytes;
        self.stats.stall_us = s.stall_us;
        self.stats.peak_resident = self.pager.peak_resident;
    }

    /// Steady-state fault rate after warmup: 0 when everything fits.
    pub fn steady_state_stall_per_step_us(&self) -> f64 {
        if self.stats.steps == 0 {
            return 0.0;
        }
        self.stats.stall_us / self.stats.steps as f64
    }

    /// Would a *non-paged* optimizer OOM on this spike? (the paper's
    /// motivating failure mode) Uses the exact optimizer-state bytes —
    /// counting whole pages (`state_pages × page_bytes`) rounded the
    /// footprint up and overstated OOM on near-boundary budgets.
    pub fn would_oom_without_paging(&self, max_seq: usize) -> bool {
        let spike = self.act_bytes_per_token * max_seq;
        self.opt_state_bytes + spike > self.pager.cfg.device_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_paging_when_everything_fits() {
        // big budget: after the initial cold faults, zero ongoing traffic
        let mut sim = PagedOptimizerSim::new(1 << 30, 100 << 20, 8 << 20, 256, 4);
        for _ in 0..50 {
            sim.on_step(64);
        }
        let cold_faults = (8 << 20) / (64 << 10);
        assert_eq!(sim.stats.faults, cold_faults as u64);
        assert_eq!(sim.stats.evictions, 0);
    }

    #[test]
    fn long_sequences_trigger_paging_but_run_completes() {
        // tight budget: optimizer state + spike exceeds device memory
        let opt = 8 << 20;
        let mut sim = PagedOptimizerSim::new(9 << 20, 0, opt, 1024, 8);
        assert!(sim.would_oom_without_paging(4096));
        for step in 0..20 {
            let seq = if step % 5 == 0 { 4096 } else { 16 };
            sim.on_step(seq);
        }
        assert!(sim.stats.spike_steps > 0, "spikes must trigger eviction");
        assert!(sim.stats.faults > 0);
        // and the "training" completed — that's the whole point
        assert_eq!(sim.stats.steps, 20);
    }

    #[test]
    fn short_batches_match_regular_speed() {
        // the paper's bs=16 claim: short sequences -> no stall after warmup
        let mut sim = PagedOptimizerSim::new(64 << 20, 16 << 20, 8 << 20, 256, 4);
        for _ in 0..10 {
            sim.on_step(64);
        }
        let warm = sim.stats.stall_us;
        for _ in 0..100 {
            sim.on_step(64);
        }
        assert_eq!(sim.stats.stall_us, warm, "no steady-state stall");
    }

    #[test]
    fn would_oom_uses_exact_bytes_not_whole_pages() {
        // optimizer state one byte over a page boundary: whole-page
        // accounting rounded 8 MiB + 1 B up to 129 × 64 KiB ≈ 8.06 MiB
        // and falsely reported OOM on budgets between the two
        let opt = (8 << 20) + 1;
        let rounded_up = 129 * (64 << 10);
        let budget = 8_400_000; // real opt < budget < rounded_up
        assert!(opt < budget && budget < rounded_up);
        // d_model 1 / n_layers 0 keeps the spike negligible (24 B/token)
        let sim = PagedOptimizerSim::new(budget, 0, opt, 1, 0);
        assert!(
            !sim.would_oom_without_paging(0),
            "near-boundary budget must not be reported as OOM"
        );
        // but it still reports OOM when the state truly does not fit
        let tight = PagedOptimizerSim::new(opt - 1, 0, opt, 1, 0);
        assert!(tight.would_oom_without_paging(0));
    }
}
