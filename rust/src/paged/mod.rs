//! Paged memory management: the paper's Paged Optimizers (section 3) as
//! an explicit simulation, generalized into a block-based manager for
//! decode KV caches.
//!
//! The paper uses NVIDIA unified memory: optimizer state lives in pageable
//! memory that is automatically evicted to CPU RAM when the GPU runs out
//! during gradient-checkpointing memory spikes, and paged back in at the
//! optimizer update. No such mechanism exists on this (CPU) substrate, so
//! we implement the *policy* itself: a device memory pool with a page
//! table, LRU eviction, on-demand page-in, and fault/latency accounting.
//! This reproduces the paper's claims in shape:
//!
//! * without paging, a long-sequence mini-batch whose activation spike
//!   exceeds the device budget OOMs;
//! * with paging, the run completes, and at moderate batch sizes the
//!   overhead is ≈0 because paging only triggers on rare spikes
//!   ("with a batch size of 16, paged optimizers provide the same training
//!   speed as regular optimizers", section 4).
//!
//! The same machinery — fixed-size units, explicit residency, migration
//! cost accounting — also manages the *serving* side's capacity
//! bottleneck: per-row decode KV caches. [`blocks`] owns them as
//! refcounted, fixed-size cache blocks with copy-on-write prefix sharing
//! and swap-out under pressure, built on the [`pool::BlockPool`]
//! substrate and the [`pager::MigrateModel`] cost model; the engine's
//! scheduler admits by blocks actually allocated instead of worst-case
//! `prompt + max_new_tokens` tokens.

pub mod blocks;
pub mod optimizer;
pub mod pager;
pub mod pool;

pub use blocks::{
    AppendOutcome, BlockConfig, BlockManager, BlockStats, RowTable,
};
pub use optimizer::{PagedOptimizerSim, PagerStats};
pub use pager::{MigrateModel, PageId, Pager, PagerConfig};
pub use pool::{BlockId, BlockPool, DevicePool};
