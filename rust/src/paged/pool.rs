//! A fixed-capacity device memory pool with named allocations.
//! Models "GPU memory": allocations either fit or OOM (unless their pages
//! are managed by the `Pager`).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug)]
pub struct DevicePool {
    pub capacity: usize,
    used: usize,
    allocs: BTreeMap<String, usize>,
}

impl DevicePool {
    pub fn new(capacity: usize) -> DevicePool {
        DevicePool { capacity, used: 0, allocs: BTreeMap::new() }
    }

    /// Pinned (non-pageable) allocation — fails hard on OOM, like a CUDA
    /// `cudaMalloc`.
    pub fn alloc(&mut self, name: &str, bytes: usize) -> Result<()> {
        if self.allocs.contains_key(name) {
            bail!("allocation {name:?} already exists");
        }
        if self.used + bytes > self.capacity {
            bail!(
                "OOM: {name} needs {bytes} B, {} of {} B used",
                self.used,
                self.capacity
            );
        }
        self.used += bytes;
        self.allocs.insert(name.to_string(), bytes);
        Ok(())
    }

    pub fn free(&mut self, name: &str) -> Result<()> {
        match self.allocs.remove(name) {
            Some(b) => {
                self.used -= b;
                Ok(())
            }
            None => bail!("allocation {name:?} not found"),
        }
    }

    pub fn used(&self) -> usize {
        self.used
    }

    pub fn free_bytes(&self) -> usize {
        self.capacity - self.used
    }

    /// Try to reserve transient bytes (activation spike); true if it fits.
    pub fn fits(&self, bytes: usize) -> bool {
        self.used + bytes <= self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_accounting() {
        let mut p = DevicePool::new(100);
        p.alloc("a", 60).unwrap();
        assert_eq!(p.used(), 60);
        assert!(p.alloc("b", 50).is_err()); // OOM
        p.alloc("c", 40).unwrap();
        assert_eq!(p.free_bytes(), 0);
        p.free("a").unwrap();
        assert_eq!(p.used(), 40);
        assert!(p.free("a").is_err());
        assert!(p.alloc("c", 1).is_err()); // duplicate
    }

    #[test]
    fn fits_is_nondestructive() {
        let mut p = DevicePool::new(10);
        p.alloc("x", 4).unwrap();
        assert!(p.fits(6));
        assert!(!p.fits(7));
        assert_eq!(p.used(), 4);
    }
}
