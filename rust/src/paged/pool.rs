//! Fixed-capacity device memory pools.
//!
//! Two primitives model "GPU memory" at different granularities:
//!
//! * [`DevicePool`] — named byte allocations that either fit or OOM,
//!   like a `cudaMalloc` arena (used by the paged-optimizer simulation).
//! * [`BlockPool`] — a pool of `n_blocks` equal-size, **refcounted**
//!   slots. This is the physical substrate of the KV block manager
//!   (`paged::blocks`): prefix sharing retains a block once per
//!   attached row, and a block is recycled the instant its last
//!   reference drops, so `allocated == freed` after every row retires.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Index of one physical cache block inside a [`BlockPool`].
pub type BlockId = u32;

/// A fixed pool of refcounted, equal-size block slots. Pure accounting:
/// the pool tracks which slots are live and how many owners each has,
/// never what they contain (that is `paged::blocks`' job).
#[derive(Debug)]
pub struct BlockPool {
    /// per-slot reference count (0 = free)
    refcount: Vec<u32>,
    /// stack of free slot ids
    free: Vec<BlockId>,
    /// lifetime counters: leak detection is `allocated == freed` once
    /// every owner has released
    allocated_total: u64,
    freed_total: u64,
}

impl BlockPool {
    /// A pool of `n_blocks` free slots.
    pub fn new(n_blocks: usize) -> BlockPool {
        BlockPool {
            refcount: vec![0; n_blocks],
            // pop order is ascending ids — deterministic, test-friendly
            // pallas-lint: allow(no-lossy-as) — pool sizes are bounded by device memory, far below u32::MAX
            free: (0..n_blocks as BlockId).rev().collect(),
            allocated_total: 0,
            freed_total: 0,
        }
    }

    /// Total slots in the pool.
    pub fn n_blocks(&self) -> usize {
        self.refcount.len()
    }

    /// Slots currently live (refcount > 0).
    pub fn in_use(&self) -> usize {
        self.refcount.len() - self.free.len()
    }

    /// Slots currently free.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks ever allocated / ever recycled (leak accounting).
    pub fn totals(&self) -> (u64, u64) {
        (self.allocated_total, self.freed_total)
    }

    /// Current reference count of `id` (0 = free or out of range).
    pub fn refcount(&self, id: BlockId) -> u32 {
        self.refcount.get(id as usize).copied().unwrap_or(0)
    }

    /// Claim a free slot with refcount 1, or `None` when the pool is
    /// exhausted (the caller decides whether that means swap or OOM).
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        // pallas-lint: allow(no-hot-path-panic) — ids on the free list were minted from 0..n_blocks
        self.refcount[id as usize] = 1;
        self.allocated_total += 1;
        Some(id)
    }

    /// Add one reference to a live block (prefix sharing).
    pub fn retain(&mut self, id: BlockId) -> Result<()> {
        match self.refcount.get_mut(id as usize) {
            Some(rc) if *rc > 0 => {
                *rc += 1;
                Ok(())
            }
            _ => bail!("retain of free or out-of-range block {id}"),
        }
    }

    /// Drop one reference; returns `true` when this was the last one and
    /// the slot went back on the free list.
    pub fn release(&mut self, id: BlockId) -> Result<bool> {
        match self.refcount.get_mut(id as usize) {
            Some(rc) if *rc > 0 => {
                *rc -= 1;
                if *rc == 0 {
                    self.free.push(id);
                    self.freed_total += 1;
                    Ok(true)
                } else {
                    Ok(false)
                }
            }
            _ => bail!("release of free or out-of-range block {id}"),
        }
    }

    /// Accounting self-check (cheap; property tests call it every step).
    pub fn check_invariants(&self) {
        let live = self.refcount.iter().filter(|&&rc| rc > 0).count();
        assert_eq!(
            live + self.free.len(),
            self.refcount.len(),
            "every slot is live xor free"
        );
        assert_eq!(
            self.allocated_total - self.freed_total,
            live as u64,
            "allocated - freed == live blocks"
        );
    }
}

#[derive(Debug)]
/// Named pinned allocations against a fixed device byte budget.
pub struct DevicePool {
    /// total device bytes
    pub capacity: usize,
    used: usize,
    allocs: BTreeMap<String, usize>,
}

impl DevicePool {
    /// An empty pool of `capacity` bytes.
    pub fn new(capacity: usize) -> DevicePool {
        DevicePool { capacity, used: 0, allocs: BTreeMap::new() }
    }

    /// Pinned (non-pageable) allocation — fails hard on OOM, like a CUDA
    /// `cudaMalloc`.
    pub fn alloc(&mut self, name: &str, bytes: usize) -> Result<()> {
        if self.allocs.contains_key(name) {
            bail!("allocation {name:?} already exists");
        }
        if self.used + bytes > self.capacity {
            bail!(
                "OOM: {name} needs {bytes} B, {} of {} B used",
                self.used,
                self.capacity
            );
        }
        self.used += bytes;
        self.allocs.insert(name.to_string(), bytes);
        Ok(())
    }

    /// Release a named allocation.
    pub fn free(&mut self, name: &str) -> Result<()> {
        match self.allocs.remove(name) {
            Some(b) => {
                self.used -= b;
                Ok(())
            }
            None => bail!("allocation {name:?} not found"),
        }
    }

    /// Bytes currently allocated.
    pub fn used(&self) -> usize {
        self.used
    }

    /// Bytes still available.
    pub fn free_bytes(&self) -> usize {
        self.capacity - self.used
    }

    /// Try to reserve transient bytes (activation spike); true if it fits.
    pub fn fits(&self, bytes: usize) -> bool {
        self.used + bytes <= self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_accounting() {
        let mut p = DevicePool::new(100);
        p.alloc("a", 60).unwrap();
        assert_eq!(p.used(), 60);
        assert!(p.alloc("b", 50).is_err()); // OOM
        p.alloc("c", 40).unwrap();
        assert_eq!(p.free_bytes(), 0);
        p.free("a").unwrap();
        assert_eq!(p.used(), 40);
        assert!(p.free("a").is_err());
        assert!(p.alloc("c", 1).is_err()); // duplicate
    }

    #[test]
    fn fits_is_nondestructive() {
        let mut p = DevicePool::new(10);
        p.alloc("x", 4).unwrap();
        assert!(p.fits(6));
        assert!(!p.fits(7));
        assert_eq!(p.used(), 4);
    }

    #[test]
    fn block_pool_alloc_retain_release() {
        let mut p = BlockPool::new(2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.free_blocks(), 0);
        assert!(p.alloc().is_none(), "pool exhausted");
        p.retain(a).unwrap();
        assert_eq!(p.refcount(a), 2);
        assert!(!p.release(a).unwrap(), "still one owner left");
        assert!(p.release(a).unwrap(), "last owner frees the slot");
        assert_eq!(p.free_blocks(), 1);
        assert!(p.release(a).is_err(), "double release is an error");
        assert!(p.retain(a).is_err(), "retain of a free slot is an error");
        p.release(b).unwrap();
        assert_eq!(p.totals(), (2, 2), "allocated == freed when all retired");
        p.check_invariants();
    }

    #[test]
    fn block_pool_recycles_freed_slots() {
        let mut p = BlockPool::new(1);
        let a = p.alloc().unwrap();
        p.release(a).unwrap();
        let b = p.alloc().unwrap();
        assert_eq!(a, b, "single slot is recycled");
        p.check_invariants();
    }
}
