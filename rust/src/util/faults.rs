//! Deterministic, seeded fault-injection plane for the serving stack.
//!
//! A [`FaultPlan`] names a seed plus a per-site schedule (probability
//! and an optional cap on total firings); [`Faults`] is the cheap
//! cloneable runtime handle threaded through the server, the decode
//! loop, and the KV block manager. Sites:
//!
//! * `slow-write` — stall a client-facing socket write for
//!   [`FaultPlan::delay`] before it happens;
//! * `conn-reset` — drop a connection mid-stream instead of finishing
//!   the response;
//! * `worker-panic` — panic inside an HTTP worker thread (exercises
//!   the catch/respawn boundary);
//! * `block-alloc` — fail a KV block allocation at the append
//!   boundary, as if the pool were exhausted (exercises the
//!   `NeedBlock` → preemption path);
//! * `decode-delay` — sleep [`FaultPlan::delay`] before a decode step
//!   (exercises the watchdog).
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost when disabled.** A disabled handle is a `None`; every
//!    check is one branch on an `Option`, no locks, no RNG.
//! 2. **Deterministic.** Each site owns an independent xoshiro stream
//!    derived from the plan seed, so the k-th check at a site fires or
//!    not regardless of how checks at *other* sites interleave. (When
//!    several threads race on the *same* site, which thread absorbs
//!    the k-th decision can vary — the decision sequence itself never
//!    does.)
//! 3. **Off the hot path.** Sites live at connection/step/allocation
//!    boundaries, never inside per-token inner loops.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use crate::util::rng::Rng;

/// Number of distinct injection sites.
pub const N_SITES: usize = 5;

/// A place in the serving stack where a fault can be injected.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FaultSite {
    /// Stall a client-facing write for the plan's delay first.
    SlowWrite,
    /// Drop the connection mid-stream instead of finishing.
    ConnReset,
    /// Panic inside an HTTP worker thread.
    WorkerPanic,
    /// Fail a KV block allocation as if the pool were exhausted.
    BlockAlloc,
    /// Sleep for the plan's delay before a decode step.
    DecodeDelay,
}

/// All sites, in index order.
pub const SITES: [FaultSite; N_SITES] = [
    FaultSite::SlowWrite,
    FaultSite::ConnReset,
    FaultSite::WorkerPanic,
    FaultSite::BlockAlloc,
    FaultSite::DecodeDelay,
];

impl FaultSite {
    /// The spec-string name of this site (`--faults slow-write=0.1`).
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::SlowWrite => "slow-write",
            FaultSite::ConnReset => "conn-reset",
            FaultSite::WorkerPanic => "worker-panic",
            FaultSite::BlockAlloc => "block-alloc",
            FaultSite::DecodeDelay => "decode-delay",
        }
    }

    fn index(self) -> usize {
        match self {
            FaultSite::SlowWrite => 0,
            FaultSite::ConnReset => 1,
            FaultSite::WorkerPanic => 2,
            FaultSite::BlockAlloc => 3,
            FaultSite::DecodeDelay => 4,
        }
    }

    fn from_name(name: &str) -> Option<FaultSite> {
        let canon = name.replace('_', "-");
        SITES.iter().copied().find(|s| s.name() == canon)
    }
}

/// Per-site schedule: fire with probability `p` on each check, at most
/// `max` times in total (`None` = unlimited).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SiteSpec {
    /// Firing probability per check, in `[0, 1]`.
    pub p: f64,
    /// Cap on total firings at this site (`None` = unlimited).
    pub max: Option<u64>,
}

/// A seed plus a per-site schedule; the parsed form of `--faults` /
/// `QLORA_FAULTS`.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed for the per-site decision streams.
    pub seed: u64,
    /// Stall applied by `slow-write` / `decode-delay` when they fire.
    pub delay: Duration,
    sites: [Option<SiteSpec>; N_SITES],
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan { seed: 0, delay: Duration::from_millis(25), sites: [None; N_SITES] }
    }
}

impl FaultPlan {
    /// Parse a spec string: comma-separated `key=value` entries where
    /// the key is `seed`, `delay-ms`, or a site name, and a site value
    /// is `<prob>` or `<prob>x<max>`. Example:
    /// `seed=42,delay-ms=5,block-alloc=0.3,worker-panic=0.5x2`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (key, value) = entry
                .split_once('=')
                .ok_or_else(|| format!("fault entry `{entry}` is not key=value"))?;
            let key = key.trim();
            let value = value.trim();
            match key {
                "seed" => {
                    plan.seed = value
                        .parse()
                        .map_err(|_| format!("bad fault seed `{value}`"))?;
                }
                "delay-ms" | "delay_ms" => {
                    let ms: u64 = value
                        .parse()
                        .map_err(|_| format!("bad fault delay `{value}`"))?;
                    plan.delay = Duration::from_millis(ms);
                }
                _ => {
                    let site = FaultSite::from_name(key).ok_or_else(|| {
                        format!(
                            "unknown fault site `{key}` (sites: {})",
                            SITES.map(FaultSite::name).join(", ")
                        )
                    })?;
                    plan.sites[site.index()] = Some(parse_site_spec(value)?);
                }
            }
        }
        Ok(plan)
    }

    /// The schedule for `site`, if one is configured.
    pub fn site(&self, site: FaultSite) -> Option<SiteSpec> {
        self.sites[site.index()]
    }

    /// Set the schedule for `site` (builder-style, for tests).
    pub fn with(mut self, site: FaultSite, p: f64, max: Option<u64>) -> FaultPlan {
        self.sites[site.index()] = Some(SiteSpec { p, max });
        self
    }

    /// True when no site has a schedule — the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.sites.iter().all(Option::is_none)
    }
}

fn parse_site_spec(value: &str) -> Result<SiteSpec, String> {
    let (p_text, max) = match value.split_once('x') {
        Some((p, m)) => {
            let max: u64 = m
                .trim()
                .parse()
                .map_err(|_| format!("bad fault cap in `{value}`"))?;
            (p.trim(), Some(max))
        }
        None => (value, None),
    };
    let p: f64 = p_text
        .parse()
        .map_err(|_| format!("bad fault probability `{p_text}`"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("fault probability {p} is outside [0, 1]"));
    }
    Ok(SiteSpec { p, max })
}

struct Lane {
    spec: SiteSpec,
    rng: Rng,
    fired: u64,
}

struct Inner {
    delay: Duration,
    // One decision stream per site; lanes without a schedule stay None
    // so an unconfigured site is a lock-free miss.
    lanes: [Option<Mutex<Lane>>; N_SITES],
}

/// Cheap cloneable runtime handle over a [`FaultPlan`]; `disabled()`
/// (the default) makes every check a single `Option` branch.
#[derive(Clone, Default)]
pub struct Faults {
    inner: Option<Arc<Inner>>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // A panicked holder leaves plain counters behind; recover the data.
    m.lock().unwrap_or_else(|poison| poison.into_inner())
}

impl Faults {
    /// A handle that never fires; every check is one `Option` branch.
    pub fn disabled() -> Faults {
        Faults::default()
    }

    /// Build the runtime handle for `plan`; an empty plan collapses to
    /// [`Faults::disabled`].
    pub fn new(plan: &FaultPlan) -> Faults {
        if plan.is_empty() {
            return Faults::disabled();
        }
        let lanes = SITES.map(|site| {
            plan.site(site).map(|spec| {
                // Independent stream per site: golden-ratio spacing on
                // the seed, matching Rng::fork's stream separation.
                let lane_seed = plan
                    .seed
                    .wrapping_add((site.index() as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
                Mutex::new(Lane { spec, rng: Rng::new(lane_seed), fired: 0 })
            })
        });
        Faults { inner: Some(Arc::new(Inner { delay: plan.delay, lanes })) }
    }

    /// Parse a spec string and build the handle in one step.
    pub fn from_spec(spec: &str) -> Result<Faults, String> {
        Ok(Faults::new(&FaultPlan::parse(spec)?))
    }

    /// True when any site can fire.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Draw the next decision for `site`: true means inject the fault
    /// now. Deterministic per site given the plan seed.
    #[inline]
    pub fn fire(&self, site: FaultSite) -> bool {
        let Some(inner) = &self.inner else { return false };
        let Some(lane) = &inner.lanes[site.index()] else { return false };
        let mut lane = lock(lane);
        if lane.spec.max.is_some_and(|max| lane.fired >= max) {
            return false;
        }
        let hit = lane.rng.bool(lane.spec.p);
        if hit {
            lane.fired += 1;
        }
        hit
    }

    /// The stall used by the delaying sites when they fire.
    pub fn delay(&self) -> Duration {
        self.inner.as_ref().map_or(Duration::ZERO, |i| i.delay)
    }

    /// How many times `site` has fired so far (stats / tests).
    pub fn fired(&self, site: FaultSite) -> u64 {
        match &self.inner {
            Some(inner) => inner.lanes[site.index()]
                .as_ref()
                .map_or(0, |lane| lock(lane).fired),
            None => 0,
        }
    }
}

impl std::fmt::Debug for Faults {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            None => write!(f, "Faults(disabled)"),
            Some(inner) => {
                write!(f, "Faults(")?;
                let mut first = true;
                for site in SITES {
                    if let Some(lane) = &inner.lanes[site.index()] {
                        let lane = lock(lane);
                        if !first {
                            write!(f, ", ")?;
                        }
                        first = false;
                        write!(f, "{}={} fired={}", site.name(), lane.spec.p, lane.fired)?;
                    }
                }
                write!(f, ")")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_never_fires_and_is_lock_free() {
        let f = Faults::disabled();
        assert!(!f.enabled());
        for site in SITES {
            for _ in 0..100 {
                assert!(!f.fire(site));
            }
            assert_eq!(f.fired(site), 0);
        }
        assert_eq!(f.delay(), Duration::ZERO);
    }

    #[test]
    fn empty_plan_collapses_to_disabled() {
        assert!(!Faults::new(&FaultPlan::default()).enabled());
        assert!(!Faults::from_spec("seed=9,delay-ms=3").unwrap().enabled());
    }

    #[test]
    fn parse_full_spec() {
        let plan =
            FaultPlan::parse("seed=42, delay-ms=5, block-alloc=0.3, worker-panic=0.5x2")
                .unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.delay, Duration::from_millis(5));
        assert_eq!(
            plan.site(FaultSite::BlockAlloc),
            Some(SiteSpec { p: 0.3, max: None })
        );
        assert_eq!(
            plan.site(FaultSite::WorkerPanic),
            Some(SiteSpec { p: 0.5, max: Some(2) })
        );
        assert_eq!(plan.site(FaultSite::ConnReset), None);
        // underscores are accepted as an alias for dashes
        let alias = FaultPlan::parse("conn_reset=1,delay_ms=7").unwrap();
        assert_eq!(alias.site(FaultSite::ConnReset), Some(SiteSpec { p: 1.0, max: None }));
        assert_eq!(alias.delay, Duration::from_millis(7));
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "block-alloc",        // no value
            "warp-core=0.1",      // unknown site
            "seed=xyz",           // non-numeric seed
            "block-alloc=1.5",    // probability out of range
            "block-alloc=-0.1",   // negative probability
            "block-alloc=0.5xq",  // non-numeric cap
            "delay-ms=ten",       // non-numeric delay
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn decision_streams_are_deterministic_and_independent() {
        let plan = FaultPlan { seed: 7, ..FaultPlan::default() }
            .with(FaultSite::BlockAlloc, 0.5, None)
            .with(FaultSite::ConnReset, 0.5, None);
        let a = Faults::new(&plan);
        let b = Faults::new(&plan);
        // same seed -> identical per-site sequences
        let seq = |f: &Faults, site| (0..64).map(|_| f.fire(site)).collect::<Vec<_>>();
        assert_eq!(seq(&a, FaultSite::BlockAlloc), seq(&b, FaultSite::BlockAlloc));
        // interleaving checks at another site does not perturb a lane:
        // draw conn-reset decisions between block-alloc draws and the
        // block-alloc sequence must match the uninterleaved run above
        let c = Faults::new(&plan);
        let interleaved: Vec<bool> = (0..64)
            .map(|_| {
                c.fire(FaultSite::ConnReset);
                c.fire(FaultSite::BlockAlloc)
            })
            .collect();
        assert_eq!(interleaved, seq(&b, FaultSite::BlockAlloc));
    }

    #[test]
    fn cap_bounds_total_firings() {
        let plan = FaultPlan::default().with(FaultSite::WorkerPanic, 1.0, Some(3));
        let f = Faults::new(&plan);
        let hits = (0..50).filter(|_| f.fire(FaultSite::WorkerPanic)).count();
        assert_eq!(hits, 3);
        assert_eq!(f.fired(FaultSite::WorkerPanic), 3);
    }

    #[test]
    fn probability_one_always_fires() {
        let plan = FaultPlan::default().with(FaultSite::DecodeDelay, 1.0, None);
        let f = Faults::new(&plan);
        assert!((0..32).all(|_| f.fire(FaultSite::DecodeDelay)));
    }
}
