//! Statistics used by the evaluation harness: descriptive stats, bootstrap
//! confidence intervals, and the agreement measures the paper reports
//! (Kendall τ, Spearman ρ, Fleiss κ — section 5.3 / 6.2).

use crate::util::rng::Rng;

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Median (averages the middle pair for even n).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        (v[n / 2 - 1] + v[n / 2]) / 2.0
    }
}

/// p-th percentile (p in [0,100]) by linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Normal-approximation 95% CI half-width of the mean.
pub fn ci95_halfwidth(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Bootstrap 95% CI of the mean (percentile method), deterministic in seed.
pub fn bootstrap_ci95(xs: &[f64], resamples: usize, seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let mut means = Vec::with_capacity(resamples);
    for _ in 0..resamples {
        let s: f64 = (0..xs.len()).map(|_| xs[rng.below(xs.len())]).sum();
        means.push(s / xs.len() as f64);
    }
    (percentile(&means, 2.5), percentile(&means, 97.5))
}

/// Kendall rank correlation τ (tau-a; the paper reports τ = 0.43 between
/// GPT-4 and human system-level rankings).
pub fn kendall_tau(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 0.0;
    }
    let mut concordant = 0i64;
    let mut discordant = 0i64;
    for i in 0..n {
        for j in (i + 1)..n {
            let s = (a[i] - a[j]) * (b[i] - b[j]);
            if s > 0.0 {
                concordant += 1;
            } else if s < 0.0 {
                discordant += 1;
            }
        }
    }
    let pairs = (n * (n - 1) / 2) as f64;
    (concordant - discordant) as f64 / pairs
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| xs[i].total_cmp(&xs[j]));
    let mut r = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0; // 1-based average rank for ties
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Pearson correlation.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (ma, mb) = (mean(a), mean(b));
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        num += (a[i] - ma) * (b[i] - mb);
        da += (a[i] - ma).powi(2);
        db += (b[i] - mb).powi(2);
    }
    if da == 0.0 || db == 0.0 {
        return 0.0;
    }
    num / (da * db).sqrt()
}

/// Spearman rank correlation ρ (paper: r = 0.55 system level).
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    pearson(&ranks(a), &ranks(b))
}

/// Fleiss' κ for inter-annotator agreement on categorical labels.
///
/// `counts[i][c]` = number of annotators assigning category c to item i;
/// every row must sum to the same number of annotators n >= 2.
/// (Paper: κ = 0.42 among humans, κ = 0.25 GPT-4 vs human majority.)
pub fn fleiss_kappa(counts: &[Vec<usize>]) -> f64 {
    let items = counts.len();
    assert!(items > 0);
    let cats = counts[0].len();
    let n: usize = counts[0].iter().sum();
    assert!(n >= 2, "need >=2 annotators");
    // per-category proportions
    let mut pj = vec![0.0; cats];
    for row in counts {
        debug_assert_eq!(row.iter().sum::<usize>(), n);
        for (j, &c) in row.iter().enumerate() {
            pj[j] += c as f64;
        }
    }
    let total = (items * n) as f64;
    for p in pj.iter_mut() {
        *p /= total;
    }
    // per-item agreement
    let mut pbar = 0.0;
    for row in counts {
        let s: f64 = row.iter().map(|&c| (c * c) as f64).sum();
        pbar += (s - n as f64) / (n as f64 * (n as f64 - 1.0));
    }
    pbar /= items as f64;
    let pe: f64 = pj.iter().map(|p| p * p).sum();
    if (1.0 - pe).abs() < 1e-12 {
        return 1.0;
    }
    (pbar - pe) / (1.0 - pe)
}

/// Inverse standard normal CDF (Acklam's algorithm, |err| < 1.15e-9).
/// Used by the Rust NF4 codebook construction — must agree with
/// `jax.scipy.special.ndtri` to float32 precision (golden-tested).
pub fn ndtri(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "ndtri domain: 0 < p < 1, got {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02,
        -2.759285104469687e+02, 1.383577518672690e+02,
        -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02,
        -1.556989798598866e+02, 6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01,
        -2.400758277161838e+00, -2.549732539343734e+00,
        4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01,
        2.445134137142996e+00, 3.754408661907416e+00,
    ];
    let plow = 0.02425;
    let x = if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
                + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // one Halley refinement step against the normal CDF
    let e = 0.5 * erfc_scalar(-x / std::f64::consts::SQRT_2) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Complementary error function (Numerical Recipes rational approximation,
/// |rel err| < 1.2e-7, refined by the Halley step in `ndtri`).
fn erfc_scalar(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223
                                            + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Standard normal CDF.
pub fn ndtr(x: f64) -> f64 {
    0.5 * erfc_scalar(-x / std::f64::consts::SQRT_2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_descriptive() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn nan_inputs_never_panic() {
        // regression: these sorts used partial_cmp().unwrap(), which
        // panics the moment a NaN reaches a comparison (the PR 6 sampler
        // bug class). total_cmp orders NaN after +inf, so NaN-bearing
        // input degrades gracefully instead of killing the serve loop.
        let xs = [2.0, f64::NAN, 1.0];
        assert_eq!(median(&xs), 2.0, "NaN sorts last; median is finite");
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!(percentile(&xs, 100.0).is_nan());
        // spearman ranks NaN as the largest value; must not panic
        let _ = spearman(&xs, &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn kendall_perfect_and_reversed() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [4.0, 3.0, 2.0, 1.0];
        assert_eq!(kendall_tau(&a, &a), 1.0);
        assert_eq!(kendall_tau(&a, &b), -1.0);
    }

    #[test]
    fn spearman_monotone() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0, 4.0, 9.0, 16.0, 25.0]; // monotone transform
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fleiss_kappa_ranges() {
        // perfect agreement
        let perfect = vec![vec![3, 0], vec![0, 3], vec![3, 0]];
        assert!((fleiss_kappa(&perfect) - 1.0).abs() < 1e-12);
        // the classic Fleiss (1971) worked example value 0.2099 (wikipedia)
        let wiki = vec![
            vec![0, 0, 0, 0, 14],
            vec![0, 2, 6, 4, 2],
            vec![0, 0, 3, 5, 6],
            vec![0, 3, 9, 2, 0],
            vec![2, 2, 8, 1, 1],
            vec![7, 7, 0, 0, 0],
            vec![3, 2, 6, 3, 0],
            vec![2, 5, 3, 2, 2],
            vec![6, 5, 2, 1, 0],
            vec![0, 2, 2, 3, 7],
        ];
        assert!((fleiss_kappa(&wiki) - 0.2099).abs() < 1e-3);
    }

    #[test]
    fn ndtri_matches_known_quantiles() {
        // reference values from scipy.special.ndtri
        for (p, x) in [
            (0.5, 0.0),
            (0.8413447460685429, 1.0),
            (0.9772498680518208, 2.0),
            (0.9677083, 1.8481308),
            (0.0228, -1.9990772),
        ] {
            assert!((ndtri(p) - x).abs() < 1e-6, "ndtri({p}) = {}", ndtri(p));
        }
    }

    #[test]
    fn ndtr_ndtri_roundtrip() {
        for x in [-3.0, -1.5, -0.1, 0.0, 0.7, 2.5] {
            assert!((ndtri(ndtr(x)) - x).abs() < 1e-6);
        }
    }

    #[test]
    fn bootstrap_ci_contains_mean() {
        let mut rng = Rng::new(9);
        let xs: Vec<f64> = (0..500).map(|_| rng.normal_ms(10.0, 2.0)).collect();
        let (lo, hi) = bootstrap_ci95(&xs, 500, 1);
        let m = mean(&xs);
        assert!(lo < m && m < hi);
        assert!(hi - lo < 1.0, "CI too wide: {lo}..{hi}");
    }
}
