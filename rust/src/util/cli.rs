//! Hand-rolled CLI argument parser (the offline registry has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positionals.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: positionals plus `--key value` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// non-flag arguments, in order (plus everything after a `--`)
    pub positional: Vec<String>,
    /// flag values keyed by name (bare `--flag` stores `"true"`)
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminator: rest are positionals
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// The value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// The value of `--key`, or `default` when absent.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Whether boolean `--key` was given (accepts `true` / `1` / `yes`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// `--key` parsed as `usize`, or `default` when absent.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} expects an integer, got {v:?}"),
            },
        }
    }

    /// `--key` parsed as `f64`, or `default` when absent.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} expects a number, got {v:?}"),
            },
        }
    }

    /// `--key` parsed as `u64`, or `default` when absent.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} expects an integer, got {v:?}"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["train", "--steps", "100", "--fast", "--lr=0.1", "x"]);
        assert_eq!(a.positional, vec!["train", "x"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.flag("fast"));
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.1);
    }

    #[test]
    fn flag_before_end() {
        let a = parse(&["--verbose", "--out", "dir"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("dir"));
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--a", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["--steps", "abc"]);
        assert!(a.usize_or("steps", 0).is_err());
    }
}
