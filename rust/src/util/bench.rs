//! Mini-criterion: a timing harness for `rust/benches/` (the offline
//! registry has no `criterion`). Warmup + timed iterations, reports
//! mean / median / p95 / stddev and optional throughput, and can emit
//! results as JSON ([`Bencher::write_json`]) so the perf trajectory is
//! machine-tracked (`make bench-quant` → `BENCH_quant.json`).

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Value;

/// One benchmark measurement summary.
#[derive(Debug, Clone)]
pub struct Summary {
    /// benchmark label (also the JSON match key for `bench_compare.py`)
    pub name: String,
    /// timed iterations (after warmup)
    pub iters: usize,
    /// mean wall time per iteration
    pub mean_ns: f64,
    /// median wall time per iteration
    pub median_ns: f64,
    /// 95th-percentile wall time per iteration
    pub p95_ns: f64,
    /// standard deviation of per-iteration wall time
    pub std_ns: f64,
    /// items/sec if `throughput_items` was set
    pub throughput: Option<f64>,
}

impl Summary {
    /// Print one human-readable result line (name, timings, throughput).
    pub fn print(&self) {
        let tp = match self.throughput {
            Some(t) => format!("  {:>12}/s", human_count(t)),
            None => String::new(),
        };
        println!(
            "{:<44} {:>10}  median {:>10}  p95 {:>10}  ±{:>9}{}",
            self.name,
            human_ns(self.mean_ns),
            human_ns(self.median_ns),
            human_ns(self.p95_ns),
            human_ns(self.std_ns),
            tp
        );
    }
}

/// Format nanoseconds with an adaptive unit (ns / µs / ms / s).
pub fn human_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a count with an adaptive suffix (k / M / G).
pub fn human_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Bench runner with a time budget per benchmark.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
    /// summaries in registration order, as written by [`Bencher::write_json`]
    pub results: Vec<Summary>,
}

impl Default for Bencher {
    fn default() -> Self {
        // QLORA_BENCH_FAST=1 shrinks budgets (used by `cargo test` smoke)
        let fast = std::env::var("QLORA_BENCH_FAST").is_ok();
        Bencher {
            warmup: Duration::from_millis(if fast { 20 } else { 200 }),
            budget: Duration::from_millis(if fast { 100 } else { 1500 }),
            min_iters: 5,
            max_iters: 100_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// A bencher with the default (or `QLORA_BENCH_FAST`) time budgets.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, which performs ONE iteration of the measured operation.
    /// Use the return value to prevent the optimizer from discarding work.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Summary {
        self.bench_with_items(name, None, &mut f)
    }

    /// Like `bench`, with a throughput annotation: `items` processed per call.
    pub fn bench_items<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items: usize,
        mut f: F,
    ) -> &Summary {
        self.bench_with_items(name, Some(items), &mut f)
    }

    fn bench_with_items<T>(
        &mut self,
        name: &str,
        items: Option<usize>,
        f: &mut dyn FnMut() -> T,
    ) -> &Summary {
        // warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // measure
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.budget && samples_ns.len() < self.max_iters)
            || samples_ns.len() < self.min_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let mean = crate::util::stats::mean(&samples_ns);
        let summary = Summary {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: mean,
            median_ns: crate::util::stats::median(&samples_ns),
            p95_ns: crate::util::stats::percentile(&samples_ns, 95.0),
            std_ns: crate::util::stats::std_dev(&samples_ns),
            throughput: items.map(|n| n as f64 / (mean / 1e9)),
        };
        summary.print();
        self.results.push(summary);
        self.results.last().unwrap()
    }

    /// Header line for a bench group.
    pub fn group(&self, title: &str) {
        println!("\n== {title} ==");
    }

    /// Look a finished measurement up by name (for derived metrics such
    /// as fused-vs-scalar speedups).
    pub fn find(&self, name: &str) -> Option<&Summary> {
        self.results.iter().find(|s| s.name == name)
    }

    /// All results as a JSON array (one object per [`Summary`]).
    pub fn to_json(&self) -> Value {
        Value::array(self.results.iter().map(|s| {
            let mut pairs = vec![
                ("name", Value::s(s.name.clone())),
                ("iters", Value::n(s.iters as f64)),
                ("mean_ns", Value::n(s.mean_ns)),
                ("median_ns", Value::n(s.median_ns)),
                ("p95_ns", Value::n(s.p95_ns)),
                ("std_ns", Value::n(s.std_ns)),
            ];
            if let Some(t) = s.throughput {
                pairs.push(("throughput_per_s", Value::n(t)));
            }
            Value::object(pairs)
        }))
    }

    /// Write `{"results": [...], <meta...>}` to `path` — the
    /// machine-readable form of a bench run. `meta` pairs (e.g. mode,
    /// thread count, derived speedups) are merged at the top level.
    pub fn write_json(&self, path: &Path, meta: &[(&str, Value)]) -> Result<()> {
        let mut pairs = vec![("results", self.to_json())];
        pairs.extend(meta.iter().cloned());
        let mut doc = Value::object(pairs).to_string();
        doc.push('\n');
        std::fs::write(path, doc)
            .with_context(|| format!("writing bench json {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("QLORA_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let s = b.bench("noop-ish", || {
            (0..100).map(|i: u64| i.wrapping_mul(31)).sum::<u64>()
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.iters >= 5);
    }

    #[test]
    fn json_roundtrips() {
        std::env::set_var("QLORA_BENCH_FAST", "1");
        let mut b = Bencher::new();
        b.bench_items("with-items", 100, || 1u64 + 1);
        b.bench("no-items", || 2u64 * 3);
        let v = b.to_json();
        let arr = v.arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().str().unwrap(), "with-items");
        assert!(arr[0].get("throughput_per_s").unwrap().num().unwrap() > 0.0);
        assert!(arr[1].opt("throughput_per_s").is_none());
        assert!(b.find("no-items").is_some());
        let dir = std::env::temp_dir().join("qlora_bench_json_test.json");
        b.write_json(&dir, &[("mode", Value::s("smoke"))]).unwrap();
        let back = Value::parse(&std::fs::read_to_string(&dir).unwrap())
            .unwrap();
        assert_eq!(back.get("mode").unwrap().str().unwrap(), "smoke");
        assert_eq!(back.get("results").unwrap().arr().unwrap().len(), 2);
        let _ = std::fs::remove_file(&dir);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_ns(500.0), "500 ns");
        assert!(human_ns(2500.0).contains("µs"));
        assert!(human_ns(2.5e6).contains("ms"));
        assert!(human_count(2.5e6).contains('M'));
    }
}
