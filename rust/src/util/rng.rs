//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core, plus the
//! distributions the experiments need (uniform, normal via Box–Muller,
//! Student-t, categorical). The offline crate registry lacks `rand`, and
//! determinism across runs/benches matters more than crypto quality here.

/// xoshiro256** PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a generator; equal seeds yield identical streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-worker / per-case seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output of the xoshiro256** core.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// N(mu, sigma^2).
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Student-t with `df` degrees of freedom (heavy-tailed weight model).
    pub fn student_t(&mut self, df: f64) -> f64 {
        // t = Z / sqrt(ChiSq(df)/df); ChiSq via sum of squared normals for
        // integer-ish df is wasteful — use Bailey's polar method instead.
        let z = self.normal();
        let mut chi = 0.0;
        let k = df.round().max(1.0) as usize;
        for _ in 0..k {
            let n = self.normal();
            chi += n * n;
        }
        z / (chi / df.round().max(1.0)).sqrt()
    }

    /// Fill a vec with standard normals (f32).
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Bernoulli with probability p.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn student_t_heavier_tails() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let big_t = (0..n).map(|_| r.student_t(3.0).abs()).filter(|x| *x > 3.0).count();
        let big_n = (0..n).map(|_| r.normal().abs()).filter(|x| *x > 3.0).count();
        assert!(big_t > big_n * 3, "t tails {big_t} vs normal {big_n}");
    }

    #[test]
    fn categorical_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.categorical(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(7);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
