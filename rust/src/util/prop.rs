//! Tiny property-based testing runner (the offline registry has no
//! `proptest`). Runs a closure over many seeded random cases and reports
//! the failing seed so a failure is reproducible with `PROP_SEED=<n>`.

use crate::util::rng::Rng;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `f` over `cases` random cases. `f` gets a per-case RNG; it should
/// panic (assert!) on property violation. If env `PROP_SEED` is set, only
/// that seed is run (reproduction mode).
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut f: F) {
    if let Ok(s) = std::env::var("PROP_SEED") {
        let seed: u64 = s.parse().expect("PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        f(&mut rng);
        return;
    }
    for case in 0..cases {
        let seed = 0x5EED_0000u64 + case as u64;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng),
        ));
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed at case {case} \
                 (reproduce with PROP_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Generators for common shapes.
pub mod gen {
    use crate::util::rng::Rng;

    /// Random length that is a multiple of `block`, between 1 and
    /// `max_blocks` blocks.
    pub fn blocked_len(rng: &mut Rng, block: usize, max_blocks: usize) -> usize {
        block * (1 + rng.below(max_blocks))
    }

    /// Vector of normals with random scale (exercises absmax scaling).
    pub fn weight_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        let scale = 10f64.powf(rng.range_f64(-3.0, 2.0));
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }

    /// Vector with outliers mixed in (LLM.int8() phenomenology).
    pub fn outlier_vec(rng: &mut Rng, n: usize, frac: f64, scale: f64) -> Vec<f32> {
        (0..n)
            .map(|_| {
                let x = rng.normal();
                if rng.bool(frac) {
                    (x * scale) as f32
                } else {
                    x as f32
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        check("counter", 10, |_rng| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    fn rng_is_per_case_deterministic() {
        let mut first = Vec::new();
        check("det", 5, |rng| first.push(rng.next_u64()));
        let mut second = Vec::new();
        check("det", 5, |rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }
}
