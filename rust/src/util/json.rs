//! Minimal JSON: a `Value` enum, a recursive-descent parser, and a writer.
//!
//! Purpose-bound substrate (the offline registry has no `serde`): parses the
//! AOT `manifest.json` and `.tensors` headers written by
//! `python/compile/aot.py`, and serializes experiment results. Supports the
//! full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use `BTreeMap` for deterministic iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// JSON `null`
    Null,
    /// JSON boolean
    Bool(bool),
    /// JSON number (always `f64`, like JavaScript)
    Num(f64),
    /// JSON string
    Str(String),
    /// JSON array
    Arr(Vec<Value>),
    /// JSON object (sorted keys)
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a complete JSON document (rejects trailing input).
    pub fn parse(s: &str) -> Result<Value> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    /// Required object member; errors on non-objects and missing keys.
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    /// Optional object member (`None` on non-objects too).
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// This value as a string, or a typed error.
    pub fn str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// This value as a number, or a typed error.
    pub fn num(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// This value truncated to `i64`, or a typed error.
    pub fn int(&self) -> Result<i64> {
        Ok(self.num()? as i64)
    }

    /// This value as a non-negative `usize`, or a typed error.
    pub fn usize(&self) -> Result<usize> {
        let n = self.num()?;
        if n < 0.0 {
            bail!("negative where usize expected: {n}");
        }
        Ok(n as usize)
    }

    /// This value as a bool, or a typed error.
    pub fn boolean(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// This value as an array slice, or a typed error.
    pub fn arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// This value as an object map, or a typed error.
    pub fn obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // -- construction helpers ------------------------------------------------

    /// Build an object from `(key, value)` pairs.
    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array from an iterator of values.
    pub fn array<I: IntoIterator<Item = Value>>(items: I) -> Value {
        Value::Arr(items.into_iter().collect())
    }

    /// Shorthand string constructor.
    pub fn s(v: impl Into<String>) -> Value {
        Value::Str(v.into())
    }

    /// Shorthand number constructor.
    pub fn n(v: f64) -> Value {
        Value::Num(v)
    }

    // -- writer ---------------------------------------------------------------

    /// Serialize to compact JSON text (round-trips through [`Value::parse`]).
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}",
                           self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}",
                           self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().str().unwrap(), "x\ny");
        assert!(v.get("c").unwrap().boolean().unwrap());
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn nested() {
        let v = Value::parse(r#"[{"x": {"y": [[]]}}]"#).unwrap();
        assert_eq!(
            v.arr().unwrap()[0].get("x").unwrap().get("y").unwrap()
                .arr().unwrap()[0],
            Value::Arr(vec![])
        );
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Value::parse(r#""café — ünïcode""#).unwrap();
        assert_eq!(v.str().unwrap(), "café — ünïcode");
    }

    #[test]
    fn numbers() {
        for (s, x) in [("0", 0.0), ("-1.5", -1.5), ("1e3", 1000.0),
                       ("2.5E-2", 0.025)] {
            assert_eq!(Value::parse(s).unwrap().num().unwrap(), x);
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\"}", "tru", "1 2"] {
            assert!(Value::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn writer_escapes() {
        let v = Value::s("a\"b\\c\nd");
        let back = Value::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn parses_python_manifest_style() {
        let src = r#"{"artifacts": [{"name": "tiny", "n_state": 5,
                      "state_sig": [{"name": "t", "dtype": "f32",
                                     "shape": [2, 3]}]}]}"#;
        let v = Value::parse(src).unwrap();
        let a = &v.get("artifacts").unwrap().arr().unwrap()[0];
        assert_eq!(a.get("n_state").unwrap().usize().unwrap(), 5);
        let shape = a.get("state_sig").unwrap().arr().unwrap()[0]
            .get("shape").unwrap();
        assert_eq!(shape.arr().unwrap().len(), 2);
    }
}
