//! Self-built substrates the offline environment forces us to own:
//! PRNG, JSON, statistics, a property-test runner, a mini bench harness,
//! and a CLI parser. Each is small, tested, and purpose-bound.

pub mod bench;
pub mod cli;
pub mod faults;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
