//! Figure 4 (appendix A.1): "LoRA r is unrelated to final performance if
//! LoRA is used on all layers." **Real training runs** over the r-sweep
//! artifacts (r ∈ {1, 2, 4, 8, 16, 32} at reproduction scale).

use anyhow::Result;

use crate::data::synthetic::{CorpusKind, EvalSuite};
use crate::util::stats;

use super::train_util::{default_steps, train_seeds};
use super::{render_table, Ctx};

/// The rank sweep: `(r, artifact name)` pairs.
pub fn sweep() -> Vec<(usize, &'static str)> {
    vec![
        (1, "tiny_r1"),
        (2, "tiny_r2"),
        (4, "tiny_r4"),
        (8, "tiny_scope_all"),
        (16, "tiny_r16"),
        (32, "tiny_r32"),
    ]
}

/// Train every rank over `seeds`; returns `(r, accuracies %)` rows.
pub fn compute(ctx: &Ctx, seeds: &[u64]) -> Result<Vec<(usize, Vec<f64>)>> {
    let steps = default_steps(ctx);
    let mut out = Vec::new();
    for (r, artifact) in sweep() {
        let runs = train_seeds(ctx, artifact, CorpusKind::Alpaca,
                               EvalSuite::VicunaProxy, steps, seeds, false)?;
        out.push((r, runs.iter().map(|x| x.eval_acc as f64 * 100.0).collect()));
    }
    Ok(out)
}

/// Run the experiment and render its report table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let seeds: Vec<u64> = if ctx.fast { vec![1] } else { vec![1, 2] };
    let results = compute(ctx, &seeds)?;
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|(r, accs)| {
            vec![
                format!("r = {r}"),
                format!("{:.1}", stats::mean(accs)),
                accs.iter()
                    .map(|a| format!("{a:.1}"))
                    .collect::<Vec<_>>()
                    .join(" "),
            ]
        })
        .collect();
    let mut out = render_table(
        "Figure 4: held-out accuracy vs LoRA r (all-layers placement)",
        &["rank", "mean acc %", "per-seed"],
        &rows,
    );
    let means: Vec<f64> =
        results.iter().map(|(_, a)| stats::mean(a)).collect();
    // exclude r=1 from the flatness check: a rank-1 bottleneck can be
    // capacity-limiting at tiny scale, and the paper sweeps r >= 8
    let hi = means[1..].iter().cloned().fold(f64::MIN, f64::max);
    let lo = means[1..].iter().cloned().fold(f64::MAX, f64::min);
    out.push_str(&format!(
        "\nclaim check: accuracy flat in r for r >= 2 \
         (spread {:.1}pt; paper: r unrelated to performance).\n",
        hi - lo
    ));
    Ok(out)
}
