//! Table 1: "Elo ratings for a competition between models, averaged for
//! 10,000 random initial orderings. The winner of a match is determined by
//! GPT-4 ... on the Vicuna benchmark."
//!
//! Real machinery over simulated judgments: all 8C2 system pairs are
//! judged by the GPT-4 judge model on 80 Vicuna-style prompts in both
//! presentation orders; Elo is computed over 10,000 random match
//! orderings with K = 32 from 1000 (paper's exact protocol) with 95% CIs.

use anyhow::Result;

use crate::elo::{MatchRecord, Tournament};
use crate::eval::judge::Judge;
use crate::eval::systems::{roster, System};
use crate::util::rng::Rng;

use super::{render_table, Ctx};

/// Judge every pair on `prompts` prompts, both orders.
pub fn play_matches(
    systems: &[System],
    judge: &Judge,
    vicuna: bool,
    prompts: usize,
    seed: u64,
) -> Vec<MatchRecord> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for a in 0..systems.len() {
        for b in (a + 1)..systems.len() {
            for _ in 0..prompts {
                // both presentation orders (the paper's order-effect control)
                out.push(MatchRecord {
                    a,
                    b,
                    outcome: judge.judge_pair(&systems[a], &systems[b],
                                              vicuna, &mut rng),
                });
                let rev = judge.judge_pair(&systems[b], &systems[a], vicuna,
                                           &mut rng);
                out.push(MatchRecord { a: b, b: a, outcome: rev });
            }
        }
    }
    out
}

/// Run the experiment and render its report table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let systems = roster();
    let judge = Judge::gpt4();
    let orderings = if ctx.fast { 500 } else { 10_000 };
    let matches = play_matches(&systems, &judge, true, 80, ctx.seed);
    let mut t = Tournament::new(systems.len());
    for m in matches {
        t.add(m);
    }
    let mut res = t.run(orderings, ctx.seed ^ 0xE10);
    res.sort_by(|a, b| b.mean.total_cmp(&a.mean));
    let paper: &[(&str, f64)] = &[
        ("GPT-4", 1348.0),
        ("Guanaco-65B", 1022.0),
        ("Guanaco-33B", 992.0),
        ("Vicuna-13B", 974.0),
        ("ChatGPT-3.5 Turbo", 966.0),
        ("Guanaco-13B", 916.0),
        ("Bard", 902.0),
        ("Guanaco-7B", 879.0),
    ];
    let rows: Vec<Vec<String>> = res
        .iter()
        .map(|r| {
            let s = &systems[r.system];
            let p = paper
                .iter()
                .find(|(n, _)| *n == s.name)
                .map(|(_, e)| format!("{e:.0}"))
                .unwrap_or_default();
            vec![
                s.name.to_string(),
                s.mem_gb
                    .map(|m| format!("{m:.0} GB"))
                    .unwrap_or_else(|| "-".into()),
                format!("{:.0} ± {:.0}", r.mean, r.ci95.max(1.0)),
                p,
            ]
        })
        .collect();
    let mut out = render_table(
        "Table 1: Elo (GPT-4 judge, Vicuna bench, 10k orderings)",
        &["Model", "Size", "Elo (ours)", "Elo (paper)"],
        &rows,
    );
    out.push_str(
        "\nshape: GPT-4 clear first (judge self-preference included),\n\
         Guanaco 65B/33B above ChatGPT, Guanaco 13B above Bard.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_paper() {
        let systems = roster();
        let judge = Judge::gpt4();
        let matches = play_matches(&systems, &judge, true, 40, 1);
        let mut t = Tournament::new(systems.len());
        for m in matches {
            t.add(m);
        }
        let res = t.run(300, 2);
        let elo = |name: &str| {
            let i = crate::eval::systems::index_of(&systems, name);
            res.iter().find(|r| r.system == i).unwrap().mean
        };
        assert!(elo("GPT-4") > elo("Guanaco-65B"));
        assert!(elo("Guanaco-65B") > elo("ChatGPT-3.5 Turbo") - 30.0);
        assert!(elo("Guanaco-13B") > elo("Guanaco-7B"));
        assert!(elo("Guanaco-65B") > elo("Bard"));
    }
}
