//! Figure 6 (appendix G): "Breakdown of the memory footprint of different
//! LLaMA models ... batch size 1, sequence length 512, gradient
//! checkpointing" — plus the abstract's 780 GB → 48 GB headline and the
//! Double-Quantization bit accounting. Entirely analytic (exact).

use anyhow::Result;

use crate::memory::{
    constant_overhead_bits, llama_family, train_footprint, Strategy,
    LLAMA_65B,
};

use super::{render_table, Ctx};

/// Run the experiment and render its report table.
pub fn run(_ctx: &Ctx) -> Result<String> {
    const MB: f64 = 1e6;
    let mut rows = Vec::new();
    for spec in llama_family() {
        for (label, strat) in [
            ("Full-16bit", Strategy::Full16),
            ("LoRA-16bit", Strategy::LoRA16 { r: 64 }),
            ("QLoRA-4bit+DQ",
             Strategy::QLoRA4 { r: 64, double_quant: true }),
        ] {
            let f = train_footprint(&spec, strat, 512, 1);
            rows.push(vec![
                spec.name.to_string(),
                label.to_string(),
                format!("{:.0}", f.base_weights as f64 / MB),
                format!("{:.0}", f.quant_constants as f64 / MB),
                format!("{:.0}", f.lora_weights as f64 / MB),
                format!("{:.0}", f.gradients as f64 / MB),
                format!("{:.0}", f.optimizer as f64 / MB),
                format!("{:.0}", f.input_grads as f64 / MB),
                format!("{:.1}", f.total_gb()),
            ]);
        }
    }
    let mut out = render_table(
        "Figure 6: training memory breakdown (MB; bs=1, seq=512, ckpt)",
        &["Model", "Strategy", "weights", "qconst", "lora", "grads",
          "optim", "act/inputgrad", "total GB"],
        &rows,
    );
    let full = train_footprint(&LLAMA_65B, Strategy::Full16, 512, 1);
    let qlora = train_footprint(
        &LLAMA_65B, Strategy::QLoRA4 { r: 64, double_quant: true }, 512, 1);
    out.push_str(&format!(
        "\nheadline: 65B full-16bit = {:.0} GB (paper: >780 GB), \
         65B QLoRA = {:.1} GB (paper: <48 GB)\n",
        full.total_gb(),
        qlora.total_gb()
    ));
    out.push_str(&format!(
        "DQ constant overhead: {:.3} -> {:.3} bits/param \
         (saving {:.3}; paper 0.373)\n",
        constant_overhead_bits(64, false, 256),
        constant_overhead_bits(64, true, 256),
        constant_overhead_bits(64, false, 256)
            - constant_overhead_bits(64, true, 256),
    ));
    out.push_str(
        "fit check: 33B QLoRA fits a 24 GB GPU only with paged optimizer\n\
         headroom; 65B QLoRA fits 48 GB (paper appendix G).\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::*;

    #[test]
    fn fit_claims() {
        // 33B QLoRA just around the 24 GB boundary; 65B under 48 GB
        let f33 = train_footprint(
            &LLAMA_33B, Strategy::QLoRA4 { r: 64, double_quant: true },
            512, 1);
        assert!(f33.total_gb() > 15.0 && f33.total_gb() < 24.5,
                "33B {}", f33.total_gb());
        let f65 = train_footprint(
            &LLAMA_65B, Strategy::QLoRA4 { r: 64, double_quant: true },
            512, 1);
        assert!(f65.total_gb() < 48.0);
    }
}
