//! Table 5: "MMLU 5-shot test results for different sizes of LLaMA
//! finetuned on the corresponding datasets using QLoRA" — 8 datasets × 4
//! sizes, plus the untuned baseline row.
//!
//! Capability-model reproduction (DESIGN.md section 2). The structural
//! claims under test: FLAN v2 best on MMLU at every size; Self-Instruct
//! *hurts* small models; chat-quality datasets (OASST1) are mid-pack on
//! MMLU despite winning the chatbot benchmarks (Table 6) — the paper's
//! "dataset suitability" finding.

use anyhow::Result;

use crate::data::synthetic::CorpusKind;
use crate::eval::capability::{base_mmlu, mmlu, SIZES};
use crate::quant::codebook::DType;

use super::{fmt1, render_table, Ctx};

/// Run the experiment and render its report table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let mut rows = Vec::new();
    let mut base_row = vec!["LLaMA no tuning".to_string()];
    for size in SIZES {
        base_row.push(fmt1(base_mmlu(size)));
    }
    rows.push(base_row);
    // paper row order
    let order = [
        CorpusKind::SelfInstruct,
        CorpusKind::Longform,
        CorpusKind::Chip2,
        CorpusKind::HhRlhf,
        CorpusKind::UnnaturalInstructions,
        CorpusKind::Oasst1,
        CorpusKind::Alpaca,
        CorpusKind::FlanV2,
    ];
    for (i, kind) in order.iter().enumerate() {
        let label = match kind {
            CorpusKind::Oasst1 => "Guanaco (OASST1)".to_string(),
            k => k.name().to_string(),
        };
        let mut row = vec![label];
        for size in SIZES {
            let v = mmlu(size, kind.name(), Some(DType::NF4), true,
                         ctx.seed ^ ((i as u64) << 12));
            row.push(fmt1(v));
        }
        rows.push(row);
    }
    let mut headers = vec!["Dataset"];
    headers.extend(SIZES);
    let mut out = render_table(
        "Table 5: MMLU 5-shot by finetuning dataset and model size",
        &headers,
        &rows,
    );
    out.push_str(
        "\nshape checks: FLAN v2 tops every column; Self-Instruct drags\n\
         13B below the untuned baseline; OASST1 (Guanaco) is mid-pack on\n\
         MMLU despite being the best chatbot (Tables 1/6) — dataset\n\
         suitability, not size, decides benchmark performance.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flan_tops_every_size() {
        for size in SIZES {
            let flan = mmlu(size, "flan-v2", Some(DType::NF4), true, 9);
            for other in ["alpaca", "oasst1", "chip2", "self-instruct"] {
                let v = mmlu(size, other, Some(DType::NF4), true, 9);
                assert!(flan > v, "{size}: flan {flan} vs {other} {v}");
            }
        }
    }

    #[test]
    fn self_instruct_hurts_13b() {
        let si = mmlu("13B", "self-instruct", Some(DType::NF4), true, 10);
        assert!(si < base_mmlu("13B"));
    }
}
