//! Table 2: "Pile Common Crawl mean perplexity for different data types
//! for 125M to 13B OPT, BLOOM, LLaMA, and Pythia models."
//!
//! Paper: Int4 34.34, FP4-E2M1 31.07, FP4-E3M0 29.48, NF4+DQ 27.41.
//!
//! Substitution (DESIGN.md section 2): no Pile or pretrained LLMs here; we
//! *measure* block-quantization error over the paper's weight model
//! (zero-centered normal, Appendix F, plus outlier coordinates) across a
//! family of synthetic "models" (different sizes/outlier profiles) — on
//! the fused multicore kernels (`quant::kernels`, via `quant_error`) — and
//! map RMSE to perplexity with a single calibrated exponential
//! (PPL = PPL16 · exp(k·rmse)), anchored at the paper's NF4 and Int4
//! endpoints. The *measured* part is the datatype error ordering.

use anyhow::Result;

use crate::quant::codebook::DType;
use crate::quant::error::{quant_error, synthetic_llm_weights};
use crate::util::rng::Rng;
use crate::util::stats;

use super::{fmt2, render_table, Ctx};

/// The synthetic "model zoo": (label, n weights, outlier frac, scale).
fn zoo() -> Vec<(&'static str, usize, f64, f64)> {
    vec![
        ("opt-125m", 64 * 512, 0.012, 6.0),
        ("opt-1b3", 64 * 1024, 0.010, 6.0),
        ("bloom-560m", 64 * 768, 0.015, 5.0),
        ("pythia-410m", 64 * 640, 0.008, 5.0),
        ("llama-7b-proxy", 64 * 2048, 0.010, 5.0),
        ("llama-13b-proxy", 64 * 3072, 0.008, 5.0),
    ]
}

/// One data type's measured error and projected perplexity.
pub struct Row {
    /// 4-bit data-type label
    pub dtype: String,
    /// round-trip RMSE averaged over the model zoo
    pub mean_rmse: f64,
    /// projected mean perplexity (two-anchor calibration)
    pub mean_ppl: f64,
}

/// Measure quantization error per data type over the synthetic zoo.
pub fn compute(seed: u64) -> Result<Vec<Row>> {
    let variants: [(&str, DType, Option<usize>); 4] = [
        ("Int4", DType::Int4, None),
        ("Float4 (E2M1)", DType::FP4E2M1, None),
        ("Float4 (E3M0)", DType::FP4E3M0, None),
        ("NFloat4 + DQ", DType::NF4, Some(256)),
    ];
    let mut measured = Vec::new();
    for (name, dt, dq) in variants {
        let mut rmses = Vec::new();
        for (i, (_, n, frac, scale)) in zoo().into_iter().enumerate() {
            let mut rng = Rng::new(seed ^ ((i as u64) << 8));
            let w = synthetic_llm_weights(&mut rng, n, frac, scale);
            let e = quant_error(&w, dt, 64, dq)?;
            rmses.push(e.mse.sqrt());
        }
        measured.push((name, stats::mean(&rmses)));
    }
    // two-anchor calibration: fit PPL = a·exp(k·rmse) through the paper's
    // Int4 (34.34) and NF4+DQ (27.41) endpoints; E2M1/E3M0 interpolate
    // through the *measured* error axis.
    let rmse_int4 = measured[0].1;
    let rmse_nf4 = measured[3].1;
    let k = (34.34_f64 / 27.41).ln() / (rmse_int4 - rmse_nf4);
    let mut rows = Vec::new();
    for (name, rmse) in measured {
        rows.push(Row {
            dtype: name.to_string(),
            mean_rmse: rmse,
            mean_ppl: 27.41 * (k * (rmse - rmse_nf4)).exp(),
        });
    }
    Ok(rows)
}

/// Render the Table 2 data-type comparison.
pub fn run(ctx: &Ctx) -> Result<String> {
    let rows = compute(ctx.seed)?;
    let paper = [34.34, 31.07, 29.48, 27.41];
    let table: Vec<Vec<String>> = rows
        .iter()
        .zip(paper.iter())
        .map(|(r, p)| {
            vec![
                r.dtype.clone(),
                format!("{:.4}", r.mean_rmse),
                fmt2(r.mean_ppl),
                fmt2(*p),
            ]
        })
        .collect();
    let mut out = render_table(
        "Table 2: Pile-CC mean perplexity by datatype (proxy)",
        &["Data type", "measured RMSE", "PPL (ours)", "PPL (paper)"],
        &table,
    );
    out.push_str(
        "\nnote: NF4+DQ best reproduces exactly (anchored); under our\n\
         synthetic weight model E2M1 measures lower error than E3M0 (and\n\
         E3M0 ~ Int4), whereas the paper's real-LLM evaluation has E3M0\n\
         ahead of E2M1 — E3M0's wide dynamic range only pays off under\n\
         real weight kurtosis/outlier structure we do not model. The\n\
         headline ordering (NF4 > FP4-family vs Int4, DQ free) holds.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_ordering() {
        let rows = compute(11).unwrap();
        let get = |n: &str| {
            rows.iter().find(|r| r.dtype.starts_with(n)).unwrap().mean_ppl
        };
        let nf4 = get("NFloat4");
        let int4 = get("Int4");
        assert!(nf4 < int4, "NF4+DQ {nf4} must beat Int4 {int4}");
        // every 4-bit float beats int4 too
        assert!(get("Float4 (E2M1)") < int4);
        // magnitudes in the paper's ballpark
        assert!(nf4 > 20.0 && nf4 < 32.0, "nf4 ppl {nf4}");
        assert!(int4 > 28.0 && int4 < 45.0, "int4 ppl {int4}");
    }
}
