//! Table 4: "Mean 5-shot MMLU test accuracy for LLaMA 7-65B models
//! finetuned with adapters on Alpaca and FLAN v2 for different data
//! types" — BFloat16 vs Float4 vs NFloat4+DQ.
//!
//! Hybrid: datatype deltas from measured quantization error with the
//! adapter-recovery coefficient (capability model); the headline claim —
//! NF4+DQ matches BF16 while FP4 trails by ~1pt — is independently
//! verified by *real* small-scale training in Table 3.

use anyhow::Result;

use crate::eval::capability::{mmlu, SIZES};
use crate::quant::codebook::DType;
use crate::util::stats;

use super::{fmt1, render_table, Ctx};

/// One table cell: projected MMLU for a size × dataset × datatype setting.
pub fn cell(size: &str, dataset: &str, dtype: Option<DType>, dq: bool,
            seed: u64) -> f64 {
    mmlu(size, dataset, dtype, dq, seed)
}

/// Run the experiment and render its report table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let variants: [(&str, Option<DType>, bool); 3] = [
        ("BFloat16", None, false),
        ("Float4", Some(DType::FP4E2M1), false),
        ("NFloat4 + DQ", Some(DType::NF4), true),
    ];
    let datasets = ["alpaca", "flan-v2"];
    let mut rows = Vec::new();
    let mut means: Vec<(String, f64)> = Vec::new();
    for (vi, (name, dt, dq)) in variants.iter().enumerate() {
        let mut row = vec![name.to_string()];
        let mut all = Vec::new();
        for (si, size) in SIZES.iter().enumerate() {
            for (di, ds) in datasets.iter().enumerate() {
                let v = cell(size, ds, *dt, *dq,
                             ctx.seed
                                 ^ ((vi as u64) << 16)
                                 ^ ((si as u64) << 8)
                                 ^ ((di as u64) << 4));
                all.push(v);
                row.push(fmt1(v));
            }
        }
        let m = stats::mean(&all);
        row.push(fmt1(m));
        means.push((name.to_string(), m));
        rows.push(row);
    }
    let mut headers = vec!["datatype".to_string()];
    for size in SIZES {
        for ds in ["Alpaca", "FLANv2"] {
            headers.push(format!("{size}/{ds}"));
        }
    }
    headers.push("Mean".to_string());
    let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut out = render_table(
        "Table 4: mean 5-shot MMLU by datatype after QLoRA finetuning",
        &href,
        &rows,
    );
    out.push_str(&format!(
        "\npaper means: BF16 53.0 | FP4 52.2 | NF4+DQ 53.1\n\
         ours:        {:.1} | {:.1} | {:.1}\n",
        means[0].1, means[1].1, means[2].1
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nf4_matches_bf16_fp4_lags() {
        let ctx = Ctx::analytic(5);
        let mut bf16 = Vec::new();
        let mut fp4 = Vec::new();
        let mut nf4 = Vec::new();
        let mut s = 0u64;
        for size in SIZES {
            for ds in ["alpaca", "flan-v2"] {
                s += 13; // decorrelate the per-cell noise draws
                bf16.push(cell(size, ds, None, false, 5 + s));
                fp4.push(cell(size, ds, Some(DType::FP4E2M1), false, 6 + s));
                nf4.push(cell(size, ds, Some(DType::NF4), true, 7 + s));
            }
        }
        let m = stats::mean;
        assert!((m(&nf4) - m(&bf16)).abs() < 0.6,
                "NF4+DQ {} vs BF16 {}", m(&nf4), m(&bf16));
        let lag = m(&bf16) - m(&fp4);
        assert!(lag > 0.4 && lag < 2.0, "FP4 lag {lag}");
        let _ = ctx;
    }
}
