//! Figure 2: "Using LoRA on all transformer layers is critical to match
//! 16-bit performance" — the LoRA-placement sweep (key+query / attention /
//! FFN / all / attention+FFN-output) against the tuned full-finetuning
//! baseline. **Real training runs** over the placement-sweep artifacts.

use anyhow::Result;

use crate::data::synthetic::{CorpusKind, EvalSuite};
use crate::util::stats;

use super::train_util::{default_steps, train_seeds};
use super::{render_table, Ctx};

/// LoRA-placement sweep roster: (label, artifact name).
pub fn placements() -> Vec<(&'static str, &'static str)> {
    vec![
        ("key+query (LoRA default)", "tiny_scope_qk"),
        ("all attention", "tiny_scope_attn"),
        ("all FFN", "tiny_scope_ffn"),
        ("attn + FFN output", "tiny_scope_attn_ffn_out"),
        ("ALL layers (QLoRA)", "tiny_scope_all"),
        ("16-bit full finetune", "tiny_fullft"),
    ]
}

/// Accuracy across seeds for one LoRA placement.
pub struct PlacementResult {
    /// placement label
    pub label: &'static str,
    /// held-out accuracy per seed
    pub accs: Vec<f64>,
}

/// Train every placement over `seeds` and collect accuracies.
pub fn compute(ctx: &Ctx, seeds: &[u64]) -> Result<Vec<PlacementResult>> {
    let steps = default_steps(ctx);
    let mut out = Vec::new();
    for (label, artifact) in placements() {
        let runs = train_seeds(ctx, artifact, CorpusKind::Alpaca,
                               EvalSuite::VicunaProxy, steps, seeds, false)?;
        out.push(PlacementResult {
            label,
            accs: runs.iter().map(|r| r.eval_acc as f64 * 100.0).collect(),
        });
    }
    Ok(out)
}

/// Render the Figure 2 placement table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let seeds: Vec<u64> = if ctx.fast { vec![1] } else { vec![1, 2, 3] };
    let results = compute(ctx, &seeds)?;
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            let per_seed = r
                .accs
                .iter()
                .map(|a| format!("{a:.1}"))
                .collect::<Vec<_>>()
                .join(" ");
            vec![
                r.label.to_string(),
                format!("{:.1}", stats::mean(&r.accs)),
                per_seed,
            ]
        })
        .collect();
    let mut out = render_table(
        "Figure 2: held-out accuracy by LoRA placement (real runs)",
        &["placement", "mean acc %", "per-seed"],
        &rows,
    );
    let all = stats::mean(&results[4].accs);
    let qk = stats::mean(&results[0].accs);
    let full = stats::mean(&results[5].accs);
    out.push_str(&format!(
        "\nclaim check: ALL-layers ({all:.1}) ≈ full finetune ({full:.1}); \
         key+query only ({qk:.1}) falls short — the paper's Figure 2.\n",
    ));
    Ok(out)
}
