//! Table 7: Elo tournaments under three judge/benchmark settings —
//! (Vicuna, human raters), (Vicuna, GPT-4), (OA 953 prompts, GPT-4) —
//! plus median rank, and the section 5.3 agreement statistics
//! (Kendall τ, Spearman ρ between judges; Fleiss κ among annotators).

use anyhow::Result;

use crate::elo::Tournament;
use crate::eval::judge::Judge;
use crate::eval::systems::roster;
use crate::util::rng::Rng;
use crate::util::stats;

use super::table1::play_matches;
use super::{render_table, Ctx};

/// One evaluation setting: judge x prompt set.
pub struct Setting {
    /// setting label
    pub label: &'static str,
    /// judge model producing the match outcomes
    pub judge: Judge,
    /// Vicuna prompts when true, OpenAssistant when false
    pub vicuna: bool,
    /// number of evaluation prompts
    pub prompts: usize,
}

/// The three paper settings (Vicuna/Human, Vicuna/GPT-4, OA/GPT-4).
pub fn settings() -> Vec<Setting> {
    vec![
        Setting { label: "Vicuna/Human", judge: Judge::human(), vicuna: true,
                  prompts: 80 },
        Setting { label: "Vicuna/GPT-4", judge: Judge::gpt4(), vicuna: true,
                  prompts: 80 },
        Setting { label: "OA/GPT-4", judge: Judge::gpt4(), vicuna: false,
                  prompts: 953 },
    ]
}

/// Render the Table 7 Elo tournament comparison.
pub fn run(ctx: &Ctx) -> Result<String> {
    let systems = roster();
    let orderings = if ctx.fast { 300 } else { 10_000 };
    let mut per_setting: Vec<Vec<(f64, usize)>> = Vec::new(); // (elo, rank)
    for (si, s) in settings().iter().enumerate() {
        let matches = play_matches(&systems, &s.judge, s.vicuna,
                                   s.prompts.min(if ctx.fast { 40 } else {
                                       s.prompts
                                   }),
                                   ctx.seed ^ ((si as u64) << 24));
        let mut t = Tournament::new(systems.len());
        for m in matches {
            t.add(m);
        }
        let res = t.run(orderings, ctx.seed ^ 0x7AB7 ^ si as u64);
        per_setting.push(res.iter().map(|r| (r.mean, r.rank)).collect());
    }
    let mut rows = Vec::new();
    for (i, sys) in systems.iter().enumerate() {
        let ranks: Vec<f64> =
            per_setting.iter().map(|s| s[i].1 as f64).collect();
        let mut row = vec![sys.name.to_string()];
        for s in &per_setting {
            row.push(format!("{:.0} ({})", s[i].0, s[i].1));
        }
        row.push(format!("{:.0}", stats::median(&ranks)));
        rows.push(row);
    }
    rows.sort_by_key(|r| r.last().unwrap().parse::<i64>().unwrap_or(99));
    let mut out = render_table(
        "Table 7: Elo by judge/benchmark (Elo (rank))",
        &["Model", "Vicuna/Human", "Vicuna/GPT-4", "OA/GPT-4", "MedianRank"],
        &rows,
    );

    // --- agreement statistics (section 5.3 / 6.2) ------------------------
    let human_elo: Vec<f64> = per_setting[0].iter().map(|x| x.0).collect();
    let gpt4_elo: Vec<f64> = per_setting[1].iter().map(|x| x.0).collect();
    let tau = stats::kendall_tau(&human_elo, &gpt4_elo);
    let rho = stats::spearman(&human_elo, &gpt4_elo);

    // example-level Fleiss κ among 3 human annotators on shared prompts
    let kappa = example_level_kappa(ctx.seed, if ctx.fast { 60 } else { 200 });
    // GPT-4 vs human-majority κ (2 "annotators": majority label + GPT-4)
    let kappa_x = gpt4_vs_human_kappa(ctx.seed, if ctx.fast { 60 } else { 200 });

    out.push_str(&format!(
        "\nsystem-level agreement human vs GPT-4: Kendall tau = {tau:.2} \
         (paper 0.43), Spearman rho = {rho:.2} (paper 0.55)\n\
         example-level Fleiss kappa, 3 humans: {kappa:.2} (paper 0.42)\n\
         GPT-4 vs human majority kappa: {kappa_x:.2} (paper 0.25)\n",
    ));
    Ok(out)
}

/// Sample per-prompt labels from 3 human annotators over close system
/// pairs and compute Fleiss κ (3 categories: A wins / B wins / tie).
pub fn example_level_kappa(seed: u64, prompts: usize) -> f64 {
    let systems = roster();
    let judge = Judge::human();
    let mut rng = Rng::new(seed ^ 0xF1E55);
    let mut counts = Vec::new();
    // uniform random pairs: the benchmark mixes easy and close matches;
    // per-prompt quality components are shared across the 3 annotators
    for _ in 0..prompts {
        let a = rng.below(systems.len());
        let b = (a + 1 + rng.below(systems.len() - 1)) % systems.len();
        let pa = Judge::prompt_effect(&mut rng);
        let pb = Judge::prompt_effect(&mut rng);
        let mut c = [0usize; 3];
        for _ in 0..3 {
            let o = judge.judge_pair_with_prompt(&systems[a], &systems[b],
                                                 true, pa, pb, &mut rng);
            match o {
                crate::elo::Outcome::WinA => c[0] += 1,
                crate::elo::Outcome::WinB => c[1] += 1,
                crate::elo::Outcome::Tie => c[2] += 1,
            }
        }
        counts.push(c.to_vec());
    }
    stats::fleiss_kappa(&counts)
}

/// κ between GPT-4 and the human majority vote on the same prompts.
pub fn gpt4_vs_human_kappa(seed: u64, prompts: usize) -> f64 {
    let systems = roster();
    let human = Judge::human();
    let gpt4 = Judge::gpt4();
    let mut rng = Rng::new(seed ^ 0x6EE4);
    let mut counts = Vec::new();
    for _ in 0..prompts {
        let a = rng.below(systems.len());
        let b = (a + 1 + rng.below(systems.len() - 1)) % systems.len();
        let pa = Judge::prompt_effect(&mut rng);
        let pb = Judge::prompt_effect(&mut rng);
        let mut votes = [0usize; 3];
        for _ in 0..3 {
            match human.judge_pair_with_prompt(&systems[a], &systems[b],
                                               true, pa, pb, &mut rng) {
                crate::elo::Outcome::WinA => votes[0] += 1,
                crate::elo::Outcome::WinB => votes[1] += 1,
                crate::elo::Outcome::Tie => votes[2] += 1,
            }
        }
        let majority = votes
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| **v)
            .unwrap()
            .0;
        // GPT-4 sees the same prompt but perceives quality its own way
        let g = match gpt4.judge_pair_with_prompt(&systems[a], &systems[b],
                                                  true, pa, pb, &mut rng) {
            crate::elo::Outcome::WinA => 0,
            crate::elo::Outcome::WinB => 1,
            crate::elo::Outcome::Tie => 2,
        };
        let mut c = vec![0usize; 3];
        c[majority] += 1;
        c[g] += 1;
        counts.push(c);
    }
    stats::fleiss_kappa(&counts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn agreement_is_moderate_not_perfect() {
        let k3 = example_level_kappa(1, 120);
        assert!(k3 > 0.1 && k3 < 0.9, "kappa {k3}");
        let kx = gpt4_vs_human_kappa(1, 120);
        assert!(kx < k3 + 0.25, "cross-judge kappa {kx} vs human {k3}");
    }
}
