//! Table 8: CrowS-Pairs bias evaluation. "A lower score indicates lower
//! likelihood of generating biased sequences."
//!
//! Simulation (DESIGN.md section 2): no CrowS data or pretrained models
//! here. Each system carries a latent per-category stereotype-preference
//! rate (calibrated to the paper's measurements); the probe samples N
//! stereotype/anti-stereotype pairs per category and reports the percent
//! preferring the stereotypical completion — the sampling machinery and
//! aggregate statistics are real. Headline under test: OASST1 finetuning
//! *reduces* bias scores far below the raw LLaMA base.

use anyhow::Result;

use crate::util::rng::Rng;
use crate::util::stats;

use super::{fmt1, render_table, Ctx};

/// CrowS-Pairs bias categories, paper row order.
pub const CATEGORIES: [&str; 9] = [
    "Gender", "Religion", "Race/Color", "Sexual orientation", "Age",
    "Nationality", "Disability", "Physical appearance",
    "Socioeconomic status",
];

/// Latent stereotype-preference rates (%) per system (paper Table 8).
pub fn profiles() -> Vec<(&'static str, [f64; 9])> {
    vec![
        ("LLaMA-65B", [70.6, 79.0, 57.0, 81.0, 70.1, 64.2, 66.7, 77.8, 71.5]),
        ("GPT-3", [62.6, 73.3, 64.7, 76.2, 64.4, 61.6, 76.7, 74.6, 73.8]),
        ("OPT-175B", [65.7, 68.6, 68.6, 78.6, 67.8, 62.9, 76.7, 76.2, 76.2]),
        ("Guanaco-65B", [47.5, 38.7, 45.3, 59.1, 36.3, 32.4, 33.9, 43.1, 55.3]),
    ]
}

/// Sample a probe: `n` pairs per category; returns measured percentages.
pub fn probe(latent: &[f64; 9], n: usize, seed: u64) -> [f64; 9] {
    let mut rng = Rng::new(seed);
    let mut out = [0.0; 9];
    for (i, &p) in latent.iter().enumerate() {
        let mut hits = 0usize;
        for _ in 0..n {
            if rng.bool(p / 100.0) {
                hits += 1;
            }
        }
        out[i] = 100.0 * hits as f64 / n as f64;
    }
    out
}

/// Run the experiment and render its report table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let n = if ctx.fast { 150 } else { 1000 };
    let mut cols = Vec::new();
    for (si, (_, latent)) in profiles().iter().enumerate() {
        cols.push(probe(latent, n, ctx.seed ^ ((si as u64) << 4)));
    }
    let mut rows = Vec::new();
    for (ci, cat) in CATEGORIES.iter().enumerate() {
        let mut row = vec![cat.to_string()];
        for col in &cols {
            row.push(fmt1(col[ci]));
        }
        rows.push(row);
    }
    let mut avg_row = vec!["Average".to_string()];
    for col in &cols {
        avg_row.push(fmt1(stats::mean(col)));
    }
    rows.push(avg_row);
    let mut headers = vec!["Category"];
    headers.extend(profiles().iter().map(|(n, _)| *n));
    let mut out = render_table(
        "Table 8: CrowS bias probe (% preferring stereotype; lower better)",
        &headers,
        &rows,
    );
    out.push_str(
        "\ncheck: Guanaco-65B average far below LLaMA-65B/GPT-3/OPT-175B\n\
         (paper: 43.5 vs 66.6/67.2/69.5 — OASST1 finetuning reduces bias).\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guanaco_least_biased() {
        let profs = profiles();
        let mut avgs = Vec::new();
        for (si, (_, latent)) in profs.iter().enumerate() {
            let got = probe(latent, 800, si as u64);
            avgs.push(stats::mean(&got));
        }
        let guanaco = avgs[3];
        for other in &avgs[..3] {
            assert!(guanaco + 10.0 < *other, "{guanaco} vs {other}");
        }
    }

    #[test]
    fn probe_concentrates_around_latent() {
        let latent = [50.0; 9];
        let got = probe(&latent, 2000, 9);
        for g in got {
            assert!((g - 50.0).abs() < 4.0);
        }
    }
}
