//! Table 3: "QLoRA replicates 16-bit LoRA and full-finetuning" — GLUE /
//! Super-NaturalInstructions comparison of BF16 full finetuning, BF16
//! LoRA, and QLoRA with Int8 / FP4 / NF4+DQ bases.
//!
//! **Real training runs** at reproduction scale: a tiny LLaMA-style model
//! finetuned on a synthetic task suite (the GLUE/SNI stand-ins) on the
//! Rust coordinator over the AOT train graphs. The claim under test is
//! exactly the paper's: adapter finetuning on a quantized base recovers
//! the 16-bit result.

use anyhow::Result;

use crate::data::synthetic::{CorpusKind, EvalSuite};
use crate::util::stats;

use super::train_util::{default_steps, train_seeds};
use super::{render_table, Ctx};

/// Accuracy statistics for one finetuning method.
pub struct MethodResult {
    /// method label
    pub method: &'static str,
    /// artifact the method trains
    pub artifact: &'static str,
    /// mean held-out accuracy across seeds
    pub acc_mean: f64,
    /// accuracy standard deviation across seeds
    pub acc_std: f64,
    /// mean final training loss
    pub loss: f64,
}

/// Method roster: (label, artifact name).
pub fn methods() -> Vec<(&'static str, &'static str)> {
    vec![
        ("BF16 full finetune", "tiny_fullft"),
        ("LoRA BF16", "tiny_lora16"),
        ("QLoRA Int8", "tiny_int8"),
        ("QLoRA FP4", "tiny_fp4"),
        ("QLoRA NF4", "tiny_nf4"),
        ("QLoRA NF4 + DQ", "tiny_scope_all"),
    ]
}

/// Train every method over `seeds` and collect statistics.
pub fn compute(ctx: &Ctx, seeds: &[u64]) -> Result<Vec<MethodResult>> {
    let steps = default_steps(ctx);
    let mut out = Vec::new();
    for (method, artifact) in methods() {
        let runs = train_seeds(ctx, artifact, CorpusKind::Alpaca,
                               EvalSuite::VicunaProxy, steps, seeds, false)?;
        let accs: Vec<f64> = runs.iter().map(|r| r.eval_acc as f64).collect();
        let losses: Vec<f64> =
            runs.iter().map(|r| r.eval_loss as f64).collect();
        out.push(MethodResult {
            method,
            artifact,
            acc_mean: stats::mean(&accs) * 100.0,
            acc_std: stats::std_dev(&accs) * 100.0,
            loss: stats::mean(&losses),
        });
    }
    Ok(out)
}

/// Render the Table 3 method comparison.
pub fn run(ctx: &Ctx) -> Result<String> {
    let seeds: Vec<u64> = if ctx.fast { vec![1] } else { vec![1, 2, 3] };
    let results = compute(ctx, &seeds)?;
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.method.to_string(),
                format!("{:.1} ± {:.1}", r.acc_mean, r.acc_std),
                format!("{:.3}", r.loss),
            ]
        })
        .collect();
    let mut out = render_table(
        "Table 3: held-out token accuracy by finetuning method (real runs)",
        &["Method", "accuracy %", "eval loss"],
        &rows,
    );
    let full = results[0].acc_mean;
    let spread: f64 = results
        .iter()
        .map(|r| (r.acc_mean - full).abs())
        .fold(0.0, f64::max);
    out.push_str(&format!(
        "\nclaim check: all adapter/quantized methods within {spread:.1}pt \
         of the 16-bit full-finetuning baseline\n\
         (paper Table 3: 16/8/4-bit adapter methods replicate BF16).\n",
    ));
    Ok(out)
}
