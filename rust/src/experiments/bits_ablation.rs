//! Extension experiment (paper section 8, "Limitations"): the paper asks
//! whether *more aggressive* quantization (e.g. 3-bit base models) could
//! still recover 16-bit performance after adapter finetuning. We sweep
//! k-bit NormalFloat (NFk, k = 2..8 — the Eq. 4 construction generalized)
//! and report measured round-trip error, the projected MMLU penalty
//! before/after adapter recovery (the Table-4-calibrated map), and the
//! total weights+constants memory at 65B scale.

use anyhow::Result;

use crate::quant::codebook::nfk_codebook;
use crate::quant::error::synthetic_llm_weights;
use crate::quant::{dequantize_blockwise_fused, quantize_blockwise_fused};
use crate::util::rng::Rng;

use super::{render_table, Ctx};

/// One NFk bit-width's measured error and projected quality.
pub struct BitsRow {
    /// NFk codebook bit width (k)
    pub bits: u32,
    /// measured round-trip quantization RMSE
    pub rmse: f64,
    /// projected MMLU penalty before adapter finetuning
    pub penalty_raw: f64,
    /// projected MMLU penalty after adapter recovery
    pub penalty_finetuned: f64,
    /// weights + quantization constants at 65B scale, gigabytes
    pub gb_65b: f64,
}

/// Sweep NFk bit widths over synthetic LLM weights.
pub fn compute(seed: u64) -> Result<Vec<BitsRow>> {
    let mut rng = Rng::new(seed);
    let w = synthetic_llm_weights(&mut rng, 64 * 1024, 0.01, 5.0);
    // NF4+DQ reference error for the recovery-calibrated penalty map
    // (same coefficients as eval::capability::dtype_penalty)
    let rmse_of = |bits: u32| -> Result<f64> {
        // fused kernels (NFk books with k > 4 exercise the generic
        // encoder; k <= 4 the branchless 16-entry path)
        let cb = nfk_codebook(bits);
        let (c, a) = quantize_blockwise_fused(&w, &cb, 64, None)?;
        let y = dequantize_blockwise_fused(&c, &a, &cb, 64, None)?;
        Ok((w
            .iter()
            .zip(y.iter())
            .map(|(p, q)| ((p - q) as f64).powi(2))
            .sum::<f64>()
            / w.len() as f64)
            .sqrt())
    };
    let ref_rmse = rmse_of(4)?;
    let params_65b = 65.2e9_f64;
    let mut rows = Vec::new();
    for bits in 2..=8u32 {
        let rmse = rmse_of(bits)?;
        let excess = (rmse - ref_rmse).max(0.0);
        rows.push(BitsRow {
            bits,
            rmse,
            penalty_raw: 0.8 + excess * 180.0,
            penalty_finetuned: 0.15 + excess * 140.0,
            gb_65b: params_65b * (bits as f64 + 0.127) / 8.0 / 1e9,
        });
    }
    Ok(rows)
}

/// Render the bit-width ablation table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let rows = compute(ctx.seed)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("NF{}", r.bits),
                format!("{:.4}", r.rmse),
                format!("{:.1}", r.penalty_raw),
                format!("{:.1}", r.penalty_finetuned),
                format!("{:.1}", r.gb_65b),
            ]
        })
        .collect();
    let mut out = render_table(
        "Extension: NFk bit-width ablation (paper section 8 future work)",
        &["dtype", "weight RMSE", "raw MMLU pen.", "finetuned pen.",
          "65B weights GB"],
        &table,
    );
    out.push_str(
        "\nreading: under the linear-recovery map NF3 still costs ~20pt at\n\
         ~25% less memory than NF4 — i.e. adapter finetuning as modeled\n\
         here does NOT close the 3-bit gap; validating the paper's\n\
         section-8 conjecture would need recovery to grow with the error\n\
         (e.g. GPTQ-style rounding). NF2 collapses outright; NF5+ buys\n\
         nothing once adapters recover NF4.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_bits() {
        let rows = compute(3).unwrap();
        for w in rows.windows(2) {
            assert!(w[1].rmse < w[0].rmse);
            assert!(w[1].penalty_finetuned <= w[0].penalty_finetuned);
            assert!(w[1].gb_65b > w[0].gb_65b);
        }
        // NF4 recovers (small penalty), NF2 does not
        let nf4 = &rows[2];
        let nf2 = &rows[0];
        assert!(nf4.penalty_finetuned < 0.5);
        assert!(nf2.penalty_finetuned > 5.0);
    }
}
