//! Tables 12/13 (appendix D): aggregated pairwise GPT-4 judgments —
//! matrix of (wins_x − wins_y)/total per system pair — and the complete
//! ordering they induce, with a transitivity check (the paper: "it is
//! clear these judgments are transitive").

use anyhow::Result;

use crate::elo::Outcome;
use crate::eval::judge::Judge;
use crate::eval::systems::roster;
use crate::util::rng::Rng;

use super::{render_table, Ctx};

/// matrix[x][y] = (#x better − #y better) / total over both orders.
pub fn pairwise_matrix(prompts: usize, seed: u64) -> (Vec<&'static str>, Vec<Vec<f64>>) {
    let systems = roster();
    let judge = Judge::gpt4();
    let n = systems.len();
    let mut m = vec![vec![0.0; n]; n];
    let mut rng = Rng::new(seed);
    for a in 0..n {
        for b in (a + 1)..n {
            let mut net = 0i64;
            let mut total = 0i64;
            for _ in 0..prompts {
                for first in [true, false] {
                    let (x, y) = if first { (a, b) } else { (b, a) };
                    let o = judge.judge_pair(&systems[x], &systems[y], true,
                                             &mut rng);
                    let delta = match o {
                        Outcome::WinA => 1,
                        Outcome::WinB => -1,
                        Outcome::Tie => 0,
                    };
                    net += if first { delta } else { -delta };
                    total += 1;
                }
            }
            let v = net as f64 / total as f64;
            m[a][b] = v;
            m[b][a] = -v;
        }
    }
    (systems.iter().map(|s| s.name).collect(), m)
}

/// Ordering induced by mean net win rate; returns (order, is_transitive).
pub fn induced_ordering(m: &[Vec<f64>]) -> (Vec<usize>, bool) {
    let n = m.len();
    let mut idx: Vec<usize> = (0..n).collect();
    let mean_net: Vec<f64> = (0..n)
        .map(|i| m[i].iter().sum::<f64>() / (n - 1) as f64)
        .collect();
    idx.sort_by(|&a, &b| mean_net[b].total_cmp(&mean_net[a]));
    // transitive iff every pair in the sorted order has non-negative net
    let mut transitive = true;
    for i in 0..n {
        for j in (i + 1)..n {
            if m[idx[i]][idx[j]] < 0.0 {
                transitive = false;
            }
        }
    }
    (idx, transitive)
}

/// Run the experiment and render its report table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let prompts = if ctx.fast { 30 } else { 80 };
    let (names, m) = pairwise_matrix(prompts, ctx.seed);
    let short: Vec<String> = names
        .iter()
        .map(|n| n.chars().take(9).collect::<String>())
        .collect();
    let mut rows = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for j in 0..names.len() {
            row.push(if i == j {
                "-".into()
            } else {
                format!("{:+.2}", m[i][j])
            });
        }
        rows.push(row);
    }
    let mut headers = vec!["Model".to_string()];
    headers.extend(short);
    let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut out = render_table(
        "Table 12: aggregated pairwise GPT-4 judgments (net win rate)",
        &href,
        &rows,
    );
    let (order, transitive) = induced_ordering(&m);
    out.push_str("\nTable 13: induced complete ordering:\n");
    for (rank, &i) in order.iter().enumerate() {
        out.push_str(&format!("  {}. {}\n", rank + 1, names[i]));
    }
    out.push_str(&format!(
        "transitive: {transitive} (paper: transitive)\n"
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_antisymmetric_and_mostly_transitive() {
        let (_names, m) = pairwise_matrix(40, 5);
        for i in 0..m.len() {
            for j in 0..m.len() {
                assert!((m[i][j] + m[j][i]).abs() < 1e-12);
            }
        }
        let (order, _transitive) = induced_ordering(&m);
        // GPT-4 (index 0 in roster) must rank first regardless
        assert_eq!(order[0], 0);
    }
}
