//! Shared helpers for the real-training experiments (Tables 3/10/11,
//! Figures 2/4): build a corpus, train an artifact through an
//! `engine::Engine` + `Trainer` pair, and measure held-out token accuracy.

use std::rc::Rc;

use anyhow::Result;

use crate::coordinator::trainer::{TrainOptions, Trainer};
use crate::data::batching::Batcher;
use crate::data::synthetic::{corpus, eval_set, CorpusKind, EvalSuite};
use crate::data::tokenizer::Tokenizer;
use crate::engine::Engine;
use crate::runtime::artifact::Manifest;
use crate::runtime::client::Runtime;

use super::Ctx;

/// Summary of one real training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// smoothed final training loss
    pub final_loss: f32,
    /// held-out loss at the end of training
    pub eval_loss: f32,
    /// held-out token accuracy at the end of training
    pub eval_acc: f32,
    /// optimizer steps run
    pub steps: usize,
    /// mean wall time per step, milliseconds
    pub mean_step_ms: f64,
}

/// Train `artifact` on `kind` for `steps`, eval on `suite`.
#[allow(clippy::too_many_arguments)]
pub fn train_once(
    rt: &Rc<Runtime>,
    manifest: &Manifest,
    artifact: &str,
    kind: CorpusKind,
    corpus_size: usize,
    suite: EvalSuite,
    steps: usize,
    data_seed: u64,
    train_on_source: bool,
) -> Result<RunResult> {
    let engine = Engine::new(rt.clone(), manifest, artifact)?;
    let mut trainer = Trainer::new(&engine)?;
    let cfg = trainer.spec().cfg.clone();
    let tok = Tokenizer::new(cfg.vocab);
    let train_ds = corpus(kind, corpus_size, data_seed);
    let train_b = Batcher::new(&train_ds, tok.clone(), cfg.batch, cfg.seq_len,
                               train_on_source);
    let eval_ds = eval_set(suite, cfg.batch * 6, data_seed ^ 0xEEE);
    let eval_b = Batcher::new(&eval_ds, tok, cfg.batch, cfg.seq_len, false);
    let opts = TrainOptions {
        steps,
        eval_every: 0,
        seed: data_seed,
        ..TrainOptions::default()
    };
    let log = trainer.train(&train_b, None, &opts)?;
    let (eval_loss, eval_acc) = trainer.eval_all(&eval_b, 0)?;
    Ok(RunResult {
        final_loss: log.smoothed_final_loss(10),
        eval_loss,
        eval_acc,
        steps,
        mean_step_ms: log.mean_step_time().as_secs_f64() * 1e3,
    })
}

/// Mean eval accuracy over `seeds` data seeds.
#[allow(clippy::too_many_arguments)]
pub fn train_seeds(
    ctx: &Ctx,
    artifact: &str,
    kind: CorpusKind,
    suite: EvalSuite,
    steps: usize,
    seeds: &[u64],
    train_on_source: bool,
) -> Result<Vec<RunResult>> {
    let (rt, manifest) = ctx.runtime()?;
    let corpus_size = 512;
    seeds
        .iter()
        .map(|&s| {
            train_once(rt, manifest, artifact, kind, corpus_size,
                       match suite {
                           EvalSuite::MmluProxy => EvalSuite::MmluProxy,
                           EvalSuite::VicunaProxy => EvalSuite::VicunaProxy,
                       },
                       steps, ctx.seed ^ s, train_on_source)
        })
        .collect()
}

/// Default step counts: enough to separate configs, small enough for CI.
pub fn default_steps(ctx: &Ctx) -> usize {
    if ctx.fast {
        40
    } else {
        140
    }
}
