//! Figure 3: "Mean zero-shot accuracy over Winogrande, HellaSwag, PiQA,
//! Arc-Easy, and Arc-Challenge using LLaMA models with different 4-bit
//! data types" — accuracy vs model size, one series per datatype.
//!
//! Hybrid reproduction: the datatype axis comes from *measured*
//! quantization error (inference-time, no finetuning recovery); the size
//! axis is a scaling baseline (`eval::capability::zero_shot`). The error
//! measurements run on the fused multicore kernels (`quant::kernels`, via
//! `quant_error`).

use anyhow::Result;

use crate::eval::capability::zero_shot;
use crate::quant::codebook::DType;

use super::{render_table, Ctx};

/// Model sizes (billions of parameters) on the figure's x-axis.
pub const SIZES_B: [f64; 6] = [0.125, 0.35, 1.3, 6.7, 13.0, 65.0];

/// Zero-shot accuracy (%) at each size for one datatype.
pub fn series(dtype: DType, double_quant: bool, seed: u64) -> Vec<f64> {
    SIZES_B
        .iter()
        .map(|&s| zero_shot(s, dtype, double_quant, seed) * 100.0)
        .collect()
}

/// Run the experiment and render its report table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let variants: [(&str, DType, bool); 4] = [
        ("Int4", DType::Int4, false),
        ("FP4 (E2M1)", DType::FP4E2M1, false),
        ("NF4", DType::NF4, false),
        ("NF4 + DQ", DType::NF4, true),
    ];
    let mut rows = Vec::new();
    for (name, dt, dq) in variants {
        let s = series(dt, dq, ctx.seed);
        let mut row = vec![name.to_string()];
        row.extend(s.iter().map(|v| format!("{v:.1}")));
        rows.push(row);
    }
    let headers: Vec<String> = std::iter::once("datatype".to_string())
        .chain(SIZES_B.iter().map(|s| format!("{s}B")))
        .collect();
    let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut out = render_table(
        "Figure 3: mean zero-shot accuracy vs size per 4-bit datatype",
        &href,
        &rows,
    );
    out.push_str("\nshape check: NF4 > FP4 > Int4 at every size; DQ ~ free.\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nf4_dominates_everywhere() {
        let nf4 = series(DType::NF4, false, 3);
        let fp4 = series(DType::FP4E2M1, false, 3);
        let int4 = series(DType::Int4, false, 3);
        for i in 0..SIZES_B.len() {
            assert!(nf4[i] > fp4[i], "size {i}");
            assert!(fp4[i] > int4[i], "size {i}");
        }
    }

    #[test]
    fn dq_within_noise() {
        let a = series(DType::NF4, false, 4);
        let b = series(DType::NF4, true, 4);
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() < 1.0);
        }
    }
}
