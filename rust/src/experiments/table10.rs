//! Table 10 (appendix B.3): "only training on the target is beneficial" —
//! the train-on-source-and-target vs train-on-target-only ablation.
//! **Real training runs**: the loss-mask toggle in the batcher is exactly
//! the mechanism under test.

use anyhow::Result;

use crate::data::synthetic::{CorpusKind, EvalSuite};
use crate::util::stats;

use super::train_util::{default_steps, train_seeds};
use super::{render_table, Ctx};

/// Train the source-masking ablation; returns `(setting, accuracies %)` rows.
pub fn compute(ctx: &Ctx, seeds: &[u64]) -> Result<Vec<(String, Vec<f64>)>> {
    let steps = default_steps(ctx);
    let datasets = [
        CorpusKind::UnnaturalInstructions,
        CorpusKind::Chip2,
        CorpusKind::Alpaca,
        CorpusKind::FlanV2,
    ];
    let mut out = Vec::new();
    for train_on_source in [true, false] {
        let label = if train_on_source {
            "Train on source and target"
        } else {
            "Train on target"
        };
        let mut accs = Vec::new();
        for kind in datasets {
            let runs = train_seeds(ctx, "tiny_scope_all", kind,
                                   EvalSuite::MmluProxy, steps, seeds,
                                   train_on_source)?;
            accs.push(stats::mean(
                &runs.iter().map(|r| r.eval_acc as f64 * 100.0)
                    .collect::<Vec<_>>(),
            ));
        }
        out.push((label.to_string(), accs));
    }
    Ok(out)
}

/// Run the experiment and render its report table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let seeds: Vec<u64> = if ctx.fast { vec![1] } else { vec![1, 2] };
    let results = compute(ctx, &seeds)?;
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|(label, accs)| {
            let mut row = vec![label.clone()];
            row.extend(accs.iter().map(|a| format!("{a:.1}")));
            row.push(format!("{:.1}", stats::mean(accs)));
            row
        })
        .collect();
    let mut out = render_table(
        "Table 10: supervise instruction+response vs response only",
        &["Setting", "Unnatural", "Chip2", "Alpaca", "FLANv2", "Mean"],
        &rows,
    );
    let both = stats::mean(&results[0].1);
    let target = stats::mean(&results[1].1);
    out.push_str(&format!(
        "\nclaim check: target-only ({target:.1}) >= source+target \
         ({both:.1}) (paper: 38.6 vs 37.5 mean MMLU).\n",
    ));
    Ok(out)
}
