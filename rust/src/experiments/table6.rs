//! Table 6: "Zero-shot Vicuna benchmark scores as a percentage of the
//! score obtained by ChatGPT evaluated by GPT-4" — the score-mode (1–10
//! rating) protocol, both presentation orders, with 95% CIs, plus the
//! memory column from the analytical memory model.

use anyhow::Result;

use crate::eval::judge::Judge;
use crate::eval::systems::System;
use crate::memory::{
    weights_footprint, Strategy, LLAMA_13B, LLAMA_33B, LLAMA_65B, LLAMA_7B,
};
use crate::util::rng::Rng;
use crate::util::stats;

use super::{render_table, Ctx};

/// Extended Table 6 roster: dataset × size variants with latent quality
/// calibrated from the paper's mean relative scores.
pub struct Entry {
    /// dataset / variant label
    pub label: &'static str,
    /// model size label (e.g. "7B")
    pub params: &'static str,
    /// finetuning precision in bits
    pub bits: u32,
    /// weights footprint, gigabytes
    pub mem_gb: f64,
    /// latent Elo-scale quality
    pub quality: f64,
    /// paper-reported mean relative score, percent
    pub paper_mean: f64,
}

fn q_of_pct(pct: f64) -> f64 {
    // inverse of the judge's score map around ChatGPT ≈ 7.0/10:
    // pct = 100 * score/7.0, score = (q-1000)/150 + 7
    let score = pct / 100.0 * 7.0;
    (score - 7.0) * 150.0 + 1000.0
}

/// The extended Table 6 roster.
pub fn entries() -> Vec<Entry> {
    let gb = |spec, four: bool| {
        let s = if four {
            Strategy::QLoRA4 { r: 64, double_quant: true }
        } else {
            Strategy::Full16
        };
        weights_footprint(&spec, s) as f64 / 1e9
    };
    vec![
        Entry { label: "GPT-4", params: "-", bits: 0, mem_gb: 0.0,
                quality: q_of_pct(114.5) + 170.0, paper_mean: 114.5 },
        Entry { label: "Bard", params: "-", bits: 0, mem_gb: 0.0,
                quality: q_of_pct(94.8), paper_mean: 94.8 },
        Entry { label: "Guanaco 65B", params: "65B", bits: 4,
                mem_gb: gb(LLAMA_65B, true), quality: q_of_pct(99.3),
                paper_mean: 99.3 },
        Entry { label: "Alpaca 65B", params: "65B", bits: 4,
                mem_gb: gb(LLAMA_65B, true), quality: q_of_pct(70.7),
                paper_mean: 70.7 },
        Entry { label: "FLAN v2 65B", params: "65B", bits: 4,
                mem_gb: gb(LLAMA_65B, true), quality: q_of_pct(48.4),
                paper_mean: 48.4 },
        Entry { label: "Guanaco 33B", params: "33B", bits: 4,
                mem_gb: gb(LLAMA_33B, true), quality: q_of_pct(97.8),
                paper_mean: 97.8 },
        Entry { label: "Open Assistant 33B", params: "33B", bits: 16,
                mem_gb: gb(LLAMA_33B, false), quality: q_of_pct(94.9),
                paper_mean: 94.9 },
        Entry { label: "Vicuna 13B", params: "13B", bits: 16,
                mem_gb: gb(LLAMA_13B, false), quality: q_of_pct(94.9),
                paper_mean: 94.9 },
        Entry { label: "Guanaco 13B", params: "13B", bits: 4,
                mem_gb: gb(LLAMA_13B, true), quality: q_of_pct(90.4),
                paper_mean: 90.4 },
        Entry { label: "HH-RLHF 13B", params: "13B", bits: 4,
                mem_gb: gb(LLAMA_13B, true), quality: q_of_pct(62.5),
                paper_mean: 62.5 },
        Entry { label: "Guanaco 7B", params: "7B", bits: 4,
                mem_gb: gb(LLAMA_7B, true), quality: q_of_pct(87.0),
                paper_mean: 87.0 },
        Entry { label: "Alpaca 7B", params: "7B", bits: 4,
                mem_gb: gb(LLAMA_7B, true), quality: q_of_pct(64.4),
                paper_mean: 64.4 },
        Entry { label: "FLAN v2 7B", params: "7B", bits: 4,
                mem_gb: gb(LLAMA_7B, true), quality: q_of_pct(44.8),
                paper_mean: 44.8 },
    ]
}

/// Run the score-mode protocol for one system vs ChatGPT.
/// Returns (mean_pct, ci95, pct_order1, pct_order2).
pub fn score_system(
    e: &Entry,
    judge: &Judge,
    prompts: usize,
    seed: u64,
) -> (f64, f64, f64, f64) {
    let chatgpt = System {
        name: "ChatGPT",
        params_b: None,
        bits: None,
        mem_gb: None,
        vicuna_quality: 1000.0,
        oa_quality: 1000.0,
        human_quality: 1000.0,
        is_gpt4: false,
    };
    let sys = System {
        name: e.label,
        params_b: None,
        bits: Some(e.bits),
        mem_gb: Some(e.mem_gb),
        vicuna_quality: e.quality,
        oa_quality: e.quality,
        human_quality: e.quality,
        is_gpt4: e.label == "GPT-4",
    };
    let mut rng = Rng::new(seed);
    let mut per_order = [Vec::new(), Vec::new()];
    for _ in 0..prompts {
        for (oi, sys_first) in [(0usize, true), (1usize, false)] {
            let (s, c) = judge.score_vs_chatgpt(&sys, &chatgpt, sys_first,
                                                &mut rng);
            per_order[oi].push(100.0 * s / c.max(0.1));
        }
    }
    let o1 = stats::mean(&per_order[0]);
    let o2 = stats::mean(&per_order[1]);
    let all: Vec<f64> = per_order.concat();
    (stats::mean(&all), stats::ci95_halfwidth(&all), o1, o2)
}

/// Render the Table 6 benchmark comparison.
pub fn run(ctx: &Ctx) -> Result<String> {
    let judge = Judge::gpt4();
    let prompts = if ctx.fast { 20 } else { 80 };
    let mut rows = Vec::new();
    for (i, e) in entries().iter().enumerate() {
        let (mean, ci, o1, o2) =
            score_system(e, &judge, prompts, ctx.seed ^ ((i as u64) << 20));
        rows.push(vec![
            e.label.to_string(),
            e.params.to_string(),
            if e.bits == 0 { "-".into() } else { format!("{}-bit", e.bits) },
            if e.mem_gb == 0.0 {
                "-".into()
            } else {
                format!("{:.0} GB", e.mem_gb)
            },
            format!("{o1:.1}%"),
            format!("{o2:.1}%"),
            format!("{mean:.1}%"),
            format!("±{ci:.1}%"),
            format!("{:.1}%", e.paper_mean),
        ]);
    }
    let mut out = render_table(
        "Table 6: Vicuna score as % of ChatGPT (GPT-4 judge, both orders)",
        &["Model", "Params", "Bits", "Memory", "first", "second", "Mean",
          "95%CI", "paper"],
        &rows,
    );
    out.push_str(
        "\nchecks: Guanaco-65B ≈ 99% of ChatGPT; 4-bit Guanaco-33B beats\n\
         16-bit Vicuna-13B while using less memory; order columns differ\n\
         (the GPT-4 order bias the paper reports); wide CIs motivate the\n\
         Elo protocol of Tables 1/7.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guanaco65_close_to_chatgpt_and_order_bias_visible() {
        let e = entries();
        let g65 = e.iter().find(|x| x.label == "Guanaco 65B").unwrap();
        let (mean, _ci, o1, o2) = score_system(g65, &Judge::gpt4(), 80, 3);
        assert!((mean - 99.3).abs() < 8.0, "mean {mean}");
        assert!(o1 > o2, "first-position bias: {o1} vs {o2}");
    }

    #[test]
    fn memory_column_4bit_vs_16bit() {
        let e = entries();
        let g33 = e.iter().find(|x| x.label == "Guanaco 33B").unwrap();
        let v13 = e.iter().find(|x| x.label == "Vicuna 13B").unwrap();
        assert!(g33.mem_gb < v13.mem_gb);
    }
}
