//! Paged-optimizer experiment (paper section 3 + section 4's runtime
//! analysis): (a) without paging, a long-sequence spike OOMs; with paging
//! the run completes; (b) at batch 16 / normal sequences, paged == regular
//! speed (zero steady-state stall).

use anyhow::Result;

use crate::paged::optimizer::PagedOptimizerSim;
use crate::util::rng::Rng;

use super::{render_table, Ctx};

/// Outcome of one paging scenario.
pub struct ScenarioResult {
    /// scenario label
    pub label: String,
    /// whether the run would OOM without paging
    pub would_oom: bool,
    /// page faults taken with paging on
    pub faults: u64,
    /// mean migration stall per step, microseconds
    pub stall_per_step_us: f64,
    /// steps whose activation spike forced evictions
    pub spike_steps: u64,
}

/// Simulate one workload against a device budget.
pub fn scenario(
    label: &str,
    device_mb: usize,
    opt_mb: usize,
    seq_dist: &[(usize, f64)],
    steps: usize,
    seed: u64,
) -> ScenarioResult {
    let mut sim =
        PagedOptimizerSim::new(device_mb << 20, 0, opt_mb << 20, 4096, 32);
    let mut rng = Rng::new(seed);
    let weights: Vec<f64> = seq_dist.iter().map(|(_, w)| *w).collect();
    let lens: Vec<usize> = seq_dist.iter().map(|(l, _)| *l).collect();
    let max_len = *lens.iter().max().unwrap();
    let mut warm_stall = 0.0;
    for step in 0..steps {
        let len = lens[rng.categorical(&weights)];
        sim.on_step(len);
        if step == steps / 5 {
            warm_stall = sim.stats.stall_us; // after warmup
        }
    }
    let steady_steps = (steps - steps / 5).max(1) as f64;
    ScenarioResult {
        label: label.to_string(),
        would_oom: sim.would_oom_without_paging(max_len),
        faults: sim.stats.faults,
        stall_per_step_us: (sim.stats.stall_us - warm_stall) / steady_steps,
        spike_steps: sim.stats.spike_steps,
    }
}

/// Render the paged-optimizer scenario table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let steps = if ctx.fast { 100 } else { 400 };
    let scenarios = vec![
        // plenty of memory, short seqs: paging silent (bs=16 claim)
        scenario("bs16 short-seq, roomy", 4096, 1024,
                 &[(512, 1.0)], steps, ctx.seed),
        // tight memory, occasional long seq: spikes absorbed
        scenario("rare long-seq spikes, tight", 1300, 1024,
                 &[(512, 0.95), (4096, 0.05)], steps, ctx.seed ^ 1),
        // pathological: every step spikes (thrash regime)
        scenario("every-step long seq (thrash)", 1300, 1024,
                 &[(4096, 1.0)], steps, ctx.seed ^ 2),
    ];
    let rows: Vec<Vec<String>> = scenarios
        .iter()
        .map(|s| {
            vec![
                s.label.clone(),
                if s.would_oom { "OOM".into() } else { "fits".into() },
                format!("{}", s.faults),
                format!("{}", s.spike_steps),
                format!("{:.1}", s.stall_per_step_us),
            ]
        })
        .collect();
    let mut out = render_table(
        "Paged optimizers: spike absorption vs overhead",
        &["scenario", "non-paged", "faults", "spike steps", "stall µs/step"],
        &rows,
    );
    out.push_str(
        "\nchecks: roomy case has ~zero steady-state stall (paper: bs=16\n\
         paged == regular); tight case *would OOM without paging* but\n\
         completes with bounded stall; thrash case shows the cost regime\n\
         the paper leaves to future work.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_claims_hold() {
        let roomy = scenario("roomy", 4096, 1024, &[(512, 1.0)], 200, 1);
        assert!(!roomy.would_oom);
        assert!(roomy.stall_per_step_us < 1.0, "{}", roomy.stall_per_step_us);

        let tight = scenario("tight", 1300, 1024,
                             &[(512, 0.95), (4096, 0.05)], 300, 2);
        assert!(tight.would_oom, "long seq must OOM without paging");
        assert!(tight.spike_steps > 0);

        let thrash = scenario("thrash", 1300, 1024, &[(4096, 1.0)], 200, 3);
        assert!(thrash.stall_per_step_us > tight.stall_per_step_us);
    }
}
