//! Experiment harness: one module per table/figure of the paper
//! (DESIGN.md section 6 maps each to its generator). Every experiment
//! renders the same rows the paper reports; `runner` dispatches by id and
//! archives outputs under `results/`.

pub mod bits_ablation;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig6;
pub mod paged_exp;
pub mod runner;
pub mod table1;
pub mod table10;
pub mod table11;
pub mod table12_13;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;
pub mod table7;
pub mod table8;
pub mod train_util;

use std::rc::Rc;

use crate::runtime::artifact::Manifest;
use crate::runtime::client::Runtime;

/// Shared context. Training-based experiments need the runtime+manifest;
/// analytic/simulated ones run standalone. The runtime is `Rc`-shared so
/// experiments can stack per-artifact `engine::Engine`s over one PJRT
/// client (and its executable cache).
pub struct Ctx {
    /// PJRT runtime, present when artifacts are available
    pub rt: Option<Rc<Runtime>>,
    /// parsed artifact manifest, present alongside `rt`
    pub manifest: Option<Manifest>,
    /// global seed
    pub seed: u64,
    /// scale factor for expensive loops (1.0 = paper-faithful counts)
    pub fast: bool,
}

impl Ctx {
    /// A context for experiments that need no runtime or artifacts.
    pub fn analytic(seed: u64) -> Ctx {
        Ctx { rt: None, manifest: None, seed, fast: false }
    }

    /// The runtime + manifest, or a run-`make artifacts` error for analytic contexts.
    pub fn runtime(&self) -> anyhow::Result<(&Rc<Runtime>, &Manifest)> {
        match (&self.rt, &self.manifest) {
            (Some(r), Some(m)) => Ok((r, m)),
            _ => anyhow::bail!(
                "this experiment trains models and needs artifacts — \
                 run `make artifacts` and pass --artifacts <dir>"
            ),
        }
    }
}

/// Fixed-width table rendering.
pub fn render_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = format!("== {title} ==\n");
    let line = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect::<Vec<_>>()
            .join("  ")
    };
    out.push_str(&line(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    out.push('\n');
    for row in rows {
        out.push_str(&line(row, &widths));
        out.push('\n');
    }
    out
}

/// Format with one decimal place (table cells).
pub fn fmt1(x: f64) -> String {
    format!("{x:.1}")
}

/// Format with two decimal places (table cells).
pub fn fmt2(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let s = render_table(
            "t",
            &["a", "long-header"],
            &[vec!["1".into(), "2".into()],
              vec!["wide-cell".into(), "3".into()]],
        );
        assert!(s.contains("== t =="));
        assert!(s.lines().count() >= 4);
    }
}
