//! Experiment dispatch: `qlora experiment <id|all>` runs a generator and
//! archives its output under `results/<id>.txt`.

use std::path::Path;

use anyhow::{bail, Result};

use super::Ctx;

/// An experiment entry point: context in, rendered report out.
pub type ExpFn = fn(&Ctx) -> Result<String>;

/// (id, needs_artifacts, description, function)
pub fn registry() -> Vec<(&'static str, bool, &'static str, ExpFn)> {
    vec![
        ("table1", false, "Elo leaderboard, GPT-4 judge (Vicuna)",
         super::table1::run as ExpFn),
        ("table2", false, "Pile-CC perplexity by 4-bit datatype",
         super::table2::run),
        ("table3", true, "QLoRA vs 16-bit methods (real training)",
         super::table3::run),
        ("table4", false, "MMLU by datatype after finetuning",
         super::table4::run),
        ("table5", false, "MMLU by finetuning dataset and size",
         super::table5::run),
        ("table6", false, "Vicuna % of ChatGPT + memory column",
         super::table6::run),
        ("table7", false, "Elo by judge/benchmark + agreement stats",
         super::table7::run),
        ("table8", false, "CrowS bias probe", super::table8::run),
        ("table10", true, "train-on-source ablation (real training)",
         super::table10::run),
        ("table11", true, "dataset size vs quality (real training)",
         super::table11::run),
        ("table12_13", false, "pairwise judgment matrix + ordering",
         super::table12_13::run),
        ("fig2", true, "LoRA placement sweep (real training)",
         super::fig2::run),
        ("fig3", false, "zero-shot accuracy vs datatype/size",
         super::fig3::run),
        ("fig4", true, "LoRA r sweep (real training)", super::fig4::run),
        ("fig6", false, "memory footprint breakdown", super::fig6::run),
        ("paged", false, "paged-optimizer spike absorption",
         super::paged_exp::run),
        ("bits", false, "NFk bit-width ablation (section 8 extension)",
         super::bits_ablation::run),
    ]
}

/// Run one experiment by id and archive its output under `results_dir`.
pub fn run_one(id: &str, ctx: &Ctx, results_dir: &Path) -> Result<String> {
    let reg = registry();
    let Some((_, _, _, f)) = reg.iter().find(|(n, ..)| *n == id) else {
        bail!(
            "unknown experiment {id:?}; available: {}",
            reg.iter().map(|(n, ..)| *n).collect::<Vec<_>>().join(", ")
        );
    };
    let out = f(ctx)?;
    std::fs::create_dir_all(results_dir)?;
    std::fs::write(results_dir.join(format!("{id}.txt")), &out)?;
    Ok(out)
}

/// Run all experiments (skipping training ones when artifacts are absent).
pub fn run_all(ctx: &Ctx, results_dir: &Path) -> Result<String> {
    let mut all = String::new();
    for (id, needs_artifacts, desc, _) in registry() {
        if needs_artifacts && ctx.rt.is_none() {
            all.push_str(&format!(
                "-- skipping {id} ({desc}): artifacts not available --\n\n"
            ));
            continue;
        }
        eprintln!("[experiments] running {id}: {desc}");
        match run_one(id, ctx, results_dir) {
            Ok(s) => {
                all.push_str(&s);
                all.push('\n');
            }
            Err(e) => all.push_str(&format!("-- {id} FAILED: {e:#} --\n\n")),
        }
    }
    std::fs::write(results_dir.join("all.txt"), &all)?;
    Ok(all)
}
