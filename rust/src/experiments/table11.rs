//! Table 11 (appendix B.4): "dataset quality rather than dataset size is
//! critical" — subsampled dataset sizes × epochs. **Real training runs**
//! at reproduction scale: corpora {Chip2, Unnatural, FLAN v2}, sizes
//! {small, medium, large}, epochs {1, 2, 3}; metric is the MMLU-proxy
//! held-out accuracy.

use anyhow::Result;

use crate::coordinator::trainer::{TrainOptions, Trainer};
use crate::data::batching::Batcher;
use crate::data::synthetic::{corpus, eval_set, CorpusKind, EvalSuite};
use crate::data::tokenizer::Tokenizer;
use crate::engine::Engine;
use crate::util::stats;

use super::{render_table, Ctx};

/// One (corpus, size, epochs) cell.
///
/// Protocol note (documented deviation): the paper trains `epochs` full
/// passes over each subsample; at its 7B scale all cells are near
/// convergence, so dataset identity dominates. At reproduction scale,
/// epoch-proportional-to-size budgets leave small cells data-limited and
/// the size axis would dominate for the wrong reason. We therefore hold
/// the *compute* budget fixed per epochs setting (steps = 55·epochs,
/// cycling the subsample) so the size axis isolates data *quantity* and
/// the dataset axis isolates *suitability* — the paper's actual question.
fn cell(ctx: &Ctx, kind: CorpusKind, size: usize, epochs: usize) -> Result<f64> {
    let (rt, manifest) = ctx.runtime()?;
    let engine = Engine::new(rt.clone(), manifest, "tiny_scope_all")?;
    let mut trainer = Trainer::new(&engine)?;
    let cfg = trainer.spec().cfg.clone();
    let tok = Tokenizer::new(cfg.vocab);
    let ds = corpus(kind, size, ctx.seed ^ size as u64);
    let b = Batcher::new(&ds, tok.clone(), cfg.batch, cfg.seq_len, false);
    let steps = (if ctx.fast { 25 } else { 55 }) * epochs;
    let opts = TrainOptions { steps, eval_every: 0, seed: ctx.seed,
                              ..TrainOptions::default() };
    trainer.train(&b, None, &opts)?;
    let eval_ds = eval_set(EvalSuite::MmluProxy, cfg.batch * 6, 0xE);
    let eb = Batcher::new(&eval_ds, tok, cfg.batch, cfg.seq_len, false);
    let (_, acc) = trainer.eval_all(&eb, 0)?;
    Ok(acc as f64 * 100.0)
}

/// Run the experiment and render its report table.
pub fn run(ctx: &Ctx) -> Result<String> {
    let corpora = [CorpusKind::Chip2, CorpusKind::UnnaturalInstructions,
                   CorpusKind::FlanV2];
    let sizes: Vec<usize> =
        if ctx.fast { vec![96, 192] } else { vec![96, 192, 288] };
    let epochs: Vec<usize> = if ctx.fast { vec![1, 2] } else { vec![1, 2, 3] };
    let mut rows = Vec::new();
    let mut per_corpus_means = Vec::new();
    let mut per_size_means: Vec<Vec<f64>> = vec![Vec::new(); sizes.len()];
    for &size in &sizes {
        let mut row = vec![format!("{size} examples")];
        for (ci, kind) in corpora.iter().enumerate() {
            for &ep in &epochs {
                let acc = cell(ctx, *kind, size, ep)?;
                row.push(format!("{acc:.1}"));
                per_size_means[sizes.iter().position(|s| *s == size).unwrap()]
                    .push(acc);
                if per_corpus_means.len() <= ci {
                    per_corpus_means.push(Vec::new());
                }
                per_corpus_means[ci].push(acc);
            }
        }
        rows.push(row);
    }
    let mut headers = vec!["datapoints".to_string()];
    for kind in corpora {
        for ep in &epochs {
            headers.push(format!("{}:e{ep}", kind.name()));
        }
    }
    let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut out = render_table(
        "Table 11: dataset size × epochs vs dataset identity (real runs)",
        &href,
        &rows,
    );
    let size_spread = {
        let means: Vec<f64> =
            per_size_means.iter().map(|v| stats::mean(v)).collect();
        means.iter().cloned().fold(f64::MIN, f64::max)
            - means.iter().cloned().fold(f64::MAX, f64::min)
    };
    let corpus_spread = {
        let means: Vec<f64> =
            per_corpus_means.iter().map(|v| stats::mean(v)).collect();
        means.iter().cloned().fold(f64::MIN, f64::max)
            - means.iter().cloned().fold(f64::MAX, f64::min)
    };
    out.push_str(&format!(
        "\nclaim check: between-dataset spread ({corpus_spread:.1}pt) far \
         exceeds between-size spread ({size_spread:.1}pt)\n\
         (paper: 1.5–8.0 MMLU between datasets vs 0.0–0.5 from size).\n",
    ));
    Ok(out)
}
