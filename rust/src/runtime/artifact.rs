//! Artifact manifest: the contract between the AOT compile path and the
//! Rust coordinator. `manifest.json` lists, per model configuration, the
//! HLO files, the init-tensor file, and the exact I/O signatures
//! (state ++ frozen ++ data → state' ++ loss). The trainer is generic over
//! this contract — it never hard-codes model internals.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Value;

/// Name, dtype, and shape of one tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// HLO parameter name
    pub name: String,
    /// dtype name as the manifest spells it (`"f32"`, `"u8"`, …)
    pub dtype: String,
    /// dimension sizes, outermost first; empty = scalar
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn parse(v: &Value) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v.get("name")?.str()?.to_string(),
            dtype: v.get("dtype")?.str()?.to_string(),
            shape: v
                .get("shape")?
                .arr()?
                .iter()
                .map(|d| d.usize())
                .collect::<Result<_>>()?,
        })
    }

    /// Element count implied by the shape (1 for scalars).
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Bytes per element for this spec's dtype. Half-precision dtypes
    /// (f16/bf16) are 2 bytes — sizing them as 4 would double-count
    /// every frozen tensor in memory-budget math (e.g. the paged
    /// optimizer's device budget). Unknown dtypes fall back to 4.
    pub fn dtype_bytes(&self) -> usize {
        match self.dtype.as_str() {
            "u8" | "i8" | "bool" => 1,
            "f16" | "bf16" | "u16" | "i16" => 2,
            "f64" | "i64" | "u64" => 8,
            _ => 4, // f32, i32, u32, and a conservative default
        }
    }

    /// Total bytes of this tensor (`elems × dtype width`).
    pub fn nbytes(&self) -> usize {
        self.elems() * self.dtype_bytes()
    }
}

/// Model configuration mirrored from `python/compile/configs.py`.
#[derive(Debug, Clone)]
pub struct ModelCfg {
    /// config name (doubles as the artifact name)
    pub name: String,
    /// vocabulary size
    pub vocab: usize,
    /// residual-stream width
    pub d_model: usize,
    /// transformer block count
    pub n_layers: usize,
    /// attention head count
    pub n_heads: usize,
    /// feed-forward hidden width
    pub d_ff: usize,
    /// compiled sequence length
    pub seq_len: usize,
    /// compiled batch size
    pub batch: usize,
    /// base-weight quantization scheme (`"nf4"`, `"fp4"`, `"int4"`, `"none"`)
    pub quant: String,
    /// whether quantization constants are themselves quantized
    pub double_quant: bool,
    /// whether LoRA adapters are attached
    pub lora: bool,
    /// LoRA rank
    pub lora_r: usize,
    /// which linears carry adapters (`"all"`, `"attn"`, …)
    pub lora_scope: String,
    /// training learning rate baked into the train graph
    pub lr: f64,
}

impl ModelCfg {
    fn parse(v: &Value) -> Result<ModelCfg> {
        Ok(ModelCfg {
            name: v.get("name")?.str()?.to_string(),
            vocab: v.get("vocab")?.usize()?,
            d_model: v.get("d_model")?.usize()?,
            n_layers: v.get("n_layers")?.usize()?,
            n_heads: v.get("n_heads")?.usize()?,
            d_ff: v.get("d_ff")?.usize()?,
            seq_len: v.get("seq_len")?.usize()?,
            batch: v.get("batch")?.usize()?,
            quant: v.get("quant")?.str()?.to_string(),
            double_quant: v.get("double_quant")?.boolean()?,
            lora: v.get("lora")?.boolean()?,
            lora_r: v.get("lora_r")?.usize()?,
            lora_scope: v.get("lora_scope")?.str()?.to_string(),
            lr: v.get("lr")?.num()?,
        })
    }

    /// Total parameter count implied by the shapes.
    pub fn n_params(&self) -> usize {
        let (d, f, v) = (self.d_model, self.d_ff, self.vocab);
        v * d + self.n_layers * (4 * d * d + 3 * d * f + 2 * d) + d
    }
}

/// One AOT-compiled model configuration.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// artifact name (the manifest key)
    pub name: String,
    /// the model configuration this artifact was lowered from
    pub cfg: ModelCfg,
    /// one optimizer step: state ++ frozen ++ data → state' ++ loss
    pub train_hlo: PathBuf,
    /// loss + accuracy over a batch, no state update
    pub eval_hlo: PathBuf,
    /// logits-only forward (generation artifacts only)
    pub fwd_hlo: Option<PathBuf>,
    /// Full-sequence forward that also fills the KV cache (generation
    /// artifacts only; `None` on train-only configs).
    pub prefill_hlo: Option<PathBuf>,
    /// O(1)-per-token KV-cached decode step (generation artifacts only).
    pub decode_hlo: Option<PathBuf>,
    /// Key/value cache signatures (shape `[batch, layers, seq, d_model]`);
    /// empty when the artifact has no cached decode graphs.
    pub cache_sig: Vec<TensorSpec>,
    /// `.tensors` file with initial state ++ frozen values, in HLO order
    pub init: PathBuf,
    /// number of mutable state tensors (trainable params + opt state)
    pub n_state: usize,
    /// number of trainable parameter tensors within the state
    pub n_trainable: usize,
    /// number of frozen tensors (quantized base + codebooks)
    pub n_frozen: usize,
    /// signatures of the mutable state tensors
    pub state_sig: Vec<TensorSpec>,
    /// signatures of the frozen tensors
    pub frozen_sig: Vec<TensorSpec>,
    /// signatures of the per-batch data tensors
    pub data_sig: Vec<TensorSpec>,
}

/// The parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// directory the manifest was loaded from
    pub dir: PathBuf,
    /// every artifact the manifest lists
    pub artifacts: Vec<ArtifactSpec>,
    /// the full parsed JSON (for fields this struct does not model)
    pub raw: Value,
}

impl Manifest {
    /// Default artifact directory: `$QLORA_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("QLORA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Parse `dir/manifest.json` and resolve artifact paths against `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {path:?} — run `make artifacts` first"
            )
        })?;
        let raw = Value::parse(&text)?;
        let mut artifacts = Vec::new();
        for a in raw.get("artifacts")?.arr()? {
            let sigs = |key: &str| -> Result<Vec<TensorSpec>> {
                a.get(key)?.arr()?.iter().map(TensorSpec::parse).collect()
            };
            artifacts.push(ArtifactSpec {
                name: a.get("name")?.str()?.to_string(),
                cfg: ModelCfg::parse(a.get("config")?)?,
                train_hlo: dir.join(a.get("train_hlo")?.str()?),
                eval_hlo: dir.join(a.get("eval_hlo")?.str()?),
                fwd_hlo: a
                    .opt("fwd_hlo")
                    .and_then(|v| v.str().ok())
                    .map(|s| dir.join(s)),
                prefill_hlo: a
                    .opt("prefill_hlo")
                    .and_then(|v| v.str().ok())
                    .map(|s| dir.join(s)),
                decode_hlo: a
                    .opt("decode_hlo")
                    .and_then(|v| v.str().ok())
                    .map(|s| dir.join(s)),
                cache_sig: match a.opt("cache_sig") {
                    Some(v) => {
                        v.arr()?.iter().map(TensorSpec::parse).collect::<Result<_>>()?
                    }
                    None => Vec::new(),
                },
                init: dir.join(a.get("init")?.str()?),
                n_state: a.get("n_state")?.usize()?,
                n_trainable: a.get("n_trainable")?.usize()?,
                n_frozen: a.get("n_frozen")?.usize()?,
                state_sig: sigs("state_sig")?,
                frozen_sig: sigs("frozen_sig")?,
                data_sig: sigs("data_sig")?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts, raw })
    }

    /// Look up an artifact by name, with a helpful error listing what exists.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "artifact {name:?} not in manifest (have: {})",
                    self.artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("qlora_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let json = r#"{"artifacts": [{
            "name": "t", "train_hlo": "t.train.hlo.txt",
            "eval_hlo": "t.eval.hlo.txt", "init": "t.init.tensors",
            "n_state": 2, "n_trainable": 1, "n_frozen": 1,
            "config": {"name": "t", "vocab": 8, "d_model": 4,
                "n_layers": 1, "n_heads": 1, "d_ff": 8, "seq_len": 4,
                "batch": 2, "quant": "nf4", "double_quant": true,
                "block": 64, "block2": 256, "lora": true, "lora_r": 2,
                "lora_alpha": 16, "lora_scope": "all", "lr": 0.0002,
                "adam_b1": 0.9, "adam_b2": 0.999, "adam_eps": 1e-8,
                "max_grad_norm": 0.3, "remat": true},
            "state_sig": [{"name": "a", "dtype": "f32", "shape": [2]},
                          {"name": "s", "dtype": "f32", "shape": []}],
            "frozen_sig": [{"name": "w", "dtype": "u8", "shape": [4]}],
            "data_sig": [{"name": "tokens", "dtype": "i32", "shape": [2, 4]}]
        }]}"#;
        std::fs::write(dir.join("manifest.json"), json).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("t").unwrap();
        assert_eq!(a.cfg.d_model, 4);
        assert_eq!(a.state_sig[1].elems(), 1);
        // decode-path keys are optional: absent means full-recompute only
        assert!(a.fwd_hlo.is_none());
        assert!(a.prefill_hlo.is_none() && a.decode_hlo.is_none());
        assert!(a.cache_sig.is_empty());
        assert!(m.get("missing").is_err());
    }

    #[test]
    fn dtype_widths_are_real_not_all_four_bytes() {
        let spec = |dtype: &str| TensorSpec {
            name: "t".into(),
            dtype: dtype.into(),
            shape: vec![3, 5],
        };
        assert_eq!(spec("u8").dtype_bytes(), 1);
        assert_eq!(spec("f16").dtype_bytes(), 2);
        assert_eq!(spec("bf16").dtype_bytes(), 2);
        assert_eq!(spec("f32").dtype_bytes(), 4);
        assert_eq!(spec("i32").dtype_bytes(), 4);
        assert_eq!(spec("f64").dtype_bytes(), 8);
        assert_eq!(spec("mystery").dtype_bytes(), 4, "unknown -> 4");
        assert_eq!(spec("bf16").nbytes(), 30);
        assert_eq!(spec("u8").nbytes(), 15);
    }
}
