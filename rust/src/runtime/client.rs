//! The PJRT CPU client + executable cache.
//!
//! One `Runtime` owns the PJRT client and a cache of compiled executables
//! keyed by HLO path, so repeated experiment runs over the same artifact
//! compile once. HLO **text** is the interchange format (xla_extension
//! 0.5.1 rejects jax>=0.5 serialized protos; the text parser reassigns
//! instruction ids — see DESIGN.md section 4).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::executor::Executable;

/// PJRT client plus a compile cache keyed by HLO path.
pub struct Runtime {
    /// the underlying PJRT client (CPU in this container)
    pub client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Create the CPU PJRT client. Expensive — create once, share.
    pub fn cpu() -> Result<Runtime> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Acquire the compile cache, recovering from poisoning: the cache
    /// maps path -> compiled executable and every insert is idempotent,
    /// so state left by a panicked holder is safe to reuse.
    fn cache_guard(
        &self,
    ) -> std::sync::MutexGuard<'_, HashMap<PathBuf, std::sync::Arc<Executable>>>
    {
        self.cache.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached).
    pub fn load_hlo(&self, path: &Path) -> Result<std::sync::Arc<Executable>> {
        {
            let cache = self.cache_guard();
            if let Some(e) = cache.get(path) {
                return Ok(e.clone());
            }
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        let arc = std::sync::Arc::new(Executable::new(exe));
        self.cache_guard().insert(path.to_path_buf(), arc.clone());
        Ok(arc)
    }

    /// Number of compiled executables held in the cache.
    pub fn cache_len(&self) -> usize {
        self.cache_guard().len()
    }
}
