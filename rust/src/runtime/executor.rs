//! Execution helpers: `Tensor` ⇄ `xla::Literal` conversion and tuple-result
//! handling. Every AOT graph is lowered with `return_tuple=True`, so an
//! execution returns one tuple literal which we decompose into outputs.

use anyhow::{bail, ensure, Context, Result};

use crate::tensorio::{Dt, Tensor};

/// A compiled PJRT executable plus run statistics.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// number of completed `run` calls (perf accounting)
    pub runs: std::sync::atomic::AtomicU64,
}

impl Executable {
    /// Wrap a loaded executable with zeroed run statistics.
    pub fn new(exe: xla::PjRtLoadedExecutable) -> Executable {
        Executable { exe, runs: std::sync::atomic::AtomicU64::new(0) }
    }

    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.runs
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let out = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .context("PJRT execute")?;
        ensure!(!out.is_empty() && !out[0].is_empty(), "no outputs");
        let mut lit = out[0][0].to_literal_sync()?;
        Ok(lit.decompose_tuple()?)
    }
}

/// Build a Literal from a host tensor.
pub fn literal_from_tensor(t: &Tensor) -> Result<xla::Literal> {
    let ty = match t.dtype {
        Dt::F32 => xla::ElementType::F32,
        Dt::U8 => xla::ElementType::U8,
        Dt::I32 => xla::ElementType::S32,
    };
    let dims: Vec<usize> = t.shape.clone();
    xla::Literal::create_from_shape_and_untyped_data(ty, &dims, &t.data)
        .with_context(|| format!("literal for {}", t.name))
}

/// Copy a Literal's f32 payload to a Vec.
pub fn literal_to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Copy a Literal back into a named host tensor (dtype from the literal).
pub fn literal_to_tensor(name: &str, l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let dtype = match shape.ty() {
        xla::ElementType::F32 => Dt::F32,
        xla::ElementType::U8 => Dt::U8,
        xla::ElementType::S32 => Dt::I32,
        t => bail!("unsupported element type {t:?}"),
    };
    let mut data = vec![0u8; l.size_bytes()];
    match dtype {
        Dt::F32 => {
            let v = l.to_vec::<f32>()?;
            data.clear();
            for x in v {
                data.extend_from_slice(&x.to_le_bytes());
            }
        }
        Dt::I32 => {
            let v = l.to_vec::<i32>()?;
            data.clear();
            for x in v {
                data.extend_from_slice(&x.to_le_bytes());
            }
        }
        Dt::U8 => {
            let v = l.to_vec::<u8>()?;
            data = v;
        }
    }
    Ok(Tensor { name: name.to_string(), dtype, shape: dims, data })
}

/// Extract the scalar f32 from a literal.
pub fn literal_scalar_f32(l: &xla::Literal) -> Result<f32> {
    Ok(l.get_first_element::<f32>()?)
}
