//! PJRT runtime: loads AOT artifacts (HLO text lowered by
//! `python/compile/aot.py`), compiles them on the CPU PJRT client, and
//! executes them from the coordinator hot path. Python is never involved.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{ArtifactSpec, Manifest, ModelCfg, TensorSpec};
pub use client::Runtime;
pub use executor::{literal_from_tensor, literal_to_f32, Executable};
