//! # qlora — a full-system reproduction of *QLoRA: Efficient Finetuning of
//! Quantized LLMs* (Dettmers et al., NeurIPS 2023)
//!
//! Three-layer architecture (see `DESIGN.md`):
//!
//! * **L1** — Pallas kernels (build-time Python) for block-wise NF4/FP4/Int4
//!   quantization, Double Quantization, and the fused QLoRA linear.
//! * **L2** — a JAX LLaMA-style transformer with QLoRA linears, AOT-lowered
//!   to HLO text per configuration (`python/compile/aot.py`).
//! * **L3** — this crate: the PJRT runtime, the finetuning coordinator
//!   (data pipeline, batching, training loop), a bit-exact native
//!   quantization substrate, the paged-optimizer simulator, the analytical
//!   memory model, the Elo evaluation machinery, and the experiment harness
//!   regenerating every table and figure of the paper.
//!
//! Python never runs on the training path: after `make artifacts` the
//! `qlora` binary is self-contained.

pub mod coordinator;
pub mod data;
pub mod elo;
pub mod eval;
pub mod experiments;
pub mod memory;
pub mod paged;
pub mod quant;
pub mod runtime;
pub mod tensorio;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
