//! # qlora — a full-system reproduction of *QLoRA: Efficient Finetuning of
//! Quantized LLMs* (Dettmers et al., NeurIPS 2023)
//!
//! Three-layer architecture (`README.md` has the quickstart and paper →
//! module map; `ARCHITECTURE.md` the full system picture):
//!
//! * **L1** — Pallas kernels (build-time Python) for block-wise NF4/FP4/Int4
//!   quantization, Double Quantization, the fused QLoRA linear, and the
//!   KV-cache decode primitives (`python/compile/kernels/decode.py`).
//! * **L2** — a JAX LLaMA-style transformer with QLoRA linears, AOT-lowered
//!   to HLO text per configuration (`python/compile/aot.py`): train, eval,
//!   and — on generation artifacts — fwd / prefill / decode-step graphs.
//! * **L3** — this crate, organized around the serving seam the paper's
//!   economics imply (one frozen 4-bit base, many cheap adapters):
//!   - [`engine`] — the public API core: an `Engine` owns the PJRT
//!     runtime, the compiled executables, and the frozen quantized base
//!     (uploaded once); an `AdapterRegistry` hot-swaps named LoRA
//!     adapters over that base; `Session`s serve `generate` (whole,
//!     streaming, or batched multi-prompt) and `eval` per adapter.
//!     Decoding runs through a `DecodeGraph` — KV-cached incremental
//!     steps by default, full-sequence recompute as fallback — and
//!     `serve` runs the request lifecycle (per-request priorities,
//!     deadlines, cancellation, token-budget admission, typed outcomes)
//!     and `generate_batch` continuously batches any number of prompts
//!     over the compiled rows via the same `Scheduler`.
//!   - [`coordinator`] — finetuning as a *client* of the engine: the
//!     training loop borrows the runtime and frozen base, owns only the
//!     mutable state, and publishes finished adapters back into the
//!     engine's registry.
//!   - the supporting subsystems: the data pipeline ([`data`]), a
//!     bit-exact native quantization substrate ([`quant`]), the
//!     paged-optimizer simulator ([`paged`]), the analytical memory model
//!     ([`memory`]), the Elo evaluation machinery ([`elo`], [`eval`] —
//!     including a judged arena over real engine sessions), and the
//!     experiment harness regenerating every table and figure of the
//!     paper ([`experiments`]).
//!
//! Python never runs on the training or serving path: after
//! `make artifacts` the `qlora` binary is self-contained.

pub mod coordinator;
pub mod data;
pub mod elo;
pub mod engine;
pub mod eval;
pub mod experiments;
pub mod memory;
pub mod paged;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensorio;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
