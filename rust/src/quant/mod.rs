//! Native quantization substrate: bit-compatible Rust twin of the L1
//! kernels (`python/compile/kernels/ref.py`).
//!
//! Implements the paper's full quantization stack — block-wise absmax
//! quantization (Eq. 1–2) over codebook datatypes (NF4 from Appendix E,
//! FP4-E2M1/E3M0, Int4/Int8, FP8-E4M3), Double Quantization of the
//! quantization constants (section 3), and nibble packing. Cross-checked
//! bit-for-bit against the Python reference via golden vectors emitted by
//! `aot.py` (see `rust/tests/golden.rs`).
//!
//! The substrate is **two-tier** (see ARCHITECTURE.md, "Quantization
//! layer"): [`kernels`] holds the fused, multicore kernels every hot path
//! goes through; [`absmax`] / [`pack`] are the simple scalar twins that
//! serve as the bit-exactness reference oracle. The two tiers are
//! bit-identical by contract, enforced by the golden vectors and the
//! fused-vs-scalar property suite (`rust/tests/prop_quant_fused.rs`).
//!
//! This substrate backs: weight preparation for the engine and runtime,
//! the memory model, Table 2 / Figure 3 quantization-error measurements,
//! and the quantization benches (`make bench-quant` →
//! `BENCH_quant.json`).

#![cfg_attr(doc, warn(missing_docs))]

pub mod absmax;
pub mod codebook;
pub mod double;
pub mod error;
pub mod kernels;
pub mod pack;
pub mod tensor;

pub use absmax::{dequantize_blockwise, quantize_blockwise};
pub use codebook::{Codebook, DType};
pub use double::{
    double_dequantize, double_dequantize_scalar, double_quantize,
    double_quantize_scalar, DoubleQuant,
};
pub use kernels::{
    dequantize_blockwise_fused, dequantize_blockwise_into,
    dequantize_fused_into, quantize_blockwise_fused, quantize_fused, Encoder,
};
pub use pack::{pack_nibbles, unpack_nibbles};
pub use tensor::QuantizedTensor;
