//! Fused, parallel quantization kernels — the performance tier of the
//! quant substrate (the scalar tier in [`super::absmax`] / [`super::pack`]
//! stays as the bit-exactness reference oracle).
//!
//! What "fused" buys over the scalar pipeline:
//!
//! * **`quantize_fused`** — transpose + absmax + encode + nibble-pack in a
//!   single pass per block with zero intermediate allocations. The scalar
//!   path (`QuantizedTensor::quantize_scalar`) materializes a transposed
//!   `Vec<f32>`, then a full unpacked `codes` Vec, then re-scans it in
//!   `pack_nibbles`; here each block is gathered straight out of the
//!   row-major weight into a stack buffer and written as packed bytes.
//! * **`dequantize_fused_into`** — a per-codebook 256-entry byte →
//!   `(f32, f32)` paired-decode LUT turns each packed byte into two scaled
//!   outputs with no `unpack_nibbles` buffer, no `data` clone, and the
//!   absmax multiply fused in; output goes into a caller-provided buffer.
//! * **[`Encoder`]** — a branchless 4-step unrolled midpoint compare for
//!   codebooks with ≤ 16 entries (every 4-bit datatype) replacing the
//!   generic binary search, plus the shared symmetric-integer shortcut
//!   ([`Codebook::int_fast_half`]).
//! * **Block-range sharding** — `std::thread::scope` +
//!   `available_parallelism` (no new deps) spreads block ranges across
//!   cores for tensors at or above [`PAR_THRESHOLD`] elements. Blocks are
//!   independent by construction (paper Eq. 1–2), so results are
//!   deterministic and identical for every shard count.
//!
//! **Bit-exactness contract.** Every function here is bit-identical to its
//! scalar twin — same true division by the block absmax (never a
//! reciprocal multiply; see the NOTE in `absmax.rs`), same comparison
//! order, same f32 arithmetic — enforced by `rust/tests/golden.rs` and the
//! fused-vs-scalar property suite (`rust/tests/prop_quant_fused.rs`).
//!
//! All functions take `threads: Option<usize>`; `None` picks
//! [`auto_threads`] (tests pass odd explicit counts to exercise shard
//! boundaries; benches pass `Some(1)` to isolate single-thread gains).
//! Whether codes are nibble-packed is derived from the codebook exactly as
//! in the Python reference (`ref.quantize_weight`): packed iff the
//! codebook has ≤ 16 entries.

use std::ops::Range;

use anyhow::{ensure, Result};

use super::codebook::Codebook;

/// Tensors with at least this many elements are sharded across cores by
/// [`auto_threads`]; smaller ones run single-threaded (thread spawn costs
/// more than it saves below ~64k elements).
pub const PAR_THRESHOLD: usize = 1 << 16;

/// Largest blocksize gathered through the per-thread stack buffer in
/// `quantize_fused`; larger blocks fall back to a two-pass strided walk
/// (still allocation-free). Covers every blocksize the repo uses
/// (weights: 32–256, DQ constants: 256).
const SCRATCH: usize = 512;

/// Row tile for the fused dequantizer's blocked un-transpose: bounds the
/// write working set to `ROW_TILE` distinct output rows (one cache line
/// each) so the column-major decode reuses row cache lines across
/// consecutive columns.
const ROW_TILE: usize = 256;

/// Worker count the fused kernels use for an `n_items`-element tensor:
/// 1 below [`PAR_THRESHOLD`], else `available_parallelism` (optionally
/// capped by env `QLORA_QUANT_THREADS`).
pub fn auto_threads(n_items: usize) -> usize {
    if n_items < PAR_THRESHOLD {
        return 1;
    }
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let cap = std::env::var("QLORA_QUANT_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(hw);
    cap.min(hw).max(1)
}

/// Split `nb` work units into at most `threads` contiguous, near-equal
/// ranges (the first `nb % threads` ranges get one extra unit). Empty
/// ranges are dropped, so odd unit counts and over-subscribed thread
/// counts are both fine.
pub fn shard_ranges(nb: usize, threads: usize) -> Vec<Range<usize>> {
    let t = threads.max(1).min(nb.max(1));
    let base = nb / t;
    let extra = nb % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for k in 0..t {
        let len = base + usize::from(k < extra);
        if len == 0 {
            continue;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

/// A per-codebook specialized encoder. All variants are bit-identical to
/// [`Codebook::encode`] over absmax-normalized inputs (`|x| <= 1`, or NaN
/// for degenerate blocks — the only values the fused kernels ever feed
/// it); the property suite pins this down.
pub enum Encoder<'c> {
    /// Uniform symmetric-integer grids: `floor(clamp(x)·half + half + ½)`
    /// (the shortcut shared with the scalar tier via
    /// [`Codebook::int_fast_half`]).
    Int {
        /// Largest code magnitude (7 for Int4, 127 for Int8).
        half: f32,
    },
    /// Any codebook with ≤ 16 entries (≤ 15 midpoints): a branchless
    /// 4-step unrolled midpoint compare over the midpoints padded to 16
    /// entries with `+∞`. Each step is a flag-to-offset add, so there is
    /// no data-dependent branch to mispredict.
    Unrolled16 {
        /// Midpoints padded to 16 entries with `f32::INFINITY`.
        mids: [f32; 16],
    },
    /// Fallback for larger codebooks: the generic binary search.
    Generic(&'c Codebook),
}

impl<'c> Encoder<'c> {
    /// Pick the fastest bit-identical encoder for `cb`.
    pub fn new(cb: &'c Codebook) -> Encoder<'c> {
        if let Some(half) = cb.int_fast_half() {
            return Encoder::Int { half };
        }
        let m = cb.midpoints();
        if m.len() <= 15 {
            let mut mids = [f32::INFINITY; 16];
            mids[..m.len()].copy_from_slice(m);
            return Encoder::Unrolled16 { mids };
        }
        Encoder::Generic(cb)
    }

    /// Nearest code for an absmax-normalized value (`|x| <= 1` or NaN).
    /// Bit-identical to `Codebook::encode` / the scalar integer shortcut
    /// on that domain.
    #[inline]
    pub fn encode(&self, x: f32) -> u8 {
        match self {
            Encoder::Int { half } => {
                let half = *half;
                let xn = x.clamp(-1.0, 1.0);
                // round-half-up matches `sum(xn >= mids)` exactly
                (xn * half + half + 0.5).floor() as u8
            }
            Encoder::Unrolled16 { mids } => {
                // rank of x in the padded midpoint table = the same
                // `sum(x >= mids)` the binary search computes (identical
                // comparisons, so identical ties and NaN handling; the
                // +inf pads never compare true for normalized inputs)
                let mut c = usize::from(x >= mids[7]) * 8;
                c += usize::from(x >= mids[c + 3]) * 4;
                c += usize::from(x >= mids[c + 1]) * 2;
                c += usize::from(x >= mids[c]);
                c as u8
            }
            Encoder::Generic(cb) => cb.encode(x),
        }
    }
}

/// 256-entry decode value table: `lut[code] = values[code]`, with
/// out-of-range codes clamped to the top entry (the scalar tier would
/// panic on them; neither occurs for codes our quantizers produce).
fn value_lut(cb: &Codebook) -> [f32; 256] {
    let top = cb.len() - 1;
    let mut lut = [0f32; 256];
    for (code, slot) in lut.iter_mut().enumerate() {
        *slot = cb.values[code.min(top)];
    }
    lut
}

/// 256-entry paired-decode table for packed nibbles:
/// `lut[byte] = (values[byte & 0xF], values[byte >> 4])`, clamped like
/// [`value_lut`].
fn pair_lut(cb: &Codebook) -> [(f32, f32); 256] {
    let top = cb.len() - 1;
    let mut lut = [(0f32, 0f32); 256];
    for (byte, slot) in lut.iter_mut().enumerate() {
        *slot = (
            cb.values[(byte & 0xF).min(top)],
            cb.values[(byte >> 4).min(top)],
        );
    }
    lut
}

/// Walk the implicit transposed flat layout `flat[j*h + i] = w[i*o + j]`
/// from flat index `f0`, calling `g` on each of `len` values in flat
/// order — the gather that replaces materializing the transposed Vec.
#[inline]
fn walk_transposed(
    w: &[f32],
    h: usize,
    o: usize,
    f0: usize,
    len: usize,
    mut g: impl FnMut(f32),
) {
    let mut i = f0 % h;
    let mut j = f0 / h;
    let mut src = i * o + j;
    for _ in 0..len {
        g(w[src]);
        i += 1;
        src += o;
        if i == h {
            i = 0;
            j += 1;
            src = j;
        }
    }
}

/// Shard `nb` work units of `unit` primary elements each across `threads`
/// scoped workers. Each worker gets its global unit range plus disjoint
/// `&mut` windows of `primary` (`unit` elements per work unit) and
/// `per_block` (1 element per unit; pass `&mut []` when the kernel has no
/// per-unit output).
fn run_sharded<T: Send>(
    nb: usize,
    unit: usize,
    threads: usize,
    primary: &mut [T],
    per_block: &mut [f32],
    run: &(dyn Fn(Range<usize>, &mut [T], &mut [f32]) + Sync),
) {
    if threads <= 1 || nb < 2 {
        run(0..nb, primary, per_block);
        return;
    }
    let has_per_block = !per_block.is_empty();
    let ranges = shard_ranges(nb, threads);
    std::thread::scope(|s| {
        let mut prest: &mut [T] = primary;
        let mut arest: &mut [f32] = per_block;
        for r in ranges {
            let (p, pt) =
                std::mem::take(&mut prest).split_at_mut(r.len() * unit);
            prest = pt;
            let a_len = if has_per_block { r.len() } else { 0 };
            let (a, at) = std::mem::take(&mut arest).split_at_mut(a_len);
            arest = at;
            s.spawn(move || run(r, p, a));
        }
    });
}

// ---------------------------------------------------------------------------
// flat (already-laid-out) kernels — drop-ins for the scalar absmax tier
// ---------------------------------------------------------------------------

/// Fused flat block quantization: absmax + encode in one pass per block,
/// block ranges sharded across cores. Drop-in for
/// [`super::absmax::quantize_blockwise`] (unpacked codes) and
/// bit-identical to it.
pub fn quantize_blockwise_fused(
    x: &[f32],
    cb: &Codebook,
    block: usize,
    threads: Option<usize>,
) -> Result<(Vec<u8>, Vec<f32>)> {
    ensure!(block > 0, "block must be positive");
    ensure!(
        x.len() % block == 0,
        "length {} not divisible by block {}",
        x.len(),
        block
    );
    let nb = x.len() / block;
    let mut codes = vec![0u8; x.len()];
    let mut absmax = vec![0f32; nb];
    let enc = Encoder::new(cb);
    // `range` is the global block range this shard owns; `codes`/`absmax`
    // are that shard's disjoint output windows.
    let run = |range: Range<usize>, codes: &mut [u8], absmax: &mut [f32]| {
        for (k, b) in range.enumerate() {
            let chunk = &x[b * block..(b + 1) * block];
            let mut am = 0f32;
            for &v in chunk {
                am = am.max(v.abs());
            }
            absmax[k] = am;
            let scale = if am > 0.0 { am } else { 1.0 };
            let out = &mut codes[k * block..(k + 1) * block];
            // NOTE: x/scale must stay a true division (not *reciprocal) to
            // remain bit-identical with the XLA reference computation.
            for (c, &v) in out.iter_mut().zip(chunk) {
                *c = enc.encode(v / scale);
            }
        }
    };
    let t = threads.unwrap_or_else(|| auto_threads(x.len()));
    run_sharded(nb, block, t, &mut codes, &mut absmax, &run);
    Ok((codes, absmax))
}

/// Fused flat dequantization into a caller buffer: decode-LUT lookup with
/// the absmax multiply fused in, no allocations, block ranges sharded
/// across cores. Bit-identical to
/// [`super::absmax::dequantize_blockwise`] for in-range codes.
///
/// Divergence on invalid input: codes `>= cb.len()` decode to the top
/// codebook entry here (LUT clamp) where the scalar twin would panic —
/// validate externally sourced codes before dequantizing (as
/// `engine::weights::from_tensors` does for artifact loads).
pub fn dequantize_blockwise_into(
    codes: &[u8],
    absmax: &[f32],
    cb: &Codebook,
    block: usize,
    out: &mut [f32],
    threads: Option<usize>,
) -> Result<()> {
    ensure!(block > 0, "block must be positive");
    ensure!(codes.len() % block == 0, "bad codes length");
    ensure!(codes.len() / block == absmax.len(), "absmax length mismatch");
    ensure!(out.len() == codes.len(), "output length mismatch");
    let lut = value_lut(cb);
    let run = |range: Range<usize>, dst: &mut [f32], _a: &mut [f32]| {
        for (k, b) in range.enumerate() {
            let am = absmax[b];
            let src = &codes[b * block..(b + 1) * block];
            let win = &mut dst[k * block..(k + 1) * block];
            for (d, &c) in win.iter_mut().zip(src) {
                *d = lut[c as usize] * am;
            }
        }
    };
    let t = threads.unwrap_or_else(|| auto_threads(codes.len()));
    run_sharded(absmax.len(), block, t, out, &mut [], &run);
    Ok(())
}

/// Allocating convenience wrapper over [`dequantize_blockwise_into`]
/// (same in-range bit-identity and same out-of-range clamp divergence).
pub fn dequantize_blockwise_fused(
    codes: &[u8],
    absmax: &[f32],
    cb: &Codebook,
    block: usize,
    threads: Option<usize>,
) -> Result<Vec<f32>> {
    let mut out = vec![0f32; codes.len()];
    dequantize_blockwise_into(codes, absmax, cb, block, &mut out, threads)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// weight-container kernels — the QuantizedTensor hot path
// ---------------------------------------------------------------------------

/// Fused weight quantization for the `QuantizedTensor` layout: transpose
/// gather + absmax + encode + (for ≤ 16-entry codebooks) nibble-pack, one
/// pass per block with zero intermediate allocations, block ranges
/// sharded across cores.
///
/// `w` is the row-major `(h, o)` weight; blocks run along the transposed
/// flat order `flat[j*h + i] = w[i*o + j]` exactly as in the scalar path.
/// Returns `(packed-or-raw codes, per-block absmax)`, bit-identical to
/// `quantize_blockwise` + `pack_nibbles` over the materialized transpose.
///
/// The packed (4-bit) layout requires an even `block` so packed bytes
/// never straddle block (and therefore shard) boundaries —
/// `QuantizedTensor::quantize` falls back to the scalar tier for the
/// odd-block corner.
pub fn quantize_fused(
    w: &[f32],
    shape: (usize, usize),
    cb: &Codebook,
    block: usize,
    threads: Option<usize>,
) -> Result<(Vec<u8>, Vec<f32>)> {
    let (h, o) = shape;
    let n = h * o;
    ensure!(w.len() == n, "weight length mismatch");
    ensure!(block > 0, "block must be positive");
    ensure!(n % block == 0, "size not divisible by block");
    let pack = cb.len() <= 16;
    if pack {
        ensure!(block % 2 == 0, "packed path needs an even block");
    }
    let nb = n / block;
    let bytes_per_block = if pack { block / 2 } else { block };
    let mut data = vec![0u8; nb * bytes_per_block];
    let mut absmax = vec![0f32; nb];
    let enc = Encoder::new(cb);
    let run = |range: Range<usize>, data: &mut [u8], absmax: &mut [f32]| {
        let mut buf = [0f32; SCRATCH];
        for (k, b) in range.enumerate() {
            let f0 = b * block;
            let ob =
                &mut data[k * bytes_per_block..(k + 1) * bytes_per_block];
            if block <= SCRATCH {
                // gather the block once into the stack buffer, then
                // absmax + encode + pack out of L1
                let vals = &mut buf[..block];
                let mut idx = 0;
                walk_transposed(w, h, o, f0, block, |v| {
                    vals[idx] = v;
                    idx += 1;
                });
                let mut am = 0f32;
                for &v in vals.iter() {
                    am = am.max(v.abs());
                }
                absmax[k] = am;
                let scale = if am > 0.0 { am } else { 1.0 };
                // NOTE: true division, as in the scalar tier.
                if pack {
                    for (byte, pair) in
                        ob.iter_mut().zip(vals.chunks_exact(2))
                    {
                        let lo = enc.encode(pair[0] / scale);
                        let hi = enc.encode(pair[1] / scale);
                        *byte = lo | (hi << 4);
                    }
                } else {
                    for (c, &v) in ob.iter_mut().zip(vals.iter()) {
                        *c = enc.encode(v / scale);
                    }
                }
            } else {
                // oversized block: two strided walks, still allocation-free
                let mut am = 0f32;
                walk_transposed(w, h, o, f0, block, |v| am = am.max(v.abs()));
                absmax[k] = am;
                let scale = if am > 0.0 { am } else { 1.0 };
                if pack {
                    let mut lo: Option<u8> = None;
                    let mut bi = 0;
                    walk_transposed(w, h, o, f0, block, |v| {
                        let c = enc.encode(v / scale);
                        match lo.take() {
                            None => lo = Some(c),
                            Some(l) => {
                                ob[bi] = l | (c << 4);
                                bi += 1;
                            }
                        }
                    });
                } else {
                    let mut bi = 0;
                    walk_transposed(w, h, o, f0, block, |v| {
                        ob[bi] = enc.encode(v / scale);
                        bi += 1;
                    });
                }
            }
        }
    };
    let t = threads.unwrap_or_else(|| auto_threads(n));
    run_sharded(nb, bytes_per_block, t, &mut data, &mut absmax, &run);
    Ok((data, absmax))
}

/// Fused weight dequantization into a caller-provided row-major `(h, o)`
/// buffer: paired-decode LUT over packed bytes (or a value LUT over raw
/// 8-bit codes), absmax multiply fused in, no unpack buffer, no clones.
/// Bit-identical to the scalar unpack → dequantize → un-transpose
/// pipeline for in-range codes (out-of-range codes clamp to the top
/// codebook entry where the scalar tier panics — see
/// [`dequantize_blockwise_into`]).
///
/// Parallelism shards **output rows** (each worker owns a contiguous
/// `&mut` band of `out`), and each band decodes column segments of the
/// packed data in [`ROW_TILE`] row tiles so the scattered writes of the
/// un-transpose stay cache-resident. Packed data needs an even `block`
/// (callers fall back to the scalar tier otherwise).
pub fn dequantize_fused_into(
    data: &[u8],
    absmax: &[f32],
    cb: &Codebook,
    block: usize,
    shape: (usize, usize),
    out: &mut [f32],
    threads: Option<usize>,
) -> Result<()> {
    let (h, o) = shape;
    let n = h * o;
    ensure!(block > 0, "block must be positive");
    ensure!(n % block == 0, "size not divisible by block");
    ensure!(out.len() == n, "output length mismatch");
    ensure!(absmax.len() == n / block, "absmax length mismatch");
    let pack = cb.len() <= 16;
    if pack {
        ensure!(block % 2 == 0, "packed path needs an even block");
        ensure!(data.len() * 2 == n, "packed data length mismatch");
    } else {
        ensure!(data.len() == n, "raw data length mismatch");
    }
    let plut = pair_lut(cb);
    let vlut = value_lut(cb);
    // Decode one column segment flat[j*h+i0 .. j*h+i0+rows) into column j
    // of `tile` (whose row 0 is global row i0).
    let seg_packed = |j: usize, i0: usize, rows: usize, tile: &mut [f32]| {
        let fa = j * h + i0;
        let fb = fa + rows;
        let mut f = fa;
        let mut row = 0usize;
        let mut b = f / block;
        let mut rem = f % block;
        let mut am = absmax[b];
        // leading element on an odd flat index uses its byte's high nibble
        if f & 1 == 1 {
            tile[row * o + j] = plut[data[f >> 1] as usize].1 * am;
            row += 1;
            f += 1;
            rem += 1;
            if rem == block {
                rem = 0;
                b += 1;
                if f < fb {
                    am = absmax[b];
                }
            }
        }
        // aligned pairs: one byte -> two scaled outputs (block is even, so
        // a pair never straddles an absmax boundary)
        while f + 2 <= fb {
            let (v0, v1) = plut[data[f >> 1] as usize];
            let idx = row * o + j;
            tile[idx] = v0 * am;
            tile[idx + o] = v1 * am;
            row += 2;
            f += 2;
            rem += 2;
            if rem == block {
                rem = 0;
                b += 1;
                if f < fb {
                    am = absmax[b];
                }
            }
        }
        // trailing element (segment ends on an odd flat index): low nibble
        if f < fb {
            tile[row * o + j] = plut[data[f >> 1] as usize].0 * am;
        }
    };
    let seg_raw = |j: usize, i0: usize, rows: usize, tile: &mut [f32]| {
        let fa = j * h + i0;
        let mut b = fa / block;
        let mut rem = fa % block;
        let mut am = absmax[b];
        for r in 0..rows {
            tile[r * o + j] = vlut[data[fa + r] as usize] * am;
            rem += 1;
            if rem == block {
                rem = 0;
                b += 1;
                if r + 1 < rows {
                    am = absmax[b];
                }
            }
        }
    };
    // `range` is this shard's band of output rows; `band` is
    // out[range.start*o .. range.end*o].
    let run = |range: Range<usize>, band: &mut [f32], _a: &mut [f32]| {
        let band_start = range.start;
        let mut t0 = range.start;
        while t0 < range.end {
            let rows = ROW_TILE.min(range.end - t0);
            let tile = &mut band[(t0 - band_start) * o..];
            for j in 0..o {
                if pack {
                    seg_packed(j, t0, rows, tile);
                } else {
                    seg_raw(j, t0, rows, tile);
                }
            }
            t0 += rows;
        }
    };
    let t = threads.unwrap_or_else(|| auto_threads(n));
    run_sharded(h, o, t, out, &mut [], &run);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::absmax::{dequantize_blockwise, quantize_blockwise};
    use crate::quant::codebook::DType;
    use crate::util::rng::Rng;

    #[test]
    fn shard_ranges_cover_and_partition() {
        for (nb, t) in [(1, 1), (7, 3), (8, 3), (64, 7), (5, 9), (0, 4)] {
            let ranges = shard_ranges(nb, t);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "nb={nb} t={t}");
                assert!(!r.is_empty());
                next = r.end;
            }
            assert_eq!(next, nb, "nb={nb} t={t}");
            assert!(ranges.len() <= t.max(1));
        }
    }

    #[test]
    fn encoder_matches_codebook_encode_on_normalized_domain() {
        let mut rng = Rng::new(41);
        for dt in [DType::NF4, DType::FP4E2M1, DType::FP4E3M0, DType::Int4,
                   DType::Int8, DType::FP8E4M3] {
            let cb = Codebook::new(dt);
            let enc = Encoder::new(&cb);
            // dense sweep + exact codebook values + exact midpoints (ties)
            for k in 0..=2000 {
                let x = -1.0 + 2.0 * (k as f32) / 2000.0;
                assert_eq!(enc.encode(x), cb.encode(x), "{dt:?} x={x}");
            }
            for &v in &cb.values {
                assert_eq!(enc.encode(v), cb.encode(v), "{dt:?} value {v}");
            }
            for &m in cb.midpoints() {
                assert_eq!(enc.encode(m), cb.encode(m), "{dt:?} mid {m}");
            }
            for _ in 0..500 {
                let x = rng.range_f64(-1.0, 1.0) as f32;
                assert_eq!(enc.encode(x), cb.encode(x), "{dt:?} x={x}");
            }
            assert_eq!(enc.encode(f32::NAN), cb.encode(f32::NAN), "{dt:?}");
        }
    }

    #[test]
    fn flat_fused_matches_scalar_across_threads() {
        let mut rng = Rng::new(42);
        let cb = Codebook::new(DType::NF4);
        let x = rng.normal_vec_f32(64 * 37); // 37 blocks: odd shard splits
        let (sc, sa) = quantize_blockwise(&x, &cb, 64).unwrap();
        for t in [1, 2, 3, 5, 8] {
            let (fc, fa) =
                quantize_blockwise_fused(&x, &cb, 64, Some(t)).unwrap();
            assert_eq!(fc, sc, "codes t={t}");
            assert_eq!(fa, sa, "absmax t={t}");
            let sd = dequantize_blockwise(&sc, &sa, &cb, 64).unwrap();
            let fd =
                dequantize_blockwise_fused(&fc, &fa, &cb, 64, Some(t))
                    .unwrap();
            for (a, b) in sd.iter().zip(fd.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "dequant t={t}");
            }
        }
    }
}
