//! 4-bit code packing: two codes per byte (`lo | hi << 4`), matching
//! `ref.pack_nibbles`. This is the storage format of NF4/FP4 weights.

use anyhow::{ensure, Result};

/// Pack pairs of 4-bit codes into bytes (`codes[0] | codes[1] << 4`).
pub fn pack_nibbles(codes: &[u8]) -> Result<Vec<u8>> {
    ensure!(codes.len() % 2 == 0, "need even number of codes");
    ensure!(codes.iter().all(|&c| c < 16), "codes must fit 4 bits");
    Ok(codes
        .chunks_exact(2)
        .map(|p| p[0] | (p[1] << 4))
        .collect())
}

/// Inverse of [`pack_nibbles`]: two 4-bit codes per input byte.
pub fn unpack_nibbles(packed: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(packed.len() * 2);
    for &b in packed {
        out.push(b & 0xF);
        out.push(b >> 4);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn known_bytes() {
        assert_eq!(pack_nibbles(&[0x1, 0x2, 0xF, 0x0]).unwrap(), vec![0x21, 0x0F]);
        assert_eq!(unpack_nibbles(&[0x21, 0x0F]), vec![0x1, 0x2, 0xF, 0x0]);
    }

    #[test]
    fn rejects_invalid() {
        assert!(pack_nibbles(&[1, 2, 3]).is_err()); // odd
        assert!(pack_nibbles(&[16, 0]).is_err()); // out of range
    }

    #[test]
    fn prop_bijection() {
        prop::check("pack-bijection", prop::default_cases(), |rng| {
            let n = 2 * (1 + rng.below(512));
            let codes: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
            let packed = pack_nibbles(&codes).unwrap();
            assert_eq!(packed.len(), n / 2);
            assert_eq!(unpack_nibbles(&packed), codes);
        });
    }
}
