//! `QuantizedTensor`: the storage container combining packed codes +
//! (optionally double-quantized) absmax constants — the cross-boundary
//! weight representation of `ref.quantize_weight` (layout: W^T flattened
//! row-major, quantization blocks contiguous along the reduction dim).
//!
//! `quantize`/`dequantize` run on the fused multicore kernels
//! ([`super::kernels`]); `quantize_scalar`/`dequantize_scalar` keep the
//! original single-threaded pipeline as the bit-exactness reference
//! oracle (the two are bit-identical — see
//! `rust/tests/prop_quant_fused.rs`).

use anyhow::{ensure, Result};

use super::absmax::{dequantize_blockwise, quantize_blockwise};
use super::codebook::{Codebook, DType};
use super::double::{
    double_dequantize, double_dequantize_scalar, double_quantize,
    double_quantize_scalar, DoubleQuant,
};
use super::kernels::{dequantize_fused_into, quantize_fused};
use super::pack::{pack_nibbles, unpack_nibbles};

/// Absmax constants: raw FP32 or double-quantized.
#[derive(Debug, Clone)]
pub enum Constants {
    /// One FP32 constant per quantization block.
    Raw(Vec<f32>),
    /// Double-quantized constants (paper section 3).
    Double(DoubleQuant),
}

/// A block-quantized weight: packed codes + quantization constants.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    /// The codebook datatype the codes index into.
    pub dtype: DType,
    /// packed nibbles for 4-bit dtypes, raw codes for 8-bit
    pub data: Vec<u8>,
    /// Per-block absmax constants (raw or double-quantized).
    pub constants: Constants,
    /// logical (h, o) shape of the original weight
    pub shape: (usize, usize),
    /// quantization blocksize along the reduction dimension
    pub block: usize,
}

impl QuantizedTensor {
    /// Quantize a (h, o) weight given in row-major `w[h][o]` order, on the
    /// fused multicore kernels (transpose + absmax + encode + pack in one
    /// pass per block; bit-identical to [`Self::quantize_scalar`]).
    pub fn quantize(
        w: &[f32],
        shape: (usize, usize),
        dtype: DType,
        block: usize,
        double_q: Option<usize>,
    ) -> Result<QuantizedTensor> {
        if dtype.bits() == 4 && block % 2 != 0 {
            // packed bytes would straddle blocks; the scalar tier handles
            // this (never hit by the paper's configs — blocks are even)
            return Self::quantize_scalar(w, shape, dtype, block, double_q);
        }
        let cb = Codebook::new(dtype);
        let (data, absmax) = quantize_fused(w, shape, &cb, block, None)?;
        let constants = match double_q {
            Some(block2) => Constants::Double(double_quantize(&absmax, block2)?),
            None => Constants::Raw(absmax),
        };
        Ok(QuantizedTensor { dtype, data, constants, shape, block })
    }

    /// Scalar reference quantizer: the original transpose → encode → pack
    /// pipeline, kept as the bit-exactness oracle for the fused path.
    pub fn quantize_scalar(
        w: &[f32],
        shape: (usize, usize),
        dtype: DType,
        block: usize,
        double_q: Option<usize>,
    ) -> Result<QuantizedTensor> {
        let (h, o) = shape;
        ensure!(w.len() == h * o, "weight length mismatch");
        ensure!((h * o) % block == 0, "size not divisible by block");
        // transpose to W^T flat (blocks run along h for fixed output unit)
        let mut flat = vec![0f32; h * o];
        for i in 0..h {
            for j in 0..o {
                flat[j * h + i] = w[i * o + j];
            }
        }
        let cb = Codebook::new(dtype);
        let (codes, absmax) = quantize_blockwise(&flat, &cb, block)?;
        let data = if dtype.bits() == 4 {
            pack_nibbles(&codes)?
        } else {
            codes
        };
        let constants = match double_q {
            // scalar DQ twin: the oracle must not run the fused kernels
            Some(block2) => {
                Constants::Double(double_quantize_scalar(&absmax, block2)?)
            }
            None => Constants::Raw(absmax),
        };
        Ok(QuantizedTensor { dtype, data, constants, shape, block })
    }

    /// Recover the dequantized weight in row-major (h, o) order
    /// (paper Eq. 6 `doubleDequant` when constants are double-quantized),
    /// on the fused kernels. Allocates only the output (and, for DQ, the
    /// small recovered-constants vector).
    pub fn dequantize(&self) -> Result<Vec<f32>> {
        let (h, o) = self.shape;
        let mut w = vec![0f32; h * o];
        self.dequantize_into(&mut w)?;
        Ok(w)
    }

    /// Dequantize into a caller-provided row-major `(h, o)` buffer —
    /// paired-decode LUT, fused absmax multiply, no unpack buffer, no
    /// clones. Bit-identical to [`Self::dequantize_scalar`].
    pub fn dequantize_into(&self, out: &mut [f32]) -> Result<()> {
        ensure!(
            out.len() == self.shape.0 * self.shape.1,
            "output length mismatch"
        );
        if self.dtype.bits() == 4 && self.block % 2 != 0 {
            let w = self.dequantize_scalar()?;
            out.copy_from_slice(&w);
            return Ok(());
        }
        let cb = Codebook::new(self.dtype);
        let recovered; // keeps the DQ-recovered constants alive
        let absmax: &[f32] = match &self.constants {
            Constants::Raw(a) => a,
            Constants::Double(dq) => {
                recovered = double_dequantize(dq)?;
                &recovered
            }
        };
        dequantize_fused_into(
            &self.data, absmax, &cb, self.block, self.shape, out, None,
        )
    }

    /// Scalar reference dequantizer (unpack → dequantize → un-transpose),
    /// kept as the bit-exactness oracle for the fused path.
    pub fn dequantize_scalar(&self) -> Result<Vec<f32>> {
        let (h, o) = self.shape;
        let cb = Codebook::new(self.dtype);
        let unpacked; // 4-bit codes need a decode buffer; 8-bit borrow
        let codes: &[u8] = if self.dtype.bits() == 4 {
            unpacked = unpack_nibbles(&self.data);
            &unpacked
        } else {
            &self.data
        };
        let recovered;
        let absmax: &[f32] = match &self.constants {
            Constants::Raw(a) => a,
            Constants::Double(dq) => {
                recovered = double_dequantize_scalar(dq)?;
                &recovered
            }
        };
        let flat = dequantize_blockwise(codes, absmax, &cb, self.block)?;
        // un-transpose
        let mut w = vec![0f32; h * o];
        for j in 0..o {
            for i in 0..h {
                w[i * o + j] = flat[j * h + i];
            }
        }
        Ok(w)
    }

    /// Stored bytes including constants (the paper's memory accounting).
    pub fn stored_bytes(&self) -> usize {
        let c = match &self.constants {
            Constants::Raw(a) => a.len() * 4,
            Constants::Double(dq) => dq.stored_bytes(),
        };
        self.data.len() + c
    }

    /// Effective bits per parameter (paper: 4.5 for NF4, 4.127 with DQ).
    pub fn bits_per_param(&self) -> f64 {
        let n = (self.shape.0 * self.shape.1) as f64;
        self.stored_bytes() as f64 * 8.0 / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_shapes() {
        let mut rng = Rng::new(8);
        let (h, o) = (64, 32);
        let w: Vec<f32> = rng.normal_vec_f32(h * o);
        let q = QuantizedTensor::quantize(&w, (h, o), DType::NF4, 64,
                                          Some(256)).unwrap();
        let back = q.dequantize().unwrap();
        assert_eq!(back.len(), h * o);
        let mse: f64 = w.iter().zip(back.iter())
            .map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / w.len() as f64;
        assert!(mse < 0.02, "mse {mse}");
    }

    #[test]
    fn bits_per_param_paper_numbers() {
        let mut rng = Rng::new(9);
        let (h, o) = (256, 256); // 65536 params, 1024 blocks
        let w: Vec<f32> = rng.normal_vec_f32(h * o);
        let raw = QuantizedTensor::quantize(&w, (h, o), DType::NF4, 64, None)
            .unwrap();
        assert!((raw.bits_per_param() - 4.5).abs() < 1e-9);
        let dq = QuantizedTensor::quantize(&w, (h, o), DType::NF4, 64,
                                           Some(256)).unwrap();
        assert!((dq.bits_per_param() - 4.127).abs() < 0.01,
                "bits {}", dq.bits_per_param());
    }

    #[test]
    fn transpose_layout_matches_python_convention() {
        // W (h=2 blocks along h for o fixed): craft weight where each
        // column has a distinct scale; absmax blocks must follow columns.
        let (h, o) = (64, 2);
        let mut w = vec![0f32; h * o];
        for i in 0..h {
            w[i * o] = 1.0; // column 0 all ones
            w[i * o + 1] = 4.0; // column 1 all fours
        }
        let q = QuantizedTensor::quantize(&w, (h, o), DType::NF4, 64, None)
            .unwrap();
        match &q.constants {
            Constants::Raw(a) => assert_eq!(a, &vec![1.0f32, 4.0f32]),
            _ => unreachable!(),
        }
        let back = q.dequantize().unwrap();
        assert_eq!(back, w); // exact: ±1 codes exist
    }

    #[test]
    fn fused_matches_scalar_container() {
        // the full-container contract; exhaustive coverage lives in
        // tests/prop_quant_fused.rs
        let mut rng = Rng::new(10);
        let (h, o) = (96, 48);
        let w: Vec<f32> = rng.normal_vec_f32(h * o);
        for dq in [None, Some(256)] {
            let f = QuantizedTensor::quantize(&w, (h, o), DType::NF4, 32, dq)
                .unwrap();
            let s = QuantizedTensor::quantize_scalar(&w, (h, o), DType::NF4,
                                                     32, dq).unwrap();
            assert_eq!(f.data, s.data);
            let (fd, sd) = (f.dequantize().unwrap(),
                            s.dequantize_scalar().unwrap());
            for (a, b) in fd.iter().zip(sd.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}
