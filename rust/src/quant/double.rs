//! Double Quantization (paper section 3): quantize the quantization
//! constants themselves. c2 (per-block absmax, FP32) are mean-centered and
//! FP8-E4M3 block-quantized with blocksize 256, keeping only FP32 c1 per
//! 256 constants. Overhead per weight parameter drops from 32/64 = 0.5 bits
//! to 8/64 + 32/(64·256) = 0.127 bits — a 0.373 bits/param saving
//! (≈3 GB on a 65B model; verified in `memory::tests`).
//!
//! Padding convention (mirrors `ref.double_quantize` exactly): when the
//! number of constants is not a multiple of 256 the input is padded with
//! its mean, whose centered value 0 has an exact FP8 code.

use anyhow::Result;

use super::absmax::{dequantize_blockwise, quantize_blockwise};
use super::codebook::{Codebook, DType};
use super::kernels::{dequantize_blockwise_fused, quantize_blockwise_fused};

/// Double-quantized quantization constants.
#[derive(Debug, Clone)]
pub struct DoubleQuant {
    /// FP8 codes of the mean-centered constants (padded length).
    pub codes2: Vec<u8>,
    /// second-level FP32 constants, one per `block2` codes
    pub absmax2: Vec<f32>,
    /// mean of the original constants
    pub mean: f32,
    /// original (pre-padding) count
    pub n: usize,
    /// second-level blocksize (the paper uses 256)
    pub block2: usize,
}

/// Quantize absmax constants (f32 mean accumulation like the reference),
/// on the fused kernels — for a 4096x4096/64 weight this is 262k
/// constants, well past the parallel threshold. Bit-identical to
/// [`double_quantize_scalar`].
pub fn double_quantize(absmax: &[f32], block2: usize) -> Result<DoubleQuant> {
    double_quantize_impl(absmax, block2, true)
}

/// Scalar-tier twin of [`double_quantize`] — part of the reference
/// oracle, so it must never route through the fused kernels under test.
pub fn double_quantize_scalar(
    absmax: &[f32],
    block2: usize,
) -> Result<DoubleQuant> {
    double_quantize_impl(absmax, block2, false)
}

fn double_quantize_impl(
    absmax: &[f32],
    block2: usize,
    fused: bool,
) -> Result<DoubleQuant> {
    let n = absmax.len();
    // mean in f64 accumulate, cast f32 (close enough to XLA's tree reduce;
    // cross-boundary equality is tested with tolerance on dequant)
    let mean = (absmax.iter().map(|&v| v as f64).sum::<f64>() / n as f64) as f32;
    let pad = (block2 - n % block2) % block2;
    let mut padded: Vec<f32> = Vec::with_capacity(n + pad);
    padded.extend_from_slice(absmax);
    padded.extend(std::iter::repeat(mean).take(pad));
    for v in padded.iter_mut() {
        *v -= mean;
    }
    let cb = Codebook::new(DType::FP8E4M3);
    let (codes2, absmax2) = if fused {
        quantize_blockwise_fused(&padded, &cb, block2, None)?
    } else {
        quantize_blockwise(&padded, &cb, block2)?
    };
    Ok(DoubleQuant { codes2, absmax2, mean, n, block2 })
}

/// Recover the (approximate) constants; returns exactly `dq.n` values
/// (fused kernels; bit-identical to [`double_dequantize_scalar`]).
pub fn double_dequantize(dq: &DoubleQuant) -> Result<Vec<f32>> {
    double_dequantize_impl(dq, true)
}

/// Scalar-tier twin of [`double_dequantize`] for the reference oracle.
pub fn double_dequantize_scalar(dq: &DoubleQuant) -> Result<Vec<f32>> {
    double_dequantize_impl(dq, false)
}

fn double_dequantize_impl(dq: &DoubleQuant, fused: bool) -> Result<Vec<f32>> {
    let cb = Codebook::new(DType::FP8E4M3);
    let mut out = if fused {
        dequantize_blockwise_fused(&dq.codes2, &dq.absmax2, &cb, dq.block2, None)?
    } else {
        dequantize_blockwise(&dq.codes2, &dq.absmax2, &cb, dq.block2)?
    };
    for v in out.iter_mut() {
        *v += dq.mean;
    }
    out.truncate(dq.n);
    Ok(out)
}

impl DoubleQuant {
    /// Stored bytes (codes + second-level constants + mean).
    pub fn stored_bytes(&self) -> usize {
        self.codes2.len() + self.absmax2.len() * 4 + 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn absmax_like(rng: &mut Rng, n: usize) -> Vec<f32> {
        // absmax constants are positive, clustered around E|max of block|
        (0..n).map(|_| (rng.normal().abs() * 0.3 + 2.0) as f32).collect()
    }

    #[test]
    fn roundtrip_close() {
        let mut rng = Rng::new(5);
        let am = absmax_like(&mut rng, 1024);
        let dq = double_quantize(&am, 256).unwrap();
        let back = double_dequantize(&dq).unwrap();
        assert_eq!(back.len(), 1024);
        for (a, b) in am.iter().zip(back.iter()) {
            // FP8-E4M3 relative step ≈ 1/16 of the centered range
            assert!((a - b).abs() < 0.15, "{a} vs {b}");
        }
    }

    #[test]
    fn padding_handles_non_multiple() {
        let mut rng = Rng::new(6);
        let am = absmax_like(&mut rng, 100); // 100 % 256 != 0
        let dq = double_quantize(&am, 256).unwrap();
        assert_eq!(dq.codes2.len(), 256);
        let back = double_dequantize(&dq).unwrap();
        assert_eq!(back.len(), 100);
    }

    #[test]
    fn memory_saving_matches_paper() {
        // paper: 0.5 -> 0.127 bits per parameter for block=64, block2=256
        let n_params: usize = 64 * 256 * 8; // 8 groups of 256 blocks
        let n_blocks = n_params / 64;
        let plain_bits = (n_blocks * 32) as f64 / n_params as f64;
        assert!((plain_bits - 0.5).abs() < 1e-9);
        let mut rng = Rng::new(7);
        let am = absmax_like(&mut rng, n_blocks);
        let dq = double_quantize(&am, 256).unwrap();
        let dq_bits = (dq.stored_bytes() * 8) as f64 / n_params as f64;
        assert!((dq_bits - 0.127).abs() < 0.002, "dq bits {dq_bits}");
        assert!((plain_bits - dq_bits - 0.373).abs() < 0.002);
    }

    #[test]
    fn prop_constant_absmax_is_lossless() {
        // all-equal constants center to exactly zero => exact recovery
        prop::check("dq-constant", 16, |rng| {
            let v = (rng.normal().abs() + 1.0) as f32;
            let am = vec![v; 512];
            let dq = double_quantize(&am, 256).unwrap();
            let back = double_dequantize(&dq).unwrap();
            for b in back {
                assert_eq!(b, v);
            }
        });
    }

    #[test]
    fn prop_error_bounded_by_fp8_step() {
        prop::check("dq-bounded", prop::default_cases(), |rng| {
            let n = 1 + rng.below(1000);
            let am = absmax_like(rng, n);
            let dq = double_quantize(&am, 256).unwrap();
            let back = double_dequantize(&dq).unwrap();
            // bound: half of the max FP8 gap (~2/15 of range) * block absmax
            let centered_max = am
                .iter()
                .map(|v| (v - dq.mean).abs())
                .fold(0f32, f32::max);
            let bound = centered_max * 0.07 + 1e-5;
            for (a, b) in am.iter().zip(back.iter()) {
                assert!((a - b).abs() <= bound, "{a} vs {b} bound {bound}");
            }
        });
    }
}
