//! Codebook datatypes (paper section 3 + Table 2).
//!
//! A codebook is a sorted table of 2^k normalized values in [-1, 1];
//! quantization maps an absmax-normalized input to the nearest entry
//! (round-to-nearest by bin midpoint, ties toward the upper code — the
//! same convention as the Python reference).

use crate::util::stats::ndtri;

/// The quantization datatypes the paper evaluates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 4-bit NormalFloat (the paper's contribution, Appendix E).
    NF4,
    /// 4-bit float, 2 exponent / 1 mantissa bit (Table 2 "Float4 (E2M1)").
    FP4E2M1,
    /// 4-bit float, 3 exponent / 0 mantissa bits (Table 2 "Float4 (E3M0)").
    FP4E3M0,
    /// Symmetric 4-bit integer (Table 2 "Int4").
    Int4,
    /// Symmetric 8-bit integer (Table 3 "QLoRA Int8").
    Int8,
    /// 8-bit float E4M3 — the Double Quantization codebook.
    FP8E4M3,
}

impl DType {
    /// Canonical lowercase name (matches `configs.py` / the CLI).
    pub fn name(self) -> &'static str {
        match self {
            DType::NF4 => "nf4",
            DType::FP4E2M1 => "fp4_e2m1",
            DType::FP4E3M0 => "fp4_e3m0",
            DType::Int4 => "int4",
            DType::Int8 => "int8",
            DType::FP8E4M3 => "fp8_e4m3",
        }
    }

    /// Parse a canonical name back into a datatype.
    pub fn from_name(s: &str) -> Option<DType> {
        Some(match s {
            "nf4" => DType::NF4,
            "fp4_e2m1" => DType::FP4E2M1,
            "fp4_e3m0" => DType::FP4E3M0,
            "int4" => DType::Int4,
            "int8" => DType::Int8,
            "fp8_e4m3" => DType::FP8E4M3,
            _ => return None,
        })
    }

    /// Bits per stored code.
    pub fn bits(self) -> usize {
        match self {
            DType::Int8 | DType::FP8E4M3 => 8,
            _ => 4,
        }
    }
}

/// The paper's exact NF4 values (Appendix E). Canonical table for both the
/// Rust and Python implementations (bit-identical across the boundary).
pub const NF4_PAPER: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

const NF4_OFFSET: f64 = 0.9677083;

/// A sorted codebook plus precomputed bin midpoints.
#[derive(Debug, Clone)]
pub struct Codebook {
    /// The datatype these values realize.
    pub dtype: DType,
    /// Sorted normalized values in [-1, 1].
    pub values: Vec<f32>,
    /// midpoints between consecutive values (len = values.len() - 1)
    mids: Vec<f32>,
}

impl Codebook {
    /// The canonical codebook for `dtype` (NF4 uses the paper's exact
    /// published table).
    pub fn new(dtype: DType) -> Codebook {
        let values = match dtype {
            DType::NF4 => NF4_PAPER.to_vec(),
            DType::FP4E2M1 => fp_values(2, 1),
            DType::FP4E3M0 => fp_values(3, 0),
            DType::FP8E4M3 => fp_values(4, 3),
            DType::Int4 => int_values(4),
            DType::Int8 => int_values(8),
        };
        Self::from_values(dtype, values)
    }

    /// Build from explicit sorted values (e.g. a derived NFk table).
    pub fn from_values(dtype: DType, values: Vec<f32>) -> Codebook {
        debug_assert!(values.windows(2).all(|w| w[0] < w[1]), "must be sorted");
        // midpoints in f32 — identical arithmetic to the Python reference
        let mids = values
            .windows(2)
            .map(|w| (w[0] + w[1]) * 0.5)
            .collect();
        Codebook { dtype, values, mids }
    }

    /// Number of codebook entries (2^bits, minus ±0 collapses).
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// The precomputed bin midpoints (len = `values.len() - 1`). Exposed
    /// for the fused kernels (`quant::kernels`), which precompute padded
    /// compare tables from them.
    pub fn midpoints(&self) -> &[f32] {
        &self.mids
    }

    /// The symmetric-integer encode shortcut: `Some(half)` when codes can
    /// be computed as `floor(clamp(x, -1, 1)·half + half + 0.5)` —
    /// bit-identical to the midpoint search for these uniform grids (the
    /// midpoints are exactly `(2i+1)/(2·half)` and ties round up either
    /// way; property-tested in `tests/prop_quant_extra.rs`). Keyed off
    /// `dtype` like the historical fast path, so it applies only to the
    /// canonical `Codebook::new` tables, never to derived NFk values.
    pub fn int_fast_half(&self) -> Option<f32> {
        match self.dtype {
            DType::Int4 => Some(7f32),
            DType::Int8 => Some(127f32),
            _ => None,
        }
    }

    /// Whether the codebook has no entries (never true for built-ins).
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Nearest code for a normalized value: `sum(x >= mids)`, i.e.
    /// round-to-nearest with ties to the upper code (matches ref.py).
    #[inline]
    pub fn encode(&self, x: f32) -> u8 {
        // binary search over midpoints: count of mids <= x
        // (mids sorted ascending; `x >= mids[i]` ⇔ i < count)
        let mut lo = 0usize;
        let mut hi = self.mids.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if x >= self.mids[mid] {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as u8
    }

    /// The normalized value a code dequantizes to.
    #[inline]
    pub fn decode(&self, code: u8) -> f32 {
        self.values[code as usize]
    }

    /// Does the codebook contain an exact zero? (The paper requires this
    /// for error-free padding; NF4's asymmetric construction guarantees it.)
    pub fn has_exact_zero(&self) -> bool {
        self.values.iter().any(|&v| v == 0.0)
    }
}

/// Generic k-bit float values (mirrors ref.fp_codebook; f64 math, f32 cast).
fn fp_values(ebits: u32, mbits: u32) -> Vec<f32> {
    let bias = (1i32 << (ebits - 1)) - 1;
    let mut mags: Vec<f64> = Vec::new();
    for e in 0..(1u32 << ebits) {
        for m in 0..(1u32 << mbits) {
            let v = if e == 0 {
                2f64.powi(1 - bias) * (m as f64 / 2f64.powi(mbits as i32))
            } else {
                2f64.powi(e as i32 - bias)
                    * (1.0 + m as f64 / 2f64.powi(mbits as i32))
            };
            mags.push(v);
        }
    }
    mags.sort_by(|a, b| a.total_cmp(b));
    mags.dedup();
    // pallas-lint: allow(no-transitive-panic) — mags holds 2^(ebits+mbits) >= 1 magnitudes by construction, so last() is always Some
    let mx = *mags.last().unwrap();
    let vals: Vec<f64> = mags.iter().map(|m| m / mx).collect();
    let mut all: Vec<f64> =
        vals.iter().map(|v| -v).chain(vals.iter().copied()).collect();
    all.sort_by(|a, b| a.total_cmp(b));
    all.dedup();
    all.into_iter().map(|v| v as f32).collect()
}

/// Symmetric integer values i/(2^{b-1}-1) — f32 division like jnp.
fn int_values(bits: u32) -> Vec<f32> {
    let half = (1i32 << (bits - 1)) - 1;
    (-half..=half).map(|i| i as f32 / half as f32).collect()
}

/// Derive the k-bit NormalFloat codebook from first principles (paper
/// Eq. 4, generalized): 2^{k-1}+1 quantiles of N(0,1) for the positive
/// half, 2^{k-1} for the negative half, unify, drop the duplicate zero,
/// normalize into [-1, 1]. `derive_nfk(4)` reproduces `NF4_PAPER` to
/// ~1e-7 (unit-tested). k > 4 realizes the paper's section-8 direction of
/// exploring other bit widths (NF3 for the "3-bit base models" question,
/// NF8 as a drop-in for the DQ constants).
pub fn derive_nfk(bits: u32) -> Vec<f32> {
    assert!((2..=8).contains(&bits), "NFk supports 2..=8 bits");
    let half = 1usize << (bits - 1);
    let mut pos: Vec<f64> = Vec::new();
    for i in 0..half {
        // linspace(offset, 0.5, half+1)[:-1]
        let p = NF4_OFFSET + (0.5 - NF4_OFFSET) * (i as f64 / half as f64);
        pos.push(ndtri(p));
    }
    let mut neg: Vec<f64> = Vec::new();
    for i in 0..(half - 1) {
        // linspace(offset, 0.5, half)[:-1]
        let p =
            NF4_OFFSET + (0.5 - NF4_OFFSET) * (i as f64 / (half - 1) as f64);
        neg.push(-ndtri(p));
    }
    let mut vals: Vec<f64> = neg;
    vals.push(0.0);
    vals.extend(pos);
    vals.sort_by(|a, b| a.total_cmp(b));
    let mx = vals.iter().fold(0f64, |a, &v| a.max(v.abs()));
    vals.into_iter().map(|v| (v / mx) as f32).collect()
}

/// Backwards-compatible alias: the NF4 derivation.
pub fn derive_nf4() -> Vec<f32> {
    derive_nfk(4)
}

/// Codebook for a derived k-bit NormalFloat (k != 4 — extension beyond
/// the paper; k == 4 uses the canonical published constants).
pub fn nfk_codebook(bits: u32) -> Codebook {
    if bits == 4 {
        return Codebook::new(DType::NF4);
    }
    Codebook::from_values(DType::NF4, derive_nfk(bits))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nfk_extension_properties() {
        // k=4 matches the published table
        let nf4 = derive_nfk(4);
        for (d, p) in nf4.iter().zip(NF4_PAPER.iter()) {
            assert!((d - p).abs() < 3e-6);
        }
        // sizes, sortedness, exact zero for every k
        for k in 2..=8u32 {
            let cb = nfk_codebook(k);
            assert_eq!(cb.len(), 1usize << k, "k={k}");
            assert!(cb.values.windows(2).all(|w| w[0] < w[1]));
            assert!(cb.has_exact_zero());
        }
        // quantization error strictly improves with bit width (paper §8:
        // the precision/bits trade-off direction)
        let mut rng = crate::util::rng::Rng::new(77);
        let x: Vec<f32> = rng.normal_vec_f32(64 * 64);
        let mse = |k: u32| {
            let cb = nfk_codebook(k);
            let (c, a) =
                crate::quant::quantize_blockwise(&x, &cb, 64).unwrap();
            let y =
                crate::quant::dequantize_blockwise(&c, &a, &cb, 64).unwrap();
            x.iter()
                .zip(y.iter())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        let errs: Vec<f64> = (2..=8).map(mse).collect();
        for w in errs.windows(2) {
            assert!(w[1] < w[0] * 0.7, "error must drop with bits: {errs:?}");
        }
    }

    #[test]
    fn nf4_derivation_matches_paper() {
        let derived = derive_nf4();
        for (d, p) in derived.iter().zip(NF4_PAPER.iter()) {
            assert!(
                (d - p).abs() < 3e-6,
                "derived {d} vs paper {p}"
            );
        }
    }

    #[test]
    fn all_codebooks_sorted_with_zero() {
        for dt in [DType::NF4, DType::FP4E2M1, DType::FP4E3M0, DType::Int4,
                   DType::Int8, DType::FP8E4M3] {
            let cb = Codebook::new(dt);
            assert!(cb.values.windows(2).all(|w| w[0] < w[1]), "{dt:?}");
            assert!(cb.has_exact_zero(), "{dt:?} lacks exact zero");
            assert_eq!(*cb.values.first().unwrap(), -1.0);
            assert_eq!(*cb.values.last().unwrap(), 1.0);
        }
    }

    #[test]
    fn sizes() {
        assert_eq!(Codebook::new(DType::NF4).len(), 16);
        assert_eq!(Codebook::new(DType::FP4E2M1).len(), 15); // ±0 collapse
        assert_eq!(Codebook::new(DType::FP4E3M0).len(), 15);
        assert_eq!(Codebook::new(DType::Int4).len(), 15);
        assert_eq!(Codebook::new(DType::Int8).len(), 255);
        assert_eq!(Codebook::new(DType::FP8E4M3).len(), 255);
    }

    #[test]
    fn encode_decode_nearest() {
        let cb = Codebook::new(DType::NF4);
        // every codebook value encodes to itself
        for (i, &v) in cb.values.iter().enumerate() {
            assert_eq!(cb.encode(v) as usize, i);
        }
        // extremes clamp
        assert_eq!(cb.encode(-5.0), 0);
        assert_eq!(cb.encode(5.0) as usize, cb.len() - 1);
        // nearest: 0.08 is closer to 0.0796 than to 0.1609
        assert_eq!(cb.decode(cb.encode(0.08)), cb.values[8]);
    }

    #[test]
    fn encode_matches_linear_scan() {
        let cb = Codebook::new(DType::FP8E4M3);
        let mut rng = crate::util::rng::Rng::new(11);
        for _ in 0..2000 {
            let x = (rng.range_f64(-1.2, 1.2)) as f32;
            let fast = cb.encode(x);
            // reference: argmin |x - v| with ties to upper
            let slow = cb
                .values
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    let da = (x - **a).abs();
                    let db = (x - **b).abs();
                    da.total_cmp(&db).then(std::cmp::Ordering::Greater)
                })
                .unwrap()
                .0;
            assert_eq!(fast as usize, slow, "x={x}");
        }
    }

    #[test]
    fn fp4_e2m1_known_values() {
        let cb = Codebook::new(DType::FP4E2M1);
        let expect = [0.0f32, 1.0 / 12.0, 1.0 / 6.0, 0.25, 1.0 / 3.0, 0.5,
                      2.0 / 3.0, 1.0];
        let pos: Vec<f32> = cb.values.iter().copied().filter(|v| *v >= 0.0)
            .collect();
        for (a, b) in pos.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-7, "{a} vs {b}");
        }
    }
}
