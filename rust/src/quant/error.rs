//! Quantization-error measurement (drives Table 2 / Figure 3).
//!
//! Round-trips weight tensors through each datatype and reports MSE / MAE /
//! SQNR. The experiments map measured error to the paper's perplexity /
//! accuracy scales via documented calibration (see `experiments::table2`).

use anyhow::Result;

use super::codebook::{Codebook, DType};
use super::double::{double_dequantize, double_quantize};
use super::kernels::{dequantize_blockwise_fused, quantize_blockwise_fused};

/// Round-trip quantization error summary for one tensor.
#[derive(Debug, Clone, Copy)]
pub struct ErrorStats {
    /// mean squared error
    pub mse: f64,
    /// mean absolute error
    pub mae: f64,
    /// signal-to-quantization-noise ratio in dB
    pub sqnr_db: f64,
}

/// Measure round-trip error of `x` under `dtype` (optionally with DQ).
pub fn quant_error(
    x: &[f32],
    dtype: DType,
    block: usize,
    double_q: Option<usize>,
) -> Result<ErrorStats> {
    // fused tier: this round-trip is the inner loop of the Table 2 /
    // Figure 3 sweeps and the capability model, so it runs multicore
    let cb = Codebook::new(dtype);
    let (codes, absmax) = quantize_blockwise_fused(x, &cb, block, None)?;
    let absmax = match double_q {
        Some(b2) => double_dequantize(&double_quantize(&absmax, b2)?)?,
        None => absmax,
    };
    let y = dequantize_blockwise_fused(&codes, &absmax, &cb, block, None)?;
    let n = x.len() as f64;
    let mut se = 0f64;
    let mut ae = 0f64;
    let mut power = 0f64;
    for (a, b) in x.iter().zip(y.iter()) {
        let e = (a - b) as f64;
        se += e * e;
        ae += e.abs();
        power += (*a as f64) * (*a as f64);
    }
    let mse = se / n;
    Ok(ErrorStats {
        mse,
        mae: ae / n,
        sqnr_db: 10.0 * ((power / n) / mse.max(1e-30)).log10(),
    })
}

/// The paper's weight model: mostly zero-centered normal (Appendix F) with
/// a small fraction of outlier coordinates (the LLM.int8() phenomenology
/// the paper's block-wise design targets). `frac`/`scale` control outliers.
pub fn synthetic_llm_weights(
    rng: &mut crate::util::rng::Rng,
    n: usize,
    outlier_frac: f64,
    outlier_scale: f64,
) -> Vec<f32> {
    (0..n)
        .map(|_| {
            let z = rng.normal();
            if rng.bool(outlier_frac) {
                (z * outlier_scale) as f32
            } else {
                z as f32
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ordering_on_llm_weights() {
        // Figure 3 / Table 2 headline shape: NF4 best, Int4 worst.
        let mut rng = Rng::new(21);
        let x = synthetic_llm_weights(&mut rng, 64 * 512, 0.01, 5.0);
        let e = |dt| quant_error(&x, dt, 64, None).unwrap().mse;
        let nf4 = e(DType::NF4);
        let fp4 = e(DType::FP4E2M1);
        let int4 = e(DType::Int4);
        assert!(nf4 < fp4, "nf4 {nf4} < fp4 {fp4}");
        assert!(fp4 < int4, "fp4 {fp4} < int4 {int4}");
    }

    #[test]
    fn dq_adds_negligible_error() {
        // paper: "double quantization ... without degrading performance"
        let mut rng = Rng::new(22);
        let x = synthetic_llm_weights(&mut rng, 64 * 2048, 0.01, 5.0);
        let plain = quant_error(&x, DType::NF4, 64, None).unwrap().mse;
        let dq = quant_error(&x, DType::NF4, 64, Some(256)).unwrap().mse;
        assert!(dq < plain * 1.02, "dq {dq} vs plain {plain}");
    }

    #[test]
    fn int8_much_better_than_4bit() {
        let mut rng = Rng::new(23);
        let x = synthetic_llm_weights(&mut rng, 64 * 256, 0.0, 1.0);
        let i8e = quant_error(&x, DType::Int8, 64, None).unwrap().mse;
        let nf4 = quant_error(&x, DType::NF4, 64, None).unwrap().mse;
        assert!(i8e * 20.0 < nf4);
    }
}
