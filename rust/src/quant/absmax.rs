//! Block-wise absmax quantization (paper Background, Eq. 1–2).
//!
//! The input is chunked into contiguous blocks of size B; each block is
//! normalized by its absolute maximum and mapped to the nearest codebook
//! entry. Small blocks (the paper uses B=64 for weights) bound the damage
//! any outlier can do to its neighbours.
//!
//! This module is the **scalar reference tier**: simple, obviously-correct
//! single-threaded kernels that serve as the bit-exactness oracle for the
//! fused/parallel tier in [`super::kernels`] (see ARCHITECTURE.md,
//! "Quantization layer"). Hot paths should call the fused tier; changes
//! here must keep the two tiers bit-identical (property-tested).

use anyhow::{ensure, Result};

use super::codebook::Codebook;

/// Quantize a flat f32 slice. Returns (codes, absmax-per-block).
pub fn quantize_blockwise(
    x: &[f32],
    cb: &Codebook,
    block: usize,
) -> Result<(Vec<u8>, Vec<f32>)> {
    ensure!(block > 0, "block must be positive");
    ensure!(
        x.len() % block == 0,
        "length {} not divisible by block {}",
        x.len(),
        block
    );
    let nb = x.len() / block;
    let mut codes = vec![0u8; x.len()];
    let mut absmax = vec![0f32; nb];
    // fast path for symmetric integer codebooks (shared with the fused
    // encoder in `quant::kernels` — see `Codebook::int_fast_half`)
    let int_half = cb.int_fast_half();
    for b in 0..nb {
        let chunk = &x[b * block..(b + 1) * block];
        let mut am = 0f32;
        for &v in chunk {
            am = am.max(v.abs());
        }
        absmax[b] = am;
        let scale = if am > 0.0 { am } else { 1.0 };
        let out = &mut codes[b * block..(b + 1) * block];
        // NOTE: x/scale must stay a true division (not *reciprocal) to
        // remain bit-identical with the XLA reference computation.
        match int_half {
            Some(half) => {
                for (o, &v) in out.iter_mut().zip(chunk) {
                    let xn = (v / scale).clamp(-1.0, 1.0);
                    // round-half-up matches `sum(xn >= mids)` exactly
                    *o = (xn * half + half + 0.5).floor() as u8;
                }
            }
            None => {
                for (o, &v) in out.iter_mut().zip(chunk) {
                    *o = cb.encode(v / scale);
                }
            }
        }
    }
    Ok((codes, absmax))
}

/// Dequantize codes produced by [`quantize_blockwise`].
pub fn dequantize_blockwise(
    codes: &[u8],
    absmax: &[f32],
    cb: &Codebook,
    block: usize,
) -> Result<Vec<f32>> {
    ensure!(codes.len() % block == 0, "bad codes length");
    ensure!(codes.len() / block == absmax.len(), "absmax length mismatch");
    let mut out = vec![0f32; codes.len()];
    for b in 0..absmax.len() {
        let am = absmax[b];
        for i in 0..block {
            out[b * block + i] = cb.decode(codes[b * block + i]) * am;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codebook::{Codebook, DType};
    use crate::util::prop::{self, gen};
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded() {
        // the worst-case relative error of round-to-nearest is half the
        // widest codebook gap times the block absmax
        let cb = Codebook::new(DType::NF4);
        let max_gap = cb
            .values
            .windows(2)
            .map(|w| w[1] - w[0])
            .fold(0f32, f32::max);
        let mut rng = Rng::new(3);
        let x = rng.normal_vec_f32(64 * 32);
        let (codes, absmax) = quantize_blockwise(&x, &cb, 64).unwrap();
        let y = dequantize_blockwise(&codes, &absmax, &cb, 64).unwrap();
        for b in 0..absmax.len() {
            for i in 0..64 {
                let idx = b * 64 + i;
                assert!(
                    (x[idx] - y[idx]).abs() <= 0.5 * max_gap * absmax[b] + 1e-6,
                    "error too large at {idx}"
                );
            }
        }
    }

    #[test]
    fn zero_block_is_exact() {
        let cb = Codebook::new(DType::NF4);
        let x = vec![0f32; 128];
        let (codes, absmax) = quantize_blockwise(&x, &cb, 64).unwrap();
        let y = dequantize_blockwise(&codes, &absmax, &cb, 64).unwrap();
        assert!(y.iter().all(|&v| v == 0.0));
        assert!(absmax.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn block_isolation() {
        // an outlier in one block must not change codes in another
        let cb = Codebook::new(DType::NF4);
        let mut rng = Rng::new(4);
        let mut x = rng.normal_vec_f32(128);
        let (codes_a, _) = quantize_blockwise(&x, &cb, 64).unwrap();
        x[0] = 1e6; // outlier in block 0
        let (codes_b, _) = quantize_blockwise(&x, &cb, 64).unwrap();
        assert_eq!(&codes_a[64..], &codes_b[64..]);
    }

    #[test]
    fn rejects_bad_lengths() {
        let cb = Codebook::new(DType::NF4);
        assert!(quantize_blockwise(&[0.0; 65], &cb, 64).is_err());
        assert!(dequantize_blockwise(&[0; 64], &[1.0, 2.0], &cb, 64).is_err());
    }

    #[test]
    fn prop_quantize_idempotent() {
        // quantizing an already-dequantized tensor must be a fixed point
        prop::check("quant-idempotent", prop::default_cases(), |rng| {
            let n = gen::blocked_len(rng, 64, 8);
            let x = gen::weight_vec(rng, n);
            let cb = Codebook::new(DType::NF4);
            let (c1, a1) = quantize_blockwise(&x, &cb, 64).unwrap();
            let y = dequantize_blockwise(&c1, &a1, &cb, 64).unwrap();
            let (c2, a2) = quantize_blockwise(&y, &cb, 64).unwrap();
            let z = dequantize_blockwise(&c2, &a2, &cb, 64).unwrap();
            for (yi, zi) in y.iter().zip(z.iter()) {
                assert!((yi - zi).abs() <= 1e-6 * yi.abs().max(1.0));
            }
        });
    }

    #[test]
    fn prop_absmax_is_per_block_max() {
        prop::check("absmax-max", prop::default_cases(), |rng| {
            let n = gen::blocked_len(rng, 32, 16);
            let x = gen::outlier_vec(rng, n, 0.02, 10.0);
            let cb = Codebook::new(DType::FP4E2M1);
            let (_, absmax) = quantize_blockwise(&x, &cb, 32).unwrap();
            for (b, am) in absmax.iter().enumerate() {
                let expect = x[b * 32..(b + 1) * 32]
                    .iter()
                    .fold(0f32, |a, v| a.max(v.abs()));
                assert_eq!(*am, expect);
            }
        });
    }

    #[test]
    fn prop_nf4_beats_int4_on_normal_data() {
        // the paper's core claim, as a property over random normal tensors
        prop::check("nf4-beats-int4", 16, |rng| {
            let n = 64 * 64;
            let x: Vec<f32> = rng.normal_vec_f32(n);
            let mse = |dt: DType| {
                let cb = Codebook::new(dt);
                let (c, a) = quantize_blockwise(&x, &cb, 64).unwrap();
                let y = dequantize_blockwise(&c, &a, &cb, 64).unwrap();
                x.iter()
                    .zip(y.iter())
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    / n as f64
            };
            assert!(mse(DType::NF4) < mse(DType::Int4));
        });
    }
}
