//! Generative judge model — the stand-in for GPT-4 and Mechanical-Turk
//! annotators (paper section 5.2 / 6.2).
//!
//! A judged match between systems A and B on a prompt:
//!   1. each system's response quality = latent quality (per-judge-kind:
//!      the paper's human and GPT-4 rankings genuinely differ) + a
//!      *prompt-specific* component shared by all annotators,
//!   2. the judge perceives the difference through **logistic** noise with
//!      scale 400/ln10 — exactly Elo's expected-score model, so tournament
//!      ratings recover the latent scale rather than saturating — plus the
//!      biases the paper documents: order bias (first response favoured)
//!      and GPT-4's self-preference,
//!   3. close calls become ties (three-class labeling, section 5.2).
//!
//! All downstream statistics — Elo, CIs, Kendall τ / Spearman ρ / Fleiss κ
//! agreement — are real computations over these sampled judgments.

use crate::elo::Outcome;
use crate::util::rng::Rng;

use super::systems::System;

/// Elo's logistic scale: 400 / ln 10.
const ELO_SCALE: f64 = 173.717792761;

/// Which annotator population a [`Judge`] models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JudgeKind {
    /// the GPT-4 judge (order- and self-biased, self-consistent)
    Gpt4,
    /// Mechanical-Turk annotators (noisier, own latent ranking)
    Human,
}

/// A biased, noisy pairwise judge (the generative model of section 5.2).
#[derive(Debug, Clone)]
pub struct Judge {
    /// which annotator population this judge models
    pub kind: JudgeKind,
    /// extra per-annotator Gaussian noise on top of the logistic
    /// comparison noise (humans are less self-consistent)
    pub noise: f64,
    /// quality margin below which the judge declares a tie
    pub tie_margin: f64,
    /// additive bonus to the response shown first (paper: "strong order
    /// effects with GPT-4 assigning higher scores to the system appearing
    /// first")
    pub order_bias: f64,
    /// additive bonus GPT-4 gives its own outputs (paper: Elo 1348 under
    /// GPT-4 judging vs 1176 under humans)
    pub self_bias: f64,
}

fn logistic(rng: &mut Rng, scale: f64) -> f64 {
    let u = rng.f64().clamp(1e-12, 1.0 - 1e-12);
    scale * (u / (1.0 - u)).ln()
}

impl Judge {
    /// The GPT-4 judge with the paper's documented biases.
    pub fn gpt4() -> Judge {
        Judge {
            kind: JudgeKind::Gpt4,
            noise: 40.0,
            tie_margin: 55.0,
            order_bias: 35.0,
            self_bias: 170.0,
        }
    }

    /// Human annotators: noisier, own latent perception (`human_quality`).
    pub fn human() -> Judge {
        Judge {
            kind: JudgeKind::Human,
            noise: 90.0,
            tie_margin: 65.0,
            order_bias: 10.0,
            self_bias: 0.0,
        }
    }

    /// Latent quality of `sys` as this judge perceives it on the chosen benchmark.
    pub fn quality(&self, sys: &System, vicuna: bool) -> f64 {
        let mut q = if !vicuna {
            sys.oa_quality
        } else if self.kind == JudgeKind::Human {
            sys.human_quality
        } else {
            sys.vicuna_quality
        };
        if self.kind == JudgeKind::Gpt4 && sys.is_gpt4 {
            q += self.self_bias;
        }
        q
    }

    /// Three-class pairwise judgment; `a` is shown first. `prompt_a/_b`
    /// are per-(prompt, system) quality components, shared across
    /// annotators of the same prompt (pass 0.0 for marginal sampling).
    pub fn judge_pair_with_prompt(
        &self,
        a: &System,
        b: &System,
        vicuna: bool,
        prompt_a: f64,
        prompt_b: f64,
        rng: &mut Rng,
    ) -> Outcome {
        let qa = self.quality(a, vicuna) + prompt_a + self.order_bias;
        let qb = self.quality(b, vicuna) + prompt_b;
        // residual per-judgment randomness; the bulk of match-level
        // variance lives in the shared prompt effects so that annotators
        // of the same prompt agree well above chance (Fleiss kappa)
        let diff = qa - qb
            + logistic(rng, 60.0)
            + rng.normal() * self.noise;
        if diff.abs() < self.tie_margin {
            Outcome::Tie
        } else if diff > 0.0 {
            Outcome::WinA
        } else {
            Outcome::WinB
        }
    }

    /// Marginal judgment: fresh prompt effects drawn internally. The total
    /// difference noise (2 prompt draws + logistic residual + annotator
    /// noise) has std ~= pi*(400/ln10)/sqrt(3), i.e. Elo's logistic
    /// expectation -- tournament ratings recover the latent scale.
    pub fn judge_pair(
        &self,
        a: &System,
        b: &System,
        vicuna: bool,
        rng: &mut Rng,
    ) -> Outcome {
        let pa = Self::prompt_effect(rng);
        let pb = Self::prompt_effect(rng);
        self.judge_pair_with_prompt(a, b, vicuna, pa, pb, rng)
    }

    /// Draw the shared per-prompt quality component for one system.
    /// Scale ~1.15*ELO_SCALE: two such draws plus the residual noise give
    /// the comparison difference the spread Elo's logistic model expects.
    pub fn prompt_effect(rng: &mut Rng) -> f64 {
        rng.normal() * (1.15 * ELO_SCALE)
    }

    /// Score mode (Table 6): rate `sys` and ChatGPT out of 10 with `sys`
    /// shown in position `sys_first`; returns (sys_score, chatgpt_score).
    pub fn score_vs_chatgpt(
        &self,
        sys: &System,
        chatgpt: &System,
        sys_first: bool,
        rng: &mut Rng,
    ) -> (f64, f64) {
        let mut vs = self.quality(sys, true)
            + Self::prompt_effect(rng)
            + rng.normal() * 60.0;
        let mut vc = self.quality(chatgpt, true)
            + Self::prompt_effect(rng)
            + rng.normal() * 60.0;
        if sys_first {
            vs += self.order_bias;
        } else {
            vc += self.order_bias;
        }
        // map Elo-scale quality to a 1..10 rating (anchor: 1000 -> 7.0)
        let to_score =
            |v: f64| ((v - 1000.0) / 150.0 + 7.0).clamp(1.0, 10.0);
        (to_score(vs), to_score(vc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::systems::roster;

    fn winrate(j: &Judge, a: usize, b: usize, n: usize, seed: u64) -> f64 {
        let r = roster();
        let mut rng = Rng::new(seed);
        let mut wins = 0.0;
        for _ in 0..n {
            match j.judge_pair(&r[a], &r[b], true, &mut rng) {
                Outcome::WinA => wins += 1.0,
                Outcome::Tie => wins += 0.5,
                Outcome::WinB => {}
            }
        }
        wins / n as f64
    }

    #[test]
    fn stronger_system_wins_more() {
        let j = Judge::gpt4();
        // GPT-4 (idx 0) vs Guanaco-7B (idx 7)
        assert!(winrate(&j, 0, 7, 400, 1) > 0.8);
        // Guanaco-65B (1) vs Bard (6)
        assert!(winrate(&j, 1, 6, 400, 2) > 0.6);
    }

    #[test]
    fn winrates_are_elo_consistent() {
        // paper: Elo 1100 vs 1000 → ≈64% expected win rate; the judge's
        // logistic noise must reproduce that, not saturate
        let j = Judge::gpt4();
        // Guanaco-65B (1022) vs Guanaco-13B (916): Δ=106 ⇒ expect ~0.65
        let w = winrate(&j, 1, 5, 4000, 3);
        assert!((w - 0.65).abs() < 0.08, "winrate {w}");
    }

    #[test]
    fn order_bias_is_measurable() {
        let j = Judge::gpt4();
        let r = roster();
        let mut rng = Rng::new(3);
        let mut first_wins = 0;
        let mut second_wins = 0;
        for _ in 0..2000 {
            match j.judge_pair(&r[1], &r[1], true, &mut rng) {
                Outcome::WinA => first_wins += 1,
                Outcome::WinB => second_wins += 1,
                Outcome::Tie => {}
            }
        }
        assert!(first_wins as f64 > second_wins as f64 * 1.1,
                "{first_wins} vs {second_wins}");
    }

    #[test]
    fn gpt4_self_preference() {
        let g = Judge::gpt4();
        let h = Judge::human();
        let wg = winrate(&g, 0, 1, 800, 4);
        let wh = winrate(&h, 0, 1, 800, 5);
        assert!(wg > wh + 0.03, "gpt4 judge {wg} vs human {wh}");
    }

    #[test]
    fn humans_prefer_guanaco7b_more() {
        // the paper's judge disagreement: humans ranked Guanaco-7B third
        let h = Judge::human();
        let g = Judge::gpt4();
        // Guanaco-7B (7) vs Guanaco-13B (5)
        let wh = winrate(&h, 7, 5, 2000, 6);
        let wg = winrate(&g, 7, 5, 2000, 7);
        assert!(wh > wg + 0.025, "human {wh} vs gpt4 {wg}");
    }

    #[test]
    fn ties_exist_between_close_systems() {
        let j = Judge::gpt4();
        let r = roster();
        let mut rng = Rng::new(6);
        let ties = (0..500)
            .filter(|_| {
                j.judge_pair(&r[3], &r[4], true, &mut rng) == Outcome::Tie
            })
            .count();
        assert!(ties > 10, "no ties between Vicuna and ChatGPT? {ties}");
    }
}
