//! Capability model for benchmark rows at scales we cannot train here
//! (7B–65B MMLU, Tables 4/5/11; Figure 3). Documented substitution
//! (DESIGN.md section 2): the *effect structure* comes from the paper,
//! the *datatype effects* come from our measured quantization error.
//!
//! MMLU(size, dataset, dtype, finetuned) =
//!     base(size)                       — paper Table 5 "LLaMA no tuning"
//!   + suitability(dataset, size)      — dataset↔benchmark match
//!   − penalty(dtype)                  — calibrated * sqrt(measured MSE),
//!                                       with adapter-finetuning recovery
//!   + seed noise
//!
//! The recovery coefficient realizes the paper's central result: after
//! QLoRA finetuning NF4(+DQ) matches BF16 while FP4 stays ~1pt behind —
//! our Table 3 *real training runs* independently verify that claim at
//! small scale.

use crate::quant::codebook::DType;
use crate::quant::error::{quant_error, synthetic_llm_weights};
use crate::util::rng::Rng;

/// LLaMA sizes used in the tables.
pub const SIZES: [&str; 4] = ["7B", "13B", "33B", "65B"];

/// Paper Table 5, "LLaMA no tuning" row.
pub fn base_mmlu(size: &str) -> f64 {
    match size {
        "7B" => 35.1,
        "13B" => 46.9,
        "33B" => 57.8,
        "65B" => 63.4,
        _ => panic!("unknown size {size}"),
    }
}

/// Dataset suitability for MMLU (paper Table 5 structure): FLAN v2 best,
/// Alpaca solid, chat-style datasets roughly neutral-to-negative, and
/// Self-Instruct actively harmful at small scale.
pub fn mmlu_suitability(dataset: &str, size: &str) -> f64 {
    let small = matches!(size, "7B" | "13B");
    match dataset {
        "flan-v2" => 6.5,
        "alpaca" => 2.2,
        "unnatural-instructions" => 2.0,
        "oasst1" => 0.2,
        "hh-rlhf" => -1.2,
        "chip2" => -1.8,
        "longform" => -2.2,
        "self-instruct" => {
            if small {
                -6.0
            } else {
                -3.5
            }
        }
        _ => 0.0,
    }
}

/// Accuracy penalty for a storage datatype, from *measured* round-trip
/// error on synthetic LLM weights. `finetuned` applies adapter recovery.
pub fn dtype_penalty(dtype: Option<DType>, double_quant: bool,
                     finetuned: bool, rng: &mut Rng) -> f64 {
    let dt = match dtype {
        None => return 0.0, // BF16
        Some(d) => d,
    };
    let w = synthetic_llm_weights(rng, 64 * 512, 0.01, 5.0);
    let rmse_of = |d: DType, dq: Option<usize>| {
        quant_error(&w, d, 64, dq).expect("quant error").mse.sqrt()
    };
    let rmse = rmse_of(dt, if double_quant { Some(256) } else { None });
    // self-calibrating: penalties are measured *relative to* NF4+DQ on the
    // same weights. After adapter finetuning a small residual remains
    // (base 0.15pt) plus 140pt per unit of excess RMSE — calibrated so FP4
    // lands ~1pt behind NF4 (paper Table 4); without finetuning the
    // inference-time loss is larger (base 0.8pt, slope 180 — Figure 3 /
    // Dettmers & Zettlemoyer 2022).
    let ref_rmse = rmse_of(DType::NF4, Some(256));
    let (base, slope) = if finetuned { (0.15, 140.0) } else { (0.8, 180.0) };
    base + (rmse - ref_rmse).max(0.0) * slope
}

/// Full capability model for one MMLU cell.
pub fn mmlu(
    size: &str,
    dataset: &str,
    dtype: Option<DType>,
    double_quant: bool,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15));
    let base = base_mmlu(size);
    let suit = mmlu_suitability(dataset, size);
    let pen = dtype_penalty(dtype, double_quant, true, &mut rng);
    let noise = rng.normal() * 0.25;
    base + suit - pen + noise
}

/// Zero-shot accuracy model for Figure 3 (mean over Winogrande/HellaSwag/
/// PiQA/Arc: quantized *without* finetuning — inference-time loss).
pub fn zero_shot(size_params_b: f64, dtype: DType, double_quant: bool,
                 seed: u64) -> f64 {
    let mut rng = Rng::new(seed.wrapping_mul(0xA5A5_A5A5_A5A5_A5A5));
    // scaling-law-ish baseline accuracy by size (Dettmers & Zettlemoyer)
    let base = 0.56 + 0.055 * (size_params_b.ln());
    let pen = dtype_penalty(Some(dtype), double_quant, false, &mut rng) / 100.0;
    (base - pen + rng.normal() * 0.002).clamp(0.25, 0.85)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_ordering_after_finetuning() {
        let mut rng = Rng::new(1);
        let bf16 = dtype_penalty(None, false, true, &mut rng);
        let nf4 = dtype_penalty(Some(DType::NF4), true, true, &mut rng);
        let fp4 = dtype_penalty(Some(DType::FP4E2M1), false, true, &mut rng);
        assert_eq!(bf16, 0.0);
        assert!(nf4 < 0.8, "nf4 penalty {nf4} should be ~recovered");
        assert!(fp4 > nf4 + 0.4, "fp4 {fp4} ~1pt behind nf4 {nf4}");
        assert!(fp4 < 2.5);
    }

    #[test]
    fn table5_structure() {
        // FLAN v2 beats chat datasets on MMLU at every size
        for size in SIZES {
            let flan = mmlu(size, "flan-v2", Some(DType::NF4), true, 7);
            let chip = mmlu(size, "chip2", Some(DType::NF4), true, 7);
            assert!(flan > chip + 4.0, "{size}: {flan} vs {chip}");
        }
        // self-instruct hurts 13B (paper: 33.3 vs 46.9 untuned)
        let si = mmlu("13B", "self-instruct", Some(DType::NF4), true, 7);
        assert!(si < base_mmlu("13B"));
    }

    #[test]
    fn zero_shot_monotone_in_size_and_dtype() {
        for (a, b) in [(7.0, 13.0), (13.0, 33.0), (33.0, 65.0)] {
            assert!(zero_shot(a, DType::NF4, false, 3)
                < zero_shot(b, DType::NF4, false, 3));
        }
        // NF4 > FP4 > Int4 at fixed size (Figure 3's claim)
        let nf4 = zero_shot(13.0, DType::NF4, false, 4);
        let fp4 = zero_shot(13.0, DType::FP4E2M1, false, 4);
        let int4 = zero_shot(13.0, DType::Int4, false, 4);
        assert!(nf4 > fp4 && fp4 > int4, "{nf4} {fp4} {int4}");
    }
}
