//! The system roster for the chatbot tournaments (Tables 1, 6, 7, 12, 13).
//!
//! Each system carries latent response-quality parameters per benchmark.
//! Quality values are the *generative model inputs* for the judge
//! simulation — they are calibrated so the simulation reproduces the
//! paper's observed effect structure (Guanaco 65B ≈ ChatGPT, Vicuna bench
//! favors open models, OA bench favors ChatGPT, GPT-4 far ahead), but all
//! tournament machinery downstream (judging, Elo, CIs, agreement stats) is
//! real computation over sampled matches.

/// One tournament participant and its latent benchmark qualities.
#[derive(Debug, Clone)]
pub struct System {
    /// display name as the paper's tables spell it
    pub name: &'static str,
    /// parameters in billions (None for API systems)
    pub params_b: Option<f64>,
    /// serving precision in bits (None for API systems)
    pub bits: Option<u32>,
    /// serving memory in GB (None for API systems)
    pub mem_gb: Option<f64>,
    /// latent quality on the Vicuna benchmark (Elo-scaled)
    pub vicuna_quality: f64,
    /// latent quality on the OA benchmark (Elo-scaled)
    pub oa_quality: f64,
    /// latent quality as perceived by *human* judges on Vicuna (the paper's
    /// Table 7 human column genuinely differs from GPT-4's — e.g. humans
    /// ranked Guanaco-7B third)
    pub human_quality: f64,
    /// is this "GPT-4 itself" (receives the judge's self-preference bias)
    pub is_gpt4: bool,
}

/// The Table 1 / Table 7 cast. Latent qualities are centered like Elo
/// (1000 ≈ average contender).
pub fn roster() -> Vec<System> {
    fn mem(spec: &crate::memory::ModelSpec, four_bit: bool) -> f64 {
        let s = if four_bit {
            Strategy::QLoRA4 { r: 64, double_quant: true }
        } else {
            Strategy::Full16
        };
        weights_footprint(spec, s) as f64 / 1e9
    }
    use crate::memory::*;
    vec![
        System {
            name: "GPT-4",
            params_b: None,
            bits: None,
            mem_gb: None,
            vicuna_quality: 1176.0,
            oa_quality: 1124.0,
            human_quality: 1176.0,
            is_gpt4: true,
        },
        System {
            name: "Guanaco-65B",
            params_b: Some(65.0),
            bits: Some(4),
            mem_gb: Some(mem(&LLAMA_65B, true)),
            vicuna_quality: 1022.0,
            oa_quality: 1008.0,
            human_quality: 1023.0,
            is_gpt4: false,
        },
        System {
            name: "Guanaco-33B",
            params_b: Some(33.0),
            bits: Some(4),
            mem_gb: Some(mem(&LLAMA_33B, true)),
            vicuna_quality: 992.0,
            oa_quality: 1002.0,
            human_quality: 1009.0,
            is_gpt4: false,
        },
        System {
            name: "Vicuna-13B",
            params_b: Some(13.0),
            bits: Some(16),
            mem_gb: Some(mem(&LLAMA_13B, false)),
            vicuna_quality: 974.0,
            oa_quality: 936.0,
            human_quality: 984.0,
            is_gpt4: false,
        },
        System {
            name: "ChatGPT-3.5 Turbo",
            params_b: None,
            bits: None,
            mem_gb: None,
            vicuna_quality: 966.0,
            oa_quality: 1015.0,
            human_quality: 916.0,
            is_gpt4: false,
        },
        System {
            name: "Guanaco-13B",
            params_b: Some(13.0),
            bits: Some(4),
            mem_gb: Some(mem(&LLAMA_13B, true)),
            vicuna_quality: 916.0,
            oa_quality: 885.0,
            human_quality: 975.0,
            is_gpt4: false,
        },
        System {
            name: "Bard",
            params_b: None,
            bits: None,
            mem_gb: None,
            vicuna_quality: 902.0,
            oa_quality: 880.0,
            human_quality: 909.0,
            is_gpt4: false,
        },
        System {
            name: "Guanaco-7B",
            params_b: Some(7.0),
            bits: Some(4),
            mem_gb: Some(mem(&LLAMA_7B, true)),
            vicuna_quality: 879.0,
            oa_quality: 860.0,
            human_quality: 1010.0,
            is_gpt4: false,
        },
    ]
}

/// Index of a system by name.
pub fn index_of(systems: &[System], name: &str) -> usize {
    systems
        .iter()
        .position(|s| s.name == name)
        .unwrap_or_else(|| panic!("unknown system {name}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_shape() {
        let r = roster();
        assert_eq!(r.len(), 8);
        assert!(r[0].is_gpt4);
        // Guanaco memory column ordering: 65B > 33B > 13B > 7B
        let g65 = r[1].mem_gb.unwrap();
        let g7 = r[7].mem_gb.unwrap();
        assert!(g65 > 30.0 && g65 < 50.0);
        assert!(g7 > 3.0 && g7 < 8.0);
        // 4-bit Guanaco 33B uses less memory than 16-bit Vicuna 13B
        assert!(r[2].mem_gb.unwrap() < r[3].mem_gb.unwrap());
    }
}
