//! Judged arena over *real* engine sessions — the paper's tournament
//! protocol (section 5.2) applied to adapters served from one frozen base.
//!
//! The roster tournaments (Tables 1/7) sample judgments from latent
//! qualities because we cannot run GPT-4-scale systems here. This module
//! closes the loop where we *can*: each named adapter in an [`Engine`]
//! generates completions for a shared prompt set through its own
//! `Session`; completions are scored against the reference responses;
//! the scores become per-(prompt, adapter) latent qualities fed through
//! the same biased-judge model and Elo-over-random-orderings aggregation
//! as the paper's protocol. One engine, many adapters, one tournament —
//! the QLoRA serving economy measured end to end.

use anyhow::{ensure, Result};

use crate::data::synthetic::{eval_set, EvalSuite};
use crate::elo::{EloSummary, MatchRecord, Tournament};
use crate::engine::{Engine, Sampler};
use crate::eval::judge::Judge;
use crate::eval::systems::System;
use crate::util::rng::Rng;

/// Outcome of [`run_arena`].
#[derive(Debug, Clone)]
pub struct ArenaReport {
    /// adapter names, index-aligned with `summaries[i].system`
    pub adapters: Vec<String>,
    /// Elo mean ± CI per adapter, from the same aggregation as Table 1
    pub summaries: Vec<EloSummary>,
    /// mean reference-match score in [0, 1] per adapter
    pub mean_score: Vec<f64>,
    /// prompts each adapter completed
    pub n_prompts: usize,
}

impl ArenaReport {
    /// Plain-text ranking table.
    pub fn table(&self) -> String {
        let mut rows: Vec<usize> = (0..self.adapters.len()).collect();
        rows.sort_by_key(|&i| self.summaries[i].rank);
        let mut out = format!(
            "== adapter arena ({} prompts) ==\n{:<4} {:<20} {:>8} {:>8} {:>7}\n",
            self.n_prompts, "rank", "adapter", "Elo", "±95%", "score"
        );
        for i in rows {
            let s = &self.summaries[i];
            out.push_str(&format!(
                "{:<4} {:<20} {:>8.0} {:>8.0} {:>7.3}\n",
                s.rank, self.adapters[i], s.mean, s.ci95, self.mean_score[i]
            ));
        }
        out
    }
}

/// Reference-match score in [0, 1]: per-position character agreement with
/// the expected response, with a penalty for length mismatch. Crude, but
/// monotone in the synthetic tasks' correctness — exactly what a latent
/// quality needs to be.
pub fn response_score(got: &str, want: &str) -> f64 {
    let want_len = want.chars().count();
    if want_len == 0 {
        return if got.is_empty() { 1.0 } else { 0.0 };
    }
    let matches = got
        .chars()
        .zip(want.chars())
        .filter(|(a, b)| a == b)
        .count();
    let len_gap = (got.chars().count() as f64 - want_len as f64).abs()
        / want_len as f64;
    (matches as f64 / want_len as f64 - 0.25 * len_gap).clamp(0.0, 1.0)
}

/// Elo-scale latent quality for one (adapter, prompt) response.
fn quality(score: f64) -> f64 {
    850.0 + 300.0 * score
}

fn arena_system(q: f64) -> System {
    System {
        name: "adapter",
        params_b: None,
        bits: None,
        mem_gb: None,
        vicuna_quality: q,
        oa_quality: q,
        human_quality: q,
        is_gpt4: false,
    }
}

/// Run a judged tournament between registered `adapters` of one engine.
///
/// Every adapter answers the same `n_prompts` held-out prompts (greedy
/// decoding, so the comparison is about the adapters, not sampling luck);
/// every unordered pair is judged in both presentation orders per prompt;
/// Elo is aggregated over `orderings` random match orders, exactly as in
/// the roster tournaments.
pub fn run_arena(
    engine: &Engine,
    adapters: &[&str],
    suite: EvalSuite,
    n_prompts: usize,
    judge: &Judge,
    orderings: usize,
    seed: u64,
) -> Result<ArenaReport> {
    ensure!(adapters.len() >= 2, "arena needs at least two adapters");
    ensure!(n_prompts > 0, "arena needs at least one prompt");
    let prompts = eval_set(suite, n_prompts, seed ^ 0xA12A);
    let sampler = Sampler { max_new_tokens: 24, ..Sampler::default() };

    // scores[a][p]: reference-match score of adapter a on prompt p
    let mut scores: Vec<Vec<f64>> = Vec::with_capacity(adapters.len());
    for name in adapters {
        let mut session = engine
            .session()
            .adapter(name)
            .sampler(sampler.clone())
            .greedy(true)
            .seed(seed)
            .build()?;
        let mut row = Vec::with_capacity(prompts.examples.len());
        for ex in &prompts.examples {
            let got = session.generate(&ex.instruction)?;
            row.push(response_score(&got, &ex.response));
        }
        scores.push(row);
    }

    let mut rng = Rng::new(seed ^ 0x517E);
    let mut tournament = Tournament::new(adapters.len());
    for p in 0..prompts.examples.len() {
        for a in 0..adapters.len() {
            for b in (a + 1)..adapters.len() {
                let sa = arena_system(quality(scores[a][p]));
                let sb = arena_system(quality(scores[b][p]));
                // judge both presentation orders: the order bias the
                // paper documents must cancel in aggregate, not be baked
                // into the ranking
                tournament.add(MatchRecord {
                    a,
                    b,
                    outcome: judge
                        .judge_pair_with_prompt(&sa, &sb, true, 0.0, 0.0,
                                                &mut rng),
                });
                let flipped = judge
                    .judge_pair_with_prompt(&sb, &sa, true, 0.0, 0.0,
                                            &mut rng);
                tournament.add(MatchRecord { a: b, b: a, outcome: flipped });
            }
        }
    }
    let summaries = tournament.run(orderings, seed ^ 0xE10);
    let mean_score = scores
        .iter()
        .map(|row| row.iter().sum::<f64>() / row.len() as f64)
        .collect();
    Ok(ArenaReport {
        adapters: adapters.iter().map(|s| s.to_string()).collect(),
        summaries,
        mean_score,
        n_prompts: prompts.examples.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_score_orders_quality() {
        assert_eq!(response_score("abcd", "abcd"), 1.0);
        assert_eq!(response_score("", ""), 1.0);
        assert_eq!(response_score("zzzz", "abcd"), 0.0);
        let perfect = response_score("abcd", "abcd");
        let half = response_score("abxy", "abcd");
        let none = response_score("wxyz", "abcd");
        assert!(perfect > half && half > none, "{perfect} {half} {none}");
        // length mismatch is penalized even when the prefix matches
        assert!(response_score("abcdxxxx", "abcd") < 1.0);
    }

    #[test]
    fn quality_maps_into_elo_band() {
        assert_eq!(quality(0.0), 850.0);
        assert_eq!(quality(1.0), 1150.0);
    }
}
