//! Chatbot evaluation harness: the system roster, the generative judge
//! model (GPT-4 / human stand-ins with the biases the paper measures), the
//! capability model used for the large-scale benchmark rows we cannot
//! train here (DESIGN.md section 2 documents the substitution), and the
//! judged arena that runs the same tournament protocol over *real*
//! adapters served by `crate::engine`.

pub mod arena;
pub mod capability;
pub mod judge;
pub mod systems;

pub use arena::{run_arena, ArenaReport};
pub use judge::{Judge, JudgeKind};
pub use systems::{roster, System};
