//! `.tensors` binary interchange format — Rust twin of
//! `python/compile/tensorio.py`.
//!
//! Layout: magic `QLT1`, u32-LE header length, JSON header
//! (`{"tensors": [{name, dtype, shape, offset, nbytes}, ...]}`),
//! then a raw little-endian data section. Tensor *order* is semantic:
//! it is the HLO parameter order for artifact init files.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::util::json::Value;

/// File magic for the `.tensors` format (`QLT1`).
pub const MAGIC: &[u8; 4] = b"QLT1";

/// Supported dtypes across the AOT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dt {
    /// 32-bit IEEE float
    F32,
    /// raw bytes (packed NF4 payloads, codebooks as bytes)
    U8,
    /// 32-bit signed integer (token ids)
    I32,
}

impl Dt {
    /// Canonical lowercase name used in `.tensors` headers.
    pub fn name(self) -> &'static str {
        match self {
            Dt::F32 => "f32",
            Dt::U8 => "u8",
            Dt::I32 => "i32",
        }
    }

    /// Parse a header dtype name.
    pub fn from_name(s: &str) -> Result<Dt> {
        Ok(match s {
            "f32" => Dt::F32,
            "u8" => Dt::U8,
            "i32" => Dt::I32,
            _ => bail!("unknown dtype {s:?}"),
        })
    }

    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            Dt::U8 => 1,
            _ => 4,
        }
    }
}

/// A named host tensor (raw little-endian bytes + shape + dtype).
#[derive(Debug, Clone)]
pub struct Tensor {
    /// tensor name (HLO parameter name for init files)
    pub name: String,
    /// element type
    pub dtype: Dt,
    /// dimension sizes, outermost first; empty = scalar
    pub shape: Vec<usize>,
    /// raw little-endian element bytes
    pub data: Vec<u8>,
}

impl Tensor {
    /// Element count implied by the shape (1 for scalars).
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(
            if self.shape.is_empty() { 1 } else { 0 },
        )
    }

    /// Build an f32 tensor from host values.
    pub fn f32(name: &str, shape: Vec<usize>, vals: &[f32]) -> Tensor {
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { name: name.into(), dtype: Dt::F32, shape, data }
    }

    /// Build an i32 tensor from host values.
    pub fn i32(name: &str, shape: Vec<usize>, vals: &[i32]) -> Tensor {
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { name: name.into(), dtype: Dt::I32, shape, data }
    }

    /// Build a u8 tensor that takes ownership of the bytes.
    pub fn u8(name: &str, shape: Vec<usize>, vals: Vec<u8>) -> Tensor {
        Tensor { name: name.into(), dtype: Dt::U8, shape, data: vals }
    }

    /// Decode the payload as f32 values (errors on dtype mismatch).
    pub fn to_f32(&self) -> Result<Vec<f32>> {
        ensure!(self.dtype == Dt::F32, "{} is not f32", self.name);
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Decode the payload as i32 values (errors on dtype mismatch).
    pub fn to_i32(&self) -> Result<Vec<i32>> {
        ensure!(self.dtype == Dt::I32, "{} is not i32", self.name);
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Write tensors preserving order, returning the (writable) file handle
/// so callers that need durability can fsync it.
fn write_tensors_file(path: &Path, tensors: &[Tensor]) -> Result<fs::File> {
    let mut entries = Vec::new();
    let mut offset = 0usize;
    for t in tensors {
        entries.push(Value::object(vec![
            ("name", Value::s(t.name.clone())),
            ("dtype", Value::s(t.dtype.name())),
            (
                "shape",
                Value::array(t.shape.iter().map(|&d| Value::n(d as f64))),
            ),
            ("offset", Value::n(offset as f64)),
            ("nbytes", Value::n(t.data.len() as f64)),
        ]));
        offset += t.data.len();
    }
    let header =
        Value::object(vec![("tensors", Value::Arr(entries))]).to_string();
    let mut f = fs::File::create(path)
        .with_context(|| format!("create {path:?}"))?;
    f.write_all(MAGIC)?;
    let header_len = u32::try_from(header.len())
        .with_context(|| format!("header too large: {} bytes", header.len()))?;
    f.write_all(&header_len.to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for t in tensors {
        f.write_all(&t.data)?;
    }
    Ok(f)
}

/// Write tensors preserving order.
pub fn write_tensors(path: &Path, tensors: &[Tensor]) -> Result<()> {
    write_tensors_file(path, tensors).map(|_| ())
}

/// Write tensors atomically: the bytes go to a hidden temp file in the
/// *same* directory (same filesystem, so the final step is a true
/// `rename(2)`), are fsynced, and only then renamed over `path`. A crash
/// mid-write leaves the previous file intact instead of a truncated,
/// unreadable `.tensors` — checkpoints must never corrupt the only copy
/// of the run state.
pub fn write_tensors_atomic(path: &Path, tensors: &[Tensor]) -> Result<()> {
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow!("no file name in {path:?}"))?;
    let mut tmp_name = std::ffi::OsString::from(".");
    tmp_name.push(file_name);
    tmp_name.push(format!(".tmp.{}", std::process::id()));
    let tmp: PathBuf = match path.parent() {
        Some(dir) if !dir.as_os_str().is_empty() => dir.join(&tmp_name),
        _ => PathBuf::from(&tmp_name),
    };
    let result = write_tensors_file(&tmp, tensors).and_then(|f| {
        // flush to disk (via the still-writable handle — a read-only
        // reopen cannot fsync on every platform) before the rename
        // makes the bytes visible; a sync failure must fail the save,
        // not fake durability
        f.sync_all().with_context(|| format!("fsync {tmp:?}"))?;
        drop(f);
        fs::rename(&tmp, path)
            .with_context(|| format!("atomic rename {tmp:?} -> {path:?}"))?;
        // the rename itself lives in the directory entry: fsync the
        // parent too, or a crash right after a "successful" save can
        // roll the file back to its previous version (unix only — on
        // other platforms opening a directory for sync is not portable)
        #[cfg(unix)]
        {
            let dir: &Path = match path.parent() {
                Some(d) if !d.as_os_str().is_empty() => d,
                _ => Path::new("."),
            };
            let d = fs::File::open(dir)
                .with_context(|| format!("open dir {dir:?} for fsync"))?;
            d.sync_all().with_context(|| format!("fsync dir {dir:?}"))?;
        }
        Ok(())
    });
    if result.is_err() {
        // never leave a straggler temp file behind a failed save (after
        // a successful rename the temp no longer exists; this is a no-op)
        let _ = fs::remove_file(&tmp);
    }
    result
}

/// Read all tensors (order preserved).
pub fn read_tensors(path: &Path) -> Result<Vec<Tensor>> {
    let bytes =
        fs::read(path).with_context(|| format!("read {path:?}"))?;
    ensure!(bytes.len() >= 8 && &bytes[..4] == MAGIC, "bad magic in {path:?}");
    let hlen =
        u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
    ensure!(bytes.len() >= 8 + hlen, "truncated header in {path:?}");
    let header = std::str::from_utf8(&bytes[8..8 + hlen])?;
    let v = Value::parse(header)?;
    let data = &bytes[8 + hlen..];
    let mut out = Vec::new();
    for e in v.get("tensors")?.arr()? {
        let name = e.get("name")?.str()?.to_string();
        let dtype = Dt::from_name(e.get("dtype")?.str()?)?;
        let shape: Vec<usize> = e
            .get("shape")?
            .arr()?
            .iter()
            .map(|d| d.usize())
            .collect::<Result<_>>()?;
        let offset = e.get("offset")?.usize()?;
        let nbytes = e.get("nbytes")?.usize()?;
        ensure!(
            offset + nbytes <= data.len(),
            "tensor {name} out of bounds"
        );
        let expected: usize =
            shape.iter().product::<usize>().max(1) * dtype.size();
        ensure!(
            nbytes == expected,
            "tensor {name}: {nbytes} bytes but shape {shape:?} implies {expected}"
        );
        out.push(Tensor {
            name,
            dtype,
            shape,
            data: data[offset..offset + nbytes].to_vec(),
        });
    }
    Ok(out)
}

/// Look up a tensor by name.
pub fn find<'a>(tensors: &'a [Tensor], name: &str) -> Result<&'a Tensor> {
    tensors
        .iter()
        .find(|t| t.name == name)
        .ok_or_else(|| anyhow!("tensor {name:?} not found"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("qlora_tio_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.tensors");
        let ts = vec![
            Tensor::f32("a/b", vec![2, 3], &[1.0, -2.5, 3.0, 0.0, 5.5, -6.0]),
            Tensor::u8("codes", vec![4], vec![1, 2, 3, 255]),
            Tensor::i32("tok", vec![2], &[7, -9]),
            Tensor::f32("scalar", vec![], &[42.0]),
        ];
        write_tensors(&path, &ts).unwrap();
        let back = read_tensors(&path).unwrap();
        assert_eq!(back.len(), 4);
        assert_eq!(back[0].name, "a/b");
        assert_eq!(back[0].to_f32().unwrap(), ts[0].to_f32().unwrap());
        assert_eq!(back[1].data, vec![1, 2, 3, 255]);
        assert_eq!(back[2].to_i32().unwrap(), vec![7, -9]);
        assert_eq!(back[3].shape, Vec::<usize>::new());
        assert_eq!(back[3].to_f32().unwrap(), vec![42.0]);
        assert_eq!(find(&back, "tok").unwrap().name, "tok");
        assert!(find(&back, "nope").is_err());
    }

    #[test]
    fn atomic_write_roundtrips_and_leaves_no_temp_files() {
        let dir = std::env::temp_dir().join("qlora_tio_atomic");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.tensors");
        let v1 = vec![Tensor::f32("a", vec![2], &[1.0, 2.0])];
        write_tensors_atomic(&path, &v1).unwrap();
        assert_eq!(read_tensors(&path).unwrap()[0].to_f32().unwrap(),
                   vec![1.0, 2.0]);
        // overwriting an existing checkpoint replaces it atomically
        let v2 = vec![Tensor::f32("a", vec![2], &[3.0, 4.0])];
        write_tensors_atomic(&path, &v2).unwrap();
        assert_eq!(read_tensors(&path).unwrap()[0].to_f32().unwrap(),
                   vec![3.0, 4.0]);
        // no `.ckpt.tensors.tmp.*` stragglers
        let leftovers: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
        // a bare relative file name (no parent directory) also works
        let cwd_rel = PathBuf::from(format!(
            "qlora_tio_atomic_rel_{}.tensors",
            std::process::id()
        ));
        write_tensors_atomic(&cwd_rel, &v1).unwrap();
        assert!(read_tensors(&cwd_rel).is_ok());
        let _ = fs::remove_file(&cwd_rel);
    }

    #[test]
    fn rejects_corrupt() {
        let dir = std::env::temp_dir().join("qlora_tio_test2");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.tensors");
        fs::write(&path, b"NOPE1234").unwrap();
        assert!(read_tensors(&path).is_err());
    }
}
