//! Elo rating machinery (paper section 5.2 "Elo Rating").
//!
//! Tournament-style model comparison: matches are pairwise judgments
//! (win/lose/tie); ratings start at 1000 with K = 32 and are updated in
//! match order; because ordering matters, the paper repeats the
//! computation over 10,000 random orderings of the match set and reports
//! mean ± 95% CI. This module implements exactly that.

pub mod tournament;

pub use tournament::{EloSummary, Tournament};

/// Match outcome from A's perspective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// system `a` won
    WinA,
    /// system `b` won
    WinB,
    /// judged too close to call
    Tie,
}

/// One judged comparison between systems `a` and `b`.
#[derive(Debug, Clone, Copy)]
pub struct MatchRecord {
    /// index of the first (order matters: shown-first) system
    pub a: usize,
    /// index of the second system
    pub b: usize,
    /// the judgment, from `a`'s perspective
    pub outcome: Outcome,
}

/// Rating-update parameters.
#[derive(Debug, Clone, Copy)]
pub struct EloConfig {
    /// K-factor: rating points at stake per match
    pub k: f64,
    /// starting rating for every system
    pub initial: f64,
}

impl Default for EloConfig {
    fn default() -> Self {
        // paper: "We start with a score of 1,000 and use K=32."
        EloConfig { k: 32.0, initial: 1000.0 }
    }
}

/// Expected score of a vs b (paper: 1100 vs 1000 → ≈65% win rate).
pub fn expected_score(ra: f64, rb: f64) -> f64 {
    1.0 / (1.0 + 10f64.powf((rb - ra) / 400.0))
}

/// Sequentially apply matches in the given order.
pub fn run_sequence(
    n_systems: usize,
    matches: &[MatchRecord],
    order: &[usize],
    cfg: EloConfig,
) -> Vec<f64> {
    let mut r = vec![cfg.initial; n_systems];
    for &idx in order {
        let m = &matches[idx];
        let ea = expected_score(r[m.a], r[m.b]);
        let sa = match m.outcome {
            Outcome::WinA => 1.0,
            Outcome::WinB => 0.0,
            Outcome::Tie => 0.5,
        };
        let delta = cfg.k * (sa - ea);
        r[m.a] += delta;
        r[m.b] -= delta;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_score_anchors() {
        assert!((expected_score(1000.0, 1000.0) - 0.5).abs() < 1e-12);
        // paper: Elo 1100 vs 1000 → ≈64%
        let p = expected_score(1100.0, 1000.0);
        assert!((p - 0.64).abs() < 0.01, "{p}");
        // symmetry
        assert!((expected_score(900.0, 1100.0)
            + expected_score(1100.0, 900.0)
            - 1.0)
            .abs() < 1e-12);
    }

    #[test]
    fn rating_is_conserved() {
        // zero-sum: total rating never changes
        let matches = vec![
            MatchRecord { a: 0, b: 1, outcome: Outcome::WinA },
            MatchRecord { a: 1, b: 2, outcome: Outcome::Tie },
            MatchRecord { a: 2, b: 0, outcome: Outcome::WinB },
        ];
        let order: Vec<usize> = (0..matches.len()).collect();
        let r = run_sequence(3, &matches, &order, EloConfig::default());
        let total: f64 = r.iter().sum();
        assert!((total - 3000.0).abs() < 1e-9);
    }

    #[test]
    fn winner_gains() {
        let matches = vec![MatchRecord { a: 0, b: 1, outcome: Outcome::WinA }];
        let r = run_sequence(2, &matches, &[0], EloConfig::default());
        assert!(r[0] > 1000.0 && r[1] < 1000.0);
        assert!((r[0] - 1016.0).abs() < 1e-9); // K/2 on an even match
    }

    #[test]
    fn upset_moves_more_than_expected_win() {
        let cfg = EloConfig::default();
        let mut r = vec![1200.0, 800.0];
        // expected win by the strong player
        let ea = expected_score(r[0], r[1]);
        let strong_gain = cfg.k * (1.0 - ea);
        // upset: weak player wins
        let upset_gain = cfg.k * (1.0 - expected_score(r[1], r[0]));
        assert!(upset_gain > strong_gain * 5.0);
        r[0] += 0.0; // silence unused warnings
    }
}
