//! Tournament aggregation: Elo over many random match orderings
//! (paper: "we repeat this procedure 10,000 times with different random
//! seeds to control for ordering effects").

use crate::util::rng::Rng;
use crate::util::stats;

use super::{run_sequence, EloConfig, MatchRecord};

/// Aggregated rating of one system across orderings.
#[derive(Debug, Clone)]
pub struct EloSummary {
    /// system index (roster order)
    pub system: usize,
    /// mean Elo over all orderings
    pub mean: f64,
    /// half-width of the 95% confidence interval
    pub ci95: f64,
    /// 1-based rank by mean (1 = best)
    pub rank: usize,
}

/// A match set to be rated over many random orderings.
pub struct Tournament {
    /// number of systems in the roster
    pub n_systems: usize,
    /// every judged comparison collected so far
    pub matches: Vec<MatchRecord>,
    /// rating-update parameters
    pub cfg: EloConfig,
}

impl Tournament {
    /// An empty tournament with the paper's default config.
    pub fn new(n_systems: usize) -> Tournament {
        Tournament { n_systems, matches: Vec::new(), cfg: EloConfig::default() }
    }

    /// Record one judged match.
    pub fn add(&mut self, m: MatchRecord) {
        debug_assert!(m.a < self.n_systems && m.b < self.n_systems);
        self.matches.push(m);
    }

    /// Mean Elo ± 95% CI over `orderings` random permutations.
    pub fn run(&self, orderings: usize, seed: u64) -> Vec<EloSummary> {
        let mut rng = Rng::new(seed);
        let n = self.matches.len();
        let mut per_system: Vec<Vec<f64>> =
            vec![Vec::with_capacity(orderings); self.n_systems];
        let mut order: Vec<usize> = (0..n).collect();
        for _ in 0..orderings {
            rng.shuffle(&mut order);
            let r = run_sequence(self.n_systems, &self.matches, &order,
                                 self.cfg);
            for (s, v) in r.into_iter().enumerate() {
                per_system[s].push(v);
            }
        }
        let mut out: Vec<EloSummary> = per_system
            .iter()
            .enumerate()
            .map(|(s, vals)| EloSummary {
                system: s,
                mean: stats::mean(vals),
                ci95: stats::ci95_halfwidth(vals),
                rank: 0,
            })
            .collect();
        // ranks by mean, descending
        let mut idx: Vec<usize> = (0..out.len()).collect();
        idx.sort_by(|&i, &j| out[j].mean.total_cmp(&out[i].mean));
        for (rank, &i) in idx.iter().enumerate() {
            out[i].rank = rank + 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elo::Outcome;

    /// Build matches from a ground-truth strength ordering.
    fn round_robin(strengths: &[f64], games: usize, seed: u64) -> Tournament {
        let mut t = Tournament::new(strengths.len());
        let mut rng = Rng::new(seed);
        for _ in 0..games {
            for a in 0..strengths.len() {
                for b in 0..strengths.len() {
                    if a == b {
                        continue;
                    }
                    let p = super::super::expected_score(strengths[a],
                                                         strengths[b]);
                    let outcome = if rng.f64() < p {
                        Outcome::WinA
                    } else {
                        Outcome::WinB
                    };
                    t.add(MatchRecord { a, b, outcome });
                }
            }
        }
        t
    }

    #[test]
    fn recovers_strength_ordering() {
        let strengths = [1300.0, 1100.0, 1000.0, 850.0];
        let t = round_robin(&strengths, 30, 1);
        let res = t.run(200, 2);
        // ranks must follow the latent strengths
        for i in 0..3 {
            assert!(res[i].mean > res[i + 1].mean,
                    "{} vs {}", res[i].mean, res[i + 1].mean);
        }
        assert_eq!(res[0].rank, 1);
        assert_eq!(res[3].rank, 4);
    }

    #[test]
    fn ci_shrinks_with_more_orderings() {
        let strengths = [1100.0, 1000.0, 900.0];
        let t = round_robin(&strengths, 10, 3);
        let narrow = t.run(400, 4);
        let wide = t.run(20, 4);
        // CI of the mean over orderings shrinks ~1/sqrt(n)
        assert!(narrow[0].ci95 < wide[0].ci95);
    }

    #[test]
    fn ties_keep_equals_equal() {
        let mut t = Tournament::new(2);
        for _ in 0..100 {
            t.add(MatchRecord { a: 0, b: 1, outcome: Outcome::Tie });
        }
        let res = t.run(50, 5);
        assert!((res[0].mean - res[1].mean).abs() < 1e-9);
        assert!((res[0].mean - 1000.0).abs() < 1e-9);
    }
}
