//! `qlora::engine` — session-based inference/serving over one frozen
//! quantized base and many hot-swappable LoRA adapters.
//!
//! QLoRA's central economy (paper section 1: the authors finetune 1,000+
//! models because adapters are tiny) is one frozen 4-bit base multiplexed
//! across cheap adapters. This module is that economy as an API
//! (`ARCHITECTURE.md` has the whole-system picture):
//!
//! ```text
//!            ┌───────────────────────────────────────────────┐
//!            │ Engine                                        │
//!            │  · Rc<Runtime>  (PJRT client + HLO exe cache) │
//!            │  · ArtifactSpec (shapes, signatures)          │
//!            │  · frozen base  (NF4 literals, uploaded ONCE) │
//!            │  · AdapterRegistry ("base", "tuned", …)       │
//!            └───────┬───────────────────┬───────────────────┘
//!        borrows rt, │                   │ borrows frozen +
//!        frozen,     │                   │ one named adapter
//!        exes        │                   │
//!            ┌───────▼────────┐   ┌──────▼──────────────────┐
//!            │ Trainer<'e>    │   │ Session<'e>             │
//!            │  owns mutable  │   │  generate / stream /    │
//!            │  state (adap-  │   │  serve (GenRequests) /  │
//!            │  ters+Adam+t)  │   │  generate_batch / eval  │
//!            └───────┬────────┘   └──────┬──────────────────┘
//!                    │ publish_          │ Scheduler: priorities,
//!                    │ adapter(name)     │ deadlines, cancellation,
//!                    │                   │ block-granular KV admission
//!                    ▼                   ▼
//!              AdapterRegistry    ┌─────────────────────────┐
//!                    ▲            │ DecodeGraph             │
//!                    │            │  CachedDecode: prefill +│
//!       load_adapter(name, file)  │   O(1) KV-cached steps  │
//!                                 │  FullDecode: full-seq   │
//!                                 │   recompute fallback    │
//!                                 └─────────────────────────┘
//! ```
//!
//! Ownership rules:
//! * `Engine` owns the runtime, the compiled executables (via the
//!   runtime's HLO cache) and the frozen base. The base is converted to
//!   device literals exactly once, in `Engine::new`.
//! * Adapters live in the [`AdapterRegistry`] as host tensors; device
//!   literals are cached per (name, version) and invalidated on swap, so
//!   hot-swapping an adapter never re-uploads the frozen base.
//! * `Session` and `Trainer` are *clients*: they borrow the engine
//!   immutably. Registering/loading adapters goes through interior
//!   mutability, so a long-lived serving session observes adapter swaps
//!   published by a concurrent (same-thread) training loop.
//! * A [`DecodeGraph`] pins its adapter's device literals at
//!   construction, so hot-swapping an adapter never corrupts KV caches
//!   built under the previous version mid-decode (see the
//!   [`decode`] module docs for the full cache-lifetime contract).
//!
//! The decode loop and [`Sampler`] used to live in `coordinator::generate`
//! welded to the `Trainer`; they now live here, and training is just one
//! more client of the engine.

#![cfg_attr(doc, warn(missing_docs))]

pub mod adapters;
pub mod decode;
pub mod sampler;
pub mod scheduler;
pub mod session;
pub mod weights;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, ensure, Context, Result};

use crate::data::batching::Batch;
use crate::runtime::artifact::{ArtifactSpec, Manifest};
use crate::runtime::client::Runtime;
use crate::runtime::executor::{literal_from_tensor, Executable};
use crate::tensorio::{read_tensors, Tensor};

pub use adapters::AdapterRegistry;
pub use decode::{CachedDecode, DecodeGraph, DecodeMode, FullDecode};
pub use sampler::Sampler;
pub use scheduler::{
    CancelHandle, JobId, JobOutcome, JobResult, Priority, Request, Scheduler,
    ServerStats, SwapOut,
};
pub use session::{
    GenRequest, ServeDriver, ServeEvent, ServeOutput, ServeProgress,
    ServeReport, Session, SessionBuilder, SourcePoll, TokenStream,
};

/// Name under which the artifact's init-time (untrained) adapter tensors
/// are registered by `Engine::new`.
pub const BASE_ADAPTER: &str = "base";

/// Uploaded-adapter cache entry: (registry version, device literals).
type UploadedAdapter = (u64, Rc<Vec<xla::Literal>>);

/// Read and validate an artifact's init-tensor file
/// (state ++ frozen, in manifest order).
fn read_init_tensors(spec: &ArtifactSpec) -> Result<Vec<Tensor>> {
    let init = read_tensors(&spec.init)
        .with_context(|| format!("init tensors for {}", spec.name))?;
    ensure!(
        init.len() == spec.n_state + spec.n_frozen,
        "init file has {} tensors, manifest expects {}",
        init.len(),
        spec.n_state + spec.n_frozen
    );
    Ok(init)
}

/// The serving core: one frozen quantized base, uploaded once, multiplexed
/// across named adapters and any number of sessions/trainers.
pub struct Engine {
    rt: Rc<Runtime>,
    /// The loaded artifact's manifest entry: model config, I/O
    /// signatures, and graph paths.
    pub spec: ArtifactSpec,
    /// frozen quantized base — literals created once, shared by every
    /// session and trainer
    frozen: Vec<xla::Literal>,
    registry: RefCell<AdapterRegistry>,
    /// device-literal cache per adapter, invalidated on hot-swap
    uploaded: RefCell<HashMap<String, UploadedAdapter>>,
}

impl Engine {
    /// Load artifact `name` over a shared runtime: read init tensors,
    /// upload the frozen base, register the init adapters as
    /// [`BASE_ADAPTER`].
    pub fn new(rt: Rc<Runtime>, manifest: &Manifest, name: &str) -> Result<Engine> {
        let spec = manifest.get(name)?.clone();
        let mut init = read_init_tensors(&spec)?;
        let frozen_host = init.split_off(spec.n_state);
        let frozen = frozen_host
            .iter()
            .map(literal_from_tensor)
            .collect::<Result<Vec<_>>>()?;
        // keep only the trainable prefix resident: serving never reads
        // the Adam moments (Trainer::new re-reads the init file)
        init.truncate(spec.n_trainable);
        let mut registry =
            AdapterRegistry::new(spec.state_sig[..spec.n_trainable].to_vec());
        registry.insert(BASE_ADAPTER, init)?;
        Ok(Engine {
            rt,
            spec,
            frozen,
            registry: RefCell::new(registry),
            uploaded: RefCell::new(HashMap::new()),
        })
    }

    /// Convenience: create a fresh CPU runtime and load `name` onto it.
    pub fn cpu(manifest: &Manifest, name: &str) -> Result<Engine> {
        Engine::new(Rc::new(Runtime::cpu()?), manifest, name)
    }

    /// The shared runtime (clone the `Rc` to build sibling engines over
    /// the same PJRT client).
    pub fn runtime(&self) -> &Rc<Runtime> {
        &self.rt
    }

    /// Frozen-base literals (uploaded once in `new`).
    pub fn frozen(&self) -> &[xla::Literal] {
        &self.frozen
    }

    /// Clone the host tensors of a registered adapter (e.g. to register
    /// a copy under another name).
    pub fn adapter_tensors(&self, name: &str) -> Result<Vec<Tensor>> {
        Ok(self.registry.borrow().get(name)?.tensors.clone())
    }

    /// Read the artifact's full init training state (trainable ++ Adam
    /// moments ++ step) from disk. The engine deliberately does not keep
    /// these resident — serving needs only the frozen base and adapters —
    /// so each trainer pays one extra file read instead of every serving
    /// process paying the Adam-moment memory.
    pub fn read_init_state(&self) -> Result<Vec<Tensor>> {
        let mut init = read_init_tensors(&self.spec)?;
        init.truncate(self.spec.n_state);
        Ok(init)
    }

    /// The forward (logits) executable; errors if the artifact was built
    /// without a fwd graph.
    pub fn fwd_exe(&self) -> Result<Arc<Executable>> {
        let path = self.spec.fwd_hlo.as_ref().ok_or_else(|| {
            anyhow!("artifact {} has no fwd graph (re-run `make artifacts`)",
                    self.spec.name)
        })?;
        self.rt.load_hlo(path)
    }

    /// The prefill executable (full forward that also fills the KV
    /// cache); errors if the artifact was built without decode graphs.
    pub fn prefill_exe(&self) -> Result<Arc<Executable>> {
        let path = self.spec.prefill_hlo.as_ref().ok_or_else(|| {
            anyhow!("artifact {} has no prefill graph (re-run `make artifacts`)",
                    self.spec.name)
        })?;
        self.rt.load_hlo(path)
    }

    /// The O(1)-per-token KV-cached decode-step executable; errors if the
    /// artifact was built without decode graphs.
    pub fn decode_exe(&self) -> Result<Arc<Executable>> {
        let path = self.spec.decode_hlo.as_ref().ok_or_else(|| {
            anyhow!("artifact {} has no decode graph (re-run `make artifacts`)",
                    self.spec.name)
        })?;
        self.rt.load_hlo(path)
    }

    /// Whether this artifact ships the KV-cached decode path (prefill +
    /// decode graphs + cache signature). [`DecodeMode::Auto`] keys off
    /// this.
    pub fn has_cached_decode(&self) -> bool {
        self.spec.prefill_hlo.is_some()
            && self.spec.decode_hlo.is_some()
            && self.spec.cache_sig.len() == 2
    }

    /// The eval (loss, accuracy) executable.
    pub fn eval_exe(&self) -> Result<Arc<Executable>> {
        self.rt.load_hlo(&self.spec.eval_hlo)
    }

    /// The train-step executable (compiled lazily: inference-only engines
    /// never pay for it).
    pub fn train_exe(&self) -> Result<Arc<Executable>> {
        self.rt.load_hlo(&self.spec.train_hlo)
    }

    /// Register adapter tensors under `name`, replacing (hot-swapping) any
    /// previous adapter of that name. Sessions pick the swap up on their
    /// next forward.
    pub fn register_adapter(&self, name: &str, tensors: Vec<Tensor>) -> Result<()> {
        self.registry.borrow_mut().insert(name, tensors)
    }

    /// Load an adapter from a `.tensors` checkpoint: either an
    /// adapters-only file (`checkpoint::save_adapters`) or a full training
    /// state (`checkpoint::save`), whose first `n_trainable` tensors are
    /// the adapters.
    pub fn load_adapter(&self, name: &str, path: &Path) -> Result<()> {
        let tensors = read_tensors(path)
            .with_context(|| format!("adapter checkpoint {path:?}"))?;
        let n = self.spec.n_trainable;
        ensure!(
            tensors.len() == n || tensors.len() == self.spec.n_state,
            "checkpoint {path:?} has {} tensors; expected {} (adapters) \
             or {} (full state)",
            tensors.len(),
            n,
            self.spec.n_state
        );
        self.register_adapter(name, tensors.into_iter().take(n).collect())
    }

    /// Drop adapter `name` (and its uploaded literals).
    pub fn remove_adapter(&self, name: &str) -> Result<()> {
        self.registry.borrow_mut().remove(name)?;
        self.uploaded.borrow_mut().remove(name);
        Ok(())
    }

    /// Whether adapter `name` is currently registered.
    pub fn has_adapter(&self, name: &str) -> bool {
        self.registry.borrow().contains(name)
    }

    /// Registered adapter names (sorted).
    pub fn adapter_names(&self) -> Vec<String> {
        self.registry
            .borrow()
            .names()
            .into_iter()
            .map(str::to_string)
            .collect()
    }

    /// Device literals for adapter `name`, uploading on first use and
    /// re-uploading only when the registry entry was swapped since. The
    /// frozen base is never touched by this path.
    pub(crate) fn adapter_literals(&self, name: &str) -> Result<Rc<Vec<xla::Literal>>> {
        let registry = self.registry.borrow();
        let entry = registry.get(name)?;
        let mut uploaded = self.uploaded.borrow_mut();
        if let Some((version, lits)) = uploaded.get(name) {
            if *version == entry.version {
                return Ok(lits.clone());
            }
        }
        let lits = entry
            .tensors
            .iter()
            .map(literal_from_tensor)
            .collect::<Result<Vec<_>>>()?;
        let rc = Rc::new(lits);
        uploaded.insert(name.to_string(), (entry.version, rc.clone()));
        Ok(rc)
    }

    /// Start building a [`Session`] over this engine.
    pub fn session(&self) -> SessionBuilder<'_> {
        SessionBuilder::new(self)
    }

    /// Convert a data batch into (tokens, loss_mask) literals, checking
    /// the compiled shape.
    pub(crate) fn batch_literals(&self, batch: &Batch) -> Result<[xla::Literal; 2]> {
        ensure!(
            batch.batch == self.spec.cfg.batch
                && batch.seq_len == self.spec.cfg.seq_len,
            "batch shape {}x{} does not match artifact {}x{}",
            batch.batch,
            batch.seq_len,
            self.spec.cfg.batch,
            self.spec.cfg.seq_len
        );
        let t = Tensor::i32("tokens", vec![batch.batch, batch.seq_len],
                            &batch.tokens);
        let m = Tensor::f32("loss_mask", vec![batch.batch, batch.seq_len],
                            &batch.mask);
        Ok([literal_from_tensor(&t)?, literal_from_tensor(&m)?])
    }
}
