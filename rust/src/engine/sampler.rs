//! Token sampling: nucleus (top-p) with optional top-k and temperature.
//!
//! The paper's evaluation setup uses nucleus sampling with p = 0.9 and
//! temperature 0.7 throughout (section 5.2); those are the defaults here.
//! Degenerate settings are well-defined rather than numerically explosive:
//! temperature ≤ 0 falls back to greedy argmax, top_p ≤ 0 keeps exactly
//! the mode, and top_k (off by default) truncates to the k most likely
//! tokens before the nucleus cut.

use crate::util::rng::Rng;

/// Sampling configuration for the decode loop (nucleus + top-k +
/// temperature, with a per-request token budget).
#[derive(Debug, Clone)]
pub struct Sampler {
    /// nucleus mass; ≤ 0 keeps exactly one token, ≥ 1 keeps all
    pub top_p: f64,
    /// keep only the k most likely tokens before the nucleus cut
    pub top_k: Option<usize>,
    /// softmax temperature; ≤ 0 means greedy argmax
    pub temperature: f64,
    /// maximum number of tokens generated per request
    pub max_new_tokens: usize,
}

impl Default for Sampler {
    fn default() -> Self {
        // paper section 5.2: "nucleus sampling with p=0.9 and temperature 0.7"
        Sampler { top_p: 0.9, top_k: None, temperature: 0.7, max_new_tokens: 32 }
    }
}

impl Sampler {
    /// Build from the shared CLI flags (`--top-p`, `--top-k`,
    /// `--temperature`, `--max-new`); `top-k 0` (the default) means off.
    pub fn from_args(
        args: &crate::util::cli::Args,
        default_max_new: usize,
    ) -> anyhow::Result<Sampler> {
        Ok(Sampler {
            top_p: args.f64_or("top-p", 0.9)?,
            top_k: match args.usize_or("top-k", 0)? {
                0 => None,
                k => Some(k),
            },
            temperature: args.f64_or("temperature", 0.7)?,
            max_new_tokens: args.usize_or("max-new", default_max_new)?,
        })
    }

    /// Smallest prefix of `sorted` (descending probabilities) whose mass
    /// reaches `top_p`; at least one token, all of them for top_p ≥ 1.
    pub fn nucleus_cutoff(sorted: &[f64], top_p: f64) -> usize {
        if top_p <= 0.0 {
            return 1;
        }
        let mut cum = 0.0;
        for (i, p) in sorted.iter().enumerate() {
            cum += p;
            if cum >= top_p {
                return i + 1;
            }
        }
        sorted.len()
    }

    /// Sample one token id from a logits row.
    ///
    /// Robust to corrupt rows: a NaN logit is treated as `-inf` (never
    /// sampled, never a panic — one bad artifact must not kill the
    /// serving thread mid-batch), a `+inf` logit wins deterministically,
    /// and an all-NaN row degrades to [`Sampler::greedy`]'s fallback.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> i32 {
        if self.temperature <= 0.0 {
            return Self::greedy(logits);
        }
        let inv_t = 1.0 / self.temperature;
        // softmax with temperature over the well-defined logits
        let mx = logits
            .iter()
            .filter(|l| !l.is_nan())
            .cloned()
            .fold(f32::NEG_INFINITY, f32::max);
        if mx == f32::NEG_INFINITY || mx == f32::INFINITY {
            // nothing finite to soften (all-NaN/-inf) or an infinite
            // spike: both are argmax cases, not softmax cases
            return Self::greedy(logits);
        }
        let mut probs: Vec<(usize, f64)> = logits
            .iter()
            .enumerate()
            .map(|(i, &l)| {
                let l = if l.is_nan() { f32::NEG_INFINITY } else { l };
                (i, (((l - mx) as f64) * inv_t).exp())
            })
            .collect();
        // z ≥ exp(0) = 1 (the max logit is finite), so never 0 or NaN
        let z: f64 = probs.iter().map(|(_, p)| p).sum();
        for p in probs.iter_mut() {
            p.1 /= z;
        }
        probs.sort_by(|a, b| b.1.total_cmp(&a.1));
        if let Some(k) = self.top_k {
            probs.truncate(k.max(1));
        }
        let weights: Vec<f64> = probs.iter().map(|(_, p)| *p).collect();
        probs.truncate(Self::nucleus_cutoff(&weights, self.top_p));
        let weights: Vec<f64> = probs.iter().map(|(_, p)| *p).collect();
        // pallas-lint: allow(no-hot-path-panic) — categorical returns an index < weights.len() == probs.len(), and nucleus_cutoff keeps ≥ 1 entry
        probs[rng.categorical(&weights)].0 as i32
    }

    /// Greedy argmax (deterministic decoding for accuracy-style eval).
    /// NaN logits are never candidates (`total_cmp` would rank a NaN
    /// above every real value); an all-NaN row falls back to id 0.
    pub fn greedy(logits: &[f32]) -> i32 {
        logits
            .iter()
            .enumerate()
            .filter(|(_, l)| !l.is_nan())
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn softmax(logits: &[f32]) -> Vec<f64> {
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f64> =
            logits.iter().map(|&l| ((l - mx) as f64).exp()).collect();
        let z: f64 = e.iter().sum();
        e.into_iter().map(|p| p / z).collect()
    }

    #[test]
    fn greedy_picks_max() {
        assert_eq!(Sampler::greedy(&[0.1, 5.0, -2.0]), 1);
    }

    #[test]
    fn zero_or_negative_temperature_is_greedy() {
        // old behaviour divided by max(T, 1e-6) and exploded the exponents
        let logits = vec![1.0, 3.0, 2.0, -1.0];
        let mut rng = Rng::new(11);
        for t in [0.0, -1.0, -1e9] {
            let s = Sampler { temperature: t, top_p: 1.0, ..Sampler::default() };
            for _ in 0..50 {
                assert_eq!(s.sample(&logits, &mut rng), 1, "T={t}");
            }
        }
    }

    #[test]
    fn top_p_zero_keeps_exactly_the_mode() {
        let s = Sampler { top_p: 0.0, temperature: 1.0, ..Sampler::default() };
        let logits = vec![0.5, 0.4, 2.0, 0.1];
        let mut rng = Rng::new(12);
        for _ in 0..100 {
            assert_eq!(s.sample(&logits, &mut rng), 2);
        }
    }

    #[test]
    fn nucleus_cutoff_is_minimal_covering_set() {
        // deterministic RNG drives the random distributions under test
        let mut rng = Rng::new(13);
        for _ in 0..200 {
            let n = 2 + rng.below(30);
            let logits: Vec<f32> =
                (0..n).map(|_| rng.normal() as f32 * 2.0).collect();
            let mut probs = softmax(&logits);
            probs.sort_by(|a, b| b.total_cmp(a));
            let p = rng.f64();
            let cut = Sampler::nucleus_cutoff(&probs, p);
            assert!((1..=n).contains(&cut));
            let mass: f64 = probs[..cut].iter().sum();
            // the kept mass covers p…
            assert!(mass >= p - 1e-12, "mass {mass} < p {p}");
            // …and no smaller prefix does
            if cut > 1 {
                let smaller: f64 = probs[..cut - 1].iter().sum();
                assert!(smaller < p, "cut {cut} not minimal: {smaller} >= {p}");
            }
        }
    }

    #[test]
    fn nucleus_restricts_tail() {
        // with a sharply peaked distribution and p=0.5 only the mode remains
        let s = Sampler { top_p: 0.5, temperature: 1.0, ..Sampler::default() };
        let logits = vec![10.0, 0.0, 0.0, 0.0];
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(s.sample(&logits, &mut rng), 0);
        }
    }

    #[test]
    fn top_k_limits_support() {
        // k=2 over a near-uniform distribution: only the two most likely
        // ids may ever appear
        let s = Sampler {
            top_p: 1.0,
            top_k: Some(2),
            temperature: 1.0,
            ..Sampler::default()
        };
        let logits = vec![1.0, 1.01, 1.02, 0.99];
        let mut rng = Rng::new(14);
        for _ in 0..500 {
            let id = s.sample(&logits, &mut rng);
            assert!(id == 2 || id == 1, "sampled {id} outside top-2");
        }
        // k=1 is greedy regardless of temperature
        let s1 = Sampler { top_k: Some(1), ..s };
        for _ in 0..100 {
            assert_eq!(s1.sample(&logits, &mut rng), 2);
        }
    }

    #[test]
    fn top_k_zero_clamps_to_one() {
        let s = Sampler {
            top_p: 1.0,
            top_k: Some(0),
            temperature: 1.0,
            ..Sampler::default()
        };
        let logits = vec![0.0, 3.0];
        let mut rng = Rng::new(15);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn temperature_flattens() {
        // with huge temperature sampling becomes ~uniform
        let s = Sampler { top_p: 1.0, temperature: 1e6, ..Sampler::default() };
        let logits = vec![3.0, 0.0];
        let mut rng = Rng::new(2);
        let ones =
            (0..2000).filter(|_| s.sample(&logits, &mut rng) == 1).count();
        assert!(ones > 700, "tail sampled {ones}/2000");
    }

    #[test]
    fn nan_logits_never_panic_or_get_sampled() {
        // a corrupt artifact can hand the sampler NaN logits mid-batch;
        // the serving thread must keep going, never panic, and never
        // emit the corrupt id (the old partial_cmp().unwrap() died here)
        let logits = vec![0.5, f32::NAN, 2.0, f32::NAN, 1.0];
        assert_eq!(Sampler::greedy(&logits), 2);
        let s = Sampler { top_p: 1.0, temperature: 1.0, ..Sampler::default() };
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let id = s.sample(&logits, &mut rng) as usize;
            assert!(logits[id].is_finite(), "sampled corrupt id {id}");
        }
        // a fully corrupt row degrades to a deterministic fallback
        let all_nan = vec![f32::NAN; 4];
        assert_eq!(Sampler::greedy(&all_nan), 0);
        assert_eq!(s.sample(&all_nan, &mut rng), 0);
        // an infinite spike wins deterministically instead of poisoning
        // the softmax with inf - inf
        let spiked = vec![0.0, f32::INFINITY, 1.0];
        assert_eq!(s.sample(&spiked, &mut rng), 1);
    }

    #[test]
    fn sampling_is_deterministic_under_seed() {
        let s = Sampler { top_p: 0.9, temperature: 0.7, ..Sampler::default() };
        let logits: Vec<f32> = (0..64).map(|i| ((i * 37) % 13) as f32).collect();
        let seq = |seed: u64| -> Vec<i32> {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| s.sample(&logits, &mut rng)).collect()
        };
        assert_eq!(seq(9), seq(9));
        assert_ne!(seq(9), seq(10));
    }
}
