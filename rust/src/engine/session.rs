//! Sessions: the inference surface of the engine.
//!
//! A `Session` pins one named adapter over the engine's frozen base and
//! exposes the decode loop three ways — whole-completion
//! ([`Session::generate`]), token-by-token streaming ([`Session::stream`]
//! / [`Session::generate_with`]), and batched multi-prompt decoding
//! ([`Session::generate_batch`], one forward per step for *all* rows) —
//! plus held-out evaluation ([`Session::eval`], [`Session::eval_all`]).
//!
//! The fwd artifact has fixed (batch, seq_len) shape, so decoding re-runs
//! the full-sequence forward with prompts left-aligned per row and reads
//! the logits at each row's current position (fine for demo-scale models;
//! a KV-cache decode graph is the standard extension and now has a single
//! home: this module).

use anyhow::{ensure, Result};

use crate::data::batching::{Batch, Batcher};
use crate::data::tokenizer::{Tokenizer, BOS, EOS, PAD, SEP};
use crate::runtime::executor::{literal_scalar_f32, literal_to_f32};
use crate::tensorio::Tensor;
use crate::util::rng::Rng;

use super::sampler::Sampler;
use super::{Engine, BASE_ADAPTER};

/// Builder returned by [`Engine::session`].
pub struct SessionBuilder<'e> {
    engine: &'e Engine,
    adapter: String,
    sampler: Sampler,
    greedy: bool,
    seed: u64,
}

impl<'e> SessionBuilder<'e> {
    pub(crate) fn new(engine: &'e Engine) -> SessionBuilder<'e> {
        SessionBuilder {
            engine,
            adapter: BASE_ADAPTER.to_string(),
            sampler: Sampler::default(),
            greedy: false,
            seed: 0,
        }
    }

    /// Serve this named adapter (default: [`BASE_ADAPTER`]).
    pub fn adapter(mut self, name: &str) -> Self {
        self.adapter = name.to_string();
        self
    }

    pub fn sampler(mut self, sampler: Sampler) -> Self {
        self.sampler = sampler;
        self
    }

    /// Deterministic argmax decoding (accuracy-style eval).
    pub fn greedy(mut self, greedy: bool) -> Self {
        self.greedy = greedy;
        self
    }

    /// Seed of the session's private sampling RNG.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Validate the adapter and produce the session.
    pub fn build(self) -> Result<Session<'e>> {
        // resolve once so a typo fails at build time, not mid-decode
        self.engine.adapter_literals(&self.adapter)?;
        let tok = Tokenizer::new(self.engine.spec.cfg.vocab);
        Ok(Session {
            engine: self.engine,
            adapter: self.adapter,
            sampler: self.sampler,
            greedy: self.greedy,
            rng: Rng::new(self.seed),
            tok,
            tokens_generated: 0,
        })
    }
}

/// One serving session: a named adapter + sampling state over a shared
/// engine. Cheap to construct; create one per request stream.
pub struct Session<'e> {
    engine: &'e Engine,
    adapter: String,
    pub sampler: Sampler,
    pub greedy: bool,
    rng: Rng,
    tok: Tokenizer,
    /// cumulative count of sampled (emitted) tokens — serving metric
    tokens_generated: u64,
}

impl<'e> Session<'e> {
    pub fn engine(&self) -> &'e Engine {
        self.engine
    }

    pub fn adapter(&self) -> &str {
        &self.adapter
    }

    /// Hot-swap which adapter this session serves (it must be registered).
    pub fn set_adapter(&mut self, name: &str) -> Result<()> {
        self.engine.adapter_literals(name)?;
        self.adapter = name.to_string();
        Ok(())
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tok
    }

    /// Total tokens sampled by this session (across all calls).
    pub fn tokens_generated(&self) -> u64 {
        self.tokens_generated
    }

    fn encode_prompt(&self, prompt: &str) -> Result<Vec<i32>> {
        let mut ids = vec![BOS];
        ids.extend(self.tok.encode(prompt));
        ids.push(SEP);
        ensure!(
            ids.len() < self.engine.spec.cfg.seq_len,
            "prompt too long ({} tokens, compiled seq_len {})",
            ids.len(),
            self.engine.spec.cfg.seq_len
        );
        Ok(ids)
    }

    /// One full-sequence forward: logits for the whole (batch, seq, vocab)
    /// buffer under this session's adapter.
    fn forward(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let cfg = &self.engine.spec.cfg;
        let exe = self.engine.fwd_exe()?;
        let adapter = self.engine.adapter_literals(&self.adapter)?;
        let t = Tensor::i32("tokens", vec![cfg.batch, cfg.seq_len], tokens);
        let tok = crate::runtime::executor::literal_from_tensor(&t)?;
        let frozen = self.engine.frozen();
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(adapter.len() + frozen.len() + 1);
        inputs.extend(adapter.iter());
        inputs.extend(frozen.iter());
        inputs.push(&tok);
        let out = exe.run(&inputs)?;
        literal_to_f32(&out[0])
    }

    fn next_token(&mut self, logits_row: &[f32]) -> i32 {
        if self.greedy {
            Sampler::greedy(logits_row)
        } else {
            self.sampler.sample(logits_row, &mut self.rng)
        }
    }

    /// Generate a full completion for one prompt.
    pub fn generate(&mut self, prompt: &str) -> Result<String> {
        self.generate_with(prompt, |_| {})
    }

    /// Generate a completion, invoking `on_token` with each decoded token
    /// fragment as it is produced (callback-style streaming).
    pub fn generate_with(
        &mut self,
        prompt: &str,
        mut on_token: impl FnMut(&str),
    ) -> Result<String> {
        let mut out = String::new();
        let mut stream = self.stream(prompt)?;
        while let Some(piece) = stream.next_token_text() {
            let piece = piece?;
            on_token(&piece);
            out.push_str(&piece);
        }
        Ok(out)
    }

    /// Token-by-token streaming decode as an iterator of decoded
    /// fragments. Ends at EOS, `max_new_tokens`, or the compiled
    /// `seq_len`.
    pub fn stream(&mut self, prompt: &str) -> Result<TokenStream<'_, 'e>> {
        self.engine.fwd_exe()?; // fail before the first next() on fwd-less artifacts
        let prompt_ids = self.encode_prompt(prompt)?;
        Ok(TokenStream { session: self, prompt_ids, out: Vec::new(), done: false })
    }

    /// Batched multi-prompt decoding: up to `cfg.batch` prompts advance in
    /// lockstep, one forward per step for all unfinished rows. With greedy
    /// decoding the per-row results are identical to `generate` on each
    /// prompt alone.
    pub fn generate_batch(&mut self, prompts: &[&str]) -> Result<Vec<String>> {
        let cfg = self.engine.spec.cfg.clone();
        ensure!(!prompts.is_empty(), "no prompts");
        ensure!(
            prompts.len() <= cfg.batch,
            "{} prompts exceed the compiled batch size {}",
            prompts.len(),
            cfg.batch
        );
        let rows: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| self.encode_prompt(p))
            .collect::<Result<_>>()?;
        let n = rows.len();
        let mut outs: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut done = vec![false; n];
        for _ in 0..self.sampler.max_new_tokens {
            for r in 0..n {
                if rows[r].len() + outs[r].len() >= cfg.seq_len {
                    done[r] = true;
                }
            }
            if done.iter().all(|&d| d) {
                break;
            }
            let mut tokens = vec![PAD; cfg.batch * cfg.seq_len];
            for r in 0..n {
                let base = r * cfg.seq_len;
                let plen = rows[r].len();
                tokens[base..base + plen].copy_from_slice(&rows[r]);
                tokens[base + plen..base + plen + outs[r].len()]
                    .copy_from_slice(&outs[r]);
            }
            let logits = self.forward(&tokens)?;
            for r in 0..n {
                if done[r] {
                    continue;
                }
                let pos = rows[r].len() + outs[r].len();
                let off = (r * cfg.seq_len + pos - 1) * cfg.vocab;
                let next = self.next_token(&logits[off..off + cfg.vocab]);
                if next == EOS {
                    done[r] = true;
                } else {
                    outs[r].push(next);
                    self.tokens_generated += 1;
                }
            }
        }
        Ok(outs.iter().map(|o| self.tok.decode(o)).collect())
    }

    /// (loss, token accuracy) on one batch under this session's adapter —
    /// no training state anywhere near this path.
    pub fn eval(&self, batch: &Batch) -> Result<(f32, f32)> {
        let exe = self.engine.eval_exe()?;
        let adapter = self.engine.adapter_literals(&self.adapter)?;
        let [tok, mask] = self.engine.batch_literals(batch)?;
        let frozen = self.engine.frozen();
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(adapter.len() + frozen.len() + 2);
        inputs.extend(adapter.iter());
        inputs.extend(frozen.iter());
        inputs.push(&tok);
        inputs.push(&mask);
        let out = exe.run(&inputs)?;
        ensure!(out.len() == 2, "eval returned {} outputs", out.len());
        Ok((literal_scalar_f32(&out[0])?, literal_scalar_f32(&out[1])?))
    }

    /// Mean (loss, accuracy) over a whole batcher.
    pub fn eval_all(&self, batcher: &Batcher, seed: u64) -> Result<(f32, f32)> {
        let batches = batcher.epoch(seed);
        ensure!(!batches.is_empty(), "empty eval set");
        let mut loss = 0f64;
        let mut acc = 0f64;
        for b in &batches {
            let (l, a) = self.eval(b)?;
            loss += l as f64;
            acc += a as f64;
        }
        let n = batches.len() as f64;
        Ok(((loss / n) as f32, (acc / n) as f32))
    }
}

/// Streaming decode state; see [`Session::stream`].
pub struct TokenStream<'s, 'e> {
    session: &'s mut Session<'e>,
    prompt_ids: Vec<i32>,
    out: Vec<i32>,
    done: bool,
}

impl TokenStream<'_, '_> {
    /// Token ids emitted so far.
    pub fn emitted(&self) -> &[i32] {
        &self.out
    }

    /// Produce the next decoded token fragment, or `None` when the stream
    /// is finished (EOS / token budget / sequence length).
    pub fn next_token_text(&mut self) -> Option<Result<String>> {
        if self.done || self.out.len() >= self.session.sampler.max_new_tokens {
            return None;
        }
        let cfg = self.session.engine.spec.cfg.clone();
        let plen = self.prompt_ids.len();
        let pos = plen + self.out.len();
        if pos >= cfg.seq_len {
            self.done = true;
            return None;
        }
        let mut tokens = vec![PAD; cfg.batch * cfg.seq_len];
        tokens[..plen].copy_from_slice(&self.prompt_ids);
        tokens[plen..pos].copy_from_slice(&self.out);
        let logits = match self.session.forward(&tokens) {
            Ok(l) => l,
            Err(e) => {
                self.done = true;
                return Some(Err(e));
            }
        };
        let off = (pos - 1) * cfg.vocab;
        let next = self.session.next_token(&logits[off..off + cfg.vocab]);
        if next == EOS {
            self.done = true;
            return None;
        }
        self.out.push(next);
        self.session.tokens_generated += 1;
        Some(Ok(self.session.tok.decode(&[next])))
    }
}

impl Iterator for TokenStream<'_, '_> {
    type Item = Result<String>;

    fn next(&mut self) -> Option<Result<String>> {
        self.next_token_text()
    }
}
