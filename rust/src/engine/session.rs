//! Sessions: the inference surface of the engine.
//!
//! A `Session` pins one named adapter over the engine's frozen base and
//! exposes the decode loop four ways — whole-completion
//! ([`Session::generate`]), token-by-token streaming ([`Session::stream`]
//! / [`Session::generate_with`]), batched multi-prompt decoding
//! ([`Session::generate_batch`]), and full request-lifecycle serving
//! ([`Session::serve`]) — plus held-out evaluation ([`Session::eval`],
//! [`Session::eval_all`]).
//!
//! Decoding runs through a [`DecodeGraph`]: by default the KV-cached
//! incremental path (one prefill per prompt, then O(1)-per-token steps
//! against per-row key/value caches), falling back to the full-sequence
//! recompute when the artifact ships no decode graphs — see
//! [`DecodeMode`] and the [`decode`](super::decode) module docs.
//!
//! Serving is a request pipeline, not "batch of strings in, strings
//! out": each [`GenRequest`] carries its own sampling parameters,
//! [`Priority`] class, optional deadline, and cancellation handle. A
//! [`Scheduler`] multiplexes any number of requests over the compiled
//! batch rows (continuous batching); by default each row's KV cache is
//! accounted in fixed-size blocks with copy-on-write prefix sharing
//! (admission charges blocks actually allocated, and the lowest-priority
//! row is swapped out under pressure — see
//! [`paged::blocks`](crate::paged::blocks)), with
//! [`SessionBuilder::token_budget`] selecting the legacy worst-case
//! token reservation instead. [`Session::serve`] reports a typed
//! [`JobOutcome`] per request plus a [`ServerStats`] block — see the
//! [`scheduler`](super::scheduler) module docs for the admission policy.

use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::data::batching::{Batch, Batcher};
use crate::data::tokenizer::{Tokenizer, BOS, EOS, SEP};
use crate::paged::BlockConfig;
use crate::runtime::executor::literal_scalar_f32;
use crate::util::faults::{FaultSite, Faults};
use crate::util::rng::Rng;

use super::decode::{CachedDecode, DecodeGraph, DecodeMode, FullDecode};
use super::sampler::Sampler;
use super::scheduler::{
    CancelHandle, JobOutcome, Priority, Request, Scheduler, ServerStats,
};
use super::{Engine, BASE_ADAPTER};

/// Builder returned by [`Engine::session`].
pub struct SessionBuilder<'e> {
    engine: &'e Engine,
    adapter: String,
    sampler: Sampler,
    greedy: bool,
    seed: u64,
    decode: DecodeMode,
    token_budget: Option<usize>,
    kv_block_tokens: Option<usize>,
    kv_blocks: Option<usize>,
    prefix_sharing: bool,
    watchdog: Option<Duration>,
    faults: Faults,
}

impl<'e> SessionBuilder<'e> {
    pub(crate) fn new(engine: &'e Engine) -> SessionBuilder<'e> {
        SessionBuilder {
            engine,
            adapter: BASE_ADAPTER.to_string(),
            sampler: Sampler::default(),
            greedy: false,
            seed: 0,
            decode: DecodeMode::Auto,
            token_budget: None,
            kv_block_tokens: None,
            kv_blocks: None,
            prefix_sharing: true,
            watchdog: None,
            faults: Faults::disabled(),
        }
    }

    /// Serve this named adapter (default: [`BASE_ADAPTER`]).
    pub fn adapter(mut self, name: &str) -> Self {
        self.adapter = name.to_string();
        self
    }

    /// Default sampling configuration for the decode loop (requests may
    /// override it per-request via [`GenRequest::sampler`]).
    pub fn sampler(mut self, sampler: Sampler) -> Self {
        self.sampler = sampler;
        self
    }

    /// Deterministic argmax decoding (accuracy-style eval).
    pub fn greedy(mut self, greedy: bool) -> Self {
        self.greedy = greedy;
        self
    }

    /// Seed of the session's private sampling RNG.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Which decode path to use (default [`DecodeMode::Auto`]: KV-cached
    /// when the artifact ships decode graphs, full recompute otherwise).
    pub fn decode(mut self, mode: DecodeMode) -> Self {
        self.decode = mode;
        self
    }

    /// Use the **legacy** admission policy: cap the sum of worst-case
    /// reserved (`prompt + max_new`) tokens across resident rows — see
    /// [`Scheduler::with_budget`](super::Scheduler::with_budget). This
    /// disables block-granular KV admission (and with it prefix sharing
    /// and swap-out) for the session; without it, serving admits by KV
    /// blocks actually allocated.
    pub fn token_budget(mut self, budget: usize) -> Self {
        self.token_budget = Some(budget);
        self
    }

    /// Tokens of K/V per cache block for block-granular admission
    /// (default 16). Smaller blocks track footprint more precisely and
    /// share shorter prefixes; larger blocks cut bookkeeping overhead.
    pub fn kv_block_tokens(mut self, tokens: usize) -> Self {
        self.kv_block_tokens = Some(tokens);
        self
    }

    /// Physical KV blocks in the pool. The default
    /// (`batch × ⌈seq_len / block_tokens⌉ + 1` headroom) never
    /// constrains below the compiled row capacity; shrink it to bound
    /// serving memory by blocks rather than rows.
    pub fn kv_blocks(mut self, blocks: usize) -> Self {
        self.kv_blocks = Some(blocks);
        self
    }

    /// Enable/disable copy-on-write prefix sharing across rows (default
    /// on). Greedy outputs are bit-identical either way — sharing only
    /// changes how many rows fit.
    pub fn prefix_sharing(mut self, on: bool) -> Self {
        self.prefix_sharing = on;
        self
    }

    /// Arm the decode-step watchdog: an in-flight request that records
    /// no token for `window` is retired with
    /// [`JobOutcome::TimedOut`](super::JobOutcome::TimedOut) instead of
    /// occupying its row forever (default: no watchdog). The window
    /// restarts at admission, so queue wait never counts against it.
    pub fn watchdog(mut self, window: Duration) -> Self {
        self.watchdog = Some(window);
        self
    }

    /// Attach a fault-injection plane (see [`crate::util::faults`]).
    /// The engine-side sites fire from this handle: `decode-delay`
    /// before each decode step, `block-alloc` inside the KV block
    /// manager. Disabled by default — and zero-cost then.
    pub fn faults(mut self, faults: Faults) -> Self {
        self.faults = faults;
        self
    }

    /// Validate the adapter and produce the session.
    pub fn build(self) -> Result<Session<'e>> {
        // resolve once so a typo fails at build time, not mid-decode
        self.engine.adapter_literals(&self.adapter)?;
        let tok = Tokenizer::new(self.engine.spec.cfg.vocab);
        let cfg = &self.engine.spec.cfg;
        let block_tokens = self.kv_block_tokens.unwrap_or(16).max(1);
        let per_row = cfg.seq_len.div_ceil(block_tokens);
        let mut block_cfg = BlockConfig::new(
            block_tokens,
            self.kv_blocks
                .unwrap_or(cfg.batch * per_row + 1 /* growth headroom */),
        );
        ensure!(
            block_cfg.n_blocks >= per_row,
            "kv_blocks {} cannot hold even one full row ({} blocks of {} \
             tokens for seq_len {})",
            block_cfg.n_blocks,
            per_row,
            block_tokens,
            cfg.seq_len
        );
        block_cfg.prefix_sharing = self.prefix_sharing;
        // K + V bytes per token, f32: what a swap-out migrates per block
        block_cfg.bytes_per_block =
            2 * cfg.n_layers * cfg.d_model * 4 * block_tokens;
        Ok(Session {
            engine: self.engine,
            adapter: self.adapter,
            sampler: self.sampler,
            greedy: self.greedy,
            decode: self.decode,
            token_budget: self.token_budget,
            block_cfg,
            watchdog: self.watchdog,
            faults: self.faults,
            rng: Rng::new(self.seed),
            tok,
            tokens_generated: 0,
        })
    }
}

/// One request through the serving pipeline: a prompt plus per-request
/// sampling parameters and lifecycle controls. Build with
/// [`GenRequest::new`] and chain the setters; everything defaults to the
/// session's own configuration.
#[derive(Debug, Clone, Default)]
pub struct GenRequest {
    /// The prompt text (tokenized by the session on submission).
    pub prompt: String,
    /// Admission class; see [`Priority`].
    pub priority: Priority,
    /// Give up this long after submission (queued requests expire
    /// without running; in-flight requests keep their partial output).
    pub deadline: Option<Duration>,
    /// Per-request sampling parameters; `None` uses the session sampler.
    pub sampler: Option<Sampler>,
    /// Cooperative cancellation flag; `None` makes the request
    /// uncancellable (a fresh private handle is used internally).
    pub cancel: Option<CancelHandle>,
}

impl GenRequest {
    /// A `Normal`-priority request with the session's default sampler,
    /// no deadline, and no cancellation handle.
    pub fn new(prompt: impl Into<String>) -> GenRequest {
        GenRequest { prompt: prompt.into(), ..GenRequest::default() }
    }

    /// Set the admission class.
    pub fn priority(mut self, p: Priority) -> GenRequest {
        self.priority = p;
        self
    }

    /// Set a deadline relative to submission.
    pub fn deadline(mut self, d: Duration) -> GenRequest {
        self.deadline = Some(d);
        self
    }

    /// Override the session sampler for this request (nucleus/top-k/
    /// temperature/`max_new_tokens`). The override is complete: it also
    /// replaces the session's `greedy` flag for this request — ask for
    /// per-request argmax decoding with `temperature: 0.0` (a
    /// non-positive temperature is exactly greedy; see [`Sampler`]).
    pub fn sampler(mut self, s: Sampler) -> GenRequest {
        self.sampler = Some(s);
        self
    }

    /// Attach a fresh [`CancelHandle`] and return it alongside the
    /// request; call [`CancelHandle::cancel`] (from any thread, or from
    /// a [`Session::serve_with`] step callback) to retire the request.
    pub fn cancellable(mut self) -> (GenRequest, CancelHandle) {
        let handle = CancelHandle::new();
        self.cancel = Some(handle.clone());
        (self, handle)
    }
}

/// Terminal state of one served request: the typed outcome plus the
/// decoded text (partial for `Cancelled`/`DeadlineExceeded`/`Aborted`).
#[derive(Debug, Clone)]
pub struct ServeOutput {
    /// How the request ended.
    pub outcome: JobOutcome,
    /// Decoded completion text (whatever was generated before the end).
    pub text: String,
}

/// Everything [`Session::serve`] returns: per-request outcomes in
/// submission order plus the aggregate serving statistics.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Per-request terminal states, in submission order.
    pub outputs: Vec<ServeOutput>,
    /// Aggregate statistics over the whole serve call (with `elapsed`
    /// filled in).
    pub stats: ServerStats,
}

/// Per-step progress snapshot handed to the [`Session::serve_with`]
/// callback after every decode step — the hook for live dashboards and
/// for cancelling in-flight requests from single-threaded drivers.
#[derive(Debug, Clone)]
pub struct ServeProgress {
    /// Decode steps executed so far (1 on the first callback).
    pub step: usize,
    /// Scheduler statistics at this step.
    pub stats: ServerStats,
}

/// One batch of work pulled from a [`ServeDriver`] by
/// [`Session::serve_loop`].
#[derive(Debug, Default)]
pub struct SourcePoll {
    /// New requests, each tagged with a caller-chosen id; every
    /// subsequent [`ServeEvent`] for that request carries the tag, so
    /// drivers never depend on scheduler job-id assignment.
    pub requests: Vec<(u64, GenRequest)>,
    /// `false` once the source will never produce another request: the
    /// loop drains in-flight work and returns.
    pub open: bool,
}

/// One lifecycle event from [`Session::serve_loop`], keyed by the
/// driver's own request tag.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// The request was rejected before submission (e.g. a prompt longer
    /// than the compiled sequence); no further events for this tag.
    Rejected {
        /// the driver's tag for the rejected request
        tag: u64,
        /// why it was rejected
        error: String,
    },
    /// One token was recorded for this request (skipped tokens lost to
    /// a swap-out are re-generated after resume, so each recorded token
    /// is reported exactly once and the concatenation of `text` pieces
    /// equals the final completion).
    Token {
        /// the driver's tag for the request
        tag: u64,
        /// the decoded fragment for this one token
        text: String,
    },
    /// The request reached a terminal outcome.
    Finished {
        /// the driver's tag for the request
        tag: u64,
        /// how it ended
        outcome: JobOutcome,
        /// full decoded completion (partial for non-`Done` outcomes)
        text: String,
    },
    /// One decode step completed — the per-step stats snapshot, for
    /// dashboards and concurrent `/v1/stats` publication.
    Step {
        /// decode steps executed so far (1 on the first event)
        step: usize,
        /// scheduler statistics at this step
        stats: ServerStats,
    },
}

/// The pluggable half of [`Session::serve_loop`]: where new requests
/// come from and where lifecycle events go. One object carries both
/// sides so a driver can share state between them without interior
/// mutability.
pub trait ServeDriver {
    /// Pull new work. `idle` is true when the scheduler has nothing
    /// queued or running: the driver may block (e.g. on a condvar)
    /// until work arrives or the source closes. When `idle` is false it
    /// must return promptly — an empty batch is fine.
    fn poll(&mut self, idle: bool) -> SourcePoll;

    /// Receive one lifecycle event. Called from the decode thread
    /// between steps; keep it cheap (hand slow work to channels).
    fn on_event(&mut self, ev: ServeEvent);
}

/// One serving session: a named adapter + sampling state over a shared
/// engine. Cheap to construct; create one per request stream.
pub struct Session<'e> {
    engine: &'e Engine,
    adapter: String,
    /// Default sampling configuration (nucleus/top-k/temperature/token
    /// budget); [`GenRequest::sampler`] overrides it per request.
    pub sampler: Sampler,
    /// Deterministic argmax decoding instead of sampling.
    pub greedy: bool,
    /// Decode-path selection; see [`DecodeMode`].
    pub decode: DecodeMode,
    /// Legacy worst-case token budget for [`Session::serve`]; `None`
    /// (the default) admits by KV blocks instead — see
    /// [`SessionBuilder::token_budget`].
    pub token_budget: Option<usize>,
    /// Block-granular KV admission config (ignored when `token_budget`
    /// is set); see [`SessionBuilder::kv_blocks`].
    pub block_cfg: BlockConfig,
    /// Decode-step watchdog window; see [`SessionBuilder::watchdog`].
    pub watchdog: Option<Duration>,
    /// Fault-injection handle for the engine-side sites; see
    /// [`SessionBuilder::faults`].
    pub faults: Faults,
    rng: Rng,
    tok: Tokenizer,
    /// cumulative count of sampled (emitted) tokens — serving metric
    tokens_generated: u64,
}

impl<'e> Session<'e> {
    /// The engine this session serves from.
    pub fn engine(&self) -> &'e Engine {
        self.engine
    }

    /// Name of the adapter this session serves.
    pub fn adapter(&self) -> &str {
        &self.adapter
    }

    /// Hot-swap which adapter this session serves (it must be registered).
    /// Decodes already in flight keep their pinned adapter literals; the
    /// swap applies from the next `generate`/`stream`/`serve` call.
    pub fn set_adapter(&mut self, name: &str) -> Result<()> {
        self.engine.adapter_literals(name)?;
        self.adapter = name.to_string();
        Ok(())
    }

    /// The session's tokenizer (byte-level, artifact vocab).
    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tok
    }

    /// Total tokens sampled by this session (across all calls).
    pub fn tokens_generated(&self) -> u64 {
        self.tokens_generated
    }

    fn encode_prompt(&self, prompt: &str) -> Result<Vec<i32>> {
        let mut ids = vec![BOS];
        ids.extend(self.tok.encode(prompt));
        ids.push(SEP);
        ensure!(
            ids.len() < self.engine.spec.cfg.seq_len,
            "prompt too long ({} tokens, compiled seq_len {})",
            ids.len(),
            self.engine.spec.cfg.seq_len
        );
        Ok(ids)
    }

    /// Build the decode graph this session is configured for, pinning the
    /// current adapter version.
    fn decode_graph(&self) -> Result<Box<dyn DecodeGraph + 'e>> {
        let use_cached = match self.decode {
            DecodeMode::Cached => true,
            DecodeMode::Full => false,
            DecodeMode::Auto => self.engine.has_cached_decode(),
        };
        if use_cached {
            Ok(Box::new(CachedDecode::new(self.engine, &self.adapter)?))
        } else {
            Ok(Box::new(FullDecode::new(self.engine, &self.adapter)?))
        }
    }

    /// Sample one token under `sampler` (or argmax when `greedy`).
    fn sample_token(
        greedy: bool,
        sampler: &Sampler,
        rng: &mut Rng,
        logits_row: &[f32],
    ) -> i32 {
        if greedy {
            Sampler::greedy(logits_row)
        } else {
            sampler.sample(logits_row, rng)
        }
    }

    fn next_token(&mut self, logits_row: &[f32]) -> i32 {
        Self::sample_token(self.greedy, &self.sampler, &mut self.rng, logits_row)
    }

    /// Generate a full completion for one prompt.
    pub fn generate(&mut self, prompt: &str) -> Result<String> {
        self.generate_with(prompt, |_| {})
    }

    /// Generate a completion, invoking `on_token` with each decoded token
    /// fragment as it is produced (callback-style streaming).
    pub fn generate_with(
        &mut self,
        prompt: &str,
        mut on_token: impl FnMut(&str),
    ) -> Result<String> {
        let mut out = String::new();
        let mut stream = self.stream(prompt)?;
        while let Some(piece) = stream.next_token_text() {
            let piece = piece?;
            on_token(&piece);
            out.push_str(&piece);
        }
        Ok(out)
    }

    /// Token-by-token streaming decode as an iterator of decoded
    /// fragments. Ends at EOS, `max_new_tokens`, or the compiled
    /// `seq_len`.
    pub fn stream(&mut self, prompt: &str) -> Result<TokenStream<'_, 'e>> {
        let mut graph = self.decode_graph()?;
        let prompt_ids = self.encode_prompt(prompt)?;
        let plen = prompt_ids.len();
        graph.start_row(0, &prompt_ids)?;
        Ok(TokenStream { session: self, graph, plen, out: Vec::new(), done: false })
    }

    /// Batched multi-prompt decoding with continuous batching: any number
    /// of prompts are multiplexed over the compiled batch rows, new
    /// prompts entering a row as soon as an earlier one retires (EOS,
    /// token budget, or sequence length). Results come back in prompt
    /// order. With greedy decoding each row's result is identical to
    /// `generate` on that prompt alone.
    ///
    /// This is the plain-prompt convenience over [`Session::serve`]:
    /// every prompt runs at `Normal` priority with the session sampler,
    /// no deadline, and no cancellation, so every outcome is `Done`.
    pub fn generate_batch(&mut self, prompts: &[&str]) -> Result<Vec<String>> {
        ensure!(!prompts.is_empty(), "no prompts");
        let reqs = prompts.iter().map(|p| GenRequest::new(*p)).collect();
        let report = self.serve(reqs)?;
        Ok(report.outputs.into_iter().map(|o| o.text).collect())
    }

    /// Serve a set of [`GenRequest`]s to completion; convenience over
    /// [`Session::serve_with`] without a progress callback.
    pub fn serve(&mut self, requests: Vec<GenRequest>) -> Result<ServeReport> {
        self.serve_with(requests, |_| {})
    }

    /// The request-lifecycle serving loop: multiplex `requests` over the
    /// compiled batch rows, honouring priorities, deadlines, and
    /// cancellation, with admission gated by the session's memory policy
    /// — block-granular KV accounting with copy-on-write prefix sharing
    /// and swap-out under pressure by default, or the legacy worst-case
    /// [`token budget`](SessionBuilder::token_budget). `on_step` runs
    /// after every decode step with a [`ServeProgress`] snapshot —
    /// cancel handles flipped inside it take effect before the next step
    /// (the row is freed and refilled from the queue within one step).
    ///
    /// Every request ends in exactly one typed [`JobOutcome`]; partial
    /// output survives cancellation, deadline expiry, *and* swap-out (a
    /// swapped-out request resumes by re-prefilling its whole history).
    /// An error from the decode graph aborts the whole loop and is
    /// returned as the `Err` (no report is produced in that case).
    pub fn serve_with(
        &mut self,
        requests: Vec<GenRequest>,
        mut on_step: impl FnMut(&ServeProgress),
    ) -> Result<ServeReport> {
        ensure!(!requests.is_empty(), "no requests");
        let mut graph = self.decode_graph()?;
        let seq_len = graph.seq_len();
        let mut sched = match self.token_budget {
            Some(budget) => Scheduler::with_budget(graph.capacity(), budget),
            None => Scheduler::with_blocks(
                graph.capacity(),
                self.block_cfg.clone(),
            )?,
        };
        sched.set_watchdog(self.watchdog);
        sched.set_faults(self.faults.clone());
        // (sampler, greedy) per job: a per-request sampler is a complete
        // override, so the session's greedy flag only applies to
        // requests that inherit the session sampler
        let mut samplers: Vec<(Sampler, bool)> =
            Vec::with_capacity(requests.len());
        let now = Instant::now();
        for req in requests {
            let prompt = self.encode_prompt(&req.prompt)?;
            let (sampler, greedy) = match req.sampler {
                Some(s) => (s, false),
                None => (self.sampler.clone(), self.greedy),
            };
            // clamp to what the compiled sequence can hold so the
            // reservation never overstates a request's footprint
            let max_new =
                sampler.max_new_tokens.min(seq_len - prompt.len());
            let mut r = Request::new(prompt, max_new).priority(req.priority);
            if let Some(d) = req.deadline {
                r = r.deadline(d);
            }
            sched.submit_with_handle(r, req.cancel.unwrap_or_default(), now);
            samplers.push((sampler, greedy));
        }
        let started = Instant::now();
        let mut step = 0usize;
        while !sched.finished() {
            let now = Instant::now();
            // cancellation + deadline expiry first: a cancelled in-flight
            // request vacates its row before this step's admissions
            for ret in sched.poll(now) {
                graph.free_row(ret.row);
            }
            let placed = sched.admit(now);
            // swap-outs happen *inside* admit (a higher-priority arrival
            // preempts resident rows), so vacate those rows before any
            // admission reuses them
            for sw in sched.take_swap_outs() {
                graph.free_row(sw.row);
            }
            for adm in placed {
                graph.start_row(adm.row, &adm.prompt)?;
                if let Some(t) = sched.row_block_table(adm.row) {
                    graph.set_block_table(adm.row, t);
                }
            }
            // retire rows that have exhausted their own budget or the
            // compiled sequence before (not after) stepping them
            for row in sched.active_rows() {
                if sched.budget_exhausted(row, seq_len) {
                    sched.retire(row)?;
                    graph.free_row(row);
                }
            }
            let rows = sched.active_rows();
            if rows.is_empty() {
                continue; // freed rows refill on the next iteration
            }
            // injected fault: a stalled accelerator step (what the
            // decode-step watchdog exists to catch)
            if self.faults.fire(FaultSite::DecodeDelay) {
                std::thread::sleep(self.faults.delay());
            }
            let logits = graph.step(&rows)?;
            let now = Instant::now();
            for (&row, row_logits) in rows.iter().zip(logits.iter()) {
                // an earlier row's push this step may have swapped this
                // row out to make room; its sampled token is simply lost
                // (the job re-prefills from its recorded history later)
                let Some(id) = sched.job_in(row) else { continue };
                // pallas-lint: allow(no-hot-path-panic) — job ids index the samplers vec built from the same submissions
                let (sampler, greedy) = &samplers[id];
                let next = Self::sample_token(
                    *greedy,
                    sampler,
                    &mut self.rng,
                    row_logits,
                );
                if next == EOS {
                    sched.retire(row)?;
                    graph.free_row(row);
                } else if sched.push(row, next, now)? {
                    self.tokens_generated += 1;
                    graph.push(row, next)?;
                    if let Some(t) = sched.row_block_table(row) {
                        graph.set_block_table(row, t);
                    }
                }
            }
            // pushes past the pool swap rows out too; vacate them so the
            // next admission round can re-place those rows
            for sw in sched.take_swap_outs() {
                graph.free_row(sw.row);
            }
            step += 1;
            on_step(&ServeProgress { step, stats: sched.stats() });
        }
        let mut stats = sched.stats();
        stats.elapsed = started.elapsed();
        let outputs = sched
            .take_results()
            .into_iter()
            .map(|r| ServeOutput {
                outcome: r.outcome,
                text: self.tok.decode(&r.tokens),
            })
            .collect();
        Ok(ServeReport { outputs, stats })
    }

    /// The open-ended twin of [`Session::serve_with`]: requests arrive
    /// *while the loop runs*, pulled from `driver` between decode steps
    /// (the scheduling loop the HTTP front end in [`crate::serve`]
    /// drives). Admission, deadlines, cancellation, swap-out, and
    /// per-step ordering are identical to `serve_with`; the differences
    /// are the incremental source (tagged requests, so the driver never
    /// depends on job-id assignment), per-token/per-completion
    /// [`ServeEvent`]s, and per-request rejection (an over-long prompt
    /// is a `Rejected` event, not a loop-level error). Returns the
    /// terminal report over everything submitted, in submission order.
    pub fn serve_loop(
        &mut self,
        driver: &mut dyn ServeDriver,
    ) -> Result<ServeReport> {
        let mut graph = self.decode_graph()?;
        let seq_len = graph.seq_len();
        let mut sched = match self.token_budget {
            Some(budget) => Scheduler::with_budget(graph.capacity(), budget),
            None => Scheduler::with_blocks(
                graph.capacity(),
                self.block_cfg.clone(),
            )?,
        };
        sched.set_watchdog(self.watchdog);
        sched.set_faults(self.faults.clone());
        // (sampler, greedy) and driver tag per job id; ids are minted
        // sequentially by submit, so plain Vecs stay in lockstep
        let mut samplers: Vec<(Sampler, bool)> = Vec::new();
        let mut tags: Vec<u64> = Vec::new();
        let mut open = true;
        let started = Instant::now();
        let mut step = 0usize;
        loop {
            if open {
                let poll = driver.poll(sched.finished());
                let now = Instant::now();
                for (tag, req) in poll.requests {
                    let prompt = match self.encode_prompt(&req.prompt) {
                        Ok(p) => p,
                        Err(e) => {
                            driver.on_event(ServeEvent::Rejected {
                                tag,
                                error: e.to_string(),
                            });
                            continue;
                        }
                    };
                    let (sampler, greedy) = match req.sampler {
                        Some(s) => (s, false),
                        None => (self.sampler.clone(), self.greedy),
                    };
                    let max_new =
                        sampler.max_new_tokens.min(seq_len - prompt.len());
                    let mut r =
                        Request::new(prompt, max_new).priority(req.priority);
                    if let Some(d) = req.deadline {
                        r = r.deadline(d);
                    }
                    sched.submit_with_handle(
                        r,
                        req.cancel.unwrap_or_default(),
                        now,
                    );
                    samplers.push((sampler, greedy));
                    tags.push(tag);
                }
                open = poll.open;
            }
            if sched.finished() {
                if open {
                    continue; // poll() blocks when idle — no busy wait
                }
                break;
            }
            // --- one decode step, ordered exactly as in serve_with ---
            let now = Instant::now();
            for ret in sched.poll(now) {
                graph.free_row(ret.row);
            }
            let placed = sched.admit(now);
            for sw in sched.take_swap_outs() {
                graph.free_row(sw.row);
            }
            for adm in placed {
                graph.start_row(adm.row, &adm.prompt)?;
                if let Some(t) = sched.row_block_table(adm.row) {
                    graph.set_block_table(adm.row, t);
                }
            }
            for row in sched.active_rows() {
                if sched.budget_exhausted(row, seq_len) {
                    sched.retire(row)?;
                    graph.free_row(row);
                }
            }
            let rows = sched.active_rows();
            if rows.is_empty() {
                // freed rows refill on the next iteration; deliver any
                // terminal outcomes recorded by the poll/sweep above,
                // and publish the stats they changed — a cancellation
                // that empties the batch must show up without waiting
                // for the next decode step
                Self::emit_finished(&mut sched, &tags, &self.tok, driver);
                driver.on_event(ServeEvent::Step {
                    step,
                    stats: sched.stats(),
                });
                continue;
            }
            // injected fault: a stalled accelerator step (what the
            // decode-step watchdog exists to catch)
            if self.faults.fire(FaultSite::DecodeDelay) {
                std::thread::sleep(self.faults.delay());
            }
            let logits = graph.step(&rows)?;
            let now = Instant::now();
            for (&row, row_logits) in rows.iter().zip(logits.iter()) {
                let Some(id) = sched.job_in(row) else { continue };
                let Some((sampler, greedy)) = samplers.get(id) else {
                    continue;
                };
                let next = Self::sample_token(
                    *greedy,
                    sampler,
                    &mut self.rng,
                    row_logits,
                );
                if next == EOS {
                    sched.retire(row)?;
                    graph.free_row(row);
                } else if sched.push(row, next, now)? {
                    self.tokens_generated += 1;
                    graph.push(row, next)?;
                    if let Some(t) = sched.row_block_table(row) {
                        graph.set_block_table(row, t);
                    }
                    driver.on_event(ServeEvent::Token {
                        tag: tags.get(id).copied().unwrap_or(u64::MAX),
                        text: self.tok.decode(&[next]),
                    });
                }
            }
            for sw in sched.take_swap_outs() {
                graph.free_row(sw.row);
            }
            Self::emit_finished(&mut sched, &tags, &self.tok, driver);
            step += 1;
            driver.on_event(ServeEvent::Step { step, stats: sched.stats() });
        }
        let mut stats = sched.stats();
        stats.elapsed = started.elapsed();
        let outputs = sched
            .take_results()
            .into_iter()
            .map(|r| ServeOutput {
                outcome: r.outcome,
                text: self.tok.decode(&r.tokens),
            })
            .collect();
        Ok(ServeReport { outputs, stats })
    }

    /// Deliver a `Finished` event for every job that reached a terminal
    /// outcome since the last drain.
    fn emit_finished(
        sched: &mut Scheduler,
        tags: &[u64],
        tok: &Tokenizer,
        driver: &mut dyn ServeDriver,
    ) {
        for (id, r) in sched.drain_finished() {
            driver.on_event(ServeEvent::Finished {
                tag: tags.get(id).copied().unwrap_or(u64::MAX),
                outcome: r.outcome,
                text: tok.decode(&r.tokens),
            });
        }
    }

    /// (loss, token accuracy) on one batch under this session's adapter —
    /// no training state anywhere near this path.
    pub fn eval(&self, batch: &Batch) -> Result<(f32, f32)> {
        let exe = self.engine.eval_exe()?;
        let adapter = self.engine.adapter_literals(&self.adapter)?;
        let [tok, mask] = self.engine.batch_literals(batch)?;
        let frozen = self.engine.frozen();
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(adapter.len() + frozen.len() + 2);
        inputs.extend(adapter.iter());
        inputs.extend(frozen.iter());
        inputs.push(&tok);
        inputs.push(&mask);
        let out = exe.run(&inputs)?;
        ensure!(out.len() == 2, "eval returned {} outputs", out.len());
        // pallas-lint: allow(no-hot-path-panic) — out.len() == 2 ensured on the line above
        Ok((literal_scalar_f32(&out[0])?, literal_scalar_f32(&out[1])?))
    }

    /// Mean (loss, accuracy) over a whole batcher.
    pub fn eval_all(&self, batcher: &Batcher, seed: u64) -> Result<(f32, f32)> {
        let batches = batcher.epoch(seed);
        ensure!(!batches.is_empty(), "empty eval set");
        let mut loss = 0f64;
        let mut acc = 0f64;
        for b in &batches {
            let (l, a) = self.eval(b)?;
            loss += l as f64;
            acc += a as f64;
        }
        let n = batches.len() as f64;
        Ok(((loss / n) as f32, (acc / n) as f32))
    }
}

/// Streaming decode state; see [`Session::stream`]. Holds its own
/// [`DecodeGraph`] (row 0), so the per-token cost is one incremental
/// decode step on KV-cached artifacts.
pub struct TokenStream<'s, 'e> {
    session: &'s mut Session<'e>,
    graph: Box<dyn DecodeGraph + 'e>,
    plen: usize,
    out: Vec<i32>,
    done: bool,
}

impl TokenStream<'_, '_> {
    /// Token ids emitted so far.
    pub fn emitted(&self) -> &[i32] {
        &self.out
    }

    /// Produce the next decoded token fragment, or `None` when the stream
    /// is finished (EOS / token budget / sequence length).
    pub fn next_token_text(&mut self) -> Option<Result<String>> {
        if self.done || self.out.len() >= self.session.sampler.max_new_tokens {
            return None;
        }
        if self.plen + self.out.len() >= self.graph.seq_len() {
            self.done = true;
            return None;
        }
        let row_logits = match self.graph.step(&[0]) {
            Ok(mut l) => l.remove(0),
            Err(e) => {
                self.done = true;
                return Some(Err(e));
            }
        };
        let next = self.session.next_token(&row_logits);
        if next == EOS {
            self.done = true;
            return None;
        }
        if let Err(e) = self.graph.push(0, next) {
            self.done = true;
            return Some(Err(e));
        }
        self.out.push(next);
        self.session.tokens_generated += 1;
        Some(Ok(self.session.tok.decode(&[next])))
    }
}

impl Iterator for TokenStream<'_, '_> {
    type Item = Result<String>;

    fn next(&mut self) -> Option<Result<String>> {
        self.next_token_text()
    }
}
