//! Sessions: the inference surface of the engine.
//!
//! A `Session` pins one named adapter over the engine's frozen base and
//! exposes the decode loop three ways — whole-completion
//! ([`Session::generate`]), token-by-token streaming ([`Session::stream`]
//! / [`Session::generate_with`]), and batched multi-prompt decoding
//! ([`Session::generate_batch`]) — plus held-out evaluation
//! ([`Session::eval`], [`Session::eval_all`]).
//!
//! Decoding runs through a [`DecodeGraph`]: by default the KV-cached
//! incremental path (one prefill per prompt, then O(1)-per-token steps
//! against per-row key/value caches), falling back to the full-sequence
//! recompute when the artifact ships no decode graphs — see
//! [`DecodeMode`] and the [`decode`](super::decode) module docs.
//! `generate_batch` accepts more prompts than the compiled batch size:
//! a [`Scheduler`] admits queued prompts into rows the moment earlier
//! requests retire (continuous batching), so throughput tracks aggregate
//! tokens rather than the slowest prompt of a padded batch.

use anyhow::{ensure, Result};

use crate::data::batching::{Batch, Batcher};
use crate::data::tokenizer::{Tokenizer, BOS, EOS, SEP};
use crate::runtime::executor::literal_scalar_f32;
use crate::util::rng::Rng;

use super::decode::{CachedDecode, DecodeGraph, DecodeMode, FullDecode};
use super::sampler::Sampler;
use super::scheduler::Scheduler;
use super::{Engine, BASE_ADAPTER};

/// Builder returned by [`Engine::session`].
pub struct SessionBuilder<'e> {
    engine: &'e Engine,
    adapter: String,
    sampler: Sampler,
    greedy: bool,
    seed: u64,
    decode: DecodeMode,
}

impl<'e> SessionBuilder<'e> {
    pub(crate) fn new(engine: &'e Engine) -> SessionBuilder<'e> {
        SessionBuilder {
            engine,
            adapter: BASE_ADAPTER.to_string(),
            sampler: Sampler::default(),
            greedy: false,
            seed: 0,
            decode: DecodeMode::Auto,
        }
    }

    /// Serve this named adapter (default: [`BASE_ADAPTER`]).
    pub fn adapter(mut self, name: &str) -> Self {
        self.adapter = name.to_string();
        self
    }

    /// Sampling configuration for the decode loop.
    pub fn sampler(mut self, sampler: Sampler) -> Self {
        self.sampler = sampler;
        self
    }

    /// Deterministic argmax decoding (accuracy-style eval).
    pub fn greedy(mut self, greedy: bool) -> Self {
        self.greedy = greedy;
        self
    }

    /// Seed of the session's private sampling RNG.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Which decode path to use (default [`DecodeMode::Auto`]: KV-cached
    /// when the artifact ships decode graphs, full recompute otherwise).
    pub fn decode(mut self, mode: DecodeMode) -> Self {
        self.decode = mode;
        self
    }

    /// Validate the adapter and produce the session.
    pub fn build(self) -> Result<Session<'e>> {
        // resolve once so a typo fails at build time, not mid-decode
        self.engine.adapter_literals(&self.adapter)?;
        let tok = Tokenizer::new(self.engine.spec.cfg.vocab);
        Ok(Session {
            engine: self.engine,
            adapter: self.adapter,
            sampler: self.sampler,
            greedy: self.greedy,
            decode: self.decode,
            rng: Rng::new(self.seed),
            tok,
            tokens_generated: 0,
        })
    }
}

/// One serving session: a named adapter + sampling state over a shared
/// engine. Cheap to construct; create one per request stream.
pub struct Session<'e> {
    engine: &'e Engine,
    adapter: String,
    /// Sampling configuration (nucleus/top-k/temperature/token budget).
    pub sampler: Sampler,
    /// Deterministic argmax decoding instead of sampling.
    pub greedy: bool,
    /// Decode-path selection; see [`DecodeMode`].
    pub decode: DecodeMode,
    rng: Rng,
    tok: Tokenizer,
    /// cumulative count of sampled (emitted) tokens — serving metric
    tokens_generated: u64,
}

impl<'e> Session<'e> {
    /// The engine this session serves from.
    pub fn engine(&self) -> &'e Engine {
        self.engine
    }

    /// Name of the adapter this session serves.
    pub fn adapter(&self) -> &str {
        &self.adapter
    }

    /// Hot-swap which adapter this session serves (it must be registered).
    /// Decodes already in flight keep their pinned adapter literals; the
    /// swap applies from the next `generate`/`stream`/`generate_batch`.
    pub fn set_adapter(&mut self, name: &str) -> Result<()> {
        self.engine.adapter_literals(name)?;
        self.adapter = name.to_string();
        Ok(())
    }

    /// The session's tokenizer (byte-level, artifact vocab).
    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tok
    }

    /// Total tokens sampled by this session (across all calls).
    pub fn tokens_generated(&self) -> u64 {
        self.tokens_generated
    }

    fn encode_prompt(&self, prompt: &str) -> Result<Vec<i32>> {
        let mut ids = vec![BOS];
        ids.extend(self.tok.encode(prompt));
        ids.push(SEP);
        ensure!(
            ids.len() < self.engine.spec.cfg.seq_len,
            "prompt too long ({} tokens, compiled seq_len {})",
            ids.len(),
            self.engine.spec.cfg.seq_len
        );
        Ok(ids)
    }

    /// Build the decode graph this session is configured for, pinning the
    /// current adapter version.
    fn decode_graph(&self) -> Result<Box<dyn DecodeGraph + 'e>> {
        let use_cached = match self.decode {
            DecodeMode::Cached => true,
            DecodeMode::Full => false,
            DecodeMode::Auto => self.engine.has_cached_decode(),
        };
        if use_cached {
            Ok(Box::new(CachedDecode::new(self.engine, &self.adapter)?))
        } else {
            Ok(Box::new(FullDecode::new(self.engine, &self.adapter)?))
        }
    }

    fn next_token(&mut self, logits_row: &[f32]) -> i32 {
        if self.greedy {
            Sampler::greedy(logits_row)
        } else {
            self.sampler.sample(logits_row, &mut self.rng)
        }
    }

    /// Generate a full completion for one prompt.
    pub fn generate(&mut self, prompt: &str) -> Result<String> {
        self.generate_with(prompt, |_| {})
    }

    /// Generate a completion, invoking `on_token` with each decoded token
    /// fragment as it is produced (callback-style streaming).
    pub fn generate_with(
        &mut self,
        prompt: &str,
        mut on_token: impl FnMut(&str),
    ) -> Result<String> {
        let mut out = String::new();
        let mut stream = self.stream(prompt)?;
        while let Some(piece) = stream.next_token_text() {
            let piece = piece?;
            on_token(&piece);
            out.push_str(&piece);
        }
        Ok(out)
    }

    /// Token-by-token streaming decode as an iterator of decoded
    /// fragments. Ends at EOS, `max_new_tokens`, or the compiled
    /// `seq_len`.
    pub fn stream(&mut self, prompt: &str) -> Result<TokenStream<'_, 'e>> {
        let mut graph = self.decode_graph()?;
        let prompt_ids = self.encode_prompt(prompt)?;
        let plen = prompt_ids.len();
        graph.start_row(0, &prompt_ids)?;
        Ok(TokenStream { session: self, graph, plen, out: Vec::new(), done: false })
    }

    /// Batched multi-prompt decoding with continuous batching: any number
    /// of prompts are multiplexed over the compiled batch rows, new
    /// prompts entering a row as soon as an earlier one retires (EOS,
    /// token budget, or sequence length). Results come back in prompt
    /// order. With greedy decoding each row's result is identical to
    /// `generate` on that prompt alone.
    pub fn generate_batch(&mut self, prompts: &[&str]) -> Result<Vec<String>> {
        ensure!(!prompts.is_empty(), "no prompts");
        let mut graph = self.decode_graph()?;
        let seq_len = graph.seq_len();
        let max_new = self.sampler.max_new_tokens;
        let mut sched = Scheduler::new(graph.capacity());
        for p in prompts {
            sched.submit(self.encode_prompt(p)?);
        }
        while !sched.finished() {
            for (row, prompt) in sched.admit() {
                graph.start_row(row, &prompt)?;
            }
            // retire rows that have exhausted their budget or the
            // compiled sequence before (not after) stepping them
            for row in sched.active_rows() {
                if sched.out_len(row) >= max_new
                    || sched.total_len(row) >= seq_len
                {
                    sched.retire(row);
                    graph.free_row(row);
                }
            }
            let rows = sched.active_rows();
            if rows.is_empty() {
                continue; // freed rows refill on the next iteration
            }
            let logits = graph.step(&rows)?;
            for (&row, row_logits) in rows.iter().zip(logits.iter()) {
                let next = self.next_token(row_logits);
                if next == EOS {
                    sched.retire(row);
                    graph.free_row(row);
                } else {
                    self.tokens_generated += 1;
                    sched.push(row, next);
                    graph.push(row, next)?;
                }
            }
        }
        Ok(sched
            .take_results()
            .iter()
            .map(|o| self.tok.decode(o))
            .collect())
    }

    /// (loss, token accuracy) on one batch under this session's adapter —
    /// no training state anywhere near this path.
    pub fn eval(&self, batch: &Batch) -> Result<(f32, f32)> {
        let exe = self.engine.eval_exe()?;
        let adapter = self.engine.adapter_literals(&self.adapter)?;
        let [tok, mask] = self.engine.batch_literals(batch)?;
        let frozen = self.engine.frozen();
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(adapter.len() + frozen.len() + 2);
        inputs.extend(adapter.iter());
        inputs.extend(frozen.iter());
        inputs.push(&tok);
        inputs.push(&mask);
        let out = exe.run(&inputs)?;
        ensure!(out.len() == 2, "eval returned {} outputs", out.len());
        Ok((literal_scalar_f32(&out[0])?, literal_scalar_f32(&out[1])?))
    }

    /// Mean (loss, accuracy) over a whole batcher.
    pub fn eval_all(&self, batcher: &Batcher, seed: u64) -> Result<(f32, f32)> {
        let batches = batcher.epoch(seed);
        ensure!(!batches.is_empty(), "empty eval set");
        let mut loss = 0f64;
        let mut acc = 0f64;
        for b in &batches {
            let (l, a) = self.eval(b)?;
            loss += l as f64;
            acc += a as f64;
        }
        let n = batches.len() as f64;
        Ok(((loss / n) as f32, (acc / n) as f32))
    }
}

/// Streaming decode state; see [`Session::stream`]. Holds its own
/// [`DecodeGraph`] (row 0), so the per-token cost is one incremental
/// decode step on KV-cached artifacts.
pub struct TokenStream<'s, 'e> {
    session: &'s mut Session<'e>,
    graph: Box<dyn DecodeGraph + 'e>,
    plen: usize,
    out: Vec<i32>,
    done: bool,
}

impl TokenStream<'_, '_> {
    /// Token ids emitted so far.
    pub fn emitted(&self) -> &[i32] {
        &self.out
    }

    /// Produce the next decoded token fragment, or `None` when the stream
    /// is finished (EOS / token budget / sequence length).
    pub fn next_token_text(&mut self) -> Option<Result<String>> {
        if self.done || self.out.len() >= self.session.sampler.max_new_tokens {
            return None;
        }
        if self.plen + self.out.len() >= self.graph.seq_len() {
            self.done = true;
            return None;
        }
        let row_logits = match self.graph.step(&[0]) {
            Ok(mut l) => l.remove(0),
            Err(e) => {
                self.done = true;
                return Some(Err(e));
            }
        };
        let next = self.session.next_token(&row_logits);
        if next == EOS {
            self.done = true;
            return None;
        }
        if let Err(e) = self.graph.push(0, next) {
            self.done = true;
            return Some(Err(e));
        }
        self.out.push(next);
        self.session.tokens_generated += 1;
        Some(Ok(self.session.tok.decode(&[next])))
    }
}

impl Iterator for TokenStream<'_, '_> {
    type Item = Result<String>;

    fn next(&mut self) -> Option<Result<String>> {
        self.next_token_text()
    }
}
