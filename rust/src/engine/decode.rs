//! The decode hot path: one abstraction, two engines.
//!
//! [`DecodeGraph`] is the row-oriented contract the serving loops
//! ([`Session::generate_batch`](super::Session::generate_batch),
//! [`Session::stream`](super::Session::stream)) drive: start a prompt in a
//! row, step all live rows with one graph execution, push the sampled
//! token, free the row. Two implementations share it:
//!
//! * [`FullDecode`] — the fallback: every step re-runs the full-sequence
//!   forward over the whole `(batch, seq_len)` buffer and reads each row's
//!   logits at its current position. Per-step cost is O(seq_len²) in
//!   attention no matter how little actually changed.
//! * [`CachedDecode`] — the KV-cached path: one *prefill* execution fills
//!   per-row key/value caches for the prompt (and emits its last-position
//!   logits), then each generated token costs a single O(1)-in-
//!   generated-length *decode step* against the caches.
//!
//! ### Cache discipline (why continuous batching is safe)
//!
//! The caches are two `(batch, layers, seq_len, d_model)` tensors that
//! thread through every graph call as opaque literals — Rust never
//! inspects their layout (that contract lives in
//! `python/compile/kernels/decode.py`). Three invariants make mid-flight
//! row reuse sound:
//!
//! 1. the prefill graph recomputes cache rows only where its `row_mask`
//!    input is 1 and passes every other row through bit-untouched, so
//!    admitting a new prompt never perturbs rows that are mid-decode;
//! 2. a decode step writes each row's K/V at exactly that row's position
//!    input, and rows with nothing to do are parked at `seq_len - 1` — a
//!    slot any live request overwrites with its own K/V before its
//!    attention window (`positions <= pos`) can ever reach it;
//! 3. attention masks positions beyond the row's current length, so
//!    whatever a retired request left behind in a freed row is dead data:
//!    the next request's prefill overwrites the prefix it will read, and
//!    the mask hides the rest.
//!
//! Adapter literals are resolved **once, at graph construction**: a decode
//! in flight keeps serving the adapter version it started with even if the
//! registry hot-swaps that name mid-decode (K/V computed under two adapter
//! versions must never mix). Swaps are picked up by the next
//! `generate`/`stream`/`generate_batch` call, which builds a fresh graph.

use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Result};

use crate::data::tokenizer::PAD;
use crate::runtime::executor::{literal_from_tensor, literal_to_f32, Executable};
use crate::tensorio::Tensor;

use super::Engine;

/// Which decode implementation a [`Session`](super::Session) uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodeMode {
    /// KV-cached when the artifact ships prefill/decode graphs, full
    /// recompute otherwise.
    #[default]
    Auto,
    /// Force the KV-cached path; building a session errors if the
    /// artifact has no decode graphs.
    Cached,
    /// Force the full-recompute fallback (the reference for equivalence
    /// tests and benchmarks).
    Full,
}

/// Row-oriented incremental decoding over one adapter + frozen base.
///
/// Rows are slots `0..capacity()`. The serving loop owns the protocol:
/// `start_row` with the prompt, then repeatedly `step` every live row
/// (one graph execution for all of them), sample from the returned
/// logits, and either `push` the token or `free_row`. Implementations
/// may batch arbitrary mixtures of freshly started and mid-decode rows
/// in one `step` call — that is what continuous batching relies on.
pub trait DecodeGraph {
    /// Number of concurrent rows (the artifact's compiled batch size).
    fn capacity(&self) -> usize;

    /// The compiled sequence length (prompt + generated tokens per row).
    fn seq_len(&self) -> usize;

    /// Begin decoding `prompt` in `row`. The row must be free and the
    /// prompt non-empty and shorter than [`DecodeGraph::seq_len`].
    fn start_row(&mut self, row: usize, prompt: &[i32]) -> Result<()>;

    /// Append a sampled token to `row`'s history.
    fn push(&mut self, row: usize, token: i32) -> Result<()>;

    /// Release `row` for reuse by a later [`DecodeGraph::start_row`].
    ///
    /// This is the row-retirement hook of the request lifecycle: the
    /// serving loop calls it for normal completion (EOS / token budget)
    /// *and* for mid-flight preemption (cancellation, deadline expiry) —
    /// implementations must tolerate a row being vacated at any point of
    /// its decode, not only at a natural stopping point. Returns whether
    /// the row was actually live (`false` for a free or out-of-range
    /// row, which is a harmless no-op).
    fn free_row(&mut self, row: usize) -> bool;

    /// Advance every row in `rows` by one position and return each row's
    /// next-token logits (vocab-sized, in `rows` order).
    fn step(&mut self, rows: &[usize]) -> Result<Vec<Vec<f32>>>;

    /// Record the physical KV block table backing `row` (from the
    /// scheduler's block manager). On this substrate the compiled
    /// graphs address a dense per-row cache slab, so the table is the
    /// *accounting* view — which blocks the row's K/V occupy for
    /// admission, sharing and swap decisions — not a gather index; the
    /// default is a no-op and [`FullDecode`] keeps it (no cache to
    /// page). A row's logits depend only on its own history (invariants
    /// above), which is why block policy cannot change its output.
    fn set_block_table(&mut self, _row: usize, _blocks: &[u32]) {}

    /// The block table last recorded for `row` (`None` when the
    /// implementation keeps no tables or the row has none).
    fn block_table(&self, _row: usize) -> Option<&[u32]> {
        None
    }

    /// `"cached"` or `"full"` — for logs and benchmark labels.
    fn kind(&self) -> &'static str;
}

/// Per-row bookkeeping shared by both implementations.
#[derive(Default)]
struct Row {
    /// prompt ++ generated tokens
    history: Vec<i32>,
    /// number of leading history positions whose K/V are cached
    /// (always 0 for the full-recompute path)
    cached: usize,
    /// physical KV blocks backing this row (the scheduler's accounting
    /// view; empty under token-budget admission or for the full path).
    /// Freed along with the row by `free_row_common`'s reset.
    blocks: Vec<u32>,
    live: bool,
}

fn check_start(rows: &mut [Row], row: usize, prompt: &[i32],
               seq_len: usize) -> Result<()> {
    let capacity = rows.len();
    let Some(slot) = rows.get_mut(row) else {
        bail!("row {row} out of range (capacity {capacity})");
    };
    ensure!(!slot.live, "row {row} is still live (free it first)");
    ensure!(!prompt.is_empty(), "empty prompt for row {row}");
    ensure!(
        prompt.len() < seq_len,
        "prompt of {} tokens does not fit the compiled seq_len {}",
        prompt.len(),
        seq_len
    );
    *slot =
        Row { history: prompt.to_vec(), cached: 0, blocks: Vec::new(), live: true };
    Ok(())
}

fn check_push(rows: &mut [Row], row: usize, token: i32,
              seq_len: usize) -> Result<()> {
    let slot = match rows.get_mut(row) {
        Some(r) if r.live => r,
        _ => bail!("row {row} is not live"),
    };
    ensure!(
        slot.history.len() < seq_len,
        "row {row} is full ({seq_len} tokens)"
    );
    slot.history.push(token);
    Ok(())
}

fn free_row_common(rows: &mut [Row], row: usize) -> bool {
    match rows.get_mut(row) {
        Some(r) if r.live => {
            *r = Row::default();
            true
        }
        _ => false,
    }
}

fn check_step_rows(rows: &[Row], selected: &[usize]) -> Result<()> {
    ensure!(!selected.is_empty(), "step called with no rows");
    for &r in selected {
        ensure!(rows.get(r).is_some_and(|x| x.live), "row {r} is not live");
    }
    Ok(())
}

// --------------------------------------------------------------------------
// Full-recompute fallback
// --------------------------------------------------------------------------

/// Fallback [`DecodeGraph`]: re-runs the full-sequence forward each step.
///
/// Works with any artifact that has a `fwd` graph; the per-step cost is
/// the whole `(batch, seq_len)` forward regardless of how many tokens are
/// new. Kept as the bit-exact reference the cached path is tested against.
pub struct FullDecode<'e> {
    engine: &'e Engine,
    exe: Arc<Executable>,
    adapter: Rc<Vec<xla::Literal>>,
    rows: Vec<Row>,
    batch: usize,
    seq_len: usize,
    vocab: usize,
}

impl<'e> FullDecode<'e> {
    /// Build over `engine`, pinning `adapter`'s current version.
    pub fn new(engine: &'e Engine, adapter: &str) -> Result<FullDecode<'e>> {
        let cfg = &engine.spec.cfg;
        Ok(FullDecode {
            engine,
            exe: engine.fwd_exe()?,
            adapter: engine.adapter_literals(adapter)?,
            rows: (0..cfg.batch).map(|_| Row::default()).collect(),
            batch: cfg.batch,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
        })
    }
}

impl DecodeGraph for FullDecode<'_> {
    fn capacity(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn start_row(&mut self, row: usize, prompt: &[i32]) -> Result<()> {
        check_start(&mut self.rows, row, prompt, self.seq_len)
    }

    fn push(&mut self, row: usize, token: i32) -> Result<()> {
        check_push(&mut self.rows, row, token, self.seq_len)
    }

    fn free_row(&mut self, row: usize) -> bool {
        free_row_common(&mut self.rows, row)
    }

    fn step(&mut self, rows: &[usize]) -> Result<Vec<Vec<f32>>> {
        check_step_rows(&self.rows, rows)?;
        let mut tokens = vec![PAD; self.batch * self.seq_len];
        for &r in rows {
            // pallas-lint: allow(no-hot-path-panic) — check_step_rows verified r < capacity and live
            let h = &self.rows[r].history;
            // pallas-lint: allow(no-hot-path-panic) — history.len() < seq_len is the check_push invariant, so the slice is in range
            tokens[r * self.seq_len..r * self.seq_len + h.len()]
                .copy_from_slice(h);
        }
        let t = Tensor::i32("tokens", vec![self.batch, self.seq_len], &tokens);
        let tok = literal_from_tensor(&t)?;
        let frozen = self.engine.frozen();
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(self.adapter.len() + frozen.len() + 1);
        inputs.extend(self.adapter.iter());
        inputs.extend(frozen.iter());
        inputs.push(&tok);
        let out = self.exe.run(&inputs)?;
        let logits = literal_to_f32(
            out.first().ok_or_else(|| anyhow!("fwd graph returned no outputs"))?,
        )?;
        Ok(rows
            .iter()
            .map(|&r| {
                // pallas-lint: allow(no-hot-path-panic) — check_step_rows verified r < capacity and live; live rows have non-empty history
                let pos = self.rows[r].history.len() - 1;
                let off = (r * self.seq_len + pos) * self.vocab;
                // pallas-lint: allow(no-hot-path-panic) — off + vocab ≤ batch·seq_len·vocab because r < batch and pos < seq_len
                logits[off..off + self.vocab].to_vec()
            })
            .collect())
    }

    fn kind(&self) -> &'static str {
        "full"
    }
}

// --------------------------------------------------------------------------
// KV-cached path
// --------------------------------------------------------------------------

/// KV-cached [`DecodeGraph`]: one prefill per admitted prompt, then
/// O(1)-per-token decode steps.
///
/// The caches thread through every execution as opaque literals (layout
/// owned by `python/compile/kernels/decode.py`); rows needing a prefill
/// and rows mid-decode are advanced in the same [`DecodeGraph::step`]
/// call with at most one prefill plus one decode execution.
pub struct CachedDecode<'e> {
    engine: &'e Engine,
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    adapter: Rc<Vec<xla::Literal>>,
    /// canonical (k, v) caches; `None` until the first prefill
    caches: Option<(xla::Literal, xla::Literal)>,
    rows: Vec<Row>,
    batch: usize,
    seq_len: usize,
    vocab: usize,
}

impl<'e> CachedDecode<'e> {
    /// Build over `engine`, pinning `adapter`'s current version. Errors
    /// if the artifact was built without prefill/decode graphs.
    pub fn new(engine: &'e Engine, adapter: &str) -> Result<CachedDecode<'e>> {
        let cfg = &engine.spec.cfg;
        ensure!(
            engine.spec.cache_sig.len() == 2,
            "artifact {} has no KV-cache signature (re-run `make artifacts`)",
            engine.spec.name
        );
        Ok(CachedDecode {
            engine,
            prefill: engine.prefill_exe()?,
            decode: engine.decode_exe()?,
            adapter: engine.adapter_literals(adapter)?,
            caches: None,
            rows: (0..cfg.batch).map(|_| Row::default()).collect(),
            batch: cfg.batch,
            seq_len: cfg.seq_len,
            vocab: cfg.vocab,
        })
    }

    /// Zero-filled cache literals matching the artifact's cache signature
    /// (used before the first prefill; content is irrelevant — see the
    /// module docs on cache discipline).
    fn zero_caches(&self) -> Result<(xla::Literal, xla::Literal)> {
        let mut out = Vec::with_capacity(2);
        for spec in &self.engine.spec.cache_sig {
            ensure!(
                spec.dtype == "f32",
                "cache tensor {} has unsupported dtype {}",
                spec.name,
                spec.dtype
            );
            let zeros = vec![0.0; spec.elems()];
            let t = Tensor::f32(&spec.name, spec.shape.clone(), &zeros);
            out.push(literal_from_tensor(&t)?);
        }
        let v = out.pop().ok_or_else(|| anyhow!("missing v_cache"))?;
        let k = out.pop().ok_or_else(|| anyhow!("missing k_cache"))?;
        Ok((k, v))
    }

    /// Execute `exe` with `adapter ++ frozen ++ caches ++ extra`, adopt
    /// the returned caches as canonical, and return the logits literal.
    /// On failure the input caches are restored, so a caller retrying
    /// after a transient error never decodes against an empty cache.
    fn run_with_caches(
        &mut self,
        exe: &Arc<Executable>,
        kc: xla::Literal,
        vc: xla::Literal,
        extra: [&xla::Literal; 2],
    ) -> Result<xla::Literal> {
        let result = {
            let frozen = self.engine.frozen();
            let mut inputs: Vec<&xla::Literal> =
                Vec::with_capacity(self.adapter.len() + frozen.len() + 4);
            inputs.extend(self.adapter.iter());
            inputs.extend(frozen.iter());
            inputs.push(&kc);
            inputs.push(&vc);
            inputs.extend(extra);
            exe.run(&inputs)
        };
        let mut out = match result {
            Ok(out) if out.len() == 3 => out,
            Ok(out) => {
                self.caches = Some((kc, vc));
                return Err(anyhow!(
                    "decode graph returned {} outputs, expected 3",
                    out.len()
                ));
            }
            Err(e) => {
                self.caches = Some((kc, vc));
                return Err(e);
            }
        };
        let mut it = out.drain(..);
        let (Some(logits), Some(k_new), Some(v_new)) =
            (it.next(), it.next(), it.next())
        else {
            // unreachable: len == 3 matched above; restore the caches
            // anyway so a bug here can't strand the decode state
            drop(it);
            self.caches = Some((kc, vc));
            bail!("decode graph outputs vanished (len == 3 checked above)");
        };
        self.caches = Some((k_new, v_new));
        Ok(logits)
    }

    fn take_caches(&mut self) -> Result<(xla::Literal, xla::Literal)> {
        match self.caches.take() {
            Some(kv) => Ok(kv),
            None => self.zero_caches(),
        }
    }
}

impl DecodeGraph for CachedDecode<'_> {
    fn capacity(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn start_row(&mut self, row: usize, prompt: &[i32]) -> Result<()> {
        check_start(&mut self.rows, row, prompt, self.seq_len)
    }

    fn push(&mut self, row: usize, token: i32) -> Result<()> {
        check_push(&mut self.rows, row, token, self.seq_len)
    }

    fn free_row(&mut self, row: usize) -> bool {
        // leftover K/V in the freed row are unreachable — even when the
        // request was preempted mid-decode by cancellation or deadline
        // expiry: the next request's prefill overwrites the prefix it
        // reads, and the position mask hides everything beyond it
        free_row_common(&mut self.rows, row)
    }

    fn set_block_table(&mut self, row: usize, blocks: &[u32]) {
        if let Some(r) = self.rows.get_mut(row) {
            if r.live {
                r.blocks.clear();
                r.blocks.extend_from_slice(blocks);
            }
        }
    }

    fn block_table(&self, row: usize) -> Option<&[u32]> {
        self.rows
            .get(row)
            .filter(|r| r.live && !r.blocks.is_empty())
            .map(|r| r.blocks.as_slice())
    }

    fn step(&mut self, rows: &[usize]) -> Result<Vec<Vec<f32>>> {
        check_step_rows(&self.rows, rows)?;
        // a row steps incrementally only when exactly its last token is
        // uncached; anything else (fresh row, drifted history) prefills
        let needs_prefill = |r: &Row| r.cached + 1 != r.history.len();
        let (pre, inc): (Vec<usize>, Vec<usize>) = rows
            .iter()
            .copied()
            // pallas-lint: allow(no-hot-path-panic) — check_step_rows verified r < capacity and live
            .partition(|&r| needs_prefill(&self.rows[r]));

        let mut per_row: Vec<Option<Vec<f32>>> = vec![None; self.batch];

        if !pre.is_empty() {
            let mut tokens = vec![PAD; self.batch * self.seq_len];
            let mut mask = vec![0f32; self.batch];
            for &r in &pre {
                // pallas-lint: allow(no-hot-path-panic) — check_step_rows verified r < capacity and live
                let h = &self.rows[r].history;
                // pallas-lint: allow(no-hot-path-panic) — history.len() < seq_len is the check_push invariant, so the slice is in range
                tokens[r * self.seq_len..r * self.seq_len + h.len()]
                    .copy_from_slice(h);
                // pallas-lint: allow(no-hot-path-panic) — mask is batch-sized and r < batch
                mask[r] = 1.0;
            }
            let tok = literal_from_tensor(&Tensor::i32(
                "tokens", vec![self.batch, self.seq_len], &tokens))?;
            let m = literal_from_tensor(&Tensor::f32(
                "row_mask", vec![self.batch], &mask))?;
            let (kc, vc) = self.take_caches()?;
            let exe = self.prefill.clone();
            let logits_lit = self.run_with_caches(&exe, kc, vc, [&tok, &m])?;
            let logits = literal_to_f32(&logits_lit)?;
            for &r in &pre {
                // pallas-lint: allow(no-hot-path-panic) — check_step_rows verified r < capacity and live
                let len = self.rows[r].history.len();
                // pallas-lint: allow(no-hot-path-panic) — same bounds as the line above
                self.rows[r].cached = len;
                let off = (r * self.seq_len + len - 1) * self.vocab;
                // pallas-lint: allow(no-hot-path-panic) — off + vocab ≤ batch·seq_len·vocab because r < batch and len ≤ seq_len; per_row is batch-sized
                per_row[r] = Some(logits[off..off + self.vocab].to_vec());
            }
        }

        if !inc.is_empty() {
            let mut token = vec![0i32; self.batch];
            // idle rows park at seq_len-1: rewritten by a live row's own
            // final step before its attention window can reach it
            let mut pos = vec![(self.seq_len - 1) as i32; self.batch];
            for &r in &inc {
                // pallas-lint: allow(no-hot-path-panic) — check_step_rows verified r < capacity and live
                let h = &self.rows[r].history;
                // pallas-lint: allow(no-hot-path-panic) — live rows have non-empty history: check_start rejects empty prompts; token is batch-sized
                token[r] = *h.last().expect("live row has history");
                // pallas-lint: allow(no-hot-path-panic) — pos is batch-sized and r < batch
                pos[r] = (h.len() - 1) as i32;
            }
            let t = literal_from_tensor(&Tensor::i32(
                "token", vec![self.batch], &token))?;
            let p = literal_from_tensor(&Tensor::i32(
                "pos", vec![self.batch], &pos))?;
            let (kc, vc) = self.take_caches()?;
            let exe = self.decode.clone();
            let logits_lit = self.run_with_caches(&exe, kc, vc, [&t, &p])?;
            let logits = literal_to_f32(&logits_lit)?;
            for &r in &inc {
                // pallas-lint: allow(no-hot-path-panic) — check_step_rows verified r < capacity and live
                self.rows[r].cached = self.rows[r].history.len();
                let off = r * self.vocab;
                // pallas-lint: allow(no-hot-path-panic) — off + vocab ≤ batch·vocab because r < batch; per_row is batch-sized
                per_row[r] = Some(logits[off..off + self.vocab].to_vec());
            }
        }

        rows.iter()
            .map(|&r| {
                // pallas-lint: allow(no-hot-path-panic) — per_row is batch-sized and check_step_rows verified r < capacity == batch
                per_row[r]
                    .take()
                    .ok_or_else(|| anyhow!("row {r} produced no logits"))
            })
            .collect()
    }

    fn kind(&self) -> &'static str {
        "cached"
    }
}
