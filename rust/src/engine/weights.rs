//! Host-side engine weight preparation — the Rust twin of
//! `model.quantize_base` / `ref.quantize_weight`, on the fused multicore
//! kernels.
//!
//! The artifact pipeline quantizes the frozen base in Python at AOT time;
//! this module is the native equivalent, so raw f32 checkpoints can be
//! prepared for (and recovered from) the engine's frozen-tensor layout
//! without a Python round-trip. Tensor names and ordering mirror
//! `aot.flatten_named` exactly (jax keystr paths, **sorted dict keys**),
//! so prepared tensors interleave with artifact `frozen_sig` entries:
//!
//! ```text
//! <prefix>['absmax2']  f32 [nb2]     (double-quant only)
//! <prefix>['codes2']   u8  [nb_pad]  (double-quant only)
//! <prefix>['mean']     f32 []        (double-quant only)
//! <prefix>['packed']   u8  [h*o/2]   (4-bit; raw codes u8 [h*o] for 8-bit)
//! <prefix>['absmax']   f32 [nb]      (raw-constants only)
//! ```
//!
//! Round-trips are lossless by construction: `to_tensors` → `from_tensors`
//! reproduces the exact `QuantizedTensor` (unit-tested), and the
//! quantize/dequantize themselves are the bit-exact fused kernels.

use anyhow::{bail, ensure, Result};

use crate::quant::codebook::{Codebook, DType};
use crate::quant::double::DoubleQuant;
use crate::quant::tensor::{Constants, QuantizedTensor};
use crate::tensorio::{find, Tensor};

/// Quantize one row-major `(h, o)` weight straight into frozen-layout
/// host tensors (fused multicore path). `double_q` is the second-level
/// blocksize, as in [`QuantizedTensor::quantize`].
pub fn prepare_weight(
    prefix: &str,
    w: &[f32],
    shape: (usize, usize),
    dtype: DType,
    block: usize,
    double_q: Option<usize>,
) -> Result<Vec<Tensor>> {
    let q = QuantizedTensor::quantize(w, shape, dtype, block, double_q)?;
    Ok(to_tensors(prefix, &q))
}

/// Serialize a [`QuantizedTensor`] into frozen-layout host tensors (names
/// and order per the module docs).
pub fn to_tensors(prefix: &str, q: &QuantizedTensor) -> Vec<Tensor> {
    // both code widths store under 'packed', exactly like
    // ref.quantize_weight (8-bit "packed" is just the raw codes)
    let mut out = Vec::with_capacity(4);
    match &q.constants {
        Constants::Double(dq) => {
            // sorted key order: absmax2, codes2, mean, packed
            out.push(Tensor::f32(
                &format!("{prefix}['absmax2']"),
                vec![dq.absmax2.len()],
                &dq.absmax2,
            ));
            out.push(Tensor::u8(
                &format!("{prefix}['codes2']"),
                vec![dq.codes2.len()],
                dq.codes2.clone(),
            ));
            out.push(Tensor::f32(
                &format!("{prefix}['mean']"),
                vec![],
                &[dq.mean],
            ));
            out.push(Tensor::u8(
                &format!("{prefix}['packed']"),
                vec![q.data.len()],
                q.data.clone(),
            ));
        }
        Constants::Raw(a) => {
            // sorted key order: absmax, packed
            out.push(Tensor::f32(
                &format!("{prefix}['absmax']"),
                vec![a.len()],
                a,
            ));
            out.push(Tensor::u8(
                &format!("{prefix}['packed']"),
                vec![q.data.len()],
                q.data.clone(),
            ));
        }
    }
    out
}

/// Reassemble a [`QuantizedTensor`] from frozen-layout host tensors
/// (inverse of [`to_tensors`]; looks tensors up by name, so extra tensors
/// in the slice are fine). `block2` is the DQ blocksize the artifact was
/// built with (the paper's 256) — only consulted when the double-quant
/// tensors are present.
pub fn from_tensors(
    prefix: &str,
    tensors: &[Tensor],
    shape: (usize, usize),
    dtype: DType,
    block: usize,
    block2: usize,
) -> Result<QuantizedTensor> {
    let (h, o) = shape;
    let n = h * o;
    ensure!(block > 0 && n % block == 0, "bad shape/block");
    ensure!(block2 > 0, "block2 must be positive");
    let nb = n / block;
    let data = find(tensors, &format!("{prefix}['packed']"))?.data.clone();
    let expect = if dtype.bits() == 4 { n / 2 } else { n };
    ensure!(
        data.len() == expect,
        "{prefix}: packed length {} != {expect}",
        data.len()
    );
    // reject out-of-range codes up front: the fused decode LUT clamps
    // them (where the scalar tier panics), so a corrupted artifact must
    // fail loudly here rather than dequantize to silently wrong weights
    let cb_len = Codebook::new(dtype).len() as u8; // canonical books: <= 255
    let in_range = if dtype.bits() == 4 {
        // 16-entry books admit every nibble; smaller ones must be checked
        cb_len == 16
            || data.iter().all(|&b| (b & 0xF) < cb_len && (b >> 4) < cb_len)
    } else {
        data.iter().all(|&b| b < cb_len)
    };
    ensure!(in_range, "{prefix}: packed codes out of codebook range");
    let constants = if let Ok(c2) = find(tensors, &format!("{prefix}['codes2']"))
    {
        let absmax2 =
            find(tensors, &format!("{prefix}['absmax2']"))?.to_f32()?;
        let mean_t = find(tensors, &format!("{prefix}['mean']"))?.to_f32()?;
        ensure!(mean_t.len() == 1, "{prefix}: mean must be scalar");
        ensure!(
            c2.data.len() % block2 == 0
                && c2.data.len() / block2 == absmax2.len(),
            "{prefix}: inconsistent double-quant tensors"
        );
        // exact padded length: codes2 from a different-sized weight must
        // fail loudly, not silently dequantize from the wrong constants
        ensure!(
            c2.data.len() == nb.div_ceil(block2) * block2,
            "{prefix}: codes2 length {} != padded block count {}",
            c2.data.len(),
            nb.div_ceil(block2) * block2
        );
        ensure!(
            c2.data.iter().all(|&b| b < u8::MAX), // FP8 book: 255 entries
            "{prefix}: codes2 out of FP8 codebook range"
        );
        Constants::Double(DoubleQuant {
            codes2: c2.data.clone(),
            absmax2,
            mean: mean_t[0],
            n: nb,
            block2,
        })
    } else if let Ok(a) = find(tensors, &format!("{prefix}['absmax']")) {
        let a = a.to_f32()?;
        ensure!(a.len() == nb, "{prefix}: absmax length {} != {nb}", a.len());
        Constants::Raw(a)
    } else {
        bail!("{prefix}: neither double-quant nor raw absmax tensors found");
    };
    Ok(QuantizedTensor { dtype, data, constants, shape, block })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_is_lossless_dq_and_raw() {
        let mut rng = Rng::new(31);
        let (h, o) = (64, 48);
        let w: Vec<f32> = rng.normal_vec_f32(h * o);
        for (dtype, dq) in [(DType::NF4, Some(256)), (DType::NF4, None),
                            (DType::Int8, Some(256))] {
            let q = QuantizedTensor::quantize(&w, (h, o), dtype, 64, dq)
                .unwrap();
            let prefix = "frozen['layers'][0]['wq']";
            let ts = to_tensors(prefix, &q);
            let back =
                from_tensors(prefix, &ts, (h, o), dtype, 64, 256).unwrap();
            assert_eq!(back.data, q.data);
            let (a, b) = (q.dequantize().unwrap(), back.dequantize().unwrap());
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn tensor_names_follow_sorted_keystr_convention() {
        let mut rng = Rng::new(32);
        let w: Vec<f32> = rng.normal_vec_f32(64 * 2);
        let ts = prepare_weight("p", &w, (64, 2), DType::NF4, 64, Some(256))
            .unwrap();
        let names: Vec<&str> = ts.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, ["p['absmax2']", "p['codes2']", "p['mean']",
                           "p['packed']"]);
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted, "must already be in sorted key order");
        let raw = prepare_weight("p", &w, (64, 2), DType::NF4, 64, None)
            .unwrap();
        let names: Vec<&str> = raw.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, ["p['absmax']", "p['packed']"]);
    }

    #[test]
    fn rejects_out_of_range_codes() {
        // Int4's 15-entry codebook leaves nibble 0xF invalid: a corrupted
        // artifact must fail at load, not dequantize to wrong weights
        let mut rng = Rng::new(34);
        let w: Vec<f32> = rng.normal_vec_f32(64 * 2);
        let mut ts = prepare_weight("p", &w, (64, 2), DType::Int4, 64, None)
            .unwrap();
        let packed = ts.iter_mut().find(|t| t.name.ends_with("'packed']"))
            .unwrap();
        packed.data[0] = 0xFF;
        let err = from_tensors("p", &ts, (64, 2), DType::Int4, 64, 256)
            .unwrap_err();
        assert!(err.to_string().contains("out of codebook range"), "{err}");
    }

    #[test]
    fn prepared_bytes_match_paper_accounting() {
        // NF4+DQ 256x256: ~4.127 bits/param through the tensor layout too
        let mut rng = Rng::new(33);
        let (h, o) = (256, 256);
        let w: Vec<f32> = rng.normal_vec_f32(h * o);
        let ts = prepare_weight("p", &w, (h, o), DType::NF4, 64, Some(256))
            .unwrap();
        let bytes: usize = ts.iter().map(|t| t.data.len()).sum();
        let bits = bytes as f64 * 8.0 / (h * o) as f64;
        assert!((bits - 4.127).abs() < 0.01, "bits {bits}");
    }
}
