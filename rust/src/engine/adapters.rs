//! Named LoRA adapter registry over one frozen base.
//!
//! The registry is pure host-side bookkeeping: adapter tensors validated
//! against the artifact's trainable signature, with a version counter per
//! entry so the engine's device-literal cache knows when a hot-swap
//! happened. Keeping it free of runtime types makes the load/swap/error
//! contract unit-testable without artifacts or a PJRT client.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, ensure, Result};

use crate::runtime::artifact::TensorSpec;
use crate::tensorio::Tensor;

/// One registered adapter: host tensors + a version bumped on every swap.
#[derive(Debug, Clone)]
pub struct AdapterEntry {
    /// The adapter's host tensors, in trainable-signature order.
    pub tensors: Vec<Tensor>,
    /// Registry-wide monotonic version; bumped on every (hot-)swap so
    /// device-literal caches know when to re-upload.
    pub version: u64,
}

/// Validated name → adapter map for one artifact's trainable signature.
#[derive(Debug, Clone)]
pub struct AdapterRegistry {
    /// expected trainable signature (`state_sig[..n_trainable]`)
    sig: Vec<TensorSpec>,
    entries: BTreeMap<String, AdapterEntry>,
    next_version: u64,
}

impl AdapterRegistry {
    /// An empty registry validating against `sig`
    /// (`state_sig[..n_trainable]` of the artifact).
    pub fn new(sig: Vec<TensorSpec>) -> AdapterRegistry {
        AdapterRegistry { sig, entries: BTreeMap::new(), next_version: 0 }
    }

    /// Insert (or hot-swap) adapter `name`. Tensors must match the
    /// trainable signature in count, dtype, and shape.
    pub fn insert(&mut self, name: &str, tensors: Vec<Tensor>) -> Result<()> {
        ensure!(!name.is_empty(), "adapter name must be non-empty");
        ensure!(
            tensors.len() == self.sig.len(),
            "adapter {name:?} has {} tensors, artifact expects {}",
            tensors.len(),
            self.sig.len()
        );
        for (t, s) in tensors.iter().zip(self.sig.iter()) {
            if t.dtype.name() != s.dtype {
                bail!(
                    "adapter {name:?} tensor {:?}: dtype {} != expected {}",
                    t.name,
                    t.dtype.name(),
                    s.dtype
                );
            }
            if t.shape != s.shape {
                bail!(
                    "adapter {name:?} tensor {:?}: shape {:?} != expected \
                     {:?} (for {})",
                    t.name,
                    t.shape,
                    s.shape,
                    s.name
                );
            }
        }
        self.next_version += 1;
        let version = self.next_version;
        self.entries
            .insert(name.to_string(), AdapterEntry { tensors, version });
        Ok(())
    }

    /// Look up adapter `name`; the error lists what *is* loaded.
    pub fn get(&self, name: &str) -> Result<&AdapterEntry> {
        self.entries.get(name).ok_or_else(|| {
            anyhow!(
                "adapter {name:?} not loaded (have: {})",
                if self.entries.is_empty() {
                    "none".to_string()
                } else {
                    self.names().join(", ")
                }
            )
        })
    }

    /// Drop adapter `name`; errors if it was never loaded.
    pub fn remove(&mut self, name: &str) -> Result<()> {
        self.entries
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| anyhow!("adapter {name:?} not loaded"))
    }

    /// Whether adapter `name` is registered.
    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// Registered names in sorted order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    /// Number of registered adapters.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether no adapter is registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig() -> Vec<TensorSpec> {
        vec![
            TensorSpec {
                name: "layer0/attn/q/lora_a".into(),
                dtype: "f32".into(),
                shape: vec![4, 2],
            },
            TensorSpec {
                name: "layer0/attn/q/lora_b".into(),
                dtype: "f32".into(),
                shape: vec![2, 4],
            },
        ]
    }

    fn adapter(fill: f32) -> Vec<Tensor> {
        vec![
            Tensor::f32("a", vec![4, 2], &[fill; 8]),
            Tensor::f32("b", vec![2, 4], &[fill; 8]),
        ]
    }

    #[test]
    fn load_get_roundtrip() {
        let mut r = AdapterRegistry::new(sig());
        assert!(r.is_empty());
        r.insert("base", adapter(0.0)).unwrap();
        assert_eq!(r.len(), 1);
        assert!(r.contains("base"));
        let e = r.get("base").unwrap();
        assert_eq!(e.tensors.len(), 2);
        assert_eq!(e.tensors[0].to_f32().unwrap(), vec![0.0; 8]);
    }

    #[test]
    fn swap_replaces_and_bumps_version() {
        let mut r = AdapterRegistry::new(sig());
        r.insert("tuned", adapter(1.0)).unwrap();
        let v1 = r.get("tuned").unwrap().version;
        r.insert("tuned", adapter(2.0)).unwrap();
        let e = r.get("tuned").unwrap();
        assert!(e.version > v1, "swap must bump the version");
        assert_eq!(e.tensors[0].to_f32().unwrap(), vec![2.0; 8]);
        assert_eq!(r.len(), 1, "swap must not duplicate the entry");
    }

    #[test]
    fn missing_adapter_error_lists_available() {
        let mut r = AdapterRegistry::new(sig());
        let e = format!("{}", r.get("nope").unwrap_err());
        assert!(e.contains("nope") && e.contains("none"), "{e}");
        r.insert("base", adapter(0.0)).unwrap();
        r.insert("tuned", adapter(1.0)).unwrap();
        let e = format!("{}", r.get("nope").unwrap_err());
        assert!(e.contains("base") && e.contains("tuned"), "{e}");
    }

    #[test]
    fn rejects_wrong_count_shape_dtype() {
        let mut r = AdapterRegistry::new(sig());
        // count
        assert!(r
            .insert("x", vec![Tensor::f32("a", vec![4, 2], &[0.0; 8])])
            .is_err());
        // shape
        let bad_shape = vec![
            Tensor::f32("a", vec![2, 4], &[0.0; 8]),
            Tensor::f32("b", vec![2, 4], &[0.0; 8]),
        ];
        let e = format!("{}", r.insert("x", bad_shape).unwrap_err());
        assert!(e.contains("shape"), "{e}");
        // dtype
        let bad_dtype = vec![
            Tensor::i32("a", vec![4, 2], &[0; 8]),
            Tensor::f32("b", vec![2, 4], &[0.0; 8]),
        ];
        let e = format!("{}", r.insert("x", bad_dtype).unwrap_err());
        assert!(e.contains("dtype"), "{e}");
        assert!(r.is_empty(), "failed inserts must not register");
    }

    #[test]
    fn remove_works_and_missing_remove_errors() {
        let mut r = AdapterRegistry::new(sig());
        r.insert("base", adapter(0.0)).unwrap();
        r.remove("base").unwrap();
        assert!(!r.contains("base"));
        assert!(r.remove("base").is_err());
    }

    #[test]
    fn names_sorted() {
        let mut r = AdapterRegistry::new(sig());
        r.insert("zeta", adapter(0.0)).unwrap();
        r.insert("alpha", adapter(0.0)).unwrap();
        assert_eq!(r.names(), vec!["alpha", "zeta"]);
    }
}
