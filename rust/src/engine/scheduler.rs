//! Continuous-batching scheduler: many prompts over few decode rows.
//!
//! A [`Scheduler`] accepts any number of submitted prompts, multiplexes
//! them onto the decode graph's fixed row capacity, and retires each row
//! the moment its request finishes — the freed row is re-admitted to the
//! next queued prompt on the following loop iteration instead of idling
//! until the slowest row of the batch completes. That converts
//! `generate_batch` from "pad everything to the slowest prompt" into a
//! rolling pipeline whose throughput tracks aggregate tokens, not the
//! worst row.
//!
//! The scheduler is pure bookkeeping (no runtime types), mirroring
//! [`AdapterRegistry`](super::AdapterRegistry): admission order, row
//! reuse, and result ordering are unit-tested without artifacts or a
//! PJRT client. The serving loop in
//! [`Session::generate_batch`](super::Session::generate_batch) drives a
//! [`DecodeGraph`](super::DecodeGraph) from its decisions.

use std::collections::VecDeque;

/// FIFO multiplexer of submitted prompts onto `capacity` decode rows.
pub struct Scheduler {
    queue: VecDeque<Job>,
    rows: Vec<Option<Active>>,
    /// final token outputs by job id (`None` while in queue / in flight)
    results: Vec<Option<Vec<i32>>>,
}

struct Job {
    id: usize,
    prompt: Vec<i32>,
}

struct Active {
    id: usize,
    prompt_len: usize,
    out: Vec<i32>,
}

impl Scheduler {
    /// A scheduler over `capacity` rows (the decode graph's batch size).
    pub fn new(capacity: usize) -> Scheduler {
        Scheduler {
            queue: VecDeque::new(),
            rows: (0..capacity.max(1)).map(|_| None).collect(),
            results: Vec::new(),
        }
    }

    /// Enqueue a tokenized prompt; returns its job id (= submission
    /// index, which is also its slot in [`Scheduler::take_results`]).
    pub fn submit(&mut self, prompt: Vec<i32>) -> usize {
        let id = self.results.len();
        self.results.push(None);
        self.queue.push_back(Job { id, prompt });
        id
    }

    /// Place queued prompts into free rows (FIFO). Returns the
    /// `(row, prompt)` placements so the caller can
    /// [`start_row`](super::DecodeGraph::start_row) each one.
    pub fn admit(&mut self) -> Vec<(usize, Vec<i32>)> {
        let mut placed = Vec::new();
        for (row, slot) in self.rows.iter_mut().enumerate() {
            if slot.is_some() {
                continue;
            }
            let Some(job) = self.queue.pop_front() else { break };
            *slot = Some(Active {
                id: job.id,
                prompt_len: job.prompt.len(),
                out: Vec::new(),
            });
            placed.push((row, job.prompt));
        }
        placed
    }

    /// Rows currently serving a request, ascending.
    pub fn active_rows(&self) -> Vec<usize> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(r, s)| s.as_ref().map(|_| r))
            .collect()
    }

    /// Tokens generated so far by the request in `row`.
    pub fn out_len(&self, row: usize) -> usize {
        self.rows[row].as_ref().map_or(0, |a| a.out.len())
    }

    /// Prompt + generated length of the request in `row`.
    pub fn total_len(&self, row: usize) -> usize {
        self.rows[row]
            .as_ref()
            .map_or(0, |a| a.prompt_len + a.out.len())
    }

    /// Record a sampled token for the request in `row`.
    pub fn push(&mut self, row: usize, token: i32) {
        if let Some(a) = self.rows[row].as_mut() {
            a.out.push(token);
        }
    }

    /// Finish the request in `row`, freeing the row and recording its
    /// generated tokens; returns the job id.
    pub fn retire(&mut self, row: usize) -> usize {
        let a = self.rows[row].take().expect("retire of an empty row");
        let id = a.id;
        self.results[id] = Some(a.out);
        id
    }

    /// True when every submitted request has been retired.
    pub fn finished(&self) -> bool {
        self.queue.is_empty() && self.rows.iter().all(Option::is_none)
    }

    /// Generated tokens per job, in submission order. Unretired jobs
    /// (only possible if the driving loop aborted early) come back empty.
    pub fn take_results(self) -> Vec<Vec<i32>> {
        self.results
            .into_iter()
            .map(Option::unwrap_or_default)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_in_fifo_order_up_to_capacity() {
        let mut s = Scheduler::new(2);
        for p in 0..4 {
            s.submit(vec![p]);
        }
        let placed = s.admit();
        assert_eq!(placed.len(), 2);
        assert_eq!(placed[0], (0, vec![0]));
        assert_eq!(placed[1], (1, vec![1]));
        assert_eq!(s.active_rows(), vec![0, 1]);
        // no free rows: nothing more admitted
        assert!(s.admit().is_empty());
    }

    #[test]
    fn retiring_frees_the_row_for_the_next_job() {
        let mut s = Scheduler::new(2);
        for p in 0..3 {
            s.submit(vec![10 + p]);
        }
        s.admit();
        s.push(0, 7);
        assert_eq!(s.retire(0), 0);
        assert!(!s.finished(), "job 2 still queued");
        let placed = s.admit();
        assert_eq!(placed, vec![(0, vec![12])], "freed row 0 is reused");
        assert_eq!(s.active_rows(), vec![0, 1]);
    }

    #[test]
    fn results_come_back_in_submission_order() {
        let mut s = Scheduler::new(2);
        for p in 0..4 {
            s.submit(vec![p]);
        }
        s.admit();
        // finish job 1 (row 1) first, then job 0; rows refill as 2, 3
        s.push(1, 101);
        s.retire(1);
        s.admit();
        s.push(0, 100);
        s.retire(0);
        s.admit();
        s.push(0, 103); // row 0 now serves job 3
        s.push(1, 102); // row 1 now serves job 2
        s.retire(1);
        s.retire(0);
        assert!(s.finished());
        assert_eq!(
            s.take_results(),
            vec![vec![100], vec![101], vec![102], vec![103]]
        );
    }

    #[test]
    fn lengths_track_prompt_and_output() {
        let mut s = Scheduler::new(1);
        s.submit(vec![1, 2, 3]);
        s.admit();
        assert_eq!(s.total_len(0), 3);
        assert_eq!(s.out_len(0), 0);
        s.push(0, 9);
        assert_eq!(s.total_len(0), 4);
        assert_eq!(s.out_len(0), 1);
    }

    #[test]
    fn zero_output_jobs_finish_empty() {
        let mut s = Scheduler::new(1);
        s.submit(vec![1]);
        s.submit(vec![2]);
        s.admit();
        s.retire(0); // e.g. max_new_tokens == 0
        s.admit();
        s.retire(0);
        assert!(s.finished());
        assert_eq!(s.take_results(), vec![Vec::<i32>::new(), vec![]]);
    }
}
