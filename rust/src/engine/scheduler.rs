//! Request-lifecycle scheduler: priorities, deadlines, cancellation and
//! memory-aware admission over few decode rows.
//!
//! The paper's one-base/many-adapters economy (QLoRA section 4) pays off
//! at serving scale, where many tenants share one frozen base. What used
//! to be a bare FIFO multiplexer is now a request pipeline:
//!
//! * every submission is a [`Request`] — tokenized prompt, a
//!   [`Priority`] class, an optional deadline, and a per-request
//!   `max_new_tokens` budget;
//! * admission is priority-ordered with aging (a queued job's effective
//!   priority rises the longer it waits, so `Low` traffic cannot starve
//!   forever) and **memory** gated, in one of two modes:
//!   - [`Scheduler::with_blocks`] (what `Session::serve` uses): each
//!     row's KV cache is a block table in a
//!     [`BlockManager`](crate::paged::BlockManager), and admission
//!     charges the blocks a job would *actually allocate* — after
//!     copy-on-write prefix sharing — plus a small growth headroom.
//!     Requests opening with the same system prompt attach to the same
//!     physical blocks, so shared-prefix traffic admits far more
//!     concurrent rows than any worst-case reservation. Under pressure,
//!     the lowest-priority resident row is **swapped out** (its blocks
//!     released, the job re-queued with its partial output) to make
//!     room for a strictly higher-priority admission or for a resident
//!     row that needs one more block mid-decode;
//!   - [`Scheduler::with_budget`] (legacy): the sum of worst-case
//!     reserved tokens (`prompt + max_new_tokens`) across resident rows
//!     never exceeds a fixed cap. Simple, but it over-reserves badly
//!     for short completions and cannot see prefix sharing at all;
//! * every job ends in exactly one typed [`JobOutcome`] — `Done`,
//!   `Cancelled` (via a [`CancelHandle`]), `DeadlineExceeded`, or
//!   `Aborted` (the driving loop stopped early, or the job can never
//!   fit) — instead of a silent empty vec;
//! * [`Scheduler::stats`] snapshots a [`ServerStats`] block (queue depth,
//!   resident tokens, KV blocks in use, shared-block hits, CoW forks,
//!   swap-outs, time-to-first-token) for the serving surface
//!   (`Session::serve`, `qlora serve`, `bench_generate`).
//!
//! The scheduler stays pure bookkeeping: no runtime types, no clocks of
//! its own (every time-dependent entry point takes `now: Instant`), so
//! admission order, cancellation, deadlines, block and budget accounting
//! are all unit- and property-testable without artifacts or a PJRT
//! client. The serving loop in [`Session::serve`](super::Session::serve)
//! drives a [`DecodeGraph`](super::DecodeGraph) from its decisions: it
//! must free the graph rows named by [`Scheduler::take_swap_outs`]
//! before reusing them, exactly like [`Retirement`]s from
//! [`Scheduler::poll`].
//!
//! Row operations ([`Scheduler::push`], [`Scheduler::retire`]) return
//! `Result` instead of indexing unchecked — an out-of-range row or a
//! double-retire from a buggy driving loop is a recoverable error, not a
//! panic that takes the whole serve loop down.

use std::cmp::Reverse;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::paged::{AppendOutcome, BlockConfig, BlockId, BlockManager};
use crate::util::faults::Faults;

/// Job identifier: the submission index, which is also the job's slot in
/// [`Scheduler::take_results`].
pub type JobId = usize;

/// Admission priority class. Higher classes are admitted first; within a
/// class, submission order wins. Queued jobs age upward (one class per
/// [`AGING_ROUNDS`] admission rounds) so `Low` cannot starve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    /// Background work (batch eval, speculative traffic).
    Low,
    /// The default class for interactive traffic.
    #[default]
    Normal,
    /// Latency-sensitive traffic; jumps every queued `Normal`/`Low` job.
    High,
}

impl Priority {
    fn rank(self) -> usize {
        match self {
            Priority::Low => 0,
            Priority::Normal => 1,
            Priority::High => 2,
        }
    }
}

/// Admission rounds a queued job waits before its effective priority
/// rises one class (aging, so low-priority jobs cannot starve forever).
pub const AGING_ROUNDS: usize = 32;

/// Cooperative cancellation flag for one request. Clone it, hand one copy
/// to the submission and keep the other; [`CancelHandle::cancel`] takes
/// effect at the scheduler's next [`Scheduler::poll`] — queued jobs never
/// start, in-flight jobs are retired (their row freed) within one step.
/// The flag is an `Arc<AtomicBool>`, so it may be flipped from another
/// thread even though the serve loop itself is single-threaded.
#[derive(Debug, Clone, Default)]
pub struct CancelHandle(Arc<AtomicBool>);

impl CancelHandle {
    /// A fresh, un-cancelled handle.
    pub fn new() -> CancelHandle {
        CancelHandle::default()
    }

    /// Request cancellation (idempotent, thread-safe).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Whether [`CancelHandle::cancel`] has been called.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// One unit of serving work: a tokenized prompt plus its lifecycle
/// parameters. Build with [`Request::new`] and chain the setters.
#[derive(Debug, Clone, Default)]
pub struct Request {
    /// Prompt token ids (already encoded; the scheduler never tokenizes).
    pub prompt: Vec<i32>,
    /// Admission class; see [`Priority`].
    pub priority: Priority,
    /// Give up on the job this long after submission (queued jobs expire
    /// without running; in-flight jobs are retired mid-decode and keep
    /// the tokens generated so far).
    pub deadline: Option<Duration>,
    /// Per-request generation budget; together with the prompt length
    /// this is the job's *reserved* footprint for budget admission.
    pub max_new_tokens: usize,
}

impl Request {
    /// A `Normal`-priority request with no deadline and a `max_new`
    /// budget of `max_new_tokens`.
    pub fn new(prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request { prompt, max_new_tokens, ..Request::default() }
    }

    /// Set the admission class.
    pub fn priority(mut self, p: Priority) -> Request {
        self.priority = p;
        self
    }

    /// Set a deadline relative to submission time.
    pub fn deadline(mut self, d: Duration) -> Request {
        self.deadline = Some(d);
        self
    }
}

/// How a job's life ended. Every submitted job reaches exactly one of
/// these (the property test's core invariant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobOutcome {
    /// Ran to completion (EOS, token budget, or sequence length).
    Done,
    /// Cancelled via its [`CancelHandle`] (queued or in flight).
    Cancelled,
    /// Its deadline passed before completion (queued or in flight).
    DeadlineExceeded,
    /// Retired by the decode-step watchdog: the job made no forward
    /// progress for the whole watchdog window (see
    /// [`Scheduler::set_watchdog`]), so it was evicted rather than
    /// allowed to stall the batch.
    TimedOut,
    /// The driving loop stopped before the job terminated.
    Aborted,
}

/// Terminal state of one job: the typed outcome plus whatever tokens were
/// generated before it ended (partial output for `Cancelled`/
/// `DeadlineExceeded`/`Aborted`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobResult {
    /// How the job ended.
    pub outcome: JobOutcome,
    /// Generated tokens (complete for `Done`, partial otherwise).
    pub tokens: Vec<i32>,
}

/// One admission decision: start `prompt` in decode row `row`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Admission {
    /// The decode row the job was placed into.
    pub row: usize,
    /// The admitted job.
    pub job: JobId,
    /// The tokens to prefill via
    /// [`DecodeGraph::start_row`](super::DecodeGraph::start_row): the
    /// prompt, plus any output already generated before a swap-out
    /// (resumed jobs re-prefill their whole history).
    pub prompt: Vec<i32>,
}

/// One mid-flight retirement from [`Scheduler::poll`]: the caller must
/// free `row` on its decode graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Retirement {
    /// The decode row that was vacated.
    pub row: usize,
    /// The job that was retired.
    pub job: JobId,
    /// Why it was retired (`Cancelled`, `DeadlineExceeded`, or
    /// `TimedOut` from the decode-step watchdog).
    pub outcome: JobOutcome,
}

/// One swap-out: the job in `row` was evicted under memory pressure (its
/// KV blocks released, the job re-queued with its partial output). The
/// caller must free `row` on its decode graph before the row is reused —
/// drain [`Scheduler::take_swap_outs`] after every
/// [`Scheduler::admit`]/[`Scheduler::push`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SwapOut {
    /// The decode row that was vacated.
    pub row: usize,
    /// The job that was swapped out (now queued again).
    pub job: JobId,
}

/// Aggregate serving statistics; snapshot via [`Scheduler::stats`].
/// `elapsed` is filled in by the serving loop (the scheduler has no
/// clock), after which [`ServerStats::tokens_per_sec`] is meaningful.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    /// Jobs submitted over the scheduler's lifetime.
    pub submitted: u64,
    /// Jobs that ran to completion.
    pub completed: u64,
    /// Jobs cancelled via their [`CancelHandle`].
    pub cancelled: u64,
    /// Jobs that hit their deadline (queued or in flight).
    pub deadline_exceeded: u64,
    /// Jobs retired by the decode-step watchdog
    /// ([`JobOutcome::TimedOut`]): no forward progress for the whole
    /// watchdog window.
    pub timed_out_jobs: u64,
    /// Requests shed at the door by overload control (429/503 before a
    /// job was ever submitted). Filled by the serving layer — the
    /// scheduler never sees a shed request.
    pub shed_requests: u64,
    /// HTTP worker threads respawned after a panic. Filled by the
    /// serving layer.
    pub worker_restarts: u64,
    /// In-flight retirements (cancel/deadline/watchdog) — rows vacated
    /// mid-decode.
    pub preemptions: u64,
    /// Jobs currently waiting for a row.
    pub queue_depth: usize,
    /// Rows currently serving a job.
    pub active_rows: usize,
    /// Sum of `prompt + generated` tokens across resident rows.
    pub resident_tokens: usize,
    /// Sum of `prompt + max_new_tokens` across resident rows — what the
    /// legacy token-budget mode charges at admission (descriptive only
    /// under block-granular admission).
    pub reserved_tokens: usize,
    /// The admission cap in token terms: the `with_budget` cap
    /// (`usize::MAX` = unbounded), or `kv_blocks × kv_block_tokens`
    /// under block-granular admission.
    pub token_budget: usize,
    /// Physical KV cache blocks in the pool (0 = token-budget mode).
    pub kv_blocks: usize,
    /// Tokens of K/V one block covers (0 = token-budget mode).
    pub kv_block_tokens: usize,
    /// KV blocks currently live across all resident rows.
    pub kv_blocks_in_use: usize,
    /// Block attachments served by copy-on-write prefix sharing instead
    /// of a fresh allocation.
    pub shared_block_hits: u64,
    /// Copy-on-write forks (first write past a shared prefix).
    pub cow_forks: u64,
    /// Rows swapped out (blocks released, job re-queued) under memory
    /// pressure.
    pub swap_outs: u64,
    /// Tokens recorded via [`Scheduler::push`].
    pub tokens_generated: u64,
    /// Mean time from submission to a job's first generated token, in
    /// microseconds (0 when no job has produced a token yet).
    pub mean_ttft_us: f64,
    /// Wall-clock span of the serve loop; filled by the caller.
    pub elapsed: Duration,
}

impl ServerStats {
    /// Generation throughput over `elapsed`. Guarded: a snapshot taken
    /// before `elapsed` is filled in, or before any token was generated,
    /// reports 0.0 — never NaN or infinity.
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs > 0.0 && self.tokens_generated > 0 {
            let rate = self.tokens_generated as f64 / secs;
            if rate.is_finite() { rate } else { 0.0 }
        } else {
            0.0
        }
    }

    /// Mean time-to-first-token in milliseconds, 0.0 on a fresh snapshot
    /// (never NaN or infinity — safe to display unconditionally).
    pub fn mean_ttft_ms(&self) -> f64 {
        if self.mean_ttft_us.is_finite() && self.mean_ttft_us > 0.0 {
            self.mean_ttft_us / 1e3
        } else {
            0.0
        }
    }

    /// One-line human summary for CLIs and benches.
    pub fn summary(&self) -> String {
        let mut line = format!(
            "{} done / {} cancelled / {} deadline-exceeded of {} submitted; \
             {} preemptions; {} tokens ({:.1} tok/s); mean TTFT {:.1} ms",
            self.completed,
            self.cancelled,
            self.deadline_exceeded,
            self.submitted,
            self.preemptions,
            self.tokens_generated,
            self.tokens_per_sec(),
            self.mean_ttft_ms(),
        );
        if self.timed_out_jobs + self.shed_requests + self.worker_restarts > 0 {
            line.push_str(&format!(
                "; {} timed-out, {} shed, {} worker restarts",
                self.timed_out_jobs, self.shed_requests, self.worker_restarts,
            ));
        }
        if self.kv_blocks > 0 {
            line.push_str(&format!(
                "; KV {}/{} blocks of {} tokens, {} shared hits, \
                 {} CoW forks, {} swap-outs",
                self.kv_blocks_in_use,
                self.kv_blocks,
                self.kv_block_tokens,
                self.shared_block_hits,
                self.cow_forks,
                self.swap_outs,
            ));
        }
        line
    }
}

/// Per-job lifecycle bookkeeping kept for the job's whole life.
struct JobMeta {
    priority: Priority,
    /// absolute expiry instant (submission time + requested deadline)
    deadline: Option<Instant>,
    cancel: CancelHandle,
    submitted_at: Instant,
    max_new_tokens: usize,
    /// admission rounds spent waiting in the queue (drives aging)
    waited_rounds: usize,
    /// last forward progress while resident: reset at admission, bumped
    /// by every recorded token (drives the decode-step watchdog)
    last_progress: Instant,
}

impl JobMeta {
    /// Effective rank after aging: one class per [`AGING_ROUNDS`] spent
    /// queued, capped at `High`. Ties break by submission order.
    fn effective_rank(&self) -> usize {
        (self.priority.rank() + self.waited_rounds / AGING_ROUNDS)
            .min(Priority::High.rank())
    }
}

struct Queued {
    id: JobId,
    prompt: Vec<i32>,
    /// tokens generated before a swap-out (empty for fresh submissions);
    /// re-admission prefills `prompt ++ out` and generation resumes
    out: Vec<i32>,
}

struct Active {
    id: JobId,
    /// the original prompt, kept so a swap-out can re-queue the job
    prompt: Vec<i32>,
    max_new_tokens: usize,
    out: Vec<i32>,
}

impl Active {
    fn resident(&self) -> usize {
        self.prompt.len() + self.out.len()
    }

    fn reserved(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }
}

/// What gates admission: a worst-case token budget (legacy) or the KV
/// block manager (blocks actually allocated, after prefix sharing).
enum Memory {
    Tokens { budget: usize },
    Blocks { mgr: BlockManager },
}

impl Memory {
    /// Placeholder swapped in while a `&mut self` method needs to hold
    /// the real memory and the scheduler's own fields at once.
    fn taken() -> Memory {
        Memory::Tokens { budget: usize::MAX }
    }
}

/// Priority/deadline-aware multiplexer of [`Request`]s onto `capacity`
/// decode rows under a memory cap (KV blocks or a token budget).
pub struct Scheduler {
    queue: VecDeque<Queued>,
    rows: Vec<Option<Active>>,
    /// terminal state by job id (`None` while queued / in flight)
    results: Vec<Option<JobResult>>,
    /// lifecycle metadata by job id
    meta: Vec<JobMeta>,
    /// admission gate: token budget or block manager
    memory: Memory,
    /// swap-outs since the last [`Scheduler::take_swap_outs`]
    swapped: Vec<SwapOut>,
    /// jobs that reached a terminal outcome since the last
    /// [`Scheduler::drain_finished`]
    newly_finished: Vec<JobId>,
    /// decode-step watchdog: a resident row making no forward progress
    /// for this long is retired [`JobOutcome::TimedOut`] at the next
    /// [`Scheduler::poll`] (`None` = no watchdog)
    watchdog: Option<Duration>,
    // --- stats accumulators (terminal outcomes counted incrementally so
    // the per-step `stats()` snapshot never rescans `results`) ---
    n_done: u64,
    n_cancelled: u64,
    n_deadline: u64,
    n_timed_out: u64,
    preemptions: u64,
    tokens_generated: u64,
    ttft_total: Duration,
    ttft_count: u64,
}

impl Scheduler {
    /// A scheduler over `capacity` rows with an unbounded token budget
    /// (row count is the only admission limit — the pre-lifecycle
    /// behaviour).
    pub fn new(capacity: usize) -> Scheduler {
        Scheduler::with_budget(capacity, usize::MAX)
    }

    /// A scheduler over `capacity` rows that keeps the sum of reserved
    /// (`prompt + max_new`) tokens across resident rows at or below
    /// `token_budget`. A single job larger than the whole budget is still
    /// admitted when the machine is idle (sole-tenant override) so it can
    /// never deadlock the queue.
    pub fn with_budget(capacity: usize, token_budget: usize) -> Scheduler {
        Scheduler::with_memory(capacity, Memory::Tokens { budget: token_budget })
    }

    /// A scheduler over `capacity` rows whose KV caches live in a
    /// [`BlockManager`] built from `cfg`: admission charges blocks a job
    /// would actually allocate (after copy-on-write prefix sharing) plus
    /// `cfg.headroom_blocks` of growth room, and the lowest-priority
    /// resident row is swapped out under pressure. Errors on a
    /// degenerate config (zero blocks or zero block size).
    pub fn with_blocks(capacity: usize, cfg: BlockConfig) -> Result<Scheduler> {
        let mgr = BlockManager::new(cfg)?;
        Ok(Scheduler::with_memory(capacity, Memory::Blocks { mgr }))
    }

    /// Arm (or disarm, with `None`) the decode-step watchdog: a
    /// resident row that records no token for `window` is retired with
    /// [`JobOutcome::TimedOut`] at the next [`Scheduler::poll`] instead
    /// of stalling the batch. The clock is the caller's `now` — pure
    /// bookkeeping, like deadlines.
    pub fn set_watchdog(&mut self, window: Option<Duration>) {
        self.watchdog = window;
    }

    /// Thread the fault-injection plane down to the KV block manager
    /// (`block-alloc` failures at the append boundary, which surface as
    /// ordinary [`AppendOutcome::NeedBlock`] pressure). No-op in
    /// token-budget mode or with a disabled handle.
    pub fn set_faults(&mut self, faults: Faults) {
        if let Memory::Blocks { mgr } = &mut self.memory {
            mgr.set_faults(faults);
        }
    }

    /// Run the KV block manager's structural self-check (see
    /// [`BlockManager::check_invariants`]); the chaos property suite
    /// calls this after every step. No-op in token-budget mode.
    pub fn check_block_invariants(&self) {
        if let Memory::Blocks { mgr } = &self.memory {
            mgr.check_invariants();
        }
    }

    fn with_memory(capacity: usize, memory: Memory) -> Scheduler {
        Scheduler {
            queue: VecDeque::new(),
            rows: (0..capacity.max(1)).map(|_| None).collect(),
            results: Vec::new(),
            meta: Vec::new(),
            memory,
            swapped: Vec::new(),
            newly_finished: Vec::new(),
            watchdog: None,
            n_done: 0,
            n_cancelled: 0,
            n_deadline: 0,
            n_timed_out: 0,
            preemptions: 0,
            tokens_generated: 0,
            ttft_total: Duration::ZERO,
            ttft_count: 0,
        }
    }

    /// Enqueue a request; returns its job id (= submission index, which
    /// is also its slot in [`Scheduler::take_results`]) and the
    /// cancellation handle for this job.
    pub fn submit(&mut self, req: Request, now: Instant) -> (JobId, CancelHandle) {
        self.submit_with_handle(req, CancelHandle::new(), now)
    }

    /// Like [`Scheduler::submit`], but cancellation is observed through a
    /// caller-provided handle (e.g. one already shared with another
    /// thread).
    pub fn submit_with_handle(
        &mut self,
        req: Request,
        cancel: CancelHandle,
        now: Instant,
    ) -> (JobId, CancelHandle) {
        let id = self.results.len();
        self.results.push(None);
        self.meta.push(JobMeta {
            priority: req.priority,
            deadline: req.deadline.map(|d| now + d),
            cancel: cancel.clone(),
            submitted_at: now,
            max_new_tokens: req.max_new_tokens,
            waited_rounds: 0,
            last_progress: now,
        });
        self.queue.push_back(Queued { id, prompt: req.prompt, out: Vec::new() });
        (id, cancel)
    }

    /// Record a terminal outcome (central spot for the stats counters).
    fn record_outcome(&mut self, id: JobId, outcome: JobOutcome, tokens: Vec<i32>) {
        match outcome {
            JobOutcome::Done => self.n_done += 1,
            JobOutcome::Cancelled => self.n_cancelled += 1,
            JobOutcome::DeadlineExceeded => self.n_deadline += 1,
            JobOutcome::TimedOut => self.n_timed_out += 1,
            JobOutcome::Aborted => {}
        }
        // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; results grows in lockstep
        self.results[id] = Some(JobResult { outcome, tokens });
        self.newly_finished.push(id);
    }

    /// Jobs that reached a terminal outcome since the last call, with a
    /// clone of their result — the per-job completion feed for
    /// incremental drivers (the HTTP server answers each request as its
    /// job finishes, without waiting for
    /// [`Scheduler::take_results`]). Drains the internal queue;
    /// `take_results` is unaffected.
    pub fn drain_finished(&mut self) -> Vec<(JobId, JobResult)> {
        std::mem::take(&mut self.newly_finished)
            .into_iter()
            .filter_map(|id| {
                self.results
                    .get(id)
                    .and_then(|r| r.clone())
                    .map(|r| (id, r))
            })
            .collect()
    }

    /// Whether job `id` should be terminated early (cancelled or past
    /// its deadline), and with which outcome. Shared by the queued sweep
    /// and the in-flight poll so the two can never diverge.
    fn queued_expiry(&self, id: JobId, now: Instant) -> Option<JobOutcome> {
        // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
        let m = &self.meta[id];
        if m.cancel.is_cancelled() {
            Some(JobOutcome::Cancelled)
        } else if m.deadline.is_some_and(|d| now >= d) {
            Some(JobOutcome::DeadlineExceeded)
        } else {
            None
        }
    }

    /// Drop queued jobs that were cancelled or whose deadline passed.
    /// The common no-expiry case is a read-only scan (no reallocation),
    /// so calling this every decode step is cheap.
    fn sweep_queue(&mut self, now: Instant) {
        let any_expired = self
            .queue
            .iter()
            .any(|q| self.queued_expiry(q.id, now).is_some());
        if !any_expired {
            return;
        }
        let mut kept = VecDeque::with_capacity(self.queue.len());
        while let Some(q) = self.queue.pop_front() {
            match self.queued_expiry(q.id, now) {
                // a swapped-out job keeps the tokens it generated
                Some(outcome) => self.record_outcome(q.id, outcome, q.out),
                None => kept.push_back(q),
            }
        }
        self.queue = kept;
    }

    /// Retire active rows whose request was cancelled or whose deadline
    /// passed, and expire queued jobs likewise. Returns the vacated rows
    /// so the caller can `free_row` them on its decode graph — a
    /// cancelled in-flight request frees its row within one step.
    pub fn poll(&mut self, now: Instant) -> Vec<Retirement> {
        self.sweep_queue(now);
        let mut retired = Vec::new();
        for row in 0..self.rows.len() {
            // pallas-lint: allow(no-hot-path-panic) — row ranges over 0..rows.len()
            let Some(a) = self.rows[row].as_ref() else { continue };
            // same expiry rules as for queued jobs (the helper reads
            // only the job's metadata, nothing queue-specific), plus
            // the resident-only watchdog: no recorded token for the
            // whole window retires the row rather than stalling the
            // batch behind a hung step
            let expiry = self.queued_expiry(a.id, now).or_else(|| {
                let stalled = self.watchdog.is_some_and(|w| {
                    // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
                    let last = self.meta[a.id].last_progress;
                    now.saturating_duration_since(last) >= w
                });
                stalled.then_some(JobOutcome::TimedOut)
            });
            let Some(outcome) = expiry else {
                continue;
            };
            // pallas-lint: allow(no-hot-path-panic) — resident: checked two lines up
            let Some(a) = self.rows[row].take() else { continue };
            if let Memory::Blocks { mgr } = &mut self.memory {
                // pallas-lint: allow(no-hot-path-panic) — resident rows are attached at admission and detached only on retire/swap/poll
                mgr.release_row(row).expect("active row is attached");
            }
            let job = a.id;
            self.record_outcome(job, outcome, a.out);
            self.preemptions += 1;
            retired.push(Retirement { row, job, outcome });
        }
        retired
    }

    /// Place queued jobs into free rows in effective-priority order
    /// (priority class + aging, ties by submission order), gated by the
    /// memory mode: blocks a job would actually allocate after prefix
    /// sharing (plus growth headroom, with swap-out of strictly
    /// lower-priority residents under pressure), or the legacy reserved
    /// (`prompt + max_new`) token budget. Admission stops at the first
    /// job that does not fit — no bypass, so a fitting low-priority job
    /// can never overtake a non-fitting high-priority one. Returns the
    /// placements for
    /// [`DecodeGraph::start_row`](super::DecodeGraph::start_row); drain
    /// [`Scheduler::take_swap_outs`] and free those graph rows *before*
    /// starting the placements (a swapped-out victim's row may be handed
    /// right back out).
    pub fn admit(&mut self, now: Instant) -> Vec<Admission> {
        self.sweep_queue(now);
        let mut memory = std::mem::replace(&mut self.memory, Memory::taken());
        let placed = self.admit_inner(&mut memory);
        self.memory = memory;
        // admission is forward progress: a job that queued for longer
        // than the watchdog window must not be retired on arrival
        for a in &placed {
            // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
            self.meta[a.job].last_progress = now;
        }
        // single aging pass: every job still queued after this round —
        // skipped for budget, skipped because rows ran out, or swapped
        // out during the round — waited one more round. (Both previous
        // aging sites, the nothing-placeable early return and the tail
        // loop, collapse into this one so they can never drift apart.)
        for q in &self.queue {
            // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
            self.meta[q.id].waited_rounds += 1;
        }
        placed
    }

    fn admit_inner(&mut self, memory: &mut Memory) -> Vec<Admission> {
        let mut free_rows: VecDeque<usize> = self
            .rows
            .iter()
            .enumerate()
            .filter_map(|(r, s)| s.is_none().then_some(r))
            .collect();
        if self.queue.is_empty() || free_rows.is_empty() {
            return Vec::new();
        }
        // stable order: effective rank desc, then submission order
        self.queue
            .make_contiguous()
            // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
            .sort_by_key(|q| (Reverse(self.meta[q.id].effective_rank()), q.id));
        let mut placed = Vec::new();
        match memory {
            Memory::Tokens { budget } => {
                let mut reserved = self.reserved_tokens();
                while let Some(q) = self.queue.front() {
                    let Some(&row) = free_rows.front() else { break };
                    let need =
                        // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
                        q.prompt.len() + self.meta[q.id].max_new_tokens;
                    // sole-tenant override: an oversized job may run alone
                    let fits = reserved == 0
                        || reserved.saturating_add(need) <= *budget;
                    if !fits {
                        break;
                    }
                    free_rows.pop_front();
                    let Some(q) = self.queue.pop_front() else { break };
                    reserved += need;
                    let history: Vec<i32> = q
                        .prompt
                        .iter()
                        .chain(q.out.iter())
                        .copied()
                        .collect();
                    // pallas-lint: allow(no-hot-path-panic) — row came off free_rows, built from rows' own indices
                    self.rows[row] = Some(Active {
                        id: q.id,
                        prompt: q.prompt,
                        // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
                        max_new_tokens: self.meta[q.id].max_new_tokens,
                        out: q.out,
                    });
                    placed.push(Admission { row, job: q.id, prompt: history });
                }
            }
            Memory::Blocks { mgr } => loop {
                let Some(q) = self.queue.front() else { break };
                let Some(&row) = free_rows.front() else { break };
                let id = q.id;
                let history: Vec<i32> =
                    q.prompt.iter().chain(q.out.iter()).copied().collect();
                // a block table is a chain of distinct physical blocks,
                // so a history longer than the whole pool can never run
                if mgr.cfg().blocks_for(history.len()) > mgr.n_blocks() {
                    let Some(q) = self.queue.pop_front() else { break };
                    self.record_outcome(id, JobOutcome::Aborted, q.out);
                    continue;
                }
                let need = mgr.probe_attach(&history);
                // sole tenant: headroom is waived, so an idle pool always
                // admits (need ≤ blocks_for(len) ≤ n_blocks = free here)
                let idle = placed.is_empty()
                    && self.rows.iter().all(Option::is_none);
                let headroom =
                    if idle { 0 } else { mgr.cfg().headroom_blocks };
                if need + headroom <= mgr.free_blocks() {
                    free_rows.pop_front();
                    let Some(q) = self.queue.pop_front() else { break };
                    mgr.attach(row, &history)
                        // pallas-lint: allow(no-hot-path-panic) — probe_attach just verified need ≤ free_blocks, and rows are detached before their row id is reused
                        .expect("probed: enough free blocks");
                    // pallas-lint: allow(no-hot-path-panic) — row came off free_rows, built from rows' own indices
                    self.rows[row] = Some(Active {
                        id,
                        prompt: q.prompt,
                        // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
                        max_new_tokens: self.meta[id].max_new_tokens,
                        out: q.out,
                    });
                    placed.push(Admission { row, job: id, prompt: history });
                    continue;
                }
                // pressure: swap out a strictly lower-ranked resident
                // and retry this head. Each victim chain is strictly
                // decreasing in rank, so this terminates; if no victim
                // exists the head waits for rows to retire normally.
                // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
                let rank = self.meta[id].effective_rank();
                match self.pick_victim(Some(rank)) {
                    Some(victim) => {
                        self.swap_out_row(mgr, victim);
                        free_rows.push_back(victim);
                    }
                    None => break,
                }
            },
        }
        placed
    }

    /// The resident row to evict under pressure: lowest effective rank,
    /// ties broken youngest-first (largest job id — oldest jobs have
    /// waited longest). With `below` set, only rows *strictly* below
    /// that rank qualify (admission-triggered preemption must not churn
    /// equals); `None` considers every resident row (a resident row
    /// needing one more block may then evict itself).
    fn pick_victim(&self, below: Option<usize>) -> Option<usize> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(r, s)| s.as_ref().map(|a| (r, a.id)))
            // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
            .map(|(r, id)| (r, self.meta[id].effective_rank(), id))
            .filter(|&(_, rank, _)| below.is_none_or(|b| rank < b))
            .min_by_key(|&(_, rank, id)| (rank, Reverse(id)))
            .map(|(r, _, _)| r)
    }

    /// Evict the job in `row`: release its KV blocks, re-queue it with
    /// its partial output, and record the vacated row for
    /// [`Scheduler::take_swap_outs`].
    fn swap_out_row(&mut self, mgr: &mut BlockManager, row: usize) {
        // pallas-lint: allow(no-hot-path-panic) — pick_victim only yields resident rows; nothing retires between pick and swap
        let Some(a) = self.rows[row].take() else { return };
        // pallas-lint: allow(no-hot-path-panic) — resident rows are attached at admission and detached only on retire/swap/poll
        mgr.swap_out(row).expect("active row is attached");
        self.swapped.push(SwapOut { row, job: a.id });
        self.queue.push_back(Queued {
            id: a.id,
            prompt: a.prompt,
            out: a.out,
        });
    }

    /// Swap-outs since the last call — the serving loop must free these
    /// rows on its decode graph (after [`Scheduler::admit`] but before
    /// starting that round's placements, and again after each token
    /// push) exactly like [`Retirement`]s.
    pub fn take_swap_outs(&mut self) -> Vec<SwapOut> {
        std::mem::take(&mut self.swapped)
    }

    /// The physical KV blocks backing `row`, in history order (`None`
    /// for a free row or in token-budget mode).
    pub fn row_block_table(&self, row: usize) -> Option<&[BlockId]> {
        match &self.memory {
            Memory::Blocks { mgr } => {
                mgr.row_table(row).map(|t| t.blocks.as_slice())
            }
            Memory::Tokens { .. } => None,
        }
    }

    /// Rows currently serving a request, ascending.
    pub fn active_rows(&self) -> Vec<usize> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(r, s)| s.as_ref().map(|_| r))
            .collect()
    }

    /// The job occupying `row`, if any.
    pub fn job_in(&self, row: usize) -> Option<JobId> {
        self.rows.get(row)?.as_ref().map(|a| a.id)
    }

    /// Tokens generated so far by the request in `row` (0 for a free or
    /// out-of-range row).
    pub fn out_len(&self, row: usize) -> usize {
        self.rows
            .get(row)
            .and_then(Option::as_ref)
            .map_or(0, |a| a.out.len())
    }

    /// Prompt + generated length of the request in `row` (0 for a free or
    /// out-of-range row).
    pub fn total_len(&self, row: usize) -> usize {
        self.rows
            .get(row)
            .and_then(Option::as_ref)
            .map_or(0, Active::resident)
    }

    /// Sum of `prompt + generated` tokens across resident rows.
    pub fn resident_tokens(&self) -> usize {
        self.rows
            .iter()
            .flatten()
            .map(Active::resident)
            .sum()
    }

    /// Sum of `prompt + max_new` tokens across resident rows — what the
    /// legacy token-budget mode charges at admission (block-granular
    /// admission ignores it; blocks in use are the real footprint).
    pub fn reserved_tokens(&self) -> usize {
        self.rows
            .iter()
            .flatten()
            .map(Active::reserved)
            .sum()
    }

    /// Whether the request in `row` has exhausted its own `max_new`
    /// budget or the compiled sequence (the caller retires it then).
    /// `false` for a free or out-of-range row.
    pub fn budget_exhausted(&self, row: usize, seq_len: usize) -> bool {
        self.rows
            .get(row)
            .and_then(Option::as_ref)
            .is_some_and(|a| {
                a.out.len() >= a.max_new_tokens || a.resident() >= seq_len
            })
    }

    /// Record a sampled token for the request in `row`; `now` feeds the
    /// time-to-first-token statistic. Errors (rather than panicking) on a
    /// free or out-of-range row.
    ///
    /// Returns whether the token was recorded. Under block-granular
    /// memory a token crossing a block boundary may need a fresh block
    /// from an exhausted pool; the lowest-priority resident row is then
    /// swapped out to make room. When that victim is `row` *itself* (it
    /// is the lowest-priority resident), the push returns `Ok(false)`:
    /// nothing was recorded, the job is queued again with its prior
    /// output, and the caller must skip its own graph push for this row
    /// (then drain [`Scheduler::take_swap_outs`]). Token-budget mode
    /// always records (`Ok(true)`).
    pub fn push(&mut self, row: usize, token: i32, now: Instant) -> Result<bool> {
        let mut memory = std::mem::replace(&mut self.memory, Memory::taken());
        let recorded = match &mut memory {
            Memory::Tokens { .. } => {
                match self.rows.get(row).and_then(Option::as_ref) {
                    Some(_) => Ok(true),
                    None => Err(anyhow!(
                        "push into free or out-of-range row {row}"
                    )),
                }
            }
            Memory::Blocks { mgr } => self.push_blocks(mgr, row, token),
        };
        self.memory = memory;
        if !recorded? {
            return Ok(false);
        }
        let Some(a) = self.rows.get_mut(row).and_then(Option::as_mut) else {
            bail!("row {row} freed mid-push despite a recorded token");
        };
        if a.out.is_empty() {
            // first token of this job's life: a job resumed after a
            // swap-out comes back with its prior output, so its TTFT is
            // never counted twice
            let ttft = now.saturating_duration_since(
                // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
                self.meta[a.id].submitted_at,
            );
            self.ttft_total += ttft;
            self.ttft_count += 1;
        }
        a.out.push(token);
        // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; meta grows in lockstep
        self.meta[a.id].last_progress = now;
        self.tokens_generated += 1;
        Ok(true)
    }

    /// Blocks-mode half of [`Scheduler::push`]: grow `row`'s block table
    /// by one token, swapping out the lowest-priority resident (possibly
    /// `row` itself → `Ok(false)`) whenever the pool runs dry.
    fn push_blocks(
        &mut self,
        mgr: &mut BlockManager,
        row: usize,
        token: i32,
    ) -> Result<bool> {
        loop {
            if self.rows.get(row).and_then(Option::as_ref).is_none() {
                bail!("push into free or out-of-range row {row}");
            }
            match mgr.append(row, token)? {
                AppendOutcome::Appended { .. } => return Ok(true),
                AppendOutcome::NeedBlock => {
                    // every swap frees a resident row, so this loop runs
                    // at most `capacity` times before `row` itself is
                    // the only candidate left and self-swaps
                    let victim = self
                        .pick_victim(None)
                        // pallas-lint: allow(no-hot-path-panic) — row was checked resident at loop top, so pick_victim(None) always has a candidate
                        .expect("row itself is resident");
                    self.swap_out_row(mgr, victim);
                    if victim == row {
                        return Ok(false);
                    }
                }
            }
        }
    }

    /// Finish the request in `row` normally ([`JobOutcome::Done`]),
    /// freeing the row (and its KV blocks) and recording its tokens;
    /// returns the job id. A double-retire or out-of-range row is an
    /// error, not a panic.
    pub fn retire(&mut self, row: usize) -> Result<JobId> {
        let Some(slot) = self.rows.get_mut(row) else {
            bail!("retire of out-of-range row {row}");
        };
        let Some(a) = slot.take() else {
            bail!("retire of already-free row {row}");
        };
        if let Memory::Blocks { mgr } = &mut self.memory {
            // pallas-lint: allow(no-hot-path-panic) — resident rows are attached at admission and detached only on retire/swap/poll
            mgr.release_row(row).expect("active row is attached");
        }
        let id = a.id;
        self.record_outcome(id, JobOutcome::Done, a.out);
        Ok(id)
    }

    /// True when every submitted request has reached a terminal outcome.
    pub fn finished(&self) -> bool {
        self.queue.is_empty() && self.rows.iter().all(Option::is_none)
    }

    /// Snapshot the serving statistics (fill `elapsed` yourself — the
    /// scheduler has no clock). O(capacity), not O(jobs ever submitted):
    /// cheap enough to call after every decode step.
    pub fn stats(&self) -> ServerStats {
        let mut st = ServerStats {
            submitted: self.results.len() as u64,
            completed: self.n_done,
            cancelled: self.n_cancelled,
            deadline_exceeded: self.n_deadline,
            timed_out_jobs: self.n_timed_out,
            // shed requests and worker restarts happen above the
            // scheduler; the serving layer merges them into snapshots
            shed_requests: 0,
            worker_restarts: 0,
            preemptions: self.preemptions,
            queue_depth: self.queue.len(),
            active_rows: self.rows.iter().flatten().count(),
            resident_tokens: self.resident_tokens(),
            reserved_tokens: self.reserved_tokens(),
            token_budget: 0,
            kv_blocks: 0,
            kv_block_tokens: 0,
            kv_blocks_in_use: 0,
            shared_block_hits: 0,
            cow_forks: 0,
            swap_outs: 0,
            tokens_generated: self.tokens_generated,
            mean_ttft_us: if self.ttft_count > 0 {
                self.ttft_total.as_micros() as f64 / self.ttft_count as f64
            } else {
                0.0
            },
            elapsed: Duration::ZERO,
        };
        match &self.memory {
            Memory::Tokens { budget } => st.token_budget = *budget,
            Memory::Blocks { mgr } => {
                st.token_budget =
                    mgr.n_blocks() * mgr.cfg().block_tokens;
                st.kv_blocks = mgr.n_blocks();
                st.kv_block_tokens = mgr.cfg().block_tokens;
                st.kv_blocks_in_use = mgr.blocks_in_use();
                st.shared_block_hits = mgr.stats.shared_hits;
                st.cow_forks = mgr.stats.cow_forks;
                st.swap_outs = mgr.stats.swap_outs;
            }
        }
        st
    }

    /// Terminal state per job, in submission order. Jobs that never
    /// terminated (the driving loop stopped early) come back as
    /// [`JobOutcome::Aborted`] with whatever tokens they had — never a
    /// silent empty vec.
    pub fn take_results(mut self) -> Vec<JobResult> {
        // queued jobs first (swapped-out jobs keep their partial
        // tokens), then anything mid-flight
        while let Some(q) = self.queue.pop_front() {
            // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; results grows in lockstep
            self.results[q.id] = Some(JobResult {
                outcome: JobOutcome::Aborted,
                tokens: q.out,
            });
        }
        for slot in &mut self.rows {
            if let Some(a) = slot.take() {
                // pallas-lint: allow(no-hot-path-panic) — ids are indices minted by submit; results grows in lockstep
                self.results[a.id] = Some(JobResult {
                    outcome: JobOutcome::Aborted,
                    tokens: a.out,
                });
            }
        }
        self.results
            .into_iter()
            // every job has a terminal outcome by this point (the two
            // sweeps above aborted anything still pending); the default
            // is an unreachable backstop, not a panic
            .map(|r| {
                r.unwrap_or(JobResult {
                    outcome: JobOutcome::Aborted,
                    tokens: Vec::new(),
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    fn req(prompt: &[i32], max_new: usize) -> Request {
        Request::new(prompt.to_vec(), max_new)
    }

    /// Convenience: tokens of every `Done` job, in submission order
    /// (mirrors the old `take_results` shape for ported tests).
    fn done_tokens(results: Vec<JobResult>) -> Vec<Vec<i32>> {
        results
            .into_iter()
            .map(|r| {
                assert_eq!(r.outcome, JobOutcome::Done);
                r.tokens
            })
            .collect()
    }

    #[test]
    fn admits_in_fifo_order_up_to_capacity() {
        let now = t0();
        let mut s = Scheduler::new(2);
        for p in 0..4 {
            s.submit(req(&[p], 8), now);
        }
        let placed = s.admit(now);
        assert_eq!(placed.len(), 2);
        assert_eq!(placed[0], Admission { row: 0, job: 0, prompt: vec![0] });
        assert_eq!(placed[1], Admission { row: 1, job: 1, prompt: vec![1] });
        assert_eq!(s.active_rows(), vec![0, 1]);
        // no free rows: nothing more admitted
        assert!(s.admit(now).is_empty());
    }

    #[test]
    fn retiring_frees_the_row_for_the_next_job() {
        let now = t0();
        let mut s = Scheduler::new(2);
        for p in 0..3 {
            s.submit(req(&[10 + p], 8), now);
        }
        s.admit(now);
        s.push(0, 7, now).unwrap();
        assert_eq!(s.retire(0).unwrap(), 0);
        assert!(!s.finished(), "job 2 still queued");
        let placed = s.admit(now);
        assert_eq!(
            placed,
            vec![Admission { row: 0, job: 2, prompt: vec![12] }],
            "freed row 0 is reused"
        );
        assert_eq!(s.active_rows(), vec![0, 1]);
    }

    #[test]
    fn results_come_back_in_submission_order() {
        let now = t0();
        let mut s = Scheduler::new(2);
        for p in 0..4 {
            s.submit(req(&[p], 8), now);
        }
        s.admit(now);
        // finish job 1 (row 1) first, then job 0; rows refill as 2, 3
        s.push(1, 101, now).unwrap();
        s.retire(1).unwrap();
        s.admit(now);
        s.push(0, 100, now).unwrap();
        s.retire(0).unwrap();
        s.admit(now);
        s.push(0, 103, now).unwrap(); // row 0 now serves job 3
        s.push(1, 102, now).unwrap(); // row 1 now serves job 2
        s.retire(1).unwrap();
        s.retire(0).unwrap();
        assert!(s.finished());
        assert_eq!(
            done_tokens(s.take_results()),
            vec![vec![100], vec![101], vec![102], vec![103]]
        );
    }

    #[test]
    fn lengths_track_prompt_and_output() {
        let now = t0();
        let mut s = Scheduler::new(1);
        s.submit(req(&[1, 2, 3], 8), now);
        s.admit(now);
        assert_eq!(s.total_len(0), 3);
        assert_eq!(s.out_len(0), 0);
        assert_eq!(s.resident_tokens(), 3);
        assert_eq!(s.reserved_tokens(), 11);
        s.push(0, 9, now).unwrap();
        assert_eq!(s.total_len(0), 4);
        assert_eq!(s.out_len(0), 1);
        assert_eq!(s.resident_tokens(), 4);
    }

    #[test]
    fn zero_output_jobs_finish_with_done_outcome() {
        let now = t0();
        let mut s = Scheduler::new(1);
        s.submit(req(&[1], 0), now);
        s.submit(req(&[2], 0), now);
        s.admit(now);
        assert!(s.budget_exhausted(0, 16), "max_new 0 retires immediately");
        s.retire(0).unwrap();
        s.admit(now);
        s.retire(0).unwrap();
        assert!(s.finished());
        assert_eq!(
            done_tokens(s.take_results()),
            vec![Vec::<i32>::new(), vec![]]
        );
    }

    #[test]
    fn row_misuse_is_an_error_not_a_panic() {
        let now = t0();
        let mut s = Scheduler::new(2);
        s.submit(req(&[1], 4), now);
        s.admit(now);
        // out-of-range everywhere
        assert!(s.push(99, 5, now).is_err());
        assert!(s.retire(99).is_err());
        assert_eq!(s.out_len(99), 0);
        assert_eq!(s.total_len(99), 0);
        assert_eq!(s.job_in(99), None);
        assert!(!s.budget_exhausted(99, 16));
        // free row
        assert!(s.push(1, 5, now).is_err());
        assert!(s.retire(1).is_err());
        // double retire
        s.retire(0).unwrap();
        assert!(s.retire(0).is_err(), "double retire must not panic");
        assert!(s.finished());
    }

    #[test]
    fn high_priority_jumps_queued_low_priority_under_full_budget() {
        // the acceptance scenario: budget full, low-priority jobs queued,
        // then a late high-priority submission — it must be admitted
        // first when the budget frees
        let now = t0();
        let mut s = Scheduler::with_budget(2, 10);
        // job 0 fills the budget (4 prompt + 4 max_new = 8 reserved)
        s.submit(req(&[1, 2, 3, 4], 4), now);
        assert_eq!(s.admit(now).len(), 1);
        // low-priority job 1 does not fit (8 + 6 > 10): queued
        s.submit(req(&[5, 6], 4).priority(Priority::Low), now);
        assert!(s.admit(now).is_empty(), "budget full: nothing admitted");
        // late high-priority job 2
        s.submit(req(&[7, 8], 4).priority(Priority::High), now);
        assert!(s.admit(now).is_empty(), "still no room");
        // budget frees: the high-priority job is admitted before the
        // earlier low-priority one
        s.retire(0).unwrap();
        let placed = s.admit(now);
        assert_eq!(placed.len(), 1, "6 + 6 > 10: only one fits");
        assert_eq!(placed[0].job, 2, "high priority jumps the queue");
        s.retire(placed[0].row).unwrap();
        let placed = s.admit(now);
        assert_eq!(placed[0].job, 1, "low-priority job runs afterwards");
        s.retire(placed[0].row).unwrap();
        let results = s.take_results();
        assert!(results.iter().all(|r| r.outcome == JobOutcome::Done));
    }

    #[test]
    fn budget_admission_counts_tokens_not_rows() {
        let now = t0();
        // 4 rows but a 12-token budget: a big job crowds out by tokens
        let mut s = Scheduler::with_budget(4, 12);
        s.submit(req(&[0; 6], 4), now); // reserved 10
        s.submit(req(&[1; 3], 2), now); // reserved 5: does not fit
        s.submit(req(&[2; 1], 1), now); // reserved 2: would fit, but FIFO
        let placed = s.admit(now);
        assert_eq!(placed.len(), 1, "token budget, not row count, gates");
        assert_eq!(placed[0].job, 0);
        assert_eq!(s.reserved_tokens(), 10);
        // no bypass: job 2 fits but must not overtake job 1
        assert!(s.admit(now).is_empty());
        s.retire(0).unwrap();
        let placed = s.admit(now);
        assert_eq!(placed.len(), 2, "both small jobs fit now");
        assert_eq!(placed[0].job, 1);
        assert_eq!(placed[1].job, 2);
    }

    #[test]
    fn oversized_job_runs_alone_instead_of_deadlocking() {
        let now = t0();
        let mut s = Scheduler::with_budget(2, 4);
        s.submit(req(&[0; 8], 4), now); // reserved 12 > budget 4
        let placed = s.admit(now);
        assert_eq!(placed.len(), 1, "sole-tenant override admits it");
        // but nothing else joins while it is resident
        s.submit(req(&[1], 1), now);
        assert!(s.admit(now).is_empty());
        s.retire(0).unwrap();
        assert_eq!(s.admit(now).len(), 1);
    }

    #[test]
    fn cancelled_in_flight_frees_its_row_within_one_poll() {
        let now = t0();
        let mut s = Scheduler::new(1);
        let (id, handle) = s.submit(req(&[1, 2], 8), now);
        s.submit(req(&[3], 8), now);
        s.admit(now);
        s.push(0, 42, now).unwrap();
        handle.cancel();
        let retired = s.poll(now);
        assert_eq!(retired.len(), 1);
        assert_eq!(retired[0].row, 0);
        assert_eq!(retired[0].job, id);
        assert_eq!(retired[0].outcome, JobOutcome::Cancelled);
        // the freed row is immediately reusable
        let placed = s.admit(now);
        assert_eq!(placed[0].row, 0);
        assert_eq!(placed[0].job, 1);
        s.retire(0).unwrap();
        let results = s.take_results();
        assert_eq!(results[0].outcome, JobOutcome::Cancelled);
        assert_eq!(results[0].tokens, vec![42], "partial output kept");
        assert_eq!(results[1].outcome, JobOutcome::Done);
    }

    #[test]
    fn cancelled_while_queued_never_runs() {
        let now = t0();
        let mut s = Scheduler::new(1);
        s.submit(req(&[1], 8), now);
        let (_, handle) = s.submit(req(&[2], 8), now);
        s.admit(now);
        handle.cancel();
        assert!(s.poll(now).is_empty(), "queued cancel vacates no row");
        s.retire(0).unwrap();
        assert!(s.admit(now).is_empty(), "cancelled job is not admitted");
        assert!(s.finished());
        let results = s.take_results();
        assert_eq!(results[1].outcome, JobOutcome::Cancelled);
        assert!(results[1].tokens.is_empty());
    }

    #[test]
    fn deadline_expiry_retires_mid_flight_and_in_queue() {
        let now = t0();
        let mut s = Scheduler::new(1);
        s.submit(
            req(&[1, 2], 8).deadline(Duration::from_millis(5)),
            now,
        );
        s.submit(
            req(&[3], 8).deadline(Duration::from_millis(5)),
            now,
        );
        s.admit(now);
        s.push(0, 7, now).unwrap();
        // nothing expires before the deadline
        assert!(s.poll(now + Duration::from_millis(4)).is_empty());
        let late = now + Duration::from_millis(10);
        let retired = s.poll(late);
        assert_eq!(retired.len(), 1, "active job retired");
        assert_eq!(retired[0].outcome, JobOutcome::DeadlineExceeded);
        assert!(s.finished(), "queued job expired in the same poll");
        let results = s.take_results();
        assert_eq!(results[0].outcome, JobOutcome::DeadlineExceeded);
        assert_eq!(results[0].tokens, vec![7], "partial output kept");
        assert_eq!(results[1].outcome, JobOutcome::DeadlineExceeded);
    }

    #[test]
    fn watchdog_retires_a_stalled_row_with_timed_out() {
        let now = t0();
        let mut s = Scheduler::new(1);
        s.set_watchdog(Some(Duration::from_millis(50)));
        s.submit(req(&[1, 2], 8), now);
        s.admit(now);
        let mid = now + Duration::from_millis(30);
        s.push(0, 7, mid).unwrap();
        // 30 ms since the last token: inside the window
        assert!(s.poll(mid + Duration::from_millis(30)).is_empty());
        // 60 ms without progress: the watchdog evicts the row
        let retired = s.poll(mid + Duration::from_millis(60));
        assert_eq!(retired.len(), 1);
        assert_eq!(retired[0].row, 0);
        assert_eq!(retired[0].outcome, JobOutcome::TimedOut);
        assert!(s.finished());
        let st = s.stats();
        assert_eq!(st.timed_out_jobs, 1);
        assert_eq!(st.preemptions, 1);
        assert!(st.summary().contains("1 timed-out"));
        let results = s.take_results();
        assert_eq!(results[0].outcome, JobOutcome::TimedOut);
        assert_eq!(results[0].tokens, vec![7], "partial output kept");
    }

    #[test]
    fn watchdog_spares_queued_jobs_and_restarts_at_admission() {
        let now = t0();
        let mut s = Scheduler::new(1);
        s.set_watchdog(Some(Duration::from_millis(10)));
        s.submit(req(&[1], 8), now);
        s.submit(req(&[2], 8), now);
        s.admit(now);
        s.retire(0).unwrap();
        // job 1 has queued far past the window; queue wait is governed
        // by deadlines, never the watchdog
        let late = now + Duration::from_millis(100);
        assert!(s.poll(late).is_empty(), "queued jobs are exempt");
        let placed = s.admit(late);
        assert_eq!(placed.len(), 1, "stale queue wait does not block admission");
        assert!(
            s.poll(late + Duration::from_millis(9)).is_empty(),
            "the window restarts at admission"
        );
        let retired = s.poll(late + Duration::from_millis(10));
        assert_eq!(retired[0].outcome, JobOutcome::TimedOut);
    }

    #[test]
    fn aging_promotes_a_starved_low_priority_job() {
        let now = t0();
        let mut s = Scheduler::new(1);
        let (low_id, _) = s.submit(req(&[9], 2).priority(Priority::Low), now);
        // a continuous stream of high-priority arrivals
        let mut admitted_low = false;
        for round in 0..(2 * AGING_ROUNDS + 2) {
            s.submit(req(&[round as i32], 2).priority(Priority::High), now);
            for a in s.admit(now) {
                if a.job == low_id {
                    admitted_low = true;
                }
                s.retire(a.row).unwrap();
            }
            if admitted_low {
                break;
            }
        }
        assert!(
            admitted_low,
            "aging must eventually admit the low-priority job"
        );
    }

    #[test]
    fn take_results_reports_aborted_for_unfinished_jobs() {
        let now = t0();
        let mut s = Scheduler::new(1);
        s.submit(req(&[1], 8), now);
        s.submit(req(&[2], 8), now);
        s.admit(now);
        s.push(0, 5, now).unwrap();
        // driving loop stops here without retiring anything
        let results = s.take_results();
        assert_eq!(results[0].outcome, JobOutcome::Aborted);
        assert_eq!(results[0].tokens, vec![5], "partial output kept");
        assert_eq!(results[1].outcome, JobOutcome::Aborted);
        assert!(results[1].tokens.is_empty());
    }

    #[test]
    fn stats_track_the_lifecycle() {
        let now = t0();
        let mut s = Scheduler::with_budget(2, 100);
        let (_, h) = s.submit(req(&[1, 2], 4), now);
        s.submit(req(&[3], 4), now);
        s.submit(req(&[4], 4), now);
        s.admit(now);
        let st = s.stats();
        assert_eq!(st.submitted, 3);
        assert_eq!(st.active_rows, 2);
        assert_eq!(st.queue_depth, 1);
        assert_eq!(st.resident_tokens, 3);
        assert_eq!(st.reserved_tokens, 11);
        assert_eq!(st.token_budget, 100);
        let later = now + Duration::from_millis(2);
        s.push(0, 7, later).unwrap();
        h.cancel();
        s.poll(later);
        let st = s.stats();
        assert_eq!(st.tokens_generated, 1);
        assert_eq!(st.cancelled, 1);
        assert_eq!(st.preemptions, 1);
        assert!(st.mean_ttft_us >= 2_000.0, "ttft {:.1}", st.mean_ttft_us);
        assert!(!st.summary().is_empty());
    }

    #[test]
    fn fresh_scheduler_stats_are_zero_and_finite() {
        let s = Scheduler::with_budget(2, 100);
        let mut st = s.stats();
        assert_eq!(st.tokens_per_sec(), 0.0, "no elapsed, no tokens");
        assert_eq!(st.mean_ttft_ms(), 0.0);
        assert!(st.tokens_per_sec().is_finite());
        assert!(st.mean_ttft_ms().is_finite());
        let line = st.summary();
        assert!(!line.contains("NaN") && !line.contains("inf"), "{line}");
        // elapsed without tokens, and tokens without elapsed: still 0.0
        st.elapsed = Duration::from_millis(5);
        assert_eq!(st.tokens_per_sec(), 0.0);
        st.elapsed = Duration::ZERO;
        st.tokens_generated = 10;
        assert_eq!(st.tokens_per_sec(), 0.0);
    }

    #[test]
    fn both_admission_paths_age_queued_jobs_identically() {
        let now = t0();
        // path A: a free row exists but admission stops mid-round
        let mut a = Scheduler::new(1);
        for p in 0..3 {
            a.submit(req(&[p], 4), now);
        }
        a.admit(now); // places job 0; jobs 1, 2 skipped (rows ran out)
        // path B: no free row at all when the round starts
        let mut b = Scheduler::new(1);
        b.submit(req(&[0], 4), now);
        b.admit(now);
        for p in 1..3 {
            b.submit(req(&[p], 4), now);
        }
        b.admit(now); // early return: nothing placeable
        for id in 1..3usize {
            assert_eq!(a.meta[id].waited_rounds, 1, "path A job {id}");
            assert_eq!(
                a.meta[id].waited_rounds, b.meta[id].waited_rounds,
                "both paths age job {id} identically"
            );
        }
    }

    #[test]
    fn shared_prefix_blocks_admit_more_rows_than_token_budget() {
        let now = t0();
        // same capacity in both units: 16 tokens vs 4 four-token blocks
        let prompt = [7i32; 8];
        let mut tokens = Scheduler::with_budget(4, 16);
        let mut blocks =
            Scheduler::with_blocks(4, BlockConfig::for_token_budget(16, 4))
                .unwrap();
        for _ in 0..4 {
            tokens.submit(req(&prompt, 4), now);
            blocks.submit(req(&prompt, 4), now);
        }
        let t = tokens.admit(now).len();
        let b = blocks.admit(now).len();
        assert_eq!(t, 1, "worst-case reservation admits one row");
        assert_eq!(b, 4, "prefix sharing admits the whole batch");
        assert!(b > t, "the acceptance criterion, at unit scale");
        let st = blocks.stats();
        assert_eq!(st.kv_blocks_in_use, 2, "one physical copy of the prompt");
        assert_eq!(st.shared_block_hits, 6, "3 followers x 2 blocks");
    }

    #[test]
    fn admission_swaps_out_lower_priority_rows_under_pressure() {
        let now = t0();
        let mut s =
            Scheduler::with_blocks(2, BlockConfig::new(2, 4)).unwrap();
        s.submit(req(&[1, 2, 3, 4], 4).priority(Priority::Low), now);
        let placed = s.admit(now);
        assert_eq!(placed.len(), 1);
        let low_row = placed[0].row;
        assert!(s.push(low_row, 50, now).unwrap());
        // a high-priority arrival needs 3 of the 4 blocks: the low row
        // (3 blocks live) is swapped out to make room
        s.submit(req(&[9; 6], 4).priority(Priority::High), now);
        let placed = s.admit(now);
        assert_eq!(placed.len(), 1, "admitted via swap-out");
        assert_eq!(placed[0].job, 1);
        assert_eq!(
            s.take_swap_outs(),
            vec![SwapOut { row: low_row, job: 0 }]
        );
        assert_eq!(s.stats().swap_outs, 1);
        // the high job finishes; the low job resumes with its partial
        // output re-prefilled, and completes
        s.retire(placed[0].row).unwrap();
        let placed = s.admit(now);
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].job, 0);
        assert_eq!(placed[0].prompt, vec![1, 2, 3, 4, 50]);
        assert!(s.push(placed[0].row, 51, now).unwrap());
        s.retire(placed[0].row).unwrap();
        let results = s.take_results();
        assert_eq!(results[0].outcome, JobOutcome::Done);
        assert_eq!(results[0].tokens, vec![50, 51], "output survived");
        assert_eq!(results[1].outcome, JobOutcome::Done);
    }

    #[test]
    fn push_past_the_pool_swaps_the_row_out_and_resumes() {
        let now = t0();
        let mut s =
            Scheduler::with_blocks(1, BlockConfig::new(2, 2)).unwrap();
        s.submit(req(&[1, 2, 3], 8), now);
        assert_eq!(s.admit(now).len(), 1);
        assert!(s.push(0, 4, now).unwrap(), "fits in the tail block");
        assert!(
            !s.push(0, 5, now).unwrap(),
            "pool dry and this row is the only victim: it swaps itself"
        );
        assert_eq!(s.take_swap_outs(), vec![SwapOut { row: 0, job: 0 }]);
        assert_eq!(s.job_in(0), None, "row vacated");
        let placed = s.admit(now);
        assert_eq!(placed[0].prompt, vec![1, 2, 3, 4], "history resumed");
        s.retire(placed[0].row).unwrap();
        let results = s.take_results();
        assert_eq!(results[0].outcome, JobOutcome::Done);
        assert_eq!(results[0].tokens, vec![4], "recorded tokens survived");
    }

    #[test]
    fn job_longer_than_the_pool_aborts_instead_of_deadlocking() {
        let now = t0();
        let mut s =
            Scheduler::with_blocks(1, BlockConfig::new(2, 2)).unwrap();
        s.submit(req(&[0; 10], 4), now); // 5 blocks can never fit in 2
        s.submit(req(&[1, 2], 2), now);
        let placed = s.admit(now);
        assert_eq!(placed.len(), 1, "the possible job still runs");
        assert_eq!(placed[0].job, 1);
        s.retire(placed[0].row).unwrap();
        assert!(s.finished());
        let results = s.take_results();
        assert_eq!(results[0].outcome, JobOutcome::Aborted);
        assert_eq!(results[1].outcome, JobOutcome::Done);
    }
}
